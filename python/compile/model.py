"""L2: the JAX compute graphs the Rust coordinator executes through PJRT.

Two graphs per loss family, both wrapping the L1 Pallas kernels:

* ``stats_model``      — (margins, y, mask) -> (w, z, loss_sum)
                         the per-iteration working-set computation
                         (Section 2's quadratic approximation coefficients).
* ``linesearch_model`` — (margins, dmargins, y, mask, alphas) -> loss_sums[K]
                         the batched Armijo evaluation (Algorithm 3).

Shapes are static per artifact (block size B, K_ALPHAS candidates); the Rust
runtime pads with mask = 0. Everything is f64 to match the Rust-side native
oracle bit-for-bit at the comparison tolerances.
"""

import jax
import jax.numpy as jnp

from compile.kernels import glm_stats as gs
from compile.kernels import linesearch as ls

jax.config.update("jax_enable_x64", True)


def stats_model(kind):
    """Returns fn(margins[B], y[B], mask[B]) -> (w[B], z[B], loss_sum[1])."""

    def fn(margins, y, mask):
        w, z, ell = gs.glm_stats(kind, margins, y, mask)
        # Sum the masked per-example losses; keep as a length-1 vector so the
        # rust side reads a uniform layout.
        return w, z, jnp.sum(ell)[None]

    return fn


def linesearch_model(kind):
    """Returns fn(margins[B], dmargins[B], y[B], mask[B], alphas[K]) -> losses[K]."""

    def fn(margins, dmargins, y, mask, alphas):
        return (ls.linesearch_losses(kind, margins, dmargins, y, mask, alphas),)

    return fn
