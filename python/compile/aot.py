"""AOT lowering: JAX graphs -> HLO text artifacts for the Rust runtime.

HLO *text* (not ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla_extension 0.5.1 bundled with the published ``xla`` crate rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Emits, for every loss kind and block size:
    artifacts/stats_{kind}_{B}.hlo.txt
    artifacts/linesearch_{kind}_{B}.hlo.txt
plus a manifest (artifacts/manifest.json) the Rust runtime reads to discover
available shapes.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import linesearch as ls
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

# Block sizes (example-axis); the runtime picks the smallest that fits n.
BLOCK_SIZES = (1024, 4096, 16384, 65536)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stats(kind, b):
    vec = jax.ShapeDtypeStruct((b,), jnp.float64)
    fn = model.stats_model(kind)
    return to_hlo_text(jax.jit(fn).lower(vec, vec, vec))


def lower_linesearch(kind, b):
    vec = jax.ShapeDtypeStruct((b,), jnp.float64)
    kvec = jax.ShapeDtypeStruct((ls.K_ALPHAS,), jnp.float64)
    fn = model.linesearch_model(kind)
    return to_hlo_text(jax.jit(fn).lower(vec, vec, vec, vec, kvec))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--kinds", default=",".join(ref.LOSS_KINDS))
    ap.add_argument("--blocks", default=",".join(str(b) for b in BLOCK_SIZES))
    args = ap.parse_args()

    kinds = [k for k in args.kinds.split(",") if k]
    blocks = [int(b) for b in args.blocks.split(",") if b]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"k_alphas": ls.K_ALPHAS, "tile": ls.TILE, "artifacts": []}
    for kind in kinds:
        for b in blocks:
            for name, lower in (("stats", lower_stats), ("linesearch", lower_linesearch)):
                fname = f"{name}_{kind}_{b}.hlo.txt"
                path = os.path.join(args.out_dir, fname)
                text = lower(kind, b)
                with open(path, "w") as f:
                    f.write(text)
                manifest["artifacts"].append(
                    {"file": fname, "model": name, "kind": kind, "block": b}
                )
                print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
