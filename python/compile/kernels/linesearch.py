"""L1 Pallas kernel: batched line-search objective.

Evaluates  L(alpha_k) = sum_i l(y_i, m_i + alpha_k * d_i)  for a whole vector
of candidate step sizes in one pass over the examples — the batched
evaluation that lets one PJRT execution serve an entire Armijo backtrack
(Algorithm 3; rust/src/solver/linesearch.rs mirrors the batching).

TPU mapping: grid over example tiles; each grid step loads one TILE of
(m, d, y, mask) into VMEM, broadcasts against the K alphas (K*TILE f64
intermediate = 512 KiB at K=64, TILE=1024 — VMEM-resident), reduces over the
tile and accumulates into the K-vector output. The output block maps every
grid step to the same block; first step initializes, later steps accumulate —
the standard Pallas reduction pattern.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

# K×TILE f64 intermediate lives in VMEM: 64 × 2048 × 8 B = 1 MiB. Larger
# tiles cut interpret-mode grid steps (see glm_stats.py) at acceptable VMEM.
TILE = 2048


def tile_for(b):
    """Largest tile ≤ TILE dividing the block size."""
    t = min(b, TILE)
    while b % t != 0:
        t //= 2
    return max(t, 1)
# Number of candidate step sizes per call. Covers the coordinator's grid
# phase (17 candidates) and Armijo phase (40) with room to spare; unused
# lanes are padded with alpha = 0 and simply ignored by the caller.
K_ALPHAS = 64


def _ls_kernel(kind, m_ref, d_ref, y_ref, mask_ref, alpha_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = m_ref[...]
    d = d_ref[...]
    y = y_ref[...]
    mask = mask_ref[...]
    alphas = alpha_ref[...]
    shifted = m[None, :] + alphas[:, None] * d[None, :]  # (K, TILE)
    ell = ref.loss_value(kind, y[None, :], shifted) * mask[None, :]
    out_ref[...] += jnp.sum(ell, axis=1)


def linesearch_losses(kind, margins, dmargins, y, mask, alphas):
    """Pallas-tiled batched line-search loss sums.

    Shapes: margins/dmargins/y/mask (B,) with B % TILE == 0; alphas (K,).
    Returns (K,) loss sums over the masked examples.
    """
    (b,) = margins.shape
    (k,) = alphas.shape
    tile = tile_for(b)
    grid = (b // tile,)
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    whole_k = pl.BlockSpec((k,), lambda i: (0,))
    kernel = functools.partial(_ls_kernel, kind)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec, vec, vec, vec, whole_k],
        out_specs=whole_k,
        out_shape=jax.ShapeDtypeStruct((k,), margins.dtype),
        interpret=True,
    )(margins, dmargins, y, mask, alphas)
