"""Pure-jnp oracles for the Pallas kernels.

These are the numerical ground truth the kernels (and, transitively, the
Rust-side `NativeCompute`) are tested against. They mirror the Rust
implementations in `rust/src/glm/loss.rs` exactly — same W_FLOOR, same
stable formulations — so the three implementations (jnp ref, Pallas kernel,
Rust native) can be cross-checked to tight tolerances.
"""

import math

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

# ---------------------------------------------------------------------------
# Normal distribution helpers WITHOUT the `erf` HLO opcode.
#
# jax.scipy.stats.norm lowers to the dedicated `erf` HLO instruction, which
# the xla_extension 0.5.1 HLO-text parser bundled with the rust `xla` crate
# does not know. We therefore implement erfc from basic ops, mirroring
# rust/src/util/stats.rs BRANCH FOR BRANCH (same Numerical-Recipes rational
# approximation, same small-|x| Maclaurin series, same z>6 tail series) so
# the Rust native path and the XLA path agree to ~1e-12 even where the
# approximation itself is only ~1e-7 from true erfc.
# ---------------------------------------------------------------------------

_SQRT_PI = math.sqrt(math.pi)
_INV_SQRT_2PI = 0.3989422804014327
_SQRT_2PI = math.sqrt(2.0 * math.pi)


def _erf_small(x):
    """Maclaurin series for erf, |x| < 0.5 (30 fixed terms, like the rust)."""
    x = jnp.clip(x, -0.6, 0.6)  # keep the unselected-branch lanes finite
    x2 = x * x
    term = x
    acc = x
    for n in range(1, 30):
        term = term * (-x2 / n)
        acc = acc + term / (2 * n + 1)
    return (2.0 / _SQRT_PI) * acc


def erfc(x):
    """Complementary error function, mirroring rust util::stats::erfc."""
    ax = jnp.abs(x)
    z = ax
    t = 1.0 / (1.0 + 0.5 * z)
    tau = t * jnp.exp(
        -z * z
        - 1.26551223
        + t
        * (1.00002368
           + t
           * (0.37409196
              + t
              * (0.09678418
                 + t
                 * (-0.18628806
                    + t
                    * (0.27886807
                       + t
                       * (-1.13520398
                          + t * (1.48851587 + t * (-0.82215223 + t * 0.17087277))))))))
    )
    zs = jnp.maximum(z, 1e-10)
    zi2 = 1.0 / (zs * zs)
    tail = jnp.exp(-z * z) / (zs * _SQRT_PI) * (1.0 - 0.5 * zi2 + 0.75 * zi2 * zi2)
    r = jnp.where(z > 6.0, tail, tau)
    r = jnp.where(x >= 0.0, r, 2.0 - r)
    return jnp.where(ax < 0.5, 1.0 - _erf_small(x), r)


def normal_cdf(x):
    return 0.5 * erfc(-x * (1.0 / math.sqrt(2.0)))


def normal_pdf(x):
    return _INV_SQRT_2PI * jnp.exp(-0.5 * x * x)


def _mills_ratio_inv(t):
    """phi(t)/Phi(t), stable for t << 0 — mirrors rust mills_ratio_inv."""
    a = jnp.maximum(-t, 1e-10)
    extreme = a + 1.0 / a
    c = normal_cdf(t)
    mid = normal_pdf(t) / jnp.maximum(c, 1e-300)
    return jnp.where((t < -30.0) | (c < 1e-300), extreme, mid)

# Floor for the working weight w = d2l/dyhat2, matching rust glm::loss::W_FLOOR.
W_FLOOR = 1e-10

LOSS_KINDS = ("logistic", "squared", "probit")


def loss_value(kind, y, yhat):
    """Example-wise loss l(y, yhat)."""
    if kind == "logistic":
        # log(1 + exp(-y yhat)), stable.
        return jnp.logaddexp(0.0, -y * yhat)
    if kind == "squared":
        return 0.5 * (y - yhat) ** 2
    if kind == "probit":
        # -log Phi(y yhat); asymptotic branch for the deep tail, mirroring
        # the rust implementation (guard c > 1e-300).
        t = y * yhat
        c = normal_cdf(t)
        direct = -jnp.log(jnp.maximum(c, 1e-300))
        tail = 0.5 * t * t + jnp.log(jnp.maximum(jnp.abs(t), 1e-10) * _SQRT_2PI)
        return jnp.where(c > 1e-300, direct, tail)
    raise ValueError(kind)


def loss_d1(kind, y, yhat):
    """dl/dyhat."""
    if kind == "logistic":
        return -y * jax.nn.sigmoid(-y * yhat)
    if kind == "squared":
        return yhat - y
    if kind == "probit":
        t = y * yhat
        return -y * _mills_ratio_inv(t)
    raise ValueError(kind)


def loss_d2(kind, y, yhat):
    """d2l/dyhat2."""
    if kind == "logistic":
        p = jax.nn.sigmoid(yhat)
        return p * (1.0 - p)
    if kind == "squared":
        return jnp.ones_like(yhat)
    if kind == "probit":
        t = y * yhat
        mills = _mills_ratio_inv(t)
        return t * mills + mills**2
    raise ValueError(kind)


def glm_stats_ref(kind, margins, y, mask):
    """Reference for the glm_stats kernel.

    Returns (w, z, per_example_loss), all masked (pad lanes produce 0).
    """
    w_raw = loss_d2(kind, y, margins)
    w = jnp.maximum(w_raw, W_FLOOR)
    g = loss_d1(kind, y, margins)
    z = -g / w
    ell = loss_value(kind, y, margins)
    return w * mask, z * mask, ell * mask


def linesearch_ref(kind, margins, y, dmargins, mask, alphas):
    """Reference for the linesearch kernel: sum_i l(y_i, m_i + a d_i) per a."""
    shifted = margins[None, :] + alphas[:, None] * dmargins[None, :]
    ell = loss_value(kind, y[None, :], shifted)
    return jnp.sum(ell * mask[None, :], axis=1)
