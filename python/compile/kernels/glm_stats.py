"""L1 Pallas kernel: per-example GLM statistics.

Computes, for a block of examples, the working weight w = d²l/dŷ², working
response z = -g/w and per-example loss from (margins, y, mask) — the inner
loop of every d-GLMNET outer iteration (Section 2 of the paper: the
quadratic approximation coefficients).

TPU mapping (DESIGN.md §Hardware-Adaptation): the example axis is tiled with
TILE-sized blocks resident in VMEM; all math is elementwise VPU work
(sigmoid / erf / exp), no MXU involvement. `interpret=True` everywhere —
the CPU PJRT plugin cannot execute Mosaic custom-calls; numerics are
identical.

VMEM footprint per grid step (TILE = 1024, f64):
  3 input vectors + 3 output vectors = 6 · 1024 · 8 B = 48 KiB  « 16 MiB VMEM.
Estimated TPU utilization is VPU-bound; see DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

# Example-axis tile. Interpret-mode Pallas executes the grid as a sequential
# HLO while-loop with dynamic-slice per step, so grid-step COUNT (not tile
# size) dominates CPU latency: prefer the largest tile that divides the
# block and stays VMEM-modest. 8192 keeps 6 resident f64 vectors at 384 KiB
# (≪ 16 MiB VMEM) and cuts the 65536-block step count 8× vs TILE=1024 —
# measured 4.7× faster through PJRT (EXPERIMENTS.md §Perf).
TILE = 8192


def tile_for(b):
    """Largest tile ≤ TILE dividing the block size."""
    t = min(b, TILE)
    while b % t != 0:
        t //= 2
    return max(t, 1)


def _stats_kernel(kind, m_ref, y_ref, mask_ref, w_ref, z_ref, l_ref):
    m = m_ref[...]
    y = y_ref[...]
    mask = mask_ref[...]
    w_raw = ref.loss_d2(kind, y, m)
    w = jnp.maximum(w_raw, ref.W_FLOOR)
    g = ref.loss_d1(kind, y, m)
    z = -g / w
    ell = ref.loss_value(kind, y, m)
    w_ref[...] = w * mask
    z_ref[...] = z * mask
    l_ref[...] = ell * mask


def glm_stats(kind, margins, y, mask):
    """Pallas-tiled (w, z, per-example loss). Shapes: all (B,), B % TILE == 0."""
    (b,) = margins.shape
    tile = tile_for(b)
    grid = (b // tile,)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    kernel = functools.partial(_stats_kernel, kind)
    dtype = margins.dtype
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((b,), dtype),
            jax.ShapeDtypeStruct((b,), dtype),
            jax.ShapeDtypeStruct((b,), dtype),
        ],
        interpret=True,
    )(margins, y, mask)
