"""Kernel-vs-reference correctness: the CORE L1 signal.

The Pallas kernels must agree with the pure-jnp oracles (ref.py) across loss
families, shapes, masks and value ranges. Hypothesis drives the sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import glm_stats as gs
from compile.kernels import linesearch as ls
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

KINDS = list(ref.LOSS_KINDS)


def _mk(n, seed, margin_scale=3.0):
    rng = np.random.default_rng(seed)
    m = jnp.array(rng.normal(scale=margin_scale, size=n))
    y = jnp.array(np.where(rng.random(n) < 0.5, 1.0, -1.0))
    mask = jnp.array((np.arange(n) < n - n // 7).astype(float))  # some padding
    return m, y, mask


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("blocks", [1, 2, 4])
def test_glm_stats_matches_ref(kind, blocks):
    n = gs.TILE * blocks
    m, y, mask = _mk(n, seed=blocks)
    w, z, ell = gs.glm_stats(kind, m, y, mask)
    wr, zr, lr = ref.glm_stats_ref(kind, m, y, mask)
    # interpret-mode Pallas and the jnp reference can differ by a few ULPs
    # on the probit tails (different fusion order in erf/exp chains).
    np.testing.assert_allclose(w, wr, rtol=1e-9, atol=1e-300)
    np.testing.assert_allclose(z, zr, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(ell, lr, rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("kind", KINDS)
def test_linesearch_matches_ref(kind):
    n = gs.TILE * 3
    m, y, mask = _mk(n, seed=9)
    rng = np.random.default_rng(10)
    d = jnp.array(rng.normal(size=n))
    alphas = jnp.array(np.concatenate([[1.0, 0.0], rng.random(ls.K_ALPHAS - 2)]))
    got = ls.linesearch_losses(kind, m, d, y, mask, alphas)
    want = ref.linesearch_ref(kind, m, y, d, mask, alphas)
    np.testing.assert_allclose(got, want, rtol=1e-11)


@pytest.mark.parametrize("kind", KINDS)
def test_mask_zero_lanes_contribute_nothing(kind):
    n = gs.TILE
    m, y, _ = _mk(n, seed=3)
    mask = jnp.zeros(n)
    w, z, ell = gs.glm_stats(kind, m, y, mask)
    assert float(jnp.abs(w).max()) == 0.0
    assert float(jnp.abs(z).max()) == 0.0
    assert float(jnp.abs(ell).max()) == 0.0


@pytest.mark.parametrize("kind", KINDS)
def test_alpha_zero_equals_stats_loss(kind):
    # linesearch at alpha=0 must equal the masked loss sum from glm_stats.
    n = gs.TILE * 2
    m, y, mask = _mk(n, seed=4)
    d = jnp.ones(n)
    alphas = jnp.zeros(ls.K_ALPHAS)
    losses = ls.linesearch_losses(kind, m, d, y, mask, alphas)
    _, _, ell = gs.glm_stats(kind, m, y, mask)
    np.testing.assert_allclose(losses[0], jnp.sum(ell), rtol=1e-12)


@settings(max_examples=20, deadline=None)
@given(
    kind=st.sampled_from(KINDS),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 20.0),
)
def test_hypothesis_stats_sweep(kind, seed, scale):
    """Random margins at many scales: kernel == ref, outputs finite."""
    n = gs.TILE
    rng = np.random.default_rng(seed)
    m = jnp.array(rng.normal(scale=scale, size=n))
    y = jnp.array(np.where(rng.random(n) < 0.5, 1.0, -1.0))
    mask = jnp.array(rng.integers(0, 2, size=n).astype(float))
    w, z, ell = gs.glm_stats(kind, m, y, mask)
    wr, zr, lr = ref.glm_stats_ref(kind, m, y, mask)
    for got, want in ((w, wr), (z, zr), (ell, lr)):
        assert bool(jnp.isfinite(got).all())
        np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(
    kind=st.sampled_from(KINDS),
    seed=st.integers(0, 2**31 - 1),
    alpha_hi=st.floats(0.01, 1.0),
)
def test_hypothesis_linesearch_sweep(kind, seed, alpha_hi):
    n = gs.TILE
    rng = np.random.default_rng(seed)
    m = jnp.array(rng.normal(size=n))
    d = jnp.array(rng.normal(size=n))
    y = jnp.array(np.where(rng.random(n) < 0.5, 1.0, -1.0))
    mask = jnp.ones(n)
    alphas = jnp.array(np.linspace(0.0, alpha_hi, ls.K_ALPHAS))
    got = ls.linesearch_losses(kind, m, d, y, mask, alphas)
    want = ref.linesearch_ref(kind, m, y, d, mask, alphas)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(got, want, rtol=1e-10)


@pytest.mark.parametrize("kind", KINDS)
def test_extreme_margins_finite(kind):
    # Saturated sigmoid / tail probit: everything must stay finite (the rust
    # side relies on this for line searches that overshoot).
    n = gs.TILE
    m = jnp.array(np.linspace(-40.0, 40.0, n))
    y = jnp.array(np.where(np.arange(n) % 2 == 0, 1.0, -1.0))
    mask = jnp.ones(n)
    w, z, ell = gs.glm_stats(kind, m, y, mask)
    for v in (w, z, ell):
        assert bool(jnp.isfinite(v).all()), f"{kind} produced non-finite values"
