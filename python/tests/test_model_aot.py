"""L2 model + AOT lowering checks: shapes, HLO text validity, manifest."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import linesearch as ls
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


@pytest.mark.parametrize("kind", ref.LOSS_KINDS)
def test_stats_model_shapes(kind):
    b = 1024
    fn = model.stats_model(kind)
    m = jnp.zeros(b)
    y = jnp.ones(b)
    mask = jnp.ones(b)
    w, z, lsum = jax.jit(fn)(m, y, mask)
    assert w.shape == (b,) and z.shape == (b,) and lsum.shape == (1,)
    # At zero margins the loss sums are known analytically.
    if kind == "logistic":
        np.testing.assert_allclose(lsum[0], b * np.log(2.0), rtol=1e-12)
    if kind == "probit":
        np.testing.assert_allclose(lsum[0], -b * np.log(0.5), rtol=1e-12)


@pytest.mark.parametrize("kind", ref.LOSS_KINDS)
def test_linesearch_model_monotone_for_descent(kind):
    # Moving along the exact margin-space Newton direction must decrease the
    # loss for small alpha.
    b = 1024
    rng = np.random.default_rng(0)
    m = jnp.array(rng.normal(size=b))
    y = jnp.array(np.where(rng.random(b) < 0.5, 1.0, -1.0))
    mask = jnp.ones(b)
    d = -ref.loss_d1(kind, y, m)  # steepest descent in margin space
    alphas = jnp.array(np.linspace(0.0, 0.2, ls.K_ALPHAS))
    fn = model.linesearch_model(kind)
    (losses,) = jax.jit(fn)(m, d, y, mask, alphas)
    assert float(losses[1]) < float(losses[0])


def test_hlo_text_lowering_roundtrip():
    text = aot.lower_stats("logistic", 1024)
    assert text.startswith("HloModule")
    assert "f64[1024]" in text
    text2 = aot.lower_linesearch("squared", 1024)
    assert f"f64[{ls.K_ALPHAS}]" in text2


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    argv = [
        sys.executable,
        "-m",
        "compile.aot",
        "--out-dir",
        str(out),
        "--kinds",
        "logistic",
        "--blocks",
        "1024",
    ]
    subprocess.run(argv, check=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["k_alphas"] == ls.K_ALPHAS
    files = {a["file"] for a in manifest["artifacts"]}
    assert files == {"stats_logistic_1024.hlo.txt", "linesearch_logistic_1024.hlo.txt"}
    for f in files:
        assert (out / f).read_text().startswith("HloModule")
