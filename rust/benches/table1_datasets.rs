//! Table 1 — dataset summary.
//!
//! Regenerates the paper's Table 1 (size, #examples train/test/validation,
//! #features, nnz, avg nonzeros) for the three synthetic stand-in corpora,
//! plus generation throughput. Scale via DGLMNET_SCALE (default 0.5).
//!
//!     cargo bench --bench table1_datasets

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::harness;
use dglmnet::util::bench::{bench, Table};

fn scale() -> f64 {
    std::env::var("DGLMNET_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5)
}

fn main() {
    let scale = scale();
    println!("=== Table 1: dataset summary (scale {scale}) ===\n");
    let corpora = harness::corpora(scale, 1);
    let mut t = Table::new(&[
        "dataset",
        "size",
        "#examples (train/test/validation)",
        "#features",
        "nnz",
        "avg nonzeros",
    ]);
    for (_, splits) in &corpora {
        let s = splits.summary();
        t.row(&[
            s.name.clone(),
            format!("{:.1} MiB", s.bytes as f64 / (1024.0 * 1024.0)),
            format!("{} / {} / {}", s.n_train, s.n_test, s.n_validation),
            s.p.to_string(),
            format!("{:.2e}", s.nnz as f64),
            format!("{:.0}", s.avg_nonzeros),
        ]);
    }
    t.print();

    println!("\npaper (Table 1, full scale): epsilon 12 GB, 0.4e6/0.05e6/0.05e6, 2000 features, 8.0e8 nnz, 2000 avg");
    println!("                             webspam 21 GB, 0.315e6/17.5e3/17.5e3, 16.6e6 features, 1.2e9 nnz, 3727 avg");
    println!("                             yandex_ad 56 GB, 57e6/2.35e6/2.35e6, 35e6 features, 5.7e9 nnz, 100 avg");
    println!("shape check: dense-low-p (epsilon) vs sparse-high-p (webspam) vs very-sparse-imbalanced (clickstream) preserved.\n");

    println!("=== generation + layout conversion throughput ===");
    for (name, splits) in &corpora {
        let train = splits.train.clone();
        bench(&format!("{name}: csr->csc conversion"), 1, 5, || {
            std::hint::black_box(train.to_csc());
        });
    }
}
