//! Partition-quality benchmark: iterations-to-tolerance per strategy.
//!
//! d-GLMNET's block-diagonal Hessian model (7) is exact when no two feature
//! blocks co-occur in a row; cross-block correlation forces the Theorem 1
//! line search to damp the merged step (α < 1) and costs outer iterations.
//! This bench plants that regime with `synth::block_correlated` — feature
//! groups that are dense-and-correlated internally and never co-occur across
//! groups — and measures, for every [`PartitionStrategy`], how many outer
//! iterations `solver::dglmnet::fit` needs to bring the relative
//! suboptimality (f − f*)/|f*| under 1e-6. A hashed layout scatters each
//! group across all M ranks (high cut fraction, damped merges); the
//! correlation-aware clustered layout recovers the planted groups (cut ≈ 0)
//! and should need strictly fewer iterations.
//!
//! Each run appends a JSON record to `BENCH_partition_quality.json` at the
//! repo root so the numbers accumulate into a trajectory across commits.
//!
//! Run with:
//!
//!     cargo bench --bench partition_quality
//!
//! `DGLMNET_SCALE` scales the row count (default 1.0).
#![allow(clippy::disallowed_macros)]

use std::path::Path;
use std::time::Instant;

use dglmnet::data::{synth, SynthConfig};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::solver::compute::NativeCompute;
use dglmnet::solver::dglmnet::DGlmnetConfig;
use dglmnet::solver::path;
use dglmnet::sparse::PartitionStrategy;
use dglmnet::util::bench::Table;
use dglmnet::util::json::{self, Json};

const SEED: u64 = 17;
const NODES: usize = 4;
const GROUPS: usize = 4;
const RHO: f64 = 0.9;
const P: usize = 96;
const MAX_ITERS: usize = 200;
const REL_TOL: f64 = 1e-6;

fn main() {
    let scale: f64 = std::env::var("DGLMNET_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let n = ((1600.0 * scale) as usize).max(400);

    println!("=== Partition quality: outer iterations to (f - f*)/|f*| <= {REL_TOL:.0e} ===");
    let ds = synth::block_correlated(&SynthConfig { n, p: P, seed: SEED }, GROUPS, RHO);
    println!(
        "block-correlated corpus: n={n} p={P} groups={GROUPS} rho={RHO} nodes={NODES}"
    );
    let compute = NativeCompute::new(LossKind::Logistic);
    let lambda1 = 0.05 * path::lambda_max(&ds, LossKind::Logistic);
    let pen = ElasticNet::l1_only(lambda1);
    let x_csc = ds.to_csc();

    // Reference optimum: M = 1 removes the block-diagonal approximation
    // entirely, so this is the tightest objective any layout can reach.
    let reference = dglmnet::solver::dglmnet::fit(
        &ds,
        &compute,
        &pen,
        &DGlmnetConfig {
            nodes: 1,
            max_iters: 2 * MAX_ITERS,
            tol: 0.0,
            eval_every: 0,
            seed: SEED,
            ..Default::default()
        },
        None,
    );

    let fits: Vec<_> = PartitionStrategy::ALL
        .iter()
        .map(|&strat| {
            let t0 = Instant::now();
            let fit = dglmnet::solver::dglmnet::fit(
                &ds,
                &compute,
                &pen,
                &DGlmnetConfig {
                    nodes: NODES,
                    max_iters: MAX_ITERS,
                    tol: 0.0,
                    eval_every: 0,
                    seed: SEED,
                    partition: strat,
                    ..Default::default()
                },
                None,
            );
            (strat, fit, t0.elapsed().as_secs_f64())
        })
        .collect();

    // f* = best objective seen by anyone, so the winning strategy reaches
    // zero suboptimality at its own last iteration at the latest.
    let f_star = fits
        .iter()
        .map(|(_, f, _)| f.objective)
        .chain([reference.objective])
        .fold(f64::INFINITY, f64::min);
    let denom = f_star.abs().max(1e-12);

    let mut table = Table::new(&[
        "strategy",
        "iters to 1e-6",
        "final subopt",
        "mean cut",
        "wall (s)",
    ]);
    let mut rec = Json::obj();
    rec.set("bench", "partition_quality")
        .set("n", n)
        .set("p", P)
        .set("groups", GROUPS)
        .set("rho", RHO)
        .set("nodes", NODES)
        .set("lambda1", lambda1)
        .set("rel_tol", REL_TOL)
        .set("f_star", f_star);
    for (strat, fit, wall) in &fits {
        // First trace point at or under the tolerance; -1 = never reached.
        let iters_to_tol: i64 = fit
            .trace
            .points
            .iter()
            .find(|pt| (pt.objective - f_star) / denom <= REL_TOL)
            .map(|pt| pt.iter as i64)
            .unwrap_or(-1);
        let cuts = strat.resolve(&x_csc, NODES, SEED).cut_fractions(&x_csc, SEED);
        let mean_cut = cuts.iter().sum::<f64>() / cuts.len().max(1) as f64;
        let final_subopt = (fit.objective - f_star) / denom;
        table.row(&[
            strat.name().into(),
            if iters_to_tol < 0 {
                format!("> {MAX_ITERS}")
            } else {
                iters_to_tol.to_string()
            },
            format!("{final_subopt:.2e}"),
            format!("{mean_cut:.3}"),
            format!("{wall:.3}"),
        ]);
        rec.set(&format!("iters_{}", strat.name()), iters_to_tol)
            .set(&format!("cut_{}", strat.name()), mean_cut)
            .set(&format!("subopt_{}", strat.name()), final_subopt);
    }
    table.print();

    let iters_of = |s: PartitionStrategy| {
        fits.iter()
            .find(|(st, _, _)| *st == s)
            .and_then(|(_, f, _)| {
                f.trace
                    .points
                    .iter()
                    .find(|pt| (pt.objective - f_star) / denom <= REL_TOL)
                    .map(|pt| pt.iter)
            })
    };
    match (iters_of(PartitionStrategy::Clustered), iters_of(PartitionStrategy::Hashed)) {
        (Some(c), Some(h)) if c < h => {
            println!("clustered beats hashed: {c} vs {h} outer iterations");
        }
        (c, h) => println!(
            "WARNING: clustered ({c:?}) did not beat hashed ({h:?}) — acceptance regression"
        ),
    }

    rec.set(
        "unix_ts",
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0),
    );
    append_record(Path::new("BENCH_partition_quality.json"), rec);
}

/// Append one record to a JSON-array trajectory file, creating it on first
/// use. A malformed existing file is replaced rather than crashing the bench.
fn append_record(path: &Path, rec: Json) {
    let mut records = match std::fs::read_to_string(path)
        .ok()
        .and_then(|text| json::parse(&text).ok())
    {
        Some(Json::Arr(items)) => items,
        _ => Vec::new(),
    };
    records.push(rec);
    match std::fs::write(path, Json::Arr(records).dump()) {
        Ok(()) => println!("appended record to {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
