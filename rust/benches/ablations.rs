//! Design-choice ablations (DESIGN.md calls these out):
//!   1. AllReduce algorithm: ring vs naive, end-to-end training time.
//!   2. Feature partition: hashed (the paper's Reduce-by-key layout) vs
//!      greedy nnz-balanced — straggler skew and ALB's interaction with it.
//!   3. ALB quorum κ sweep under an injected straggler.
//!   4. λ-path warm start vs cold starts (solver::path, the §8.2 protocol).
//!
//!     cargo bench --bench ablations

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::cluster::allreduce::AllReduceAlgo;
use dglmnet::coordinator::{fit_distributed, DistributedConfig};
use dglmnet::data::{synth, Corpus, SynthConfig};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::solver::compute::NativeCompute;
use dglmnet::solver::dglmnet::DGlmnetConfig;
use dglmnet::solver::path;
use dglmnet::sparse::FeaturePartition;
use dglmnet::util::bench::Table;
use std::time::{Duration, Instant};

fn main() {
    allreduce_ablation();
    partition_ablation();
    kappa_ablation();
    warmstart_ablation();
}

fn allreduce_ablation() {
    println!("=== Ablation 1: ring vs naive AllReduce (end-to-end, M=8) ===");
    let ds = synth::webspam_like(
        &SynthConfig {
            n: 4000,
            p: 10_000,
            seed: 31,
        },
        80,
    );
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::l1_only(1.0);
    let mut t = Table::new(&["allreduce", "wall (s)", "total MiB", "hottest-node MiB"]);
    for algo in [AllReduceAlgo::Naive, AllReduceAlgo::Ring] {
        let cfg = DistributedConfig {
            nodes: 8,
            max_iters: 10,
            tol: 0.0,
            eval_every: 0,
            allreduce: algo,
            ..Default::default()
        };
        let t0 = Instant::now();
        let fit = fit_distributed(&ds, None, &compute, &pen, &cfg);
        t.row(&[
            format!("{algo:?}"),
            format!("{:.3}", t0.elapsed().as_secs_f64()),
            format!("{:.2}", fit.comm_bytes as f64 / (1024.0 * 1024.0)),
            "(see Table 2 bench)".into(),
        ]);
    }
    t.print();
}

fn partition_ablation() {
    println!("\n=== Ablation 2: hashed vs nnz-balanced feature partition ===");
    // Power-law columns make hashing unbalanced.
    let ds = synth::webspam_like(
        &SynthConfig {
            n: 3000,
            p: 8_000,
            seed: 32,
        },
        100,
    );
    let x = ds.to_csc();
    let mut t = Table::new(&["partition", "nnz skew (max/mean)"]);
    let hashed = FeaturePartition::hashed(x.ncols, 8, 1);
    let balanced = FeaturePartition::nnz_balanced(&x, 8);
    t.row(&["hashed (paper)".into(), format!("{:.3}", hashed.skew(&x))]);
    t.row(&["nnz-balanced (LPT)".into(), format!("{:.3}", balanced.skew(&x))]);
    t.print();
    println!("(hash skew is the intrinsic straggler source ALB §7 addresses)");
}

fn kappa_ablation() {
    println!("\n=== Ablation 3: ALB quorum κ under a 60 ms/pass straggler (M=4) ===");
    let ds = synth::webspam_like(
        &SynthConfig {
            n: 1200,
            p: 4_000,
            seed: 33,
        },
        60,
    );
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::l1_only(0.5);
    let mut delays = vec![Duration::ZERO; 4];
    delays[2] = Duration::from_millis(60);
    let mut t = Table::new(&["kappa", "wall (s)", "final objective"]);
    for kappa in [None, Some(0.5), Some(0.75), Some(1.0)] {
        let cfg = DistributedConfig {
            nodes: 4,
            alb_kappa: kappa,
            max_iters: 8,
            tol: 0.0,
            eval_every: 0,
            straggler_delays: delays.clone(),
            chunk: 8,
            ..Default::default()
        };
        let t0 = Instant::now();
        let fit = fit_distributed(&ds, None, &compute, &pen, &cfg);
        t.row(&[
            kappa
                .map(|k| format!("{k}"))
                .unwrap_or_else(|| "BSP (no ALB)".into()),
            format!("{:.3}", t0.elapsed().as_secs_f64()),
            format!("{:.4}", fit.objective),
        ]);
    }
    t.print();
    println!("(κ=1.0 waits for everyone ≈ BSP; smaller κ trades per-iteration progress for straggler immunity)");
}

fn warmstart_ablation() {
    println!("\n=== Ablation 4: λ-path warm starts vs cold starts (§8.2 protocol) ===");
    let splits = Corpus::webspam_like(0.15, 34);
    let compute = NativeCompute::new(LossKind::Logistic);
    let lmax = path::lambda_max(&splits.train, LossKind::Logistic);
    let lambdas: Vec<f64> = (0..6).map(|k| lmax * 0.5f64.powi(k + 1)).collect();
    let cfg = DGlmnetConfig {
        nodes: 4,
        max_iters: 200,
        tol: 1e-9,
        eval_every: 0,
        ..Default::default()
    };
    let t0 = Instant::now();
    let warm = path::l1_path(&splits, &compute, &lambdas, 0.0, &cfg).expect("non-empty grid");
    let warm_time = t0.elapsed().as_secs_f64();
    let warm_iters: usize = warm.points.iter().map(|p| p.iters).sum();

    let t1 = Instant::now();
    let mut cold_iters = 0;
    for &l1 in &lambdas {
        let f = dglmnet::solver::dglmnet::fit(
            &splits.train,
            &compute,
            &ElasticNet::l1_only(l1),
            &cfg,
            None,
        );
        cold_iters += f.iters;
    }
    let cold_time = t1.elapsed().as_secs_f64();

    let mut t = Table::new(&["strategy", "total iters", "wall (s)"]);
    t.row(&["warm-started path".into(), warm_iters.to_string(), format!("{warm_time:.3}")]);
    t.row(&["cold starts".into(), cold_iters.to_string(), format!("{cold_time:.3}")]);
    t.print();
    let best = warm.best_point();
    println!(
        "validation-best λ1 = {:.4} (auPRC {:.4}, nnz {}) — the §8.2 selection",
        best.lambda1, best.val_auprc, best.nnz
    );
}
