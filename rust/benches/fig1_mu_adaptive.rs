//! Figure 1 — constant μ = 1 vs adaptive μ (L1 regularization).
//!
//! Regenerates the paper's three panels (relative objective suboptimality,
//! testing quality, number of non-zero weights — all vs time) on the
//! conflict-heavy correlated-dense dataset, where the block-diagonal
//! Hessian approximation is poor and the line search backtracks.
//!
//!     cargo bench --bench fig1_mu_adaptive

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::cluster::allreduce::AllReduceAlgo;
use dglmnet::coordinator::{fit_distributed, DistributedConfig};
use dglmnet::data::{synth, SynthConfig};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::harness;
use dglmnet::solver::compute::NativeCompute;

fn main() {
    let scale = std::env::var("DGLMNET_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let n = (3000.0 * scale) as usize;
    let splits = synth::correlated_dense(
        &SynthConfig {
            n,
            p: 400,
            seed: 13,
        },
        0.6,
    )
    .split(n / 10, n / 10);
    let kind = LossKind::Logistic;
    let pen = ElasticNet::l1_only(10.0);
    let compute = NativeCompute::new(kind);
    let f_star = harness::reference_optimum(&splits, kind, &pen);
    println!(
        "=== Figure 1: constant vs adaptive μ (correlated_dense n={} p=400, L1) ===",
        splits.train.n()
    );

    let base = DistributedConfig {
        nodes: 16,
        max_iters: 40,
        eval_every: 1,
        tol: 0.0,
        allreduce: AllReduceAlgo::Ring,
        ..Default::default()
    };
    let adaptive = fit_distributed(
        &splits.train,
        Some(&splits.test),
        &compute,
        &pen,
        &DistributedConfig {
            adaptive_mu: true,
            ..base.clone()
        },
    );
    let constant = fit_distributed(
        &splits.train,
        Some(&splits.test),
        &compute,
        &pen,
        &DistributedConfig {
            adaptive_mu: false,
            ..base
        },
    );

    let mut at = adaptive.trace.clone();
    at.algorithm = "adaptive-mu".into();
    let mut ct = constant.trace.clone();
    ct.algorithm = "constant-mu(1)".into();
    harness::print_convergence("Fig 1 (subopt / auPRC / nnz vs time)", &[&at, &ct], f_star);

    let full_steps = |t: &dglmnet::solver::trace::Trace| {
        t.points.iter().filter(|p| p.alpha >= 1.0).count()
    };
    let max_mu = |t: &dglmnet::solver::trace::Trace| {
        t.points.iter().map(|p| p.mu).fold(1.0f64, f64::max)
    };
    println!(
        "\nline-search full steps: adaptive {}/{} (max μ {:.0}), constant {}/{}",
        full_steps(&at),
        at.points.len(),
        max_mu(&at),
        full_steps(&ct),
        ct.points.len()
    );
    println!(
        "paper's Fig 1 claim: adaptive μ slightly improves convergence/accuracy, dramatically improves sparsity.\n\
         measured: final nnz adaptive {} vs constant {}; final subopt {:.2e} vs {:.2e}",
        at.points.last().unwrap().nnz,
        ct.points.last().unwrap().nnz,
        (at.final_objective() - f_star) / f_star,
        (ct.final_objective() - f_star) / f_star,
    );
}
