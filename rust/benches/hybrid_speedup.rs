//! Hybrid-mode CD-pass throughput: one rank's feature block run as T
//! sub-block pool waves for T ∈ {1, 2, 4, 8}, against the classic coupled
//! single-thread cycle as the baseline. This measures exactly the hot path
//! `--threads` accelerates — the per-iteration local subproblem — without
//! transport noise, so the table is the intra-rank speedup ceiling for any
//! cluster shape.
//!
//!     cargo bench --bench hybrid_speedup

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::data::{synth, SynthConfig};
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::solver::subproblem::{cd_cycle, CycleBudget, HybridCd, SubproblemState};
use dglmnet::util::bench::{append_json_record, bench, Table};
use dglmnet::util::rng::Rng;

fn main() {
    let ds = synth::webspam_like(
        &SynthConfig {
            n: 20_000,
            p: 24_000,
            seed: 1,
        },
        100,
    );
    let x = ds.to_csc();
    let n = x.nrows;
    let p = x.ncols;
    let nnz = x.nnz();
    println!("hybrid_speedup: one rank's block n={n} p={p} nnz={nnz}");

    let mut rng = Rng::new(2);
    let beta = vec![0.0; p];
    let w: Vec<f64> = (0..n).map(|_| rng.range_f64(0.01, 0.25)).collect();
    let z: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let pen = ElasticNet::new(0.5, 0.1);

    // Baseline: the classic coupled cycle (what --threads 1 runs today).
    let mut st = SubproblemState::new(p, n);
    let classic = bench("classic coupled cd pass", 1, 8, || {
        st.reset();
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::full_cycle(p),
        );
    });

    let mut table = Table::new(&["threads", "pass (median)", "updates/s", "speedup vs T=1"]);
    let mut t1 = f64::NAN;
    let mut medians: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let mut h = HybridCd::new(&x, threads);
        let mut state = SubproblemState::new(p, n);
        let s = bench(&format!("hybrid cd pass T={threads}"), 1, 8, || {
            state.reset();
            h.bsp_pass(&beta, &w, &z, 1.0, 1e-6, &pen, &mut state);
        });
        let med = s.median();
        if threads == 1 {
            t1 = med;
        }
        medians.push((threads, med));
        table.row(&[
            threads.to_string(),
            dglmnet::util::bench::fmt_dur(med),
            format!("{:.2e}", p as f64 / med),
            format!("{:.2}x", t1 / med),
        ]);
    }
    table.print();
    println!(
        "    (classic coupled pass median {}; T=1 hybrid ≈ classic is the \
         zero-overhead check)",
        dglmnet::util::bench::fmt_dur(classic.median())
    );

    // Same trajectory file as the kernel matrix: the hybrid pass is the
    // composite workload the micro-kernels feed, so its history rides along.
    append_json_record(std::path::Path::new("BENCH_hotpath.json"), |rec| {
        rec.set("bench", "hybrid_speedup")
            .set("n", n)
            .set("p", p)
            .set("nnz", nnz)
            .set("classic_pass_s", classic.median());
        for (threads, med) in &medians {
            rec.set(format!("hybrid_t{threads}_s").as_str(), *med);
        }
        rec.set(
            "unix_ts",
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
        );
    });
}
