//! Figures 2, 3, 4 — L1 regularization comparison.
//!
//! For each corpus (epsilon_like, webspam_like, clickstream), runs
//! d-GLMNET, d-GLMNET-ALB, ADMM (sharing + Shooting) and online truncated
//! gradient, and prints the paper's three series:
//!   Fig 2: relative objective suboptimality vs time
//!   Fig 3: testing quality (auPRC) vs time
//!   Fig 4: number of non-zero weights vs time
//!
//!     cargo bench --bench fig2_4_l1_compare

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::glm::loss::LossKind;
use dglmnet::harness::{self, RunConfig};
use dglmnet::solver::compute::NativeCompute;
use dglmnet::util::bench::Table;

fn main() {
    let scale = std::env::var("DGLMNET_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    let iters = std::env::var("DGLMNET_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    println!("=== Figures 2-4: L1 comparison (scale {scale}, {iters} iterations/epochs, M=8) ===");

    let mut summary = Table::new(&[
        "dataset",
        "algorithm",
        "final subopt",
        "best auPRC",
        "final nnz",
        "time-to-10% (s)",
    ]);

    for (name, splits) in harness::corpora(scale, 7) {
        let rc = RunConfig {
            kind: LossKind::Logistic,
            pen: harness::default_lambda(name, true),
            nodes: 8,
            max_iters: iters,
            eval_every: 1,
            seed: 9,
        };
        let compute = NativeCompute::new(rc.kind);
        let f_star = harness::reference_optimum(&splits, rc.kind, &rc.pen);

        let d = harness::run_dglmnet(&splits, &rc, &compute, None);
        let dalb = harness::run_dglmnet(&splits, &rc, &compute, Some(0.75));
        let admm = harness::run_admm(&splits, &rc, 1.0);
        let online = harness::run_online(&splits, &rc);

        let traces = [&d.trace, &dalb.trace, &admm, &online];
        harness::print_convergence(name, &traces, f_star);
        for tr in traces {
            summary.row(&[
                name.to_string(),
                tr.algorithm.clone(),
                format!("{:.2e}", (tr.final_objective() - f_star) / f_star),
                format!("{:.4}", harness::best_auprc(tr).unwrap_or(f64::NAN)),
                tr.points.last().map(|p| p.nnz).unwrap_or(0).to_string(),
                tr.time_to_suboptimality(f_star, 0.10)
                    .map(|t| format!("{t:.3}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
    }

    println!("\n=== summary (paper shape: d-GLMNET ≥ ADMM on sparse corpora; ADMM competitive on dense epsilon; online fast early / poor final objective) ===");
    summary.print();
}
