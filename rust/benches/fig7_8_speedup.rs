//! Figures 7, 8 — relative speedup of d-GLMNET-ALB vs number of nodes.
//!
//! Time to reach 2.5% relative suboptimality for M ∈ {1, 2, 4, 8, 16},
//! normalized to M = 1, for L1 (Fig 7) and L2 (Fig 8). Linear speedup is
//! printed as the reference column (the paper's fictional red line).
//!
//! Timing axis: the **virtual cluster clock** — per-node thread CPU time
//! (max over nodes each iteration) plus gigabit-modeled wire time. The
//! simulation host may have fewer cores than simulated nodes (this box has
//! one), so wall-clock cannot show parallel speedup; per-thread CPU time
//! measures exactly the per-node work an M-node cluster would do. See
//! DESIGN.md §Substitutions.
//!
//! Regime note: the paper's corpora carry ~10³ nonzeros per example
//! (webspam: 3727), so per-iteration compute dwarfs the Θ(Mn) AllReduce;
//! the speedup corpus reproduces that ratio.
//!
//!     cargo bench --bench fig7_8_speedup          # DGLMNET_SCALE=1 default

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::cluster::fabric::NetworkModel;
use dglmnet::coordinator::{fit_distributed, DistributedConfig};
use dglmnet::data::{synth, SynthConfig};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::harness;
use dglmnet::solver::compute::NativeCompute;
use dglmnet::util::bench::Table;

fn main() {
    let scale = std::env::var("DGLMNET_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let n = (6000.0 * scale) as usize;
    let avg_nnz = 800usize; // paper-webspam-like row density
    let splits = synth::webspam_like(
        &SynthConfig {
            n,
            p: 20_000,
            seed: 23,
        },
        avg_nnz,
    )
    .split(n / 10, n / 10);
    println!(
        "speedup corpus: n={} p={} nnz={} ({:.0} avg/row); axis = virtual cluster clock (CPU-time max + gigabit wire)",
        splits.train.n(),
        splits.train.p(),
        splits.train.nnz(),
        splits.train.nnz() as f64 / splits.train.n() as f64
    );

    let nodes_list = [1usize, 2, 4, 8, 16];

    for (fig, l1_mode) in [("Figure 7 (L1)", true), ("Figure 8 (L2)", false)] {
        println!("\n=== {fig}: relative speedup of d-GLMNET-ALB ===");
        let kind = LossKind::Logistic;
        let pen = if l1_mode {
            ElasticNet::l1_only(2.0)
        } else {
            ElasticNet::l2_only(2.0)
        };
        let compute = NativeCompute::new(kind);
        let f_star = harness::reference_optimum(&splits, kind, &pen);
        let mut t = Table::new(&[
            "nodes",
            "iters to 2.5%",
            "sim time to 2.5% (s)",
            "speedup",
            "linear (ref)",
        ]);
        let mut t1: Option<f64> = None;
        for &nodes in &nodes_list {
            let cfg = DistributedConfig {
                nodes,
                alb_kappa: Some(0.75),
                adaptive_mu: l1_mode,
                max_iters: 80,
                eval_every: 0,
                tol: 1e-9,
                seed: 29,
                virtual_time: true,
                network: NetworkModel::gigabit(),
                ..Default::default()
            };
            let fit = fit_distributed(&splits.train, None, &compute, &pen, &cfg);
            let iters_to = fit
                .trace
                .points
                .iter()
                .find(|p| (p.objective - f_star) / f_star <= 0.025)
                .map(|p| p.iter);
            let time = fit.trace.time_to_suboptimality(f_star, 0.025);
            let Some(time) = time else {
                t.row(&[
                    nodes.to_string(),
                    "-".into(),
                    "did not reach".into(),
                    "-".into(),
                    format!("{nodes}.00x"),
                ]);
                continue;
            };
            if nodes == 1 {
                t1 = Some(time);
            }
            t.row(&[
                nodes.to_string(),
                iters_to.map(|i| i.to_string()).unwrap_or_default(),
                format!("{time:.3}"),
                t1.map(|t1| format!("{:.2}x", t1 / time))
                    .unwrap_or_else(|| "-".into()),
                format!("{nodes}.00x"),
            ]);
        }
        t.print();
    }
    println!("\npaper shape: sub-linear speedup that flattens with M (block-diagonal Hessian degrades + communication grows).");
}
