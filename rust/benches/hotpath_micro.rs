//! Hot-path microbenchmarks — the §Perf instrumentation.
//!
//! Measures the building blocks the end-to-end figures are made of:
//!   - the `kernels::` per-primitive matrix: scalar vs vector-strict vs
//!     fast-math (plus the experimental f32-margin helpers), appended to
//!     `BENCH_hotpath.json` as a trajectory across commits
//!   - CD cycle throughput (effective nnz traversal rate) — the L3 hot loop
//!   - AllReduce naive vs ring at realistic vector sizes
//!   - XLA stats/linesearch execution vs the native oracle — the L2/L1 path
//!   - batched vs per-α line-search evaluation
//!
//!     cargo bench --bench hotpath_micro

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use std::path::Path;

use dglmnet::cluster::allreduce::{allreduce_sum, AllReduceAlgo};
use dglmnet::cluster::fabric::{fabric, NetworkModel};
use dglmnet::data::{synth, SynthConfig};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::kernels::vector::f32mode;
use dglmnet::kernels::{CdKernels, ScalarKernels, VectorKernels};
use dglmnet::runtime::{Runtime, XlaCompute};
use dglmnet::solver::compute::{GlmCompute, NativeCompute};
use dglmnet::solver::subproblem::{cd_cycle, CycleBudget, SubproblemState};
use dglmnet::util::bench::{append_json_record, bench};
use dglmnet::util::rng::Rng;

fn main() {
    kernel_matrix();
    cd_cycle_throughput();
    allreduce_comparison();
    xla_vs_native();
    linesearch_batching();
}

/// The `kernels::` primitive matrix: every hot-loop primitive timed under
/// all three implementations. The benches construct the impls directly
/// (never flipping the process-global mode) so the matrix is
/// self-contained. Medians land in `BENCH_hotpath.json` keyed
/// `<primitive>_<impl>_s`, plus derived `<primitive>_speedup` =
/// scalar / vector-strict — the number the tentpole claims (≥ 1.0).
fn kernel_matrix() {
    println!("\n=== kernels:: primitive matrix (scalar | vector-strict | vector-fast) ===");
    const N: usize = 1 << 20; // dense margin length
    let mut rng = Rng::new(11);

    // One long sparse column with ~50% density and striding row indices:
    // streams like the power-law columns the CD loop actually touches.
    let rows: Vec<u32> = (0..N as u32).step_by(2).collect();
    let vals: Vec<f64> = rows.iter().map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let dense: Vec<f64> = (0..N).map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let w: Vec<f64> = (0..N).map(|_| rng.range_f64(0.01, 0.25)).collect();
    let z: Vec<f64> = (0..N).map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let t: Vec<f64> = (0..N).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let d: Vec<f64> = (0..N).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let y: Vec<f64> = (0..N)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
        .collect();

    let impls: [(&str, &dyn CdKernels); 3] = [
        ("scalar", &ScalarKernels),
        ("strict", &VectorKernels { fast: false }),
        ("fast", &VectorKernels { fast: true }),
    ];
    // (record key, median seconds) pairs accumulated across the matrix.
    let mut medians: Vec<(String, f64)> = Vec::new();
    let mut record = |key: String, median: f64| medians.push((key, median));

    for (tag, ker) in impls {
        let s = bench(&format!("sparse_dot {tag} (nnz={})", rows.len()), 2, 10, || {
            // SAFETY: rows holds strided indices < N == dense.len().
            std::hint::black_box(unsafe { ker.sparse_dot(&rows, &vals, &dense) });
        });
        record(format!("sparse_dot_{tag}_s"), s.median());

        let mut acc = dense.clone();
        let s = bench(&format!("axpy_col {tag} (nnz={})", rows.len()), 2, 10, || {
            // SAFETY: rows holds strided indices < N == acc.len().
            unsafe { ker.axpy_col(&rows, &vals, 1e-9, &mut acc) };
            std::hint::black_box(acc[0]);
        });
        record(format!("axpy_col_{tag}_s"), s.median());

        let s = bench(
            &format!("col_weighted_quad {tag} (nnz={})", rows.len()),
            2,
            10,
            || {
                // SAFETY: rows holds strided indices < N == w/z/t len.
                std::hint::black_box(unsafe {
                    ker.col_weighted_quad(&rows, &vals, &w, &z, &t, 1.0)
                });
            },
        );
        record(format!("col_weighted_quad_{tag}_s"), s.median());

        let s = bench(&format!("neg_wz_dot {tag} (n={N})"), 2, 10, || {
            std::hint::black_box(ker.neg_wz_dot(&w, &z, &d));
        });
        record(format!("neg_wz_dot_{tag}_s"), s.median());

        let s = bench(&format!("logloss_sum {tag} (n={N})"), 2, 10, || {
            std::hint::black_box(ker.logloss_sum(&y, &dense));
        });
        record(format!("logloss_sum_{tag}_s"), s.median());

        let mut out = vec![0.0; N];
        let s = bench(&format!("sigmoid_margins {tag} (n={N})"), 2, 10, || {
            ker.sigmoid_margins(&dense, &mut out);
            std::hint::black_box(out[0]);
        });
        record(format!("sigmoid_margins_{tag}_s"), s.median());

        let mut m = dense.clone();
        let s = bench(&format!("margin_update {tag} (n={N})"), 2, 10, || {
            ker.margin_update_with_xdelta(&mut m, &d, 1e-9);
            std::hint::black_box(m[0]);
        });
        record(format!("margin_update_{tag}_s"), s.median());
    }

    // The experimental f32-margin helpers (bench/parity only — not a
    // solver dispatch mode): halved margin bytes vs the f64 kernels above.
    let m32: Vec<f32> = dense.iter().map(|&x| x as f32).collect();
    let d32: Vec<f32> = d.iter().map(|&x| x as f32).collect();
    let s = bench(&format!("logloss_sum f32 (n={N})"), 2, 10, || {
        std::hint::black_box(f32mode::logloss_sum_f32(&y, &m32));
    });
    record("logloss_sum_f32_s".to_string(), s.median());
    let mut out32 = vec![0.0f32; N];
    let s = bench(&format!("sigmoid_margins f32 (n={N})"), 2, 10, || {
        f32mode::sigmoid_margins_f32(&m32, &mut out32);
        std::hint::black_box(out32[0]);
    });
    record("sigmoid_margins_f32_s".to_string(), s.median());
    let mut acc32 = m32.clone();
    let s = bench(&format!("margin_update f32 (n={N})"), 2, 10, || {
        f32mode::margin_update_f32(&mut acc32, &d32, 1e-9);
        std::hint::black_box(acc32[0]);
    });
    record("margin_update_f32_s".to_string(), s.median());

    // Derived speedups (scalar / vector-strict): the tentpole's claim is
    // that the unrolled default is never slower than the reference.
    let get = |key: &str| {
        medians
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN)
    };
    let primitives = [
        "sparse_dot",
        "axpy_col",
        "col_weighted_quad",
        "neg_wz_dot",
        "logloss_sum",
        "sigmoid_margins",
        "margin_update",
    ];
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for prim in primitives {
        let sc = get(&format!("{prim}_scalar_s"));
        let vs = get(&format!("{prim}_strict_s"));
        let speedup = sc / vs.max(1e-12);
        println!("    -> {prim}: vector-strict {speedup:.2}x vs scalar");
        speedups.push((format!("{prim}_speedup"), speedup));
    }

    append_json_record(Path::new("BENCH_hotpath.json"), |rec| {
        rec.set("bench", "hotpath_kernels").set("n", N);
        for (k, v) in &medians {
            rec.set(k.as_str(), *v);
        }
        for (k, v) in &speedups {
            rec.set(k.as_str(), *v);
        }
        rec.set(
            "unix_ts",
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|dur| dur.as_secs())
                .unwrap_or(0),
        );
    });
}

fn cd_cycle_throughput() {
    println!("\n=== CD cycle throughput (L3 hot loop) ===");
    let ds = synth::webspam_like(
        &SynthConfig {
            n: 20_000,
            p: 30_000,
            seed: 1,
        },
        100,
    );
    let x = ds.to_csc();
    let n = x.nrows;
    let mut rng = Rng::new(2);
    let beta = vec![0.0; x.ncols];
    let w: Vec<f64> = (0..n).map(|_| rng.range_f64(0.01, 0.25)).collect();
    let z: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let pen = ElasticNet::new(0.5, 0.1);
    let mut st = SubproblemState::new(x.ncols, n);
    let nnz = x.nnz();
    let s = bench("cd_cycle full pass (2M nnz)", 1, 10, || {
        st.reset();
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::full_cycle(x.ncols),
        );
    });
    // Each coordinate touches its column twice (gather + scatter).
    let rate = 2.0 * nnz as f64 * 16.0 / s.median() / 1e9;
    println!("    -> effective column traversal {:.2} GB/s ({} nnz, 16 B/entry touched twice)", rate, nnz);
}

fn allreduce_comparison() {
    println!("\n=== AllReduce: naive vs ring (M=8) ===");
    for n in [1_000usize, 100_000, 1_000_000] {
        for algo in [AllReduceAlgo::Naive, AllReduceAlgo::Ring] {
            let label = format!("allreduce {:?} n={n}", algo);
            bench(&label, 1, 5, || {
                let (eps, _) = fabric(8, NetworkModel::default());
                crossbeam_utils::thread::scope(|s| {
                    for ep in eps {
                        s.spawn(move |_| {
                            let mut ep = ep;
                            let mut data = vec![1.0f64; n];
                            allreduce_sum(&mut ep, 0, &mut data, algo);
                        });
                    }
                })
                .unwrap();
            });
        }
    }
}

fn xla_vs_native() {
    println!("\n=== GLM stats: XLA (Pallas artifact via PJRT) vs native ===");
    let rt = match Runtime::start("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("(skipping XLA benches: {e})");
            return;
        }
    };
    let mut rng = Rng::new(3);
    for n in [4096usize, 65_536] {
        let margins: Vec<f64> = (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect();
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        let mut w = vec![0.0; n];
        let mut z = vec![0.0; n];
        let xla = XlaCompute::new(rt.handle(), LossKind::Logistic);
        let nat = NativeCompute::new(LossKind::Logistic);
        bench(&format!("stats native n={n}"), 2, 10, || {
            std::hint::black_box(nat.stats(&y, &margins, &mut w, &mut z));
        });
        bench(&format!("stats xla    n={n}"), 2, 10, || {
            std::hint::black_box(xla.stats(&y, &margins, &mut w, &mut z));
        });
    }
}

fn linesearch_batching() {
    println!("\n=== Line search: batched K=17 vs 17 single-α calls (native) ===");
    let mut rng = Rng::new(4);
    let n = 100_000;
    let margins: Vec<f64> = (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect();
    let dmargins: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
        .collect();
    let alphas: Vec<f64> = (0..17).map(|k| k as f64 / 17.0).collect();
    let nat = NativeCompute::new(LossKind::Logistic);
    bench("loss_at_alphas batched (17)", 1, 8, || {
        std::hint::black_box(nat.loss_at_alphas(&y, &margins, &dmargins, &alphas));
    });
    bench("loss_at_alphas 17 x single", 1, 8, || {
        for &a in &alphas {
            std::hint::black_box(nat.loss_at_alphas(&y, &margins, &dmargins, &[a]));
        }
    });
}
