//! Hot-path microbenchmarks — the §Perf instrumentation.
//!
//! Measures the building blocks the end-to-end figures are made of:
//!   - CD cycle throughput (effective nnz traversal rate) — the L3 hot loop
//!   - AllReduce naive vs ring at realistic vector sizes
//!   - XLA stats/linesearch execution vs the native oracle — the L2/L1 path
//!   - batched vs per-α line-search evaluation
//!
//!     cargo bench --bench hotpath_micro

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::cluster::allreduce::{allreduce_sum, AllReduceAlgo};
use dglmnet::cluster::fabric::{fabric, NetworkModel};
use dglmnet::data::{synth, SynthConfig};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::runtime::{Runtime, XlaCompute};
use dglmnet::solver::compute::{GlmCompute, NativeCompute};
use dglmnet::solver::subproblem::{cd_cycle, CycleBudget, SubproblemState};
use dglmnet::util::bench::bench;
use dglmnet::util::rng::Rng;

fn main() {
    cd_cycle_throughput();
    allreduce_comparison();
    xla_vs_native();
    linesearch_batching();
}

fn cd_cycle_throughput() {
    println!("\n=== CD cycle throughput (L3 hot loop) ===");
    let ds = synth::webspam_like(
        &SynthConfig {
            n: 20_000,
            p: 30_000,
            seed: 1,
        },
        100,
    );
    let x = ds.to_csc();
    let n = x.nrows;
    let mut rng = Rng::new(2);
    let beta = vec![0.0; x.ncols];
    let w: Vec<f64> = (0..n).map(|_| rng.range_f64(0.01, 0.25)).collect();
    let z: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let pen = ElasticNet::new(0.5, 0.1);
    let mut st = SubproblemState::new(x.ncols, n);
    let nnz = x.nnz();
    let s = bench("cd_cycle full pass (2M nnz)", 1, 10, || {
        st.reset();
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::full_cycle(x.ncols),
        );
    });
    // Each coordinate touches its column twice (gather + scatter).
    let rate = 2.0 * nnz as f64 * 16.0 / s.median() / 1e9;
    println!("    -> effective column traversal {:.2} GB/s ({} nnz, 16 B/entry touched twice)", rate, nnz);
}

fn allreduce_comparison() {
    println!("\n=== AllReduce: naive vs ring (M=8) ===");
    for n in [1_000usize, 100_000, 1_000_000] {
        for algo in [AllReduceAlgo::Naive, AllReduceAlgo::Ring] {
            let label = format!("allreduce {:?} n={n}", algo);
            bench(&label, 1, 5, || {
                let (eps, _) = fabric(8, NetworkModel::default());
                crossbeam_utils::thread::scope(|s| {
                    for ep in eps {
                        s.spawn(move |_| {
                            let mut ep = ep;
                            let mut data = vec![1.0f64; n];
                            allreduce_sum(&mut ep, 0, &mut data, algo);
                        });
                    }
                })
                .unwrap();
            });
        }
    }
}

fn xla_vs_native() {
    println!("\n=== GLM stats: XLA (Pallas artifact via PJRT) vs native ===");
    let rt = match Runtime::start("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("(skipping XLA benches: {e})");
            return;
        }
    };
    let mut rng = Rng::new(3);
    for n in [4096usize, 65_536] {
        let margins: Vec<f64> = (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect();
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        let mut w = vec![0.0; n];
        let mut z = vec![0.0; n];
        let xla = XlaCompute::new(rt.handle(), LossKind::Logistic);
        let nat = NativeCompute::new(LossKind::Logistic);
        bench(&format!("stats native n={n}"), 2, 10, || {
            std::hint::black_box(nat.stats(&y, &margins, &mut w, &mut z));
        });
        bench(&format!("stats xla    n={n}"), 2, 10, || {
            std::hint::black_box(xla.stats(&y, &margins, &mut w, &mut z));
        });
    }
}

fn linesearch_batching() {
    println!("\n=== Line search: batched K=17 vs 17 single-α calls (native) ===");
    let mut rng = Rng::new(4);
    let n = 100_000;
    let margins: Vec<f64> = (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect();
    let dmargins: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let y: Vec<f64> = (0..n)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
        .collect();
    let alphas: Vec<f64> = (0..17).map(|k| k as f64 / 17.0).collect();
    let nat = NativeCompute::new(LossKind::Logistic);
    bench("loss_at_alphas batched (17)", 1, 8, || {
        std::hint::black_box(nat.loss_at_alphas(&y, &margins, &dmargins, &alphas));
    });
    bench("loss_at_alphas 17 x single", 1, 8, || {
        for &a in &alphas {
            std::hint::black_box(nat.loss_at_alphas(&y, &margins, &dmargins, &[a]));
        }
    });
}
