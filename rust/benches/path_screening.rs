//! KKT strong-rule screening ablation: the §8.2 λ-path sweep with and
//! without sequential screening, comparing the coordinate-update counts
//! point by point. Screening is exact (the violation re-cycle guarantees
//! it — see `solver::path`), so the objectives must agree while the
//! screened sweep touches a fraction of the block per pass.
//!
//!     cargo bench --bench path_screening

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::data::Corpus;
use dglmnet::glm::loss::LossKind;
use dglmnet::solver::compute::NativeCompute;
use dglmnet::solver::dglmnet::DGlmnetConfig;
use dglmnet::solver::path::{self, l1_path_with_screening};
use dglmnet::util::bench::Table;
use std::time::Instant;

fn main() {
    println!("=== λ-path screening: CD updates with vs without strong rules ===");
    let splits = Corpus::webspam_like(0.25, 41);
    let compute = NativeCompute::new(LossKind::Logistic);
    let grid = path::paper_lambda_grid();
    let cfg = DGlmnetConfig {
        nodes: 8,
        max_iters: 100,
        tol: 1e-9,
        eval_every: 0,
        ..Default::default()
    };

    let t0 = Instant::now();
    let screened = l1_path_with_screening(&splits, &compute, &grid, 0.0, &cfg, true)
        .expect("screened sweep");
    let t_screened = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let full = l1_path_with_screening(&splits, &compute, &grid, 0.0, &cfg, false)
        .expect("unscreened sweep");
    let t_full = t1.elapsed().as_secs_f64();

    let mut t = Table::new(&[
        "λ1",
        "nnz",
        "updates (screened)",
        "updates (full)",
        "touched frac",
        "obj gap",
    ]);
    for (a, b) in screened.points.iter().zip(full.points.iter()) {
        let frac = if b.cd_updates > 0 {
            a.cd_updates as f64 / b.cd_updates as f64
        } else {
            1.0
        };
        let gap = (a.objective - b.objective).abs() / b.objective.abs().max(1e-12);
        t.row(&[
            format!("{:.4}", a.lambda1),
            a.nnz.to_string(),
            a.cd_updates.to_string(),
            b.cd_updates.to_string(),
            format!("{frac:.3}"),
            format!("{gap:.1e}"),
        ]);
    }
    t.print();

    let su = screened.total_cd_updates();
    let fu = full.total_cd_updates();
    println!(
        "\ntotals: screened {su} updates in {t_screened:.3}s | full {fu} updates in {t_full:.3}s \
         | update ratio {:.3} | speedup {:.2}x",
        su as f64 / fu as f64,
        t_full / t_screened.max(1e-9),
    );
    assert!(
        su < fu,
        "screening must perform strictly fewer updates ({su} vs {fu})"
    );
    println!(
        "best point agrees: λ1={} (screened) vs λ1={} (full)",
        screened.best_point().lambda1,
        full.best_point().lambda1
    );
}
