//! Table 2 — computational load of the algorithms.
//!
//! Regenerates the paper's Table 2: per-iteration complexity, memory
//! footprint and communication cost for online-TG, L-BFGS, d-GLMNET and
//! ADMM. The paper reports analytic columns; we print both the analytic
//! formula (in the paper's units) AND the measured quantities from the
//! instrumented fabric / solver state.
//!
//!     cargo bench --bench table2_load

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::cluster::allreduce::AllReduceAlgo;
use dglmnet::coordinator::{fit_distributed, DistributedConfig};
use dglmnet::data::Corpus;
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::solver::admm::{fit_admm, AdmmConfig};
use dglmnet::solver::compute::NativeCompute;
use dglmnet::solver::lbfgs::{fit_lbfgs, LbfgsConfig};
use dglmnet::solver::online::{fit_online, OnlineConfig};
use dglmnet::util::bench::Table;
use std::time::Instant;

fn main() {
    let scale = std::env::var("DGLMNET_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.4);
    let m = 8usize;
    let splits = Corpus::webspam_like(scale, 3);
    let (n, p, nnz) = (splits.train.n(), splits.train.p(), splits.train.nnz());
    println!("=== Table 2: computational load (webspam_like n={n} p={p} nnz={nnz}, M={m}) ===\n");

    let kind = LossKind::Logistic;
    let iters = 5usize;

    // --- d-GLMNET (measured comm from the fabric) ---
    let compute = NativeCompute::new(kind);
    let pen = ElasticNet::l1_only(1.0);
    let t0 = Instant::now();
    let d = fit_distributed(
        &splits.train,
        None,
        &compute,
        &pen,
        &DistributedConfig {
            nodes: m,
            max_iters: iters,
            tol: 0.0,
            eval_every: 0,
            allreduce: AllReduceAlgo::Ring,
            ..Default::default()
        },
    );
    let d_time = t0.elapsed().as_secs_f64() / iters as f64;
    let d_comm = d.comm_bytes as f64 / iters as f64;

    // --- ADMM ---
    let t0 = Instant::now();
    let _a = fit_admm(
        &splits.train,
        None,
        &AdmmConfig {
            kind,
            l1: 1.0,
            l2: 0.0,
            nodes: m,
            max_iters: iters,
            eval_every: 0,
            ..Default::default()
        },
    );
    let a_time = t0.elapsed().as_secs_f64() / iters as f64;

    // --- online-TG ---
    let t0 = Instant::now();
    let _o = fit_online(
        &splits.train,
        None,
        &OnlineConfig {
            kind,
            l1: 1.0,
            nodes: m,
            epochs: iters,
            eval_every: 0,
            ..Default::default()
        },
    );
    let o_time = t0.elapsed().as_secs_f64() / iters as f64;

    // --- L-BFGS ---
    let t0 = Instant::now();
    let _l = fit_lbfgs(
        &splits.train,
        None,
        &LbfgsConfig {
            kind,
            l2: 1.0,
            nodes: m,
            max_iters: iters,
            warmstart_epochs: 0,
            eval_every: 0,
            tol: 0.0,
            ..Default::default()
        },
    );
    let l_time = t0.elapsed().as_secs_f64() / iters as f64;

    let fmt_b = |b: f64| format!("{:.2} MiB", b / (1024.0 * 1024.0));
    let mut t = Table::new(&[
        "algorithm",
        "iteration complexity",
        "memory footprint (paper units)",
        "communication cost (paper units)",
        "measured s/iter",
        "measured comm/iter",
    ]);
    t.row(&[
        "online-TG".into(),
        "O(nnz)".into(),
        format!("2Mp = {}", fmt_b((2 * m * p) as f64 * 8.0)),
        format!("2Mp = {}", fmt_b((2 * m * p) as f64 * 8.0)),
        format!("{o_time:.3}"),
        "weight averaging (in-proc)".into(),
    ]);
    t.row(&[
        "L-BFGS (r=15)".into(),
        "O(nnz)".into(),
        format!("2rMp = {}", fmt_b((2 * 15 * m * p) as f64 * 8.0)),
        format!("Mp = {}", fmt_b((m * p) as f64 * 8.0)),
        format!("{l_time:.3}"),
        "gradient reduce (in-proc)".into(),
    ]);
    t.row(&[
        "d-GLMNET".into(),
        "O(nnz)".into(),
        format!(
            "3Mn + 2p = {} (measured peak/node: {})",
            fmt_b((3 * m * n + 2 * p) as f64 * 8.0),
            fmt_b(d.peak_node_f64_slots as f64 * 8.0)
        ),
        format!("Mn = {}", fmt_b((m * n) as f64 * 8.0)),
        format!("{d_time:.3}"),
        fmt_b(d_comm),
    ]);
    t.row(&[
        "ADMM".into(),
        "O(nnz)".into(),
        format!("5Mn + p = {}", fmt_b((5 * m * n + p) as f64 * 8.0)),
        format!("Mn = {}", fmt_b((m * n) as f64 * 8.0)),
        format!("{a_time:.3}"),
        "x̄/z̄/u vectors (in-proc)".into(),
    ]);
    t.print();
    println!(
        "\nshape check vs paper Table 2: d-GLMNET/ADMM communicate Θ(Mn) per iteration \
         (measured d-GLMNET ring traffic {} ≈ 2·(M−1)/M · Mn·8B = {}); by-example methods move Θ(Mp).",
        fmt_b(d_comm),
        fmt_b((2 * (m - 1) * n) as f64 * 8.0),
    );

    // --- asynchronous column: per-rank load under d-GLMNET-ALB (§7) ---
    // One injected straggler; the per-rank table shows the cut-off rank
    // doing less CD work while the fast ranks' sync wait stays small —
    // the Table-2 accounting extended to asynchronous runs.
    println!("\n=== d-GLMNET-ALB (κ=0.75) per-rank load, 40 ms straggler on rank 2 ===");
    let alb = fit_distributed(
        &splits.train,
        None,
        &compute,
        &pen,
        &DistributedConfig {
            nodes: m,
            alb_kappa: Some(0.75),
            max_iters: iters,
            tol: 0.0,
            eval_every: 0,
            allreduce: AllReduceAlgo::Ring,
            chunk: 8,
            straggler_delays: dglmnet::harness::delays_with_straggler(
                m,
                2,
                std::time::Duration::from_millis(40),
            ),
            ..Default::default()
        },
    );
    dglmnet::harness::print_rank_loads(&alb.per_rank);
}
