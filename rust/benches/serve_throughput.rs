//! Serving throughput and latency — the load numbers behind the ROADMAP
//! north star ("serve heavy traffic ... as fast as the hardware allows").
//!
//! Spins an in-process `serve` endpoint over a synthetic sparse model and
//! drives it closed-loop with the `bench-serve` load generator, sweeping
//! client fan-in and micro-batch linger. Reports QPS, rows/s and p50/p99
//! per configuration, plus the server-side view (batch coalescing factor).
//!
//!     cargo bench --bench serve_throughput

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use std::sync::Arc;
use std::time::Duration;

use dglmnet::serve::{
    run_loadgen, serve, synthetic_model, BatcherConfig, LoadgenConfig, ModelRegistry,
    NativeFactory, Scorer, ServerConfig,
};
use dglmnet::util::bench::Table;

const P: usize = 1 << 18;

fn run_config(
    threads: usize,
    max_wait: Duration,
    max_batch_rows: usize,
    table: &mut Table,
) {
    // ~1% support, like a converged L1 click model.
    let registry = Arc::new(ModelRegistry::with_model(synthetic_model(P, P / 100, 1)));
    let scorer = Arc::new(Scorer::new(registry, Box::new(NativeFactory)));
    let mut server = serve(
        scorer,
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            io_threads: threads + 2,
            batcher: BatcherConfig {
                max_batch_rows,
                max_wait,
                workers: 2,
            },
        },
    )
    .expect("bind");
    let report = run_loadgen(
        server.addr(),
        LoadgenConfig {
            threads,
            requests_per_thread: 2_000,
            rows_per_request: 4,
            nnz_per_row: 32,
            p: P,
            seed: 7,
        },
    )
    .expect("loadgen");
    let server_lat = server.latency();
    table.row(&[
        threads.to_string(),
        format!("{}µs", max_wait.as_micros()),
        max_batch_rows.to_string(),
        format!("{:.0}", report.qps()),
        format!("{:.0}", report.rows_per_sec()),
        format!("{:.3}", report.hist.quantile_ns(0.50) as f64 / 1e6),
        format!("{:.3}", report.hist.quantile_ns(0.99) as f64 / 1e6),
        format!("{:.3}", server_lat.quantile_ns(0.50) as f64 / 1e6),
    ]);
    server.stop();
}

fn main() {
    println!("=== serve throughput: client fan-in sweep (linger 200µs) ===");
    let headers = [
        "clients",
        "linger",
        "max batch",
        "qps",
        "rows/s",
        "p50 ms",
        "p99 ms",
        "srv p50 ms",
    ];
    let mut t = Table::new(&headers);
    for threads in [1, 2, 4, 8] {
        run_config(threads, Duration::from_micros(200), 256, &mut t);
    }
    t.print();

    println!("\n=== serve throughput: micro-batch linger sweep (4 clients) ===");
    let mut t = Table::new(&headers);
    for wait_us in [0u64, 50, 200, 1_000] {
        run_config(4, Duration::from_micros(wait_us), 256, &mut t);
    }
    t.print();

    println!("\n=== serve throughput: batch-size cap sweep (8 clients) ===");
    let mut t = Table::new(&headers);
    for cap in [1usize, 16, 256, 4_096] {
        run_config(8, Duration::from_micros(200), cap, &mut t);
    }
    t.print();
}
