//! Figures 5, 6 — L2 regularization comparison.
//!
//! For each corpus, runs d-GLMNET (constant μ = 1, per the paper), its ALB
//! variant, ADMM and online-warmstarted L-BFGS, printing
//!   Fig 5: relative objective suboptimality vs time
//!   Fig 6: testing quality (auPRC) vs time
//!
//!     cargo bench --bench fig5_6_l2_compare

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::glm::loss::LossKind;
use dglmnet::harness::{self, RunConfig};
use dglmnet::solver::compute::NativeCompute;
use dglmnet::util::bench::Table;

fn main() {
    let scale = std::env::var("DGLMNET_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    let iters = std::env::var("DGLMNET_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    println!("=== Figures 5-6: L2 comparison (scale {scale}, {iters} iterations, M=8) ===");

    let mut summary = Table::new(&[
        "dataset",
        "algorithm",
        "final subopt",
        "best auPRC",
        "time-to-2.5% (s)",
    ]);

    for (name, splits) in harness::corpora(scale, 17) {
        let rc = RunConfig {
            kind: LossKind::Logistic,
            pen: harness::default_lambda(name, false),
            nodes: 8,
            max_iters: iters,
            eval_every: 1,
            seed: 19,
        };
        let compute = NativeCompute::new(rc.kind);
        let f_star = harness::reference_optimum(&splits, rc.kind, &rc.pen);

        let d = harness::run_dglmnet(&splits, &rc, &compute, None);
        let dalb = harness::run_dglmnet(&splits, &rc, &compute, Some(0.75));
        let admm = harness::run_admm(&splits, &rc, 1.0);
        let lbfgs = harness::run_lbfgs(&splits, &rc);

        let traces = [&d.trace, &dalb.trace, &admm, &lbfgs];
        harness::print_convergence(name, &traces, f_star);
        for tr in traces {
            summary.row(&[
                name.to_string(),
                tr.algorithm.clone(),
                format!("{:.2e}", (tr.final_objective() - f_star) / f_star),
                format!("{:.4}", harness::best_auprc(tr).unwrap_or(f64::NAN)),
                tr.time_to_suboptimality(f_star, 0.025)
                    .map(|t| format!("{t:.3}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
    }

    println!("\n=== summary (paper shape: d-GLMNET wins on sparse high-p corpora; online+L-BFGS wins on dense epsilon) ===");
    summary.print();
}
