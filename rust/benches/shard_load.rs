//! Ingestion benchmark: text libsvm parse vs binary shard-block load.
//!
//! Times three ways to get the training data into a rank's memory:
//!
//!   1. `text parse`   — read + parse the whole libsvm text file (what every
//!      rank of a text-ingest cluster does before sharding, protocol ≤ v6);
//!   2. `block load`   — open the shard header and load one rank's block
//!      plus the shared labels (the protocol-v7 out-of-core path);
//!   3. `full rebuild` — reassemble the complete splits from a shard
//!      directory (`load_splits_full`, the single-node consumption path).
//!
//! Alongside wall time it reports bytes read from disk per variant, which is
//! the quantity the out-of-core claim is about: a rank's block file is a
//! ~1/M slice of the corpus, so both time and I/O shrink with the block
//! count. Each run appends a JSON record to `BENCH_shard_load.json` at the
//! repo root so the numbers accumulate into a trajectory across commits.
//!
//! Run with:
//!
//!     cargo bench --bench shard_load
//!
//! `DGLMNET_SCALE` scales the synthetic corpus (default 0.25).
#![allow(clippy::disallowed_macros)]

use std::path::Path;

use dglmnet::data::shards::{self, PartitionKind};
use dglmnet::sparse::libsvm::{self, LibsvmData};
use dglmnet::util::bench::{append_json_record, bench, fmt_dur, Table};

const SEED: u64 = 7;
const BLOCKS: usize = 4;

fn main() {
    let scale: f64 = std::env::var("DGLMNET_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);

    let splits = dglmnet::harness::load_splits("epsilon_like", scale, SEED).expect("corpus");
    let (n, p, nnz) = (splits.train.n(), splits.train.p(), splits.train.nnz());
    println!("shard_load: epsilon_like scale={scale} n={n} p={p} nnz={nnz} blocks={BLOCKS}");

    let tmp = std::env::temp_dir().join(format!("dglmnet-shard-load-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).expect("create temp dir");

    // The text baseline: the train split serialized as libsvm text, exactly
    // what `dglmnet convert` would ingest.
    let text_path = tmp.join("train.libsvm");
    let text = LibsvmData {
        x: splits.train.x.clone(),
        y: splits.train.y.clone(),
    };
    libsvm::write_file(&text_path, &text).expect("write libsvm text");
    let text_bytes = std::fs::metadata(&text_path).expect("stat text file").len();

    // The binary shards, converted from the same recipe with the same
    // hashed partition the cluster path derives.
    let shard_dir = tmp.join("shards");
    shards::convert_recipe("epsilon_like", scale, SEED, BLOCKS, PartitionKind::Hashed, &shard_dir)
        .expect("convert");

    // Bytes read per variant, measured once outside the timing loops.
    let header = shards::open_header(&shard_dir).expect("open header");
    let (_, block_stats) = header.load_block(&shard_dir, 0).expect("load block 0");
    let (_, label_stats) = header.load_labels(&shard_dir).expect("load labels");
    let block_bytes = block_stats.bytes_read + label_stats.bytes_read;
    let full_bytes: u64 = {
        let mut total = label_stats.bytes_read;
        for rk in 0..header.num_blocks() {
            let (_, s) = header.load_block(&shard_dir, rk).expect("load block");
            total += s.bytes_read;
        }
        total
    };

    let parse = bench("text parse", 1, 5, || {
        let d = libsvm::read_file(&text_path).expect("parse libsvm");
        std::hint::black_box(d.x.nnz());
    });
    let block = bench("block load (rank 0 + labels)", 1, 5, || {
        let h = shards::open_header(&shard_dir).expect("open header");
        let (csc, _) = h.load_block(&shard_dir, 0).expect("load block 0");
        let (y, _) = h.load_labels(&shard_dir).expect("load labels");
        std::hint::black_box((csc.nnz(), y.len()));
    });
    let full = bench("full rebuild (all blocks)", 1, 5, || {
        let s = shards::load_splits_full(&shard_dir).expect("load full splits");
        std::hint::black_box(s.train.nnz());
    });

    let mut table = Table::new(&["variant", "median", "bytes read"]);
    table.row(&[
        "text parse".into(),
        fmt_dur(parse.median()),
        format!("{text_bytes}"),
    ]);
    table.row(&[
        "block load (rank 0 + labels)".into(),
        fmt_dur(block.median()),
        format!("{block_bytes}"),
    ]);
    table.row(&[
        "full rebuild (all blocks)".into(),
        fmt_dur(full.median()),
        format!("{full_bytes}"),
    ]);
    table.print();
    println!(
        "block load vs text parse: {:.1}x faster, {:.1}x fewer bytes",
        parse.median() / block.median().max(1e-12),
        text_bytes as f64 / (block_bytes as f64).max(1.0),
    );

    append_json_record(Path::new("BENCH_shard_load.json"), |rec| {
        rec.set("bench", "shard_load")
            .set("scale", scale)
            .set("n", n)
            .set("p", p)
            .set("nnz", nnz)
            .set("blocks", BLOCKS)
            .set("text_parse_s", parse.median())
            .set("block_load_s", block.median())
            .set("full_rebuild_s", full.median())
            .set("text_bytes", text_bytes)
            .set("block_bytes", block_bytes)
            .set("full_bytes", full_bytes)
            .set(
                "unix_ts",
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.as_secs())
                    .unwrap_or(0),
            );
    });

    let _ = std::fs::remove_dir_all(&tmp);
}
