//! Oracle equivalence: the distributed coordinator — over BOTH transports,
//! and even across real OS processes — must reproduce the single-process
//! Algorithm 1 reference (`solver::dglmnet::fit`) exactly: the transport is
//! plumbing, the math may not change.

use dglmnet::coordinator::{
    fit_distributed, fit_distributed_tcp, fit_path_distributed, fit_path_distributed_tcp,
    DistributedConfig,
};
use dglmnet::data::{synth, Corpus, Dataset, SynthConfig};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::metrics;
use dglmnet::solver::compute::NativeCompute;
use dglmnet::solver::dglmnet as dg;
use dglmnet::solver::dglmnet::DGlmnetConfig;
use dglmnet::solver::path::{self, l1_path};

fn ds(n: usize, p: usize, seed: u64) -> Dataset {
    synth::epsilon_like(&SynthConfig { n, p, seed })
}

fn dist_cfg(nodes: usize, max_iters: usize, seed: u64) -> DistributedConfig {
    DistributedConfig {
        nodes,
        max_iters,
        eval_every: 0,
        tol: 0.0,
        seed,
        ..Default::default()
    }
}

fn ref_cfg(nodes: usize, max_iters: usize, seed: u64) -> DGlmnetConfig {
    DGlmnetConfig {
        nodes,
        max_iters,
        eval_every: 0,
        tol: 0.0,
        seed,
        ..Default::default()
    }
}

/// Both transports, M ∈ {1, 2, 4}: objective within 1e-6 of the reference
/// (in practice bit-for-bit up to collective summation order).
#[test]
fn distributed_matches_reference_over_both_transports() {
    let train = ds(150, 14, 21);
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.3, 0.1);
    for m in [1, 2, 4] {
        let seq = dg::fit(&train, &compute, &pen, &ref_cfg(m, 12, 21), None);
        let fab = fit_distributed(&train, None, &compute, &pen, &dist_cfg(m, 12, 21));
        let tcp = fit_distributed_tcp(&train, None, &compute, &pen, &dist_cfg(m, 12, 21))
            .expect("tcp cluster");
        for (name, got) in [("fabric", &fab.objective), ("tcp", &tcp.objective)] {
            let gap = (got - seq.objective).abs() / seq.objective.abs().max(1e-12);
            assert!(
                gap < 1e-6,
                "{name} M={m}: objective {} vs reference {} (gap {gap:.3e})",
                got,
                seq.objective
            );
        }
        for (a, b) in fab.beta.iter().zip(seq.beta.iter()) {
            assert!((a - b).abs() < 1e-8, "fabric M={m} beta: {a} vs {b}");
        }
        for (a, b) in tcp.beta.iter().zip(seq.beta.iter()) {
            assert!((a - b).abs() < 1e-8, "tcp M={m} beta: {a} vs {b}");
        }
    }
}

/// The clustered-partition column of the oracle matrix (job-spec v8): with
/// `--partition cluster` the block structure comes from the co-occurrence
/// clusterer instead of hashing, but it is resolved through the SAME seam
/// on both sides, so for M ∈ {2, 4} over BOTH transports the distributed
/// fit must still match the single-process reference within 1e-6 — on data
/// with planted correlation structure, where the clusterer actually
/// produces non-trivial blocks.
#[test]
fn clustered_partition_matches_reference_over_both_transports() {
    use dglmnet::sparse::PartitionStrategy;
    let train = synth::block_correlated(&SynthConfig { n: 160, p: 16, seed: 26 }, 4, 0.8);
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.3, 0.1);
    for m in [2, 4] {
        let mut rcfg = ref_cfg(m, 12, 26);
        rcfg.partition = PartitionStrategy::Clustered;
        let seq = dg::fit(&train, &compute, &pen, &rcfg, None);
        let mut dcfg = dist_cfg(m, 12, 26);
        dcfg.partition = PartitionStrategy::Clustered;
        let fab = fit_distributed(&train, None, &compute, &pen, &dcfg);
        let tcp = fit_distributed_tcp(&train, None, &compute, &pen, &dcfg)
            .expect("tcp clustered cluster");
        for (name, got) in [("fabric", &fab.objective), ("tcp", &tcp.objective)] {
            let gap = (got - seq.objective).abs() / seq.objective.abs().max(1e-12);
            assert!(
                gap < 1e-6,
                "{name} clustered M={m}: objective {} vs reference {} (gap {gap:.3e})",
                got,
                seq.objective
            );
        }
        for (a, b) in fab.beta.iter().zip(seq.beta.iter()) {
            assert!((a - b).abs() < 1e-8, "fabric clustered M={m} beta: {a} vs {b}");
        }
        for (a, b) in tcp.beta.iter().zip(seq.beta.iter()) {
            assert!((a - b).abs() < 1e-8, "tcp clustered M={m} beta: {a} vs {b}");
        }
        // The per-rank table must carry the cut diagnostic for every rank.
        for load in fab.per_rank.iter().chain(tcp.per_rank.iter()) {
            assert!(
                (0.0..=1.0).contains(&load.cut),
                "clustered M={m}: rank {} cut {} outside [0, 1]",
                load.rank,
                load.cut
            );
        }
    }
}

/// The ALB column of the oracle matrix: the asynchronous path has no
/// iterate-for-iterate oracle (fast ranks run extra passes, stragglers cut
/// short), but at convergence it must land on the same optimum — within a
/// quality tolerance of the high-precision reference — for M ∈ {2, 4} over
/// BOTH transports, so the per-iteration quorum protocol is guarded by the
/// same suite that pins BSP.
#[test]
fn alb_matches_reference_within_quality_tolerance_over_both_transports() {
    let train = ds(200, 16, 24);
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.3, 0.1);
    // High-precision single-process optimum f*.
    let f_star = dg::fit(
        &train,
        &compute,
        &pen,
        &DGlmnetConfig {
            nodes: 1,
            max_iters: 500,
            tol: 1e-13,
            patience: 5,
            eval_every: 0,
            seed: 24,
            ..Default::default()
        },
        None,
    )
    .objective;
    for m in [2, 4] {
        let mut cfg = dist_cfg(m, 200, 24);
        cfg.tol = 1e-10;
        cfg.patience = 3;
        cfg.alb_kappa = Some(0.75);
        let fab = fit_distributed(&train, None, &compute, &pen, &cfg);
        let tcp = fit_distributed_tcp(&train, None, &compute, &pen, &cfg).expect("tcp alb");
        for (name, got) in [("fabric", fab.objective), ("tcp", tcp.objective)] {
            let gap = (got - f_star) / f_star.abs().max(1e-12);
            assert!(
                gap < 1e-3,
                "{name} ALB M={m}: objective {got} vs reference {f_star} (gap {gap:.3e})"
            );
            assert!(
                gap > -1e-6,
                "{name} ALB M={m}: objective {got} below the reference optimum {f_star}"
            );
        }
    }
}

/// The L1 run's support (which features are exactly zero) survives the
/// distributed path on both transports.
#[test]
fn l1_sparsity_pattern_preserved() {
    let train = ds(200, 40, 22);
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::l1_only(4.0);
    let seq = dg::fit(&train, &compute, &pen, &ref_cfg(4, 20, 22), None);
    let seq_nnz = metrics::nnz_weights(&seq.beta);
    assert!(
        seq_nnz < 40,
        "reference must actually be sparse (nnz {seq_nnz})"
    );
    // Naive allreduce accumulates blocks in the same order as the
    // sequential reference, keeping the soft-threshold inputs bit-aligned.
    let mut cfg = dist_cfg(4, 20, 22);
    cfg.allreduce = dglmnet::cluster::AllReduceAlgo::Naive;
    let fab = fit_distributed(&train, None, &compute, &pen, &cfg);
    let tcp = fit_distributed_tcp(&train, None, &compute, &pen, &cfg).expect("tcp");
    for (name, beta) in [("fabric", &fab.beta), ("tcp", &tcp.beta)] {
        assert_eq!(
            metrics::nnz_weights(beta),
            seq_nnz,
            "{name}: nnz drifted from the reference"
        );
        for (j, (a, b)) in beta.iter().zip(seq.beta.iter()).enumerate() {
            // Support must match: a weight the reference zeroed out stays
            // zero on the distributed path (and vice versa).
            if (*a == 0.0) != (*b == 0.0) {
                panic!("{name}: support mismatch at feature {j} ({a} vs {b})");
            }
        }
    }
}

/// The λ-path column of the oracle matrix: the distributed warm-started
/// sweep (screening + validation selection included) must pick the SAME
/// best (λ, objective) as the single-process `l1_path` — per point within
/// 1e-6 — for M ∈ {2, 4} over BOTH transports. The transport is plumbing;
/// the §8.2 protocol may not change.
#[test]
fn distributed_path_matches_single_process_sweep() {
    let splits = Corpus::webspam_like(0.05, 31);
    let compute = NativeCompute::new(LossKind::Logistic);
    let lmax = path::lambda_max(&splits.train, LossKind::Logistic);
    let lambdas: Vec<f64> = (0..5).map(|k| lmax * 0.6f64.powi(k + 1)).collect();
    let l2 = 0.05;
    for m in [2, 4] {
        // Reference: the single-process sweep with the SAME block count and
        // partition seed — block structure is part of the iterate sequence.
        let ref_cfg = DGlmnetConfig {
            nodes: m,
            max_iters: 60,
            tol: 1e-9,
            eval_every: 0,
            seed: 31,
            ..Default::default()
        };
        let reference = l1_path(&splits, &compute, &lambdas, l2, &ref_cfg).unwrap();

        let mut dcfg = dist_cfg(m, 60, 31);
        dcfg.tol = 1e-9;
        let fab = fit_path_distributed(&splits, &compute, &lambdas, l2, &dcfg, true)
            .expect("fabric path");
        let tcp = fit_path_distributed_tcp(&splits, &compute, &lambdas, l2, &dcfg, true)
            .expect("tcp path");
        for (name, got) in [("fabric", &fab.path), ("tcp", &tcp.path)] {
            assert_eq!(
                got.best, reference.best,
                "{name} M={m}: best index {} vs reference {}",
                got.best, reference.best
            );
            assert_eq!(
                got.best_point().lambda1,
                reference.best_point().lambda1,
                "{name} M={m}: best λ drifted"
            );
            for (a, b) in got.points.iter().zip(reference.points.iter()) {
                let gap = (a.objective - b.objective).abs() / b.objective.abs().max(1e-12);
                assert!(
                    gap < 1e-6,
                    "{name} M={m} λ1={}: objective {} vs reference {} (gap {gap:.3e})",
                    a.lambda1,
                    a.objective,
                    b.objective
                );
            }
            let bgap = (got.best_point().objective - reference.best_point().objective).abs()
                / reference.best_point().objective.abs().max(1e-12);
            assert!(bgap < 1e-6, "{name} M={m}: best objective gap {bgap:.3e}");
        }
    }
}

/// The hybrid-threads column of the oracle matrix, part 1 — determinism:
/// the convex problem has ONE optimum, and the hybrid sub-block structure
/// only changes the block count (M·T blocks, Theorem 1 unchanged), so a
/// machine-converged T ∈ {2, 4} fit must land on the T=1 objective to
/// 1e-12 on BOTH transports. The ordered reduction makes each run exact:
/// repeating a hybrid fit reproduces β bit-for-bit regardless of pool
/// scheduling.
#[test]
fn hybrid_threads_match_t1_objective_at_machine_convergence() {
    // Small, strongly convex (ridge + ν), well conditioned: every variant
    // reaches machine convergence well inside the iteration budget.
    let train = ds(100, 12, 27);
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.1, 0.5);
    let converged = |threads: usize, tcp: bool| {
        let cfg = DistributedConfig {
            nodes: 2,
            threads,
            max_iters: 400,
            tol: 0.0, // run the full budget: both variants end machine-converged
            eval_every: 0,
            seed: 27,
            ..Default::default()
        };
        if tcp {
            fit_distributed_tcp(&train, None, &compute, &pen, &cfg)
                .expect("tcp hybrid")
                .objective
        } else {
            fit_distributed(&train, None, &compute, &pen, &cfg).objective
        }
    };
    for tcp in [false, true] {
        let name = if tcp { "tcp" } else { "fabric" };
        let f1 = converged(1, tcp);
        for threads in [2, 4] {
            let ft = converged(threads, tcp);
            let gap = (ft - f1).abs() / f1.abs().max(1e-12);
            assert!(
                gap < 1e-12,
                "{name} T={threads}: objective {ft} vs T=1 {f1} (gap {gap:.3e})"
            );
        }
    }
}

/// Part 2 — scheduling-independence as a property: over random problems,
/// two runs of the same hybrid fit are bit-identical (β and objective),
/// and the converged objective agrees with T=1 to 1e-12.
#[test]
fn prop_hybrid_fit_deterministic_and_objective_matches_t1() {
    dglmnet::util::prop::check("hybrid fit deterministic + T-invariant optimum", 5, |rng| {
        let n = 60 + rng.below(60);
        let p = 8 + rng.below(8);
        let train = ds(n, p, rng.next_u64());
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.05 + rng.range_f64(0.0, 0.2), 0.3 + rng.range_f64(0.0, 0.5));
        let threads = if rng.bernoulli(0.5) { 2 } else { 4 };
        let fit_with = |t: usize| {
            let cfg = DistributedConfig {
                nodes: 2,
                threads: t,
                max_iters: 250,
                tol: 0.0,
                eval_every: 0,
                seed: 9,
                ..Default::default()
            };
            fit_distributed(&train, None, &compute, &pen, &cfg)
        };
        let a = fit_with(threads);
        let b = fit_with(threads);
        if a.beta != b.beta {
            return Err(format!("T={threads}: repeated fit changed β"));
        }
        if a.objective != b.objective {
            return Err(format!("T={threads}: repeated fit changed the objective"));
        }
        let f1 = fit_with(1).objective;
        let gap = (a.objective - f1).abs() / f1.abs().max(1e-12);
        if gap < 1e-12 {
            Ok(())
        } else {
            Err(format!(
                "T={threads}: converged objective {} vs T=1 {f1} (gap {gap:.3e})",
                a.objective
            ))
        }
    });
}

/// Part 3 — the M × T quality grid: hybrid fits for M ∈ {2, 4} × T ∈ {1, 4}
/// must land within a quality tolerance of the high-precision
/// single-process reference optimum over BOTH transports (the ALB column's
/// contract, now with intra-rank threads in the matrix).
#[test]
fn hybrid_threads_grid_matches_reference_over_both_transports() {
    let train = ds(160, 14, 29);
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.2, 0.1);
    let f_star = dg::fit(
        &train,
        &compute,
        &pen,
        &DGlmnetConfig {
            nodes: 1,
            max_iters: 500,
            tol: 1e-13,
            patience: 5,
            eval_every: 0,
            seed: 29,
            ..Default::default()
        },
        None,
    )
    .objective;
    for m in [2, 4] {
        for threads in [1, 4] {
            let cfg = DistributedConfig {
                nodes: m,
                threads,
                max_iters: 200,
                tol: 1e-10,
                patience: 3,
                eval_every: 0,
                seed: 29,
                ..Default::default()
            };
            let fab = fit_distributed(&train, None, &compute, &pen, &cfg);
            let tcp =
                fit_distributed_tcp(&train, None, &compute, &pen, &cfg).expect("tcp hybrid");
            for load in fab.per_rank.iter().chain(tcp.per_rank.iter()) {
                assert!(
                    load.threads <= threads && load.threads >= 1,
                    "M={m} T={threads}: rank {} reported {} threads",
                    load.rank,
                    load.threads
                );
            }
            for (name, got) in [("fabric", fab.objective), ("tcp", tcp.objective)] {
                let gap = (got - f_star) / f_star.abs().max(1e-12);
                assert!(
                    gap < 1e-3,
                    "{name} M={m} T={threads}: objective {got} vs reference {f_star} (gap {gap:.3e})"
                );
                assert!(
                    gap > -1e-6,
                    "{name} M={m} T={threads}: objective {got} below the optimum {f_star}"
                );
            }
        }
    }
}

/// Table 2: ring-allreduce traffic per iteration stays ≈ Mn doubles
/// (2·8·n bytes out per node per XΔβ allreduce) on the TCP backend too.
#[test]
fn tcp_comm_bytes_per_iteration_close_to_mn_doubles() {
    let n = 400;
    let m = 4;
    let iters = 5;
    let train = ds(n, 30, 23);
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.2, 0.0);
    let fit = fit_distributed_tcp(&train, None, &compute, &pen, &dist_cfg(m, iters, 23))
        .expect("tcp cluster");
    assert_eq!(fit.iters, iters);
    let per_iter = fit.comm_bytes as f64 / iters as f64;
    // Dominant term: the XΔβ ring allreduce, ~2n doubles out per node
    // → 16·n·M bytes per iteration; headers, the scalar collectives and
    // the line-search reg ray add a bounded overhead on top.
    let expected = 16.0 * n as f64 * m as f64;
    assert!(
        per_iter > 0.5 * expected && per_iter < 3.0 * expected,
        "per-iteration TCP traffic {per_iter:.0} B vs expected ≈{expected:.0} B"
    );
}

// ---------------------------------------------------------------------------
// True multi-process end-to-end: 3 `dglmnet worker` processes + 1
// coordinator process on loopback, checked against the in-process reference.
// ---------------------------------------------------------------------------

#[test]
fn multiprocess_cluster_end_to_end() {
    use std::io::BufRead;
    use std::process::{Child, Command, Stdio};

    let bin = env!("CARGO_BIN_EXE_dglmnet");
    let mut workers: Vec<Child> = Vec::new();
    let mut addrs: Vec<String> = Vec::new();

    // Belt-and-braces cleanup: kill leftover workers on any exit path.
    struct Cleanup<'a>(&'a mut Vec<Child>);
    impl Drop for Cleanup<'_> {
        fn drop(&mut self) {
            for c in self.0.iter_mut() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }

    for _ in 0..3 {
        let mut child = Command::new(bin)
            .args(["worker", "--listen", "127.0.0.1:0"])
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn worker");
        // The worker prints its resolved address before accepting.
        let stdout = child.stdout.take().expect("worker stdout");
        let mut reader = std::io::BufReader::new(stdout);
        let mut line = String::new();
        reader.read_line(&mut line).expect("worker banner");
        let addr = line
            .trim()
            .strip_prefix("worker: listening on ")
            .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
            .to_string();
        addrs.push(addr);
        // Keep draining the pipe so the worker never blocks on a full one.
        std::thread::spawn(move || {
            let mut sink = String::new();
            loop {
                sink.clear();
                if reader.read_line(&mut sink).unwrap_or(0) == 0 {
                    break;
                }
            }
        });
        workers.push(child);
    }
    let cleanup = Cleanup(&mut workers);

    let trace_path = std::env::temp_dir().join(format!(
        "dglmnet_cluster_e2e_{}.json",
        std::process::id()
    ));
    let cluster = format!("127.0.0.1:0,{}", addrs.join(","));
    let out = Command::new(bin)
        .args([
            "train",
            "--cluster",
            &cluster,
            "--dataset",
            "epsilon_like",
            "--scale",
            "0.05",
            "--seed",
            "1",
            "--loss",
            "logistic",
            "--l1",
            "0.5",
            "--l2",
            "0.0",
            "--max-iters",
            "8",
            "--eval-every",
            "0",
            "--trace",
            trace_path.to_str().unwrap(),
        ])
        .output()
        .expect("run coordinator");
    assert!(
        out.status.success(),
        "coordinator failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    drop(cleanup); // workers have exited with the job; reap them

    // Final objective from the trace JSON the coordinator wrote.
    let text = std::fs::read_to_string(&trace_path).expect("trace file");
    std::fs::remove_file(&trace_path).ok();
    let trace = dglmnet::util::json::parse(&text).expect("trace json");
    let objectives = match trace.get("objective") {
        Some(dglmnet::util::json::Json::Arr(xs)) => {
            xs.iter().filter_map(|x| x.as_f64()).collect::<Vec<_>>()
        }
        _ => panic!("trace has no objective series"),
    };
    let cluster_obj = *objectives.last().expect("non-empty objective series");

    // In-process reference with the identical recipe: same dataset, seed,
    // M = 4 blocks, and the coordinator's default tol/patience (1e-7 / 2).
    let splits = dglmnet::harness::load_splits("epsilon_like", 0.05, 1).expect("splits");
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.5, 0.0);
    let seq = dg::fit(
        &splits.train,
        &compute,
        &pen,
        &DGlmnetConfig {
            nodes: 4,
            max_iters: 8,
            tol: 1e-7,
            patience: 2,
            seed: 1,
            eval_every: 0,
            ..Default::default()
        },
        None,
    )
    .objective;
    let gap = (cluster_obj - seq).abs() / seq.abs().max(1e-12);
    assert!(
        gap < 1e-6,
        "4-process cluster objective {cluster_obj} vs reference {seq} (gap {gap:.3e})"
    );
}

// ---------------------------------------------------------------------------
// Fast-math kernel tier (job-spec v9): the reordered-accumulation kernels
// are NOT bit-reproducible, so they get their own tolerance column in the
// oracle matrix — and their own subprocess tests, because the kernel mode
// is process-global and must never be flipped inside the test runner.
// ---------------------------------------------------------------------------

/// Spawn `n` `dglmnet worker` subprocesses (with `extra` CLI args appended),
/// returning the children plus their resolved listen addresses. Each
/// worker's stdout is drained on a background thread.
#[allow(clippy::type_complexity)]
fn spawn_worker_procs(
    n: usize,
    extra: &[&str],
) -> (Vec<std::process::Child>, Vec<String>) {
    use std::io::BufRead;
    use std::process::{Command, Stdio};
    let bin = env!("CARGO_BIN_EXE_dglmnet");
    let mut workers = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..n {
        let mut args = vec!["worker", "--listen", "127.0.0.1:0"];
        args.extend_from_slice(extra);
        let mut child = Command::new(bin)
            .args(&args)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn worker");
        let stdout = child.stdout.take().expect("worker stdout");
        let mut reader = std::io::BufReader::new(stdout);
        let mut line = String::new();
        reader.read_line(&mut line).expect("worker banner");
        let addr = line
            .trim()
            .strip_prefix("worker: listening on ")
            .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
            .to_string();
        addrs.push(addr);
        std::thread::spawn(move || {
            let mut sink = String::new();
            loop {
                sink.clear();
                if reader.read_line(&mut sink).unwrap_or(0) == 0 {
                    break;
                }
            }
        });
        workers.push(child);
    }
    (workers, addrs)
}

fn kill_workers(mut workers: Vec<std::process::Child>) {
    for c in workers.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// A `--fast-math` cluster reassociates every reduction, so it has no
/// bit-for-bit oracle — but it must land within the documented end-to-end
/// tolerance tier (~1e-4 relative) of the strict in-process reference on
/// the identical recipe. This is the tolerance the DESIGN.md §Kernels tier
/// table promises users of the flag.
#[test]
fn fast_math_cluster_tracks_strict_reference_within_tolerance() {
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_dglmnet");
    let (workers, addrs) = spawn_worker_procs(2, &[]);

    let trace_path = std::env::temp_dir().join(format!(
        "dglmnet_fastmath_e2e_{}.json",
        std::process::id()
    ));
    let cluster = format!("127.0.0.1:0,{}", addrs.join(","));
    let out = Command::new(bin)
        .args([
            "train",
            "--cluster",
            &cluster,
            "--fast-math",
            "--dataset",
            "epsilon_like",
            "--scale",
            "0.05",
            "--seed",
            "1",
            "--loss",
            "logistic",
            "--l1",
            "0.5",
            "--l2",
            "0.0",
            "--max-iters",
            "8",
            "--eval-every",
            "0",
            "--trace",
            trace_path.to_str().unwrap(),
        ])
        .output()
        .expect("run fast-math coordinator");
    kill_workers(workers);
    assert!(
        out.status.success(),
        "fast-math coordinator failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("kernels=fast-math"),
        "train banner should advertise the kernel tier:\n{stdout}"
    );

    let text = std::fs::read_to_string(&trace_path).expect("trace file");
    std::fs::remove_file(&trace_path).ok();
    let trace = dglmnet::util::json::parse(&text).expect("trace json");
    let objectives = match trace.get("objective") {
        Some(dglmnet::util::json::Json::Arr(xs)) => {
            xs.iter().filter_map(|x| x.as_f64()).collect::<Vec<_>>()
        }
        _ => panic!("trace has no objective series"),
    };
    let fast_obj = *objectives.last().expect("non-empty objective series");

    // Strict in-process reference on the identical recipe (M = 3 blocks).
    let splits = dglmnet::harness::load_splits("epsilon_like", 0.05, 1).expect("splits");
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.5, 0.0);
    let seq = dg::fit(
        &splits.train,
        &compute,
        &pen,
        &DGlmnetConfig {
            nodes: 3,
            max_iters: 8,
            tol: 1e-7,
            patience: 2,
            seed: 1,
            eval_every: 0,
            ..Default::default()
        },
        None,
    )
    .objective;
    let gap = (fast_obj - seq).abs() / seq.abs().max(1e-12);
    assert!(
        gap < 1e-4,
        "fast-math cluster objective {fast_obj} vs strict reference {seq} (gap {gap:.3e}) \
         exceeds the end-to-end tolerance tier"
    );
}

/// A worker pinned to strict kernels (`--fast-math off`) must REJECT a
/// `--fast-math` job with a pointed error instead of silently solving with
/// the other tier — mixing kernel modes across ranks would corrupt the
/// collectives' tolerance story without any visible symptom.
#[test]
fn worker_pinned_to_strict_rejects_fast_math_job() {
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_dglmnet");
    let (workers, addrs) = spawn_worker_procs(1, &["--fast-math", "off"]);

    let cluster = format!("127.0.0.1:0,{}", addrs.join(","));
    let out = Command::new(bin)
        .args([
            "train",
            "--cluster",
            &cluster,
            "--fast-math",
            "--dataset",
            "epsilon_like",
            "--scale",
            "0.05",
            "--seed",
            "1",
            "--max-iters",
            "2",
            "--eval-every",
            "0",
        ])
        .output()
        .expect("run mismatched coordinator");
    kill_workers(workers);
    assert!(
        !out.status.success(),
        "coordinator must fail when a worker rejects the kernel tier:\nstdout: {}",
        String::from_utf8_lossy(&out.stdout)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("rejected the job"),
        "stderr should carry the ship-job rejection:\n{stderr}"
    );
    assert!(
        stderr.contains("pinned to strict kernels"),
        "stderr should carry the worker's pointed mismatch error:\n{stderr}"
    );
}
