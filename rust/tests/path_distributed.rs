//! End-to-end distributed λ-path sweep: real `dglmnet worker` processes
//! plus a `dglmnet path --cluster` coordinator on loopback, checked against
//! the single-process `l1_path` reference — the §8.2 hyper-parameter search
//! as an actual multi-process workload (job-spec v3 `path` mode).

use dglmnet::glm::loss::LossKind;
use dglmnet::solver::compute::NativeCompute;
use dglmnet::solver::dglmnet::DGlmnetConfig;
use dglmnet::solver::path::l1_path;

#[test]
fn multiprocess_path_sweep_end_to_end() {
    use std::io::BufRead;
    use std::process::{Child, Command, Stdio};

    let bin = env!("CARGO_BIN_EXE_dglmnet");
    let mut workers: Vec<Child> = Vec::new();
    let mut addrs: Vec<String> = Vec::new();

    // Belt-and-braces cleanup: kill leftover workers on any exit path.
    struct Cleanup<'a>(&'a mut Vec<Child>);
    impl Drop for Cleanup<'_> {
        fn drop(&mut self) {
            for c in self.0.iter_mut() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }

    for _ in 0..2 {
        let mut child = Command::new(bin)
            .args(["worker", "--listen", "127.0.0.1:0"])
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn worker");
        let stdout = child.stdout.take().expect("worker stdout");
        let mut reader = std::io::BufReader::new(stdout);
        let mut line = String::new();
        reader.read_line(&mut line).expect("worker banner");
        let addr = line
            .trim()
            .strip_prefix("worker: listening on ")
            .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
            .to_string();
        addrs.push(addr);
        // Keep draining the pipe so the worker never blocks on a full one.
        std::thread::spawn(move || {
            let mut sink = String::new();
            loop {
                sink.clear();
                if reader.read_line(&mut sink).unwrap_or(0) == 0 {
                    break;
                }
            }
        });
        workers.push(child);
    }
    let cleanup = Cleanup(&mut workers);

    let cluster = format!("127.0.0.1:0,{}", addrs.join(","));
    let out = Command::new(bin)
        .args([
            "path",
            "--cluster",
            &cluster,
            "--dataset",
            "epsilon_like",
            "--scale",
            "0.05",
            "--seed",
            "1",
            "--loss",
            "logistic",
            "--lambdas",
            "2.0,0.5,0.125",
            "--l2",
            "0.0",
            "--max-iters",
            "8",
        ])
        .output()
        .expect("run path coordinator");
    assert!(
        out.status.success(),
        "path coordinator failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    drop(cleanup); // workers have exited with the job; reap them

    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(
        stdout.contains("<- best"),
        "per-λ table should mark the best point:\n{stdout}"
    );

    // Parse the "best: λ1=… | objective=…" summary line.
    let best_line = stdout
        .lines()
        .find(|l| l.starts_with("best: "))
        .unwrap_or_else(|| panic!("no best line in:\n{stdout}"));
    let field = |key: &str| -> f64 {
        let start = best_line
            .find(key)
            .unwrap_or_else(|| panic!("no '{key}' in {best_line:?}"))
            + key.len();
        best_line[start..]
            .split(|c: char| c == ' ' || c == '|')
            .next()
            .and_then(|tok| tok.parse().ok())
            .unwrap_or_else(|| panic!("unparsable '{key}' in {best_line:?}"))
    };
    let got_lambda = field("λ1=");
    let got_objective = field("objective=");

    // Single-process reference with the identical recipe: same dataset,
    // seed, M = 3 blocks, and the path CLI's tol/patience (1e-7 / 2).
    let splits = dglmnet::harness::load_splits("epsilon_like", 0.05, 1).expect("splits");
    let compute = NativeCompute::new(LossKind::Logistic);
    let reference = l1_path(
        &splits,
        &compute,
        &[2.0, 0.5, 0.125],
        0.0,
        &DGlmnetConfig {
            nodes: 3,
            max_iters: 8,
            tol: 1e-7,
            patience: 2,
            seed: 1,
            eval_every: 0,
            ..Default::default()
        },
    )
    .expect("reference sweep");
    let want = reference.best_point();
    assert_eq!(
        got_lambda, want.lambda1,
        "3-process sweep picked λ1={got_lambda}, reference {}",
        want.lambda1
    );
    // The CLI prints the objective with 6 decimals; compare at that grain.
    let gap = (got_objective - want.objective).abs() / want.objective.abs().max(1e-12);
    assert!(
        gap < 1e-4,
        "3-process best objective {got_objective} vs reference {} (gap {gap:.3e})",
        want.objective
    );
}
