//! Chaos suite for the elastic fault-tolerance layer (protocol v6): peer
//! death surfaces as a typed [`TransportError`] instead of a panic, the
//! per-iteration checkpoints make it survivable, and a `--rejoin` worker
//! picks the job back up inside the coordinator's recovery window.
//!
//! The clusters here are real: every rank runs the actual process entry
//! points over loopback sockets, with the death injected through the same
//! `WorkerOverrides::die_after_iters` knob the CLI exposes as `--die-after`.

use dglmnet::cluster::checkpoint::{Checkpoint, RankBlock, ResumePoint};
use dglmnet::cluster::process::{
    run_worker_on, run_worker_rejoin, train_cluster, JobMode, JobSpec, WorkerOverrides,
};
use dglmnet::cluster::{AllReduceAlgo, TransportError};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::harness;
use dglmnet::solver::compute::NativeCompute;
use dglmnet::solver::dglmnet as dg;
use dglmnet::solver::dglmnet::DGlmnetConfig;
use dglmnet::util::prop;
use std::net::TcpListener;

/// The cluster-oracle job (epsilon_like @ 0.05, 3 ranks, 7 BSP iterations)
/// with the fault-tolerance fields left off — each test flips on what it
/// needs.
fn chaos_spec(cluster: Vec<String>) -> JobSpec {
    JobSpec {
        rank: 0,
        cluster,
        dataset: "epsilon_like".into(),
        scale: 0.05,
        seed: 3,
        loss: "logistic".into(),
        l1: 0.5,
        l2: 0.1,
        max_iters: 7,
        mu0: 1.0,
        adaptive_mu: true,
        tol: 1e-7,
        patience: 2,
        eval_every: 0,
        allreduce: AllReduceAlgo::Ring,
        alb_kappa: None,
        max_passes: 4,
        chunk: 64,
        virtual_time: false,
        straggler_delays: Vec::new(),
        slow_factors: Vec::new(),
        mode: JobMode::Train,
        lambda_grid: Vec::new(),
        screen: false,
        threads: Vec::new(),
        checkpoint_dir: None,
        checkpoint_every: 0,
        resume: false,
        partition: None,
        fast_math: false,
    }
}

/// Without checkpoints a dead rank is fatal — but it must die as a typed
/// transport error on every rank, never a panic or a hang: the coordinator
/// job fails with a downcastable [`TransportError`], the chaos rank reports
/// its own injected death, and the innocent bystander rank sees its peer
/// disappear mid-collective.
#[test]
fn peer_death_without_checkpoints_is_a_typed_transport_error() {
    let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let w2 = TcpListener::bind("127.0.0.1:0").unwrap();
    let a1 = w1.local_addr().unwrap().to_string();
    let a2 = w2.local_addr().unwrap().to_string();
    let s = chaos_spec(vec!["127.0.0.1:0".into(), a1, a2]);

    let chaos = WorkerOverrides { die_after_iters: Some(1), ..Default::default() };
    let h1 = std::thread::spawn(move || run_worker_on(w1, chaos));
    let h2 = std::thread::spawn(move || run_worker_on(w2, WorkerOverrides::default()));

    let err = train_cluster(&s, None).expect_err("a dead rank must fail the job");
    assert!(
        err.downcast_ref::<TransportError>().is_some(),
        "coordinator error is untyped: {err:#}"
    );

    let e1 = h1.join().unwrap().expect_err("rank 1 was told to die");
    assert_eq!(
        e1.downcast_ref::<TransportError>(),
        Some(&TransportError::PeerGone { peer: 1 }),
        "rank 1 must report its own injected death"
    );
    let e2 = h2.join().unwrap().expect_err("rank 2 lost its peer");
    assert!(
        e2.downcast_ref::<TransportError>().is_some(),
        "rank 2 error is untyped: {e2:#}"
    );
}

/// The headline recovery scenario: rank 1 crashes at the start of iteration
/// 2, comes back on the same port with the chaos knob removed (a restarted
/// `--rejoin` worker), rank 2 never exits (its `--rejoin` loop sends it
/// back to the accept loop where it answers the recovery probe), and the
/// coordinator re-ships a resume job from the iteration-1 checkpoint. The
/// resumed fit must land on the uninterrupted single-process optimum.
#[test]
fn checkpointed_cluster_survives_death_and_a_rejoining_worker_resumes() {
    let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let w1_back = w1.try_clone().unwrap(); // the restart keeps the port alive
    let w2 = TcpListener::bind("127.0.0.1:0").unwrap();
    let a1 = w1.local_addr().unwrap().to_string();
    let a2 = w2.local_addr().unwrap().to_string();

    let dir = harness::checkpoint_dir_for("chaos-rejoin");
    let mut s = chaos_spec(vec!["127.0.0.1:0".into(), a1, a2]);
    s.checkpoint_dir = Some(dir.to_string_lossy().to_string());
    s.checkpoint_every = 1;

    let h1 = std::thread::spawn(move || {
        let chaos = WorkerOverrides { die_after_iters: Some(1), ..Default::default() };
        let err = run_worker_on(w1, chaos).expect_err("rank 1 was told to die");
        assert!(err.downcast_ref::<TransportError>().is_some(), "{err:#}");
        run_worker_rejoin(w1_back, WorkerOverrides::default()).unwrap()
    });
    let h2 = std::thread::spawn(move || {
        run_worker_rejoin(w2, WorkerOverrides::default()).unwrap()
    });

    let fit = train_cluster(&s, None).expect("recovery must complete the job");
    assert_eq!(h1.join().unwrap(), 1);
    assert_eq!(h2.join().unwrap(), 2);

    assert!(
        dir.read_dir().unwrap().next().is_some(),
        "no checkpoint files were written to {dir:?}"
    );
    std::fs::remove_dir_all(&dir).ok();

    // Resume restores β, margins, cursors, μ and the stall counter
    // bit-identically from the checkpoint, so the recovered run solves the
    // same optimization as an uninterrupted one — hold it to the cluster
    // oracle's bound against the single-process reference.
    let splits = harness::load_splits("epsilon_like", 0.05, 3).unwrap();
    let seq = dg::fit(
        &splits.train,
        &NativeCompute::new(LossKind::Logistic),
        &ElasticNet::new(0.5, 0.1),
        &DGlmnetConfig {
            nodes: 3,
            max_iters: 7,
            tol: 1e-7,
            patience: 2,
            seed: 3,
            eval_every: 0,
            ..Default::default()
        },
        None,
    );
    assert!(
        (fit.objective - seq.objective).abs() / seq.objective.abs() < 1e-6,
        "resumed cluster objective {} vs uninterrupted reference {}",
        fit.objective,
        seq.objective
    );
}

/// Checkpoints are exact state transfer, not approximations: a write →
/// `latest` → `resume_point` → `flatten` → `unflatten` round trip must
/// preserve every f64 bit for every rank, or "resume" would silently mean
/// "restart from somewhere nearby".
#[test]
fn checkpoint_roundtrip_is_bit_identical() {
    prop::check("checkpoint round-trip preserves every bit", 40, |rng| {
        let m = 1 + rng.below(4);
        let ranks: Vec<RankBlock> = (0..m)
            .map(|_| {
                let k = 1 + rng.below(3);
                RankBlock {
                    cursor: rng.below(1000),
                    sub_cursors: (0..k).map(|_| rng.below(1000)).collect(),
                    beta: prop::dense_vec(rng, 1 + rng.below(6), 10.0),
                }
            })
            .collect();
        let ck = Checkpoint {
            iter: 1 + rng.below(500),
            stall: rng.below(5),
            mu: rng.range_f64(1e-9, 64.0),
            f_cur: rng.range_f64(-1e6, 1e6),
            lambda_idx: rng.below(128) as u64,
            margins: prop::dense_vec(rng, 1 + rng.below(8), 100.0),
            ranks,
        };

        let dir = harness::checkpoint_dir_for("chaos-roundtrip");
        let path = ck.write_atomic(&dir).map_err(|e| e.to_string())?;
        let (latest_path, back) = Checkpoint::latest(&dir).ok_or("latest() found nothing")?;
        std::fs::remove_dir_all(&dir).ok();
        if latest_path != path {
            return Err(format!("latest picked {latest_path:?}, wrote {path:?}"));
        }

        if back.iter != ck.iter || back.stall != ck.stall || back.lambda_idx != ck.lambda_idx {
            return Err("header drift across the round trip".into());
        }
        if back.mu.to_bits() != ck.mu.to_bits() || back.f_cur.to_bits() != ck.f_cur.to_bits() {
            return Err("scalar drift across the round trip".into());
        }
        if back.margins.len() != ck.margins.len()
            || back.margins.iter().zip(&ck.margins).any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err("margin drift across the round trip".into());
        }

        for r in 0..m {
            let fa = ck.resume_point(r).flatten();
            let fb = back.resume_point(r).flatten();
            if fa.len() != fb.len() {
                return Err(format!("rank {r}: resume point length {} vs {}", fa.len(), fb.len()));
            }
            if fa.iter().zip(&fb).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("rank {r}: resume point bit drift"));
            }
            let again = ResumePoint::unflatten(&fa)?.flatten();
            if again.iter().zip(&fa).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("rank {r}: unflatten∘flatten is not the identity"));
            }
        }
        Ok(())
    });
}
