//! Integration tests across modules: full training pipelines, XLA-vs-native
//! engine parity end-to-end, straggler/failure injection, and cross-solver
//! agreement on the shared optimum.

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::cluster::allreduce::AllReduceAlgo;
use dglmnet::cluster::fabric::NetworkModel;
use dglmnet::coordinator::{fit_distributed, DistributedConfig};
use dglmnet::data::{synth, Corpus, Dataset, SynthConfig};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::metrics;
use dglmnet::runtime::{Runtime, XlaCompute};
use dglmnet::solver::admm::{fit_admm, AdmmConfig};
use dglmnet::solver::compute::NativeCompute;
use dglmnet::solver::dglmnet as dg;
use dglmnet::solver::dglmnet::DGlmnetConfig;
use dglmnet::solver::lbfgs::{fit_lbfgs, LbfgsConfig};
use dglmnet::sparse::libsvm;
use std::time::Duration;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// The whole pipeline on a libsvm file round-trip: write a synthetic corpus
/// to disk, read it back, train, evaluate.
#[test]
fn libsvm_roundtrip_training_pipeline() {
    let splits = Corpus::webspam_like(0.05, 3);
    let dir = std::env::temp_dir().join(format!("dglmnet_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("train.libsvm");
    libsvm::write_file(
        &path,
        &libsvm::LibsvmData {
            x: splits.train.x.clone(),
            y: splits.train.y.clone(),
        },
    )
    .unwrap();
    let back = libsvm::read_file(&path).unwrap();
    let ds = Dataset::new("roundtrip", back.x, back.y);
    assert_eq!(ds.n(), splits.train.n());
    let compute = NativeCompute::new(LossKind::Logistic);
    let cfg = DistributedConfig {
        nodes: 4,
        max_iters: 10,
        eval_every: 0,
        ..Default::default()
    };
    let fit = fit_distributed(&ds, None, &compute, &ElasticNet::new(0.5, 0.1), &cfg);
    assert!(fit.objective.is_finite());
    std::fs::remove_dir_all(&dir).ok();
}

/// End-to-end XLA-engine training must match the native engine exactly
/// (same iterates: the compute seam is numerically equivalent).
#[test]
fn xla_engine_end_to_end_parity() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let splits = Corpus::clickstream(0.05, 5);
    let pen = ElasticNet::l1_only(0.5);
    let cfg = DistributedConfig {
        nodes: 4,
        max_iters: 8,
        eval_every: 0,
        tol: 0.0,
        ..Default::default()
    };
    let rt = Runtime::start("artifacts").expect("runtime");
    for kind in [LossKind::Logistic, LossKind::Squared, LossKind::Probit] {
        let xla = XlaCompute::new(rt.handle(), kind);
        let nat = NativeCompute::new(kind);
        let fx = fit_distributed(&splits.train, None, &xla, &pen, &cfg);
        let fn_ = fit_distributed(&splits.train, None, &nat, &pen, &cfg);
        let gap = (fx.objective - fn_.objective).abs() / fn_.objective.abs().max(1e-12);
        assert!(
            gap < 1e-6,
            "{kind:?}: xla {} vs native {}",
            fx.objective,
            fn_.objective
        );
        // nnz patterns must agree too (the soft-threshold decisions).
        assert_eq!(
            metrics::nnz_weights(&fx.beta),
            metrics::nnz_weights(&fn_.beta),
            "{kind:?} nnz mismatch"
        );
    }
}

/// All four solver families agree on the (unique) L2 optimum.
#[test]
fn solvers_agree_on_l2_optimum() {
    let ds = synth::epsilon_like(&SynthConfig {
        n: 150,
        p: 10,
        seed: 7,
    });
    let l2 = 0.5;
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::l2_only(l2);

    let dg = dg::fit(
        &ds,
        &compute,
        &pen,
        &DGlmnetConfig {
            nodes: 3,
            max_iters: 400,
            tol: 1e-13,
            patience: 3,
            eval_every: 0,
            ..Default::default()
        },
        None,
    );
    let admm = fit_admm(
        &ds,
        None,
        &AdmmConfig {
            kind: LossKind::Logistic,
            l1: 0.0,
            l2,
            nodes: 3,
            max_iters: 400,
            shooting_passes: 8,
            eval_every: 0,
            ..Default::default()
        },
    );
    let lbfgs = fit_lbfgs(
        &ds,
        None,
        &LbfgsConfig {
            kind: LossKind::Logistic,
            l2,
            nodes: 3,
            max_iters: 200,
            tol: 1e-13,
            warmstart_epochs: 0,
            eval_every: 0,
            ..Default::default()
        },
    );
    let f = dg.objective;
    assert!((admm.objective - f).abs() / f < 5e-3, "admm {} vs {f}", admm.objective);
    assert!((lbfgs.objective - f).abs() / f < 1e-5, "lbfgs {} vs {f}", lbfgs.objective);
}

/// ALB under a pathological straggler (one node 100× slower) still converges
/// to the same optimum and cuts wall-clock massively.
#[test]
fn alb_failure_injection_straggler() {
    let ds = synth::webspam_like(
        &SynthConfig {
            n: 600,
            p: 2000,
            seed: 8,
        },
        40,
    );
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::l1_only(0.5);
    let mut delays = vec![Duration::ZERO; 4];
    delays[1] = Duration::from_millis(120);
    let base = DistributedConfig {
        nodes: 4,
        max_iters: 6,
        tol: 0.0,
        eval_every: 0,
        straggler_delays: delays,
        chunk: 8,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let bsp = fit_distributed(&ds, None, &compute, &pen, &base);
    let bsp_time = t0.elapsed();
    let t1 = std::time::Instant::now();
    let alb = fit_distributed(
        &ds,
        None,
        &compute,
        &pen,
        &DistributedConfig {
            alb_kappa: Some(0.75),
            ..base
        },
    );
    let alb_time = t1.elapsed();
    assert!(
        alb_time.as_secs_f64() < 0.7 * bsp_time.as_secs_f64(),
        "ALB {alb_time:?} should be well under BSP {bsp_time:?}"
    );
    // Same ballpark objective after equal iteration counts.
    assert!(
        (alb.objective - bsp.objective).abs() / bsp.objective < 0.2,
        "alb {} vs bsp {}",
        alb.objective,
        bsp.objective
    );
}

/// A lossy-ish network model (sleep per message) slows training but does not
/// change the result: the collectives are exact regardless of the model.
#[test]
fn network_model_changes_time_not_result() {
    let ds = synth::epsilon_like(&SynthConfig {
        n: 80,
        p: 8,
        seed: 9,
    });
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.2, 0.1);
    let fast_cfg = DistributedConfig {
        nodes: 3,
        max_iters: 5,
        tol: 0.0,
        eval_every: 0,
        ..Default::default()
    };
    let slow_cfg = DistributedConfig {
        network: NetworkModel {
            latency_us_per_msg: 300.0,
            ns_per_byte: 10.0,
            sleep: true,
        },
        ..fast_cfg.clone()
    };
    let fast = fit_distributed(&ds, None, &compute, &pen, &fast_cfg);
    let slow = fit_distributed(&ds, None, &compute, &pen, &slow_cfg);
    assert_eq!(fast.beta, slow.beta, "network model must not change math");
    assert!(slow.sim_wire_secs > 0.0);
}

/// Naive and ring AllReduce produce identical training trajectories.
#[test]
fn allreduce_algo_invariance() {
    let ds = synth::clickstream(
        &SynthConfig {
            n: 400,
            p: 600,
            seed: 10,
        },
        6,
        0.1,
    );
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::l1_only(0.3);
    let run = |algo| {
        let cfg = DistributedConfig {
            nodes: 4,
            max_iters: 6,
            tol: 0.0,
            eval_every: 0,
            allreduce: algo,
            ..Default::default()
        };
        fit_distributed(&ds, None, &compute, &pen, &cfg)
    };
    let a = run(AllReduceAlgo::Naive);
    let b = run(AllReduceAlgo::Ring);
    // Ring sums chunks in a different order → tiny fp differences are
    // possible; they must stay at rounding level.
    for (x, y) in a.beta.iter().zip(b.beta.iter()) {
        assert!((x - y).abs() < 1e-9, "beta diverged: {x} vs {y}");
    }
}

/// Probit end-to-end on the distributed path.
#[test]
fn probit_distributed_training() {
    let ds = synth::epsilon_like(&SynthConfig {
        n: 300,
        p: 12,
        seed: 11,
    });
    let compute = NativeCompute::new(LossKind::Probit);
    let pen = ElasticNet::new(0.1, 0.1);
    let cfg = DistributedConfig {
        nodes: 4,
        max_iters: 40,
        eval_every: 0,
        ..Default::default()
    };
    let fit = fit_distributed(&ds, None, &compute, &pen, &cfg);
    let scores = ds.x.mul_vec(&fit.beta);
    assert!(metrics::roc_auc(&ds.y, &scores) > 0.65);
}

/// Elastic net interpolates: solution nnz decreases as l1 grows.
#[test]
fn regularization_path_monotone_sparsity() {
    let ds = synth::webspam_like(
        &SynthConfig {
            n: 500,
            p: 1500,
            seed: 12,
        },
        30,
    );
    let compute = NativeCompute::new(LossKind::Logistic);
    let cfg = DistributedConfig {
        nodes: 4,
        max_iters: 40,
        eval_every: 0,
        ..Default::default()
    };
    let mut prev_nnz = usize::MAX;
    for l1 in [0.1, 1.0, 10.0] {
        let fit = fit_distributed(&ds, None, &compute, &ElasticNet::l1_only(l1), &cfg);
        let nnz = metrics::nnz_weights(&fit.beta);
        assert!(
            nnz <= prev_nnz,
            "nnz not monotone along the path: {nnz} after {prev_nnz} (l1={l1})"
        );
        prev_nnz = nnz;
    }
    assert!(prev_nnz < 1500);
}

/// The full production story: train, save, serve over TCP, score from
/// concurrent clients, hot-swap a retrained model mid-traffic, and verify
/// the endpoint's answers match offline `predict_proba` for both versions.
#[test]
fn serve_end_to_end_with_hot_swap() {
    use dglmnet::glm::GlmModel;
    use dglmnet::serve::{serve, ModelRegistry, NativeFactory, Scorer, ServeClient, ServerConfig};
    use std::sync::Arc;

    let splits = Corpus::clickstream(0.05, 11);
    let compute = NativeCompute::new(LossKind::Logistic);
    let cfg = DistributedConfig {
        nodes: 4,
        max_iters: 10,
        eval_every: 0,
        ..Default::default()
    };
    let fit_v1 = fit_distributed(&splits.train, None, &compute, &ElasticNet::l1_only(0.5), &cfg);
    let fit_v2 = fit_distributed(&splits.train, None, &compute, &ElasticNet::l1_only(2.0), &cfg);
    let m1 = GlmModel::new(LossKind::Logistic, fit_v1.beta);
    let m2 = GlmModel::new(LossKind::Logistic, fit_v2.beta);

    let dir = std::env::temp_dir().join(format!("dglmnet_serve_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    m1.save(&path).unwrap();

    let registry = Arc::new(ModelRegistry::new());
    registry.load_path(&path).unwrap();
    let scorer = Arc::new(Scorer::new(Arc::clone(&registry), Box::new(NativeFactory)));
    let mut server = serve(
        scorer,
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            io_threads: 6,
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr();

    // Expected probabilities for the first few test rows under each model.
    let x = &splits.test.x;
    let n_rows = 8.min(x.nrows);
    let rows: Vec<Vec<(u32, f64)>> = (0..n_rows)
        .map(|i| x.row(i).map(|(c, v)| (c as u32, v)).collect())
        .collect();
    let expect = |m: &GlmModel| -> Vec<f64> {
        rows.iter().map(|r| m.kind.prob(m.margin_sparse(r))).collect()
    };
    let want_v1 = expect(&m1);
    let want_v2 = expect(&m2);

    // 4 concurrent clients each score repeatedly; answers must match one of
    // the two published versions, consistently with the version tag.
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..4 {
            let rows = rows.clone();
            let want_v1 = want_v1.clone();
            let want_v2 = want_v2.clone();
            handles.push(s.spawn(move || {
                let mut c = ServeClient::connect(addr).unwrap();
                for _ in 0..30 {
                    let (version, probs) = c.predict(&rows).unwrap();
                    let want = if version == 1 { &want_v1 } else { &want_v2 };
                    assert!(version == 1 || version == 2, "version {version}");
                    for (got, want) in probs.iter().zip(want.iter()) {
                        assert!((got - want).abs() < 1e-12, "got {got} want {want}");
                    }
                }
            }));
        }
        // Mid-traffic promotion: retrained model lands at the same path.
        m2.save(&path).unwrap();
        let mut admin = ServeClient::connect(addr).unwrap();
        assert_eq!(admin.swap_model(None).unwrap(), 2);
        for h in handles {
            h.join().unwrap();
        }
        let health = admin.health().unwrap();
        assert_eq!(health.get("version").unwrap().as_f64(), Some(2.0));
        assert!(health.get("requests").unwrap().as_f64().unwrap() >= 121.0);
        assert_eq!(health.get("swaps").unwrap().as_f64(), Some(1.0));
    });

    server.stop();
    std::fs::remove_dir_all(&dir).ok();
}
