//! Deterministic straggler/chaos suite for transport-level ALB (§7).
//!
//! Proves the per-iteration quorum-tag protocol end to end, against BOTH
//! interconnect backends (in-process fabric, TCP mesh on loopback):
//!
//! 1. the κ quorum fires at exactly ⌈κ·M⌉ pass reports — never earlier;
//! 2. a cut-off straggler's cyclic cursor resumes mid-block across outer
//!    iterations (no weight starved, paper §7);
//! 3. under a programmable per-rank delay schedule, ALB cuts the cumulative
//!    post-CD sync wait versus BSP while test logloss stays within
//!    tolerance of the BSP reference, and the per-rank load report shows
//!    the straggler doing less CD work;
//! 4. a real 4-process ALB run through the shipped binary converges to the
//!    BSP single-process reference (logloss within 1e-3).
//!
//! Plus `util::prop` property tests for `RemoteQuorum`: duplicate frames
//! never double-count, reporting is idempotent, reports are monotone, and
//! late frames on a retired tag never leak into the next iteration.

use dglmnet::cluster::{
    bind_loopback, fabric, AlbMode, NetworkModel, RemoteQuorum, TcpOptions, TcpTransport,
    Transport, TAG_STRIDE,
};
use dglmnet::coordinator::worker::{run_alb_subproblem, WorkerConfig};
use dglmnet::coordinator::{fit_distributed, fit_distributed_tcp, DistributedConfig};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::glm::GlmModel;
use dglmnet::metrics;
use dglmnet::solver::compute::NativeCompute;
use dglmnet::solver::subproblem::{HybridCd, SubproblemState};
use dglmnet::sparse::Csc;
use dglmnet::util::prop;
use std::time::Duration;

// ---------------------------------------------------------------------------
// Backend parameterization: all endpoints owned by ONE thread — fabric and
// TCP sends never block, so quorum schedules can be driven deterministically.
// ---------------------------------------------------------------------------

fn fabric_endpoints(m: usize) -> Vec<Box<dyn Transport>> {
    let (eps, _) = fabric(m, NetworkModel::default());
    eps.into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect()
}

fn tcp_endpoints(m: usize) -> Vec<Box<dyn Transport>> {
    let (addrs, listeners) = bind_loopback(m).expect("bind loopback");
    let mut out: Vec<Option<Box<dyn Transport>>> = (0..m).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (rank, listener) in listeners.into_iter().enumerate() {
            let addrs = addrs.clone();
            handles.push(s.spawn(move || {
                TcpTransport::with_listener(rank, &addrs, &listener, TcpOptions::default())
                    .expect("tcp mesh")
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            out[rank] = Some(Box::new(h.join().expect("mesh thread")));
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

type Backend = (&'static str, fn(usize) -> Vec<Box<dyn Transport>>);
const BACKENDS: [Backend; 2] = [("fabric", fabric_endpoints), ("tcp", tcp_endpoints)];

/// Poll `q` over `t` until it has observed `want` reports (TCP delivery is
/// asynchronous). Panics after a generous deadline instead of hanging.
fn await_reports(name: &str, q: &mut RemoteQuorum, t: &mut dyn Transport, want: usize) {
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        q.should_stop(t).unwrap();
        if q.reports() >= want {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "{name}: rank {} saw only {}/{want} reports before the deadline",
            t.rank(),
            q.reports()
        );
        std::thread::yield_now();
    }
}

// ---------------------------------------------------------------------------
// 1. Exact quorum threshold
// ---------------------------------------------------------------------------

#[test]
fn quorum_fires_at_exactly_ceil_kappa_m_over_both_backends() {
    for (name, make) in BACKENDS {
        let m = 4;
        for (kappa, threshold) in [(0.5, 2usize), (0.75, 3), (1.0, 4)] {
            let mut eps = make(m);
            let tag = TAG_STRIDE;
            let mut quorums: Vec<RemoteQuorum> =
                (0..m).map(|_| RemoteQuorum::new(m, kappa, tag)).collect();
            assert_eq!(quorums[0].threshold(), threshold, "{name} κ={kappa}");

            // threshold − 1 ranks report: NOBODY may stop yet.
            for r in 0..threshold - 1 {
                quorums[r].report_full_pass(eps[r].as_mut()).unwrap();
            }
            for r in 0..m {
                // Wait until every frame sent so far has been observed, so
                // the negative assertion is deterministic (not a race):
                // reporters count their own pass + the other reporters'
                // frames, non-reporters count all reporters — both sum to
                // threshold − 1 reports.
                await_reports(name, &mut quorums[r], eps[r].as_mut(), threshold - 1);
                assert!(
                    !quorums[r].should_stop(eps[r].as_mut()).unwrap(),
                    "{name} κ={kappa}: rank {r} stopped at {} < ⌈κM⌉ = {threshold}",
                    threshold - 1
                );
            }

            // One more report reaches the threshold: EVERYBODY stops —
            // for κ < 1 that includes rank M−1, which never reported.
            quorums[threshold - 1].report_full_pass(eps[threshold - 1].as_mut()).unwrap();
            for r in 0..m {
                let deadline = std::time::Instant::now() + Duration::from_secs(10);
                while !quorums[r].should_stop(eps[r].as_mut()).unwrap() {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "{name} κ={kappa}: rank {r} never observed the quorum"
                    );
                    std::thread::yield_now();
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Cut-off straggler resumes mid-block
// ---------------------------------------------------------------------------

fn straggler_cfg(chunk: usize) -> WorkerConfig {
    WorkerConfig {
        adaptive_mu: true,
        mu0: 1.0,
        eta1: 2.0,
        eta2: 2.0,
        nu: 1e-6,
        max_iters: 1,
        tol: 0.0,
        patience: 1,
        linesearch: Default::default(),
        eval_every: 0,
        allreduce: dglmnet::cluster::AllReduceAlgo::Naive,
        max_passes: 4,
        chunk,
        threads: 1,
        straggler_delay: Duration::ZERO,
        virtual_time: false,
        slow_factor: 1.0,
        network: NetworkModel::default(),
        checkpoint_dir: None,
        checkpoint_every: 0,
        die_after_iters: None,
    }
}

#[test]
fn straggler_cursor_resumes_mid_block_across_iterations_over_both_backends() {
    for (name, make) in BACKENDS {
        let m = 2;
        let mut eps = make(m);
        // 10-column block on 4 examples; dense-ish so every update touches t.
        let x = Csc::from_triplets(
            4,
            10,
            (0..10).map(|j| (j % 4, j, 1.0 + j as f64 * 0.1)).collect::<Vec<_>>(),
        );
        let beta = vec![0.0; 10];
        let w = vec![1.0; 4];
        let z = vec![0.5; 4];
        let pen = ElasticNet::new(0.01, 0.0);
        let cfg = straggler_cfg(4);
        let mut state = SubproblemState::new(10, 4);
        let mode = AlbMode::Transport { kappa: 0.5 }; // M=2 → threshold 1

        let mut cursors = Vec::new();
        for it in 0..3u64 {
            state.reset(); // Δβ and t cleared, cursor preserved
            let tag = (it + 1) * TAG_STRIDE;
            // The fast peer (rank 1) completes its pass and broadcasts.
            let mut peer = RemoteQuorum::new(m, 0.5, tag);
            peer.report_full_pass(eps[1].as_mut()).unwrap();
            // Rank 0 is the straggler: wait until the quorum is visible so
            // the schedule is deterministic on both backends, then run its
            // subproblem — the do-while loop grants exactly one chunk.
            let mut quorum = mode.begin_iteration(m, tag);
            let deadline = std::time::Instant::now() + Duration::from_secs(10);
            while !quorum.should_stop(eps[0].as_mut()).unwrap() {
                assert!(
                    std::time::Instant::now() < deadline,
                    "{name}: quorum frame never arrived"
                );
                std::thread::yield_now();
            }
            let out = run_alb_subproblem(
                &x,
                &beta,
                &w,
                &z,
                1.0,
                &pen,
                &cfg,
                &mut state,
                None,
                &mut quorum,
                eps[0].as_mut(),
                None,
            )
            .unwrap();
            assert_eq!(out.updates, 4, "{name} iter {it}: one chunk exactly");
            assert!(!out.reported, "{name} iter {it}: straggler was cut off");
            assert_eq!(out.full_passes, 0, "{name} iter {it}");
            cursors.push(state.cursor);
        }
        // 4 updates per iteration over a 10-column block: the cursor walks
        // 4 → 8 → wraps to 2, i.e. the straggler resumed mid-block twice.
        assert_eq!(cursors, vec![4, 8, 2], "{name}: cursor must resume cyclically");
    }
}

/// The hybrid wave variant of the same schedule: a cut-off straggler with
/// T=2 sub-blocks runs exactly one wave (chunk coordinates per sub-block)
/// when the quorum already fired, and every sub-block's cursor resumes
/// mid-sub-block next iteration — over both backends.
#[test]
fn hybrid_straggler_runs_one_wave_and_subblock_cursors_resume() {
    for (name, make) in BACKENDS {
        let m = 2;
        let mut eps = make(m);
        let x = Csc::from_triplets(
            4,
            10,
            (0..10).map(|j| (j % 4, j, 1.0 + j as f64 * 0.1)).collect::<Vec<_>>(),
        );
        let beta = vec![0.0; 10];
        let w = vec![1.0; 4];
        let z = vec![0.5; 4];
        let pen = ElasticNet::new(0.01, 0.0);
        let mut cfg = straggler_cfg(4);
        cfg.threads = 2;
        let mut state = SubproblemState::new(10, 4);
        let mut hybrid = HybridCd::new(&x, 2); // sub-blocks 0..5 and 5..10
        let mode = AlbMode::Transport { kappa: 0.5 }; // M=2 → threshold 1

        let mut cursors: Vec<Vec<usize>> = Vec::new();
        for it in 0..3u64 {
            state.reset();
            let tag = (it + 1) * TAG_STRIDE;
            let mut peer = RemoteQuorum::new(m, 0.5, tag);
            peer.report_full_pass(eps[1].as_mut()).unwrap();
            let mut quorum = mode.begin_iteration(m, tag);
            let deadline = std::time::Instant::now() + Duration::from_secs(10);
            while !quorum.should_stop(eps[0].as_mut()).unwrap() {
                assert!(
                    std::time::Instant::now() < deadline,
                    "{name}: quorum frame never arrived"
                );
                std::thread::yield_now();
            }
            let out = run_alb_subproblem(
                &x,
                &beta,
                &w,
                &z,
                1.0,
                &pen,
                &cfg,
                &mut state,
                Some(&mut hybrid),
                &mut quorum,
                eps[0].as_mut(),
                None,
            )
            .unwrap();
            // One wave: chunk=4 coordinates on each of the 2 sub-blocks.
            assert_eq!(out.updates, 8, "{name} iter {it}: one wave exactly");
            assert!(!out.reported, "{name} iter {it}: straggler was cut off");
            assert_eq!(out.full_passes, 0, "{name} iter {it}");
            cursors.push(hybrid.states.iter().map(|s| s.cursor).collect());
        }
        // Each 5-column sub-block advances its own cursor by 4 per
        // iteration: 4 → 3 (wrapped) → 2.
        assert_eq!(
            cursors,
            vec![vec![4, 4], vec![3, 3], vec![2, 2]],
            "{name}: sub-block cursors must resume cyclically"
        );
        // Per-thread accounting totals the straggler's updates.
        assert_eq!(hybrid.updates_per_thread, vec![12, 12], "{name}");
    }
}

// ---------------------------------------------------------------------------
// 3. ALB cuts sync wait under an injected slow rank, quality preserved
// ---------------------------------------------------------------------------

fn logloss_of(beta: &[f64], splits: &dglmnet::data::Splits) -> f64 {
    let model = GlmModel::new(LossKind::Logistic, beta.to_vec());
    let probs = model.predict_proba(&splits.test.x);
    metrics::logloss(&splits.test.y, &probs)
}

fn chaos_cfg(delays: Vec<Duration>) -> DistributedConfig {
    DistributedConfig {
        nodes: 4,
        max_iters: 60,
        tol: 1e-9,
        patience: 2,
        eval_every: 0,
        seed: 41,
        chunk: 4,
        straggler_delays: delays,
        ..Default::default()
    }
}

#[test]
fn alb_cuts_sync_wait_and_matches_bsp_quality_over_fabric() {
    let splits = dglmnet::data::synth::Corpus::epsilon_like(0.05, 41);
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.3, 0.1);
    let delays = dglmnet::harness::delays_with_straggler(4, 2, Duration::from_millis(25));

    let bsp = fit_distributed(&splits.train, None, &compute, &pen, &chaos_cfg(delays.clone()));
    let alb = fit_distributed(
        &splits.train,
        None,
        &compute,
        &pen,
        &DistributedConfig {
            alb_kappa: Some(0.75),
            ..chaos_cfg(delays)
        },
    );

    // (a) The straggler inflates BSP's post-CD sync wait; ALB cuts it.
    let bsp_wait = bsp.barrier_wait_secs / bsp.iters as f64;
    let alb_wait = alb.barrier_wait_secs / alb.iters as f64;
    assert!(
        alb_wait < 0.7 * bsp_wait,
        "ALB per-iteration sync wait {alb_wait:.4}s not well under BSP {bsp_wait:.4}s"
    );

    // (b) Quality: test logloss within tolerance of the BSP reference.
    let ll_bsp = logloss_of(&bsp.beta, &splits);
    let ll_alb = logloss_of(&alb.beta, &splits);
    assert!(
        (ll_alb - ll_bsp).abs() < 1e-3,
        "ALB logloss {ll_alb} drifted from BSP {ll_bsp}"
    );

    // (c) Per-rank load accounting shows the cut-off straggler.
    let straggler = &alb.per_rank[2];
    let fast_min = alb
        .per_rank
        .iter()
        .filter(|l| l.rank != 2)
        .map(|l| l.cd_updates)
        .min()
        .unwrap();
    assert!(
        straggler.cd_updates < fast_min,
        "straggler updates {} vs fastest {fast_min}",
        straggler.cd_updates
    );
    assert!(straggler.cutoffs > 0, "straggler was never cut off");
}

#[test]
fn alb_cuts_sync_wait_and_matches_bsp_quality_over_tcp() {
    let splits = dglmnet::data::synth::Corpus::epsilon_like(0.05, 42);
    let compute = NativeCompute::new(LossKind::Logistic);
    let pen = ElasticNet::new(0.3, 0.1);
    let delays = dglmnet::harness::delays_with_straggler(4, 1, Duration::from_millis(25));

    let mut cfg = chaos_cfg(delays);
    cfg.seed = 42;
    let bsp = fit_distributed_tcp(&splits.train, None, &compute, &pen, &cfg).expect("bsp tcp");
    let alb = fit_distributed_tcp(
        &splits.train,
        None,
        &compute,
        &pen,
        &DistributedConfig {
            alb_kappa: Some(0.75),
            ..cfg
        },
    )
    .expect("alb tcp");

    let bsp_wait = bsp.barrier_wait_secs / bsp.iters as f64;
    let alb_wait = alb.barrier_wait_secs / alb.iters as f64;
    assert!(
        alb_wait < 0.7 * bsp_wait,
        "TCP ALB per-iteration sync wait {alb_wait:.4}s not well under BSP {bsp_wait:.4}s"
    );

    let ll_bsp = logloss_of(&bsp.beta, &splits);
    let ll_alb = logloss_of(&alb.beta, &splits);
    assert!(
        (ll_alb - ll_bsp).abs() < 1e-3,
        "TCP ALB logloss {ll_alb} drifted from BSP {ll_bsp}"
    );

    let straggler = &alb.per_rank[1];
    let fast_min = alb
        .per_rank
        .iter()
        .filter(|l| l.rank != 1)
        .map(|l| l.cd_updates)
        .min()
        .unwrap();
    assert!(
        straggler.cd_updates < fast_min,
        "TCP straggler updates {} vs fastest {fast_min}",
        straggler.cd_updates
    );
}

// ---------------------------------------------------------------------------
// 4. Real 4-process ALB cluster through the shipped binary
// ---------------------------------------------------------------------------

#[test]
fn multiprocess_alb_cluster_end_to_end() {
    use std::io::BufRead;
    use std::process::{Child, Command, Stdio};

    let bin = env!("CARGO_BIN_EXE_dglmnet");
    let mut workers: Vec<Child> = Vec::new();
    let mut addrs: Vec<String> = Vec::new();

    struct Cleanup<'a>(&'a mut Vec<Child>);
    impl Drop for Cleanup<'_> {
        fn drop(&mut self) {
            for c in self.0.iter_mut() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }

    for _ in 0..3 {
        let mut child = Command::new(bin)
            .args(["worker", "--listen", "127.0.0.1:0"])
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn worker");
        let stdout = child.stdout.take().expect("worker stdout");
        let mut reader = std::io::BufReader::new(stdout);
        let mut line = String::new();
        reader.read_line(&mut line).expect("worker banner");
        let addr = line
            .trim()
            .strip_prefix("worker: listening on ")
            .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
            .to_string();
        addrs.push(addr);
        std::thread::spawn(move || {
            let mut sink = String::new();
            loop {
                sink.clear();
                if reader.read_line(&mut sink).unwrap_or(0) == 0 {
                    break;
                }
            }
        });
        workers.push(child);
    }
    let cleanup = Cleanup(&mut workers);

    let model_path = std::env::temp_dir().join(format!(
        "dglmnet_alb_e2e_model_{}.json",
        std::process::id()
    ));
    let cluster = format!("127.0.0.1:0,{}", addrs.join(","));
    // Rank 2 carries an injected 40 ms/pass straggler delay via the job spec.
    let out = Command::new(bin)
        .args([
            "train",
            "--cluster",
            &cluster,
            "--alb-kappa",
            "0.75",
            "--straggler-delays-ms",
            "0,0,40,0",
            "--chunk",
            "8",
            "--dataset",
            "epsilon_like",
            "--scale",
            "0.05",
            "--seed",
            "1",
            "--loss",
            "logistic",
            "--l1",
            "0.5",
            "--l2",
            "0.0",
            "--max-iters",
            "50",
            "--eval-every",
            "0",
            "--save-model",
            model_path.to_str().unwrap(),
        ])
        .output()
        .expect("run coordinator");
    assert!(
        out.status.success(),
        "ALB coordinator failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    drop(cleanup); // workers have exited with the job; reap them

    let stdout = String::from_utf8_lossy(&out.stdout).to_string();

    // The per-rank comm report must show the straggler (rank 2) performing
    // fewer CD updates than every fast rank.
    let mut updates: Vec<Option<u64>> = vec![None; 4];
    for line in stdout.lines() {
        let cells: Vec<&str> = line
            .trim()
            .trim_matches('|')
            .split('|')
            .map(str::trim)
            .collect();
        if cells.len() >= 7 {
            if let (Ok(rank), Ok(upd)) = (cells[0].parse::<usize>(), cells[1].parse::<u64>()) {
                if rank < 4 {
                    updates[rank] = Some(upd);
                }
            }
        }
    }
    let updates: Vec<u64> = updates
        .into_iter()
        .map(|u| u.expect("per-rank load row missing from coordinator output"))
        .collect();
    let fast_min = [updates[0], updates[1], updates[3]]
        .into_iter()
        .min()
        .unwrap();
    assert!(
        updates[2] < fast_min,
        "straggler rank 2 did {} updates vs fastest {fast_min}\n{stdout}",
        updates[2]
    );

    // Quality: the cluster model's test logloss within 1e-3 of the BSP
    // single-process reference on the identical recipe.
    let model = GlmModel::load(&model_path).expect("saved cluster model");
    std::fs::remove_file(&model_path).ok();
    let splits = dglmnet::harness::load_splits("epsilon_like", 0.05, 1).expect("splits");
    let probs = model.predict_proba(&splits.test.x);
    let ll_cluster = metrics::logloss(&splits.test.y, &probs);

    let seq = dglmnet::solver::dglmnet::fit(
        &splits.train,
        &NativeCompute::new(LossKind::Logistic),
        &ElasticNet::new(0.5, 0.0),
        &dglmnet::solver::dglmnet::DGlmnetConfig {
            nodes: 4,
            max_iters: 50,
            tol: 1e-7,
            patience: 2,
            seed: 1,
            eval_every: 0,
            ..Default::default()
        },
        None,
    );
    let ll_ref = logloss_of(&seq.beta, &splits);
    assert!(
        (ll_cluster - ll_ref).abs() < 1e-3,
        "4-process ALB logloss {ll_cluster} vs BSP reference {ll_ref}"
    );
}

// ---------------------------------------------------------------------------
// RemoteQuorum property tests (util::prop, single-threaded fabric: sends
// are visible to try_recv immediately, so every schedule is deterministic)
// ---------------------------------------------------------------------------

#[test]
fn prop_duplicate_pass_done_frames_never_double_count() {
    prop::check("duplicate frames never double-count", 60, |rng| {
        let m = 2 + rng.below(4); // 2..=5
        let kappa = [0.5, 0.75, 1.0][rng.below(3)];
        let (mut eps, _) = fabric(m, NetworkModel::default());
        let tag = TAG_STRIDE;
        let mut q = RemoteQuorum::new(m, kappa, tag);
        let mut distinct = 0usize;
        for r in 1..m {
            let dups = rng.below(4); // 0..=3 raw frames from rank r
            for _ in 0..dups {
                eps[r].send(0, tag, Vec::new()).unwrap();
            }
            if dups > 0 {
                distinct += 1;
            }
        }
        q.should_stop(&mut eps[0]).unwrap(); // drains everything that arrived
        if q.reports() != distinct {
            return Err(format!(
                "m={m}: counted {} reports from {distinct} distinct ranks",
                q.reports()
            ));
        }
        let want_stop = distinct >= q.threshold();
        if q.should_stop(&mut eps[0]).unwrap() != want_stop {
            return Err(format!(
                "m={m} κ={kappa}: stop={} with {distinct}/{} reports",
                !want_stop,
                q.threshold()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_report_full_pass_is_idempotent() {
    prop::check("report_full_pass is idempotent", 40, |rng| {
        let m = 2 + rng.below(4);
        let (mut eps, _) = fabric(m, NetworkModel::default());
        let mut q = RemoteQuorum::new(m, 1.0, 7);
        let repeats = 1 + rng.below(5);
        for _ in 0..repeats {
            q.report_full_pass(&mut eps[0]).unwrap();
        }
        if q.reports() != 1 {
            return Err(format!("own report counted {} times", q.reports()));
        }
        // Exactly one broadcast: M−1 empty frames, no matter how often the
        // worker re-reports.
        let (bytes, msgs) = eps[0].sent();
        if msgs != (m - 1) as u64 || bytes != 16 * (m - 1) as u64 {
            return Err(format!("broadcast not idempotent: {msgs} msgs, {bytes} B"));
        }
        Ok(())
    });
}

#[test]
fn prop_reports_are_monotone_and_stop_is_sticky() {
    prop::check("reports monotone, stop sticky", 60, |rng| {
        let m = 3 + rng.below(3); // 3..=5
        let (mut eps, _) = fabric(m, NetworkModel::default());
        let tag = 3 * TAG_STRIDE;
        let mut q = RemoteQuorum::new(m, 0.75, tag);
        // Random event schedule: own report + each peer reporting 0..2
        // times, interleaved.
        let mut events: Vec<usize> = vec![0]; // 0 = own report
        for r in 1..m {
            for _ in 0..1 + rng.below(2) {
                events.push(r);
            }
        }
        // Fisher-Yates with the prop rng.
        for i in (1..events.len()).rev() {
            events.swap(i, rng.below(i + 1));
        }
        let mut last_reports = 0usize;
        let mut stopped = false;
        for ev in events {
            if ev == 0 {
                q.report_full_pass(&mut eps[0]).unwrap();
            } else {
                eps[ev].send(0, tag, Vec::new()).unwrap();
            }
            let stop_now = q.should_stop(&mut eps[0]).unwrap();
            if q.reports() < last_reports {
                return Err(format!(
                    "reports regressed {last_reports} -> {}",
                    q.reports()
                ));
            }
            last_reports = q.reports();
            if stopped && !stop_now {
                return Err("stop signal un-fired".into());
            }
            stopped = stop_now;
            if stop_now != (last_reports >= q.threshold()) {
                return Err(format!(
                    "stop={stop_now} with {last_reports}/{} reports",
                    q.threshold()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_retired_tag_frames_never_leak_into_next_quorum() {
    prop::check("retired tags never leak", 60, |rng| {
        let m = 2 + rng.below(4);
        let (mut eps, _) = fabric(m, NetworkModel::default());
        let tag_a = TAG_STRIDE;
        let tag_b = 2 * TAG_STRIDE;

        // Iteration A: everyone reports, the quorum fires and is retired.
        let mut qa = RemoteQuorum::new(m, 1.0, tag_a);
        qa.report_full_pass(&mut eps[0]).unwrap();
        for r in 1..m {
            eps[r].send(0, tag_a, Vec::new()).unwrap();
        }
        if !qa.should_stop(&mut eps[0]).unwrap() {
            return Err("iteration A quorum did not fire".into());
        }

        // Late stragglers keep spraying frames on the RETIRED tag...
        for r in 1..m {
            for _ in 0..rng.below(3) {
                eps[r].send(0, tag_a, Vec::new()).unwrap();
            }
        }
        // ...which must be invisible to iteration B's quorum.
        let mut qb = RemoteQuorum::new(m, 1.0, tag_b);
        qb.should_stop(&mut eps[0]).unwrap();
        if qb.reports() != 0 {
            return Err(format!(
                "B counted {} reports from retired-tag frames",
                qb.reports()
            ));
        }
        // Genuine B-frames still count exactly once per rank.
        let fresh = 1 + rng.below(m - 1); // 1..=m−1 ranks report for B
        for r in 1..=fresh {
            eps[r].send(0, tag_b, Vec::new()).unwrap();
        }
        qb.should_stop(&mut eps[0]).unwrap();
        if qb.reports() != fresh {
            return Err(format!("B saw {} of {fresh} fresh reports", qb.reports()));
        }
        Ok(())
    });
}
