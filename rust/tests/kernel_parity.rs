//! Kernel-parity properties for the `kernels::` seam (DESIGN.md §Kernels).
//!
//! Two distinct contracts are pinned here:
//!
//! * **strict parity** — `VectorKernels { fast: false }` must be
//!   *bit-identical* (`to_bits` equality) to `ScalarKernels` on every
//!   primitive, for every length (straddling the unroll width), and for
//!   special values (±inf, subnormals). This is what lets the default mode
//!   be the unrolled one without touching the 1e-12 hybrid/cluster oracles.
//! * **fast-math tolerance** — `VectorKernels { fast: true }` reassociates
//!   reductions, so it only promises ≤ 1e-7 relative agreement on finite
//!   inputs (the documented per-primitive tier). Element-wise primitives
//!   and the loss grid carry no accumulation order and must stay
//!   bit-identical even in fast-math mode.
//!
//! These tests construct the implementations DIRECTLY — they never flip the
//! process-global mode, because the test runner is multi-threaded and the
//! mode cell is shared by every test in the process.

use dglmnet::kernels::vector::{f32mode, LANES};
use dglmnet::kernels::{CdKernels, ScalarKernels, VectorKernels};
use dglmnet::util::prop;
use dglmnet::util::rng::Rng;

const SCALAR: ScalarKernels = ScalarKernels;
const STRICT: VectorKernels = VectorKernels { fast: false };
const FAST: VectorKernels = VectorKernels { fast: true };

/// Per-primitive fast-math tolerance tier (relative, finite inputs).
const FAST_TOL: f64 = 1e-7;

/// Lengths that straddle the unroll width: empty, sub-lane, exactly one
/// block, one block ± 1, several blocks ± remainders.
fn straddle_lengths() -> Vec<usize> {
    vec![
        0,
        1,
        LANES - 1,
        LANES,
        LANES + 1,
        2 * LANES,
        3 * LANES + 2,
        16 * LANES + 3,
    ]
}

fn bits_eq(label: &str, a: f64, b: f64) -> Result<(), String> {
    if a.to_bits() == b.to_bits() {
        Ok(())
    } else {
        Err(format!("{label}: {a:?} != {b:?} (bitwise)"))
    }
}

fn all_bits_eq(label: &str, a: &[f64], b: &[f64]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{label}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        bits_eq(&format!("{label}[{i}]"), *x, *y)?;
    }
    Ok(())
}

/// A random sparse column over a dense dimension `dim`: sorted unique u32
/// row indices + values, sized to straddle the unroll width.
fn sparse_col(rng: &mut Rng, dim: usize, nnz: usize) -> (Vec<u32>, Vec<f64>) {
    let pairs = prop::sparse_vec(rng, dim, nnz, 3.0);
    let rows: Vec<u32> = pairs.iter().map(|&(i, _)| i as u32).collect();
    let vals: Vec<f64> = pairs.iter().map(|&(_, v)| v).collect();
    (rows, vals)
}

// ---------------------------------------------------------------------------
// strict parity: vector-strict ≡ scalar, bitwise
// ---------------------------------------------------------------------------

#[test]
fn strict_sparse_dot_bit_exact() {
    prop::check("strict sparse_dot ≡ scalar", 300, |rng| {
        let dim = 8 + rng.below(120);
        let (rows, vals) = sparse_col(rng, dim, 1 + rng.below(dim));
        let dense = prop::dense_vec(rng, dim, 5.0);
        let (a, b) = unsafe {
            (
                SCALAR.sparse_dot(&rows, &vals, &dense),
                STRICT.sparse_dot(&rows, &vals, &dense),
            )
        };
        bits_eq("sparse_dot", a, b)
    });
}

#[test]
fn strict_axpy_col_bit_exact() {
    prop::check("strict axpy_col ≡ scalar", 300, |rng| {
        let dim = 8 + rng.below(120);
        let (rows, vals) = sparse_col(rng, dim, 1 + rng.below(dim));
        let coef = rng.range_f64(-4.0, 4.0);
        let base = prop::dense_vec(rng, dim, 2.0);
        let mut ya = base.clone();
        let mut yb = base;
        unsafe {
            SCALAR.axpy_col(&rows, &vals, coef, &mut ya);
            STRICT.axpy_col(&rows, &vals, coef, &mut yb);
        }
        all_bits_eq("axpy_col", &ya, &yb)
    });
}

#[test]
fn strict_col_weighted_quad_bit_exact() {
    prop::check("strict col_weighted_quad ≡ scalar", 300, |rng| {
        let dim = 8 + rng.below(120);
        let (rows, vals) = sparse_col(rng, dim, 1 + rng.below(dim));
        // w is a working-weight vector: positive, floored like the solver's.
        let w: Vec<f64> = (0..dim).map(|_| rng.range_f64(1e-6, 0.25)).collect();
        let z = prop::dense_vec(rng, dim, 4.0);
        let t = prop::dense_vec(rng, dim, 4.0);
        let mu = rng.range_f64(0.0, 2.0);
        let ((a1, a2), (b1, b2)) = unsafe {
            (
                SCALAR.col_weighted_quad(&rows, &vals, &w, &z, &t, mu),
                STRICT.col_weighted_quad(&rows, &vals, &w, &z, &t, mu),
            )
        };
        bits_eq("s1", a1, b1)?;
        bits_eq("s2", a2, b2)
    });
}

#[test]
fn strict_dense_reductions_bit_exact_across_straddle_lengths() {
    // Deterministic straddle sweep first (every remainder shape), then the
    // randomized pass below hits random lengths on top.
    for n in straddle_lengths() {
        let mut rng = Rng::new(0xBEEF ^ n as u64);
        let v = prop::dense_vec(&mut rng, n, 3.0);
        let w: Vec<f64> = (0..n).map(|_| rng.range_f64(1e-6, 0.25)).collect();
        let z = prop::dense_vec(&mut rng, n, 4.0);
        let d = prop::dense_vec(&mut rng, n, 4.0);
        let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let m = prop::dense_vec(&mut rng, n, 8.0);
        bits_eq("sq_norm", SCALAR.sq_norm(&v), STRICT.sq_norm(&v)).unwrap();
        bits_eq(
            "neg_wz_dot",
            SCALAR.neg_wz_dot(&w, &z, &d),
            STRICT.neg_wz_dot(&w, &z, &d),
        )
        .unwrap();
        bits_eq(
            "logloss_sum",
            SCALAR.logloss_sum(&y, &m),
            STRICT.logloss_sum(&y, &m),
        )
        .unwrap();
    }
}

#[test]
fn strict_dense_reductions_bit_exact_random_lengths() {
    prop::check("strict dense reductions ≡ scalar", 300, |rng| {
        let n = rng.below(200);
        let v = prop::dense_vec(rng, n, 3.0);
        let w: Vec<f64> = (0..n).map(|_| rng.range_f64(1e-6, 0.25)).collect();
        let z = prop::dense_vec(rng, n, 4.0);
        let d = prop::dense_vec(rng, n, 4.0);
        bits_eq("sq_norm", SCALAR.sq_norm(&v), STRICT.sq_norm(&v))?;
        bits_eq(
            "neg_wz_dot",
            SCALAR.neg_wz_dot(&w, &z, &d),
            STRICT.neg_wz_dot(&w, &z, &d),
        )
    });
}

#[test]
fn strict_parity_with_infinities_and_subnormals() {
    // Special values must flow through the strict unroll bit-for-bit: the
    // sequential accumulator sees the same operands in the same order, so
    // ±inf propagation and subnormal rounding agree exactly.
    let specials = [
        f64::INFINITY,
        f64::NEG_INFINITY,
        f64::MIN_POSITIVE,          // smallest normal
        f64::MIN_POSITIVE / 2048.0, // subnormal
        -f64::MIN_POSITIVE / 4096.0,
        0.0,
        -0.0,
        1e300,
        -1e300,
    ];
    for n in straddle_lengths() {
        let mut rng = Rng::new(0x5CA1E ^ n as u64);
        let mut v = prop::dense_vec(&mut rng, n, 2.0);
        // Sprinkle specials at positions covering block starts, interiors
        // and the remainder tail.
        for (k, s) in specials.iter().enumerate() {
            if n > 0 {
                let at = (k * 5 + 3) % n;
                v[at] = *s;
            }
        }
        let d = prop::dense_vec(&mut rng, n, 2.0);
        bits_eq("sq_norm/special", SCALAR.sq_norm(&v), STRICT.sq_norm(&v)).unwrap();
        bits_eq(
            "neg_wz_dot/special",
            SCALAR.neg_wz_dot(&v, &d, &d),
            STRICT.neg_wz_dot(&v, &d, &d),
        )
        .unwrap();
        // Sparse gather over a column whose values include the specials.
        let rows: Vec<u32> = (0..n as u32).collect();
        let (a, b) = unsafe {
            (
                SCALAR.sparse_dot(&rows, &v, &d),
                STRICT.sparse_dot(&rows, &v, &d),
            )
        };
        bits_eq("sparse_dot/special", a, b).unwrap();
    }
}

#[test]
fn strict_logloss_grid_bit_exact() {
    prop::check("strict logloss_grid ≡ scalar", 200, |rng| {
        let n = rng.below(150);
        let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let m = prop::dense_vec(rng, n, 6.0);
        let dm = prop::dense_vec(rng, n, 6.0);
        let alphas = [1.0, 0.5, 0.25, 0.125, 0.0625];
        let mut oa = vec![0.0; alphas.len()];
        let mut ob = vec![0.0; alphas.len()];
        SCALAR.logloss_grid(&y, &m, &dm, &alphas, &mut oa);
        STRICT.logloss_grid(&y, &m, &dm, &alphas, &mut ob);
        all_bits_eq("logloss_grid", &oa, &ob)
    });
}

// ---------------------------------------------------------------------------
// fast-math: reductions within the 1e-7 tier; element-wise still bit-exact
// ---------------------------------------------------------------------------

#[test]
fn fast_math_reductions_within_tier() {
    prop::check("fast-math reductions ≤ 1e-7 relative", 300, |rng| {
        let dim = 8 + rng.below(200);
        let (rows, vals) = sparse_col(rng, dim, 1 + rng.below(dim));
        let dense = prop::dense_vec(rng, dim, 5.0);
        let w: Vec<f64> = (0..dim).map(|_| rng.range_f64(1e-6, 0.25)).collect();
        let z = prop::dense_vec(rng, dim, 4.0);
        let t = prop::dense_vec(rng, dim, 4.0);
        let d = prop::dense_vec(rng, dim, 4.0);
        let y: Vec<f64> = (0..dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let mu = rng.range_f64(0.0, 2.0);

        let (sd_s, sd_f) = unsafe {
            (
                SCALAR.sparse_dot(&rows, &vals, &dense),
                FAST.sparse_dot(&rows, &vals, &dense),
            )
        };
        prop::close(sd_s, sd_f, FAST_TOL).map_err(|e| format!("sparse_dot: {e}"))?;

        let ((s1, s2), (f1, f2)) = unsafe {
            (
                SCALAR.col_weighted_quad(&rows, &vals, &w, &z, &t, mu),
                FAST.col_weighted_quad(&rows, &vals, &w, &z, &t, mu),
            )
        };
        prop::close(s1, f1, FAST_TOL).map_err(|e| format!("quad s1: {e}"))?;
        prop::close(s2, f2, FAST_TOL).map_err(|e| format!("quad s2: {e}"))?;

        prop::close(SCALAR.sq_norm(&vals), FAST.sq_norm(&vals), FAST_TOL)
            .map_err(|e| format!("sq_norm: {e}"))?;
        prop::close(
            SCALAR.neg_wz_dot(&w, &z, &d),
            FAST.neg_wz_dot(&w, &z, &d),
            FAST_TOL,
        )
        .map_err(|e| format!("neg_wz_dot: {e}"))?;
        prop::close(
            SCALAR.logloss_sum(&y, &z),
            FAST.logloss_sum(&y, &z),
            FAST_TOL,
        )
        .map_err(|e| format!("logloss_sum: {e}"))
    });
}

#[test]
fn fast_math_elementwise_still_bit_exact() {
    prop::check("fast-math element-wise ≡ scalar (bitwise)", 300, |rng| {
        let dim = 8 + rng.below(150);
        let (rows, vals) = sparse_col(rng, dim, 1 + rng.below(dim));
        let coef = rng.range_f64(-4.0, 4.0);
        let base = prop::dense_vec(rng, dim, 2.0);
        let d = prop::dense_vec(rng, dim, 3.0);
        let w: Vec<f64> = (0..dim).map(|_| rng.range_f64(1e-6, 0.25)).collect();
        let z = prop::dense_vec(rng, dim, 4.0);
        let alpha = rng.range_f64(0.0, 1.0);

        let mut ya = base.clone();
        let mut yb = base.clone();
        unsafe {
            SCALAR.axpy_col(&rows, &vals, coef, &mut ya);
            FAST.axpy_col(&rows, &vals, coef, &mut yb);
        }
        all_bits_eq("axpy_col", &ya, &yb)?;

        let mut ma = base.clone();
        let mut mb = base.clone();
        SCALAR.margin_update_with_xdelta(&mut ma, &d, alpha);
        FAST.margin_update_with_xdelta(&mut mb, &d, alpha);
        all_bits_eq("margin_update", &ma, &mb)?;

        let mut ga = vec![0.0; dim];
        let mut gb = vec![0.0; dim];
        SCALAR.neg_wz(&w, &z, &mut ga);
        FAST.neg_wz(&w, &z, &mut gb);
        all_bits_eq("neg_wz", &ga, &gb)?;

        let mut pa = vec![0.0; dim];
        let mut pb = vec![0.0; dim];
        SCALAR.sigmoid_margins(&base, &mut pa);
        FAST.sigmoid_margins(&base, &mut pb);
        all_bits_eq("sigmoid_margins", &pa, &pb)
    });
}

#[test]
fn fast_math_logloss_grid_bit_exact() {
    // The loss grid shares the strict path even in fast-math mode (it is
    // exp-bound; nothing to reassociate) — pin that so line search stays
    // bit-identical across modes.
    prop::check("fast-math logloss_grid ≡ scalar (bitwise)", 200, |rng| {
        let n = rng.below(150);
        let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let m = prop::dense_vec(rng, n, 6.0);
        let dm = prop::dense_vec(rng, n, 6.0);
        let alphas = [1.0, 0.5, 0.25];
        let mut oa = vec![0.0; alphas.len()];
        let mut ob = vec![0.0; alphas.len()];
        SCALAR.logloss_grid(&y, &m, &dm, &alphas, &mut oa);
        FAST.logloss_grid(&y, &m, &dm, &alphas, &mut ob);
        all_bits_eq("logloss_grid", &oa, &ob)
    });
}

#[test]
fn fast_math_subnormal_inputs_stay_finite_and_close() {
    // Subnormals: reassociation may round differently but must stay within
    // the tier (the sums here are dominated by normal-range values).
    let mut rng = Rng::new(0xD15EA5E);
    for n in straddle_lengths() {
        let mut v = prop::dense_vec(&mut rng, n, 1.0);
        if n > 2 {
            v[0] = f64::MIN_POSITIVE / 1024.0;
            v[n / 2] = -f64::MIN_POSITIVE / 512.0;
        }
        let s = SCALAR.sq_norm(&v);
        let f = FAST.sq_norm(&v);
        assert!(f.is_finite());
        prop::close(s, f, FAST_TOL).unwrap();
    }
}

// ---------------------------------------------------------------------------
// f32 margin mode: accumulates in f64, tolerances follow f32's epsilon
// ---------------------------------------------------------------------------

#[test]
fn f32mode_matches_f64_kernels_at_f32_precision() {
    prop::check("f32 margin kernels track f64 at ~1e-5", 200, |rng| {
        let n = rng.below(150);
        let m64 = prop::dense_vec(rng, n, 8.0);
        let d64 = prop::dense_vec(rng, n, 2.0);
        let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let m32: Vec<f32> = m64.iter().map(|&x| x as f32).collect();
        let d32: Vec<f32> = d64.iter().map(|&x| x as f32).collect();

        // logloss: f64 accumulator over f32 margins — error is the f32
        // representation of the margins, not the accumulation.
        let l64 = SCALAR.logloss_sum(&y, &m64);
        let l32 = f32mode::logloss_sum_f32(&y, &m32);
        prop::close(l64, l32, 1e-5).map_err(|e| format!("logloss_sum_f32: {e}"))?;

        // sigmoid: computed in f64, rounded once to f32.
        let mut p64 = vec![0.0; n];
        SCALAR.sigmoid_margins(&m64, &mut p64);
        let mut p32 = vec![0.0f32; n];
        f32mode::sigmoid_margins_f32(&m32, &mut p32);
        for i in 0..n {
            prop::close(p64[i], f64::from(p32[i]), 1e-5)
                .map_err(|e| format!("sigmoid_margins_f32[{i}]: {e}"))?;
        }

        // step apply in f32 vs f64.
        let alpha = rng.range_f64(0.0, 1.0);
        let mut y64 = m64.clone();
        SCALAR.margin_update_with_xdelta(&mut y64, &d64, alpha);
        let mut y32 = m32.clone();
        f32mode::margin_update_f32(&mut y32, &d32, alpha as f32);
        for i in 0..n {
            prop::close(y64[i], f64::from(y32[i]), 1e-5)
                .map_err(|e| format!("margin_update_f32[{i}]: {e}"))?;
        }
        Ok(())
    });
}
