//! Out-of-core ingestion acceptance (protocol v7): a cluster trained from a
//! `shards:<dir>` directory must (a) reproduce the text-ingest fit — the
//! hashed partition recorded by the converter is the same one the text path
//! derives, so the optimization problem per rank is bit-identical — and
//! (b) actually be out-of-core: every rank's loaded-matrix dims and
//! bytes-read stay strictly below the full p-column matrix.

use std::net::TcpListener;
use std::path::PathBuf;

use dglmnet::cluster::allreduce::AllReduceAlgo;
use dglmnet::cluster::process::{run_worker_on, train_cluster, JobMode, JobSpec, WorkerOverrides};
use dglmnet::data::shards;
use dglmnet::sparse::{FeaturePartition, PartitionStrategy};

const SCALE: f64 = 0.03;
const SEED: u64 = 5;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "dglmnet-shard-cluster-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn base_spec(cluster: Vec<String>, dataset: String) -> JobSpec {
    JobSpec {
        rank: 0,
        cluster,
        dataset,
        scale: SCALE,
        seed: SEED,
        loss: "logistic".into(),
        l1: 0.5,
        l2: 0.1,
        max_iters: 6,
        mu0: 1.0,
        adaptive_mu: true,
        tol: 1e-7,
        patience: 2,
        eval_every: 0,
        allreduce: AllReduceAlgo::Ring,
        alb_kappa: None,
        max_passes: 4,
        chunk: 64,
        straggler_delays: Vec::new(),
        virtual_time: false,
        slow_factors: Vec::new(),
        mode: JobMode::Train,
        lambda_grid: Vec::new(),
        screen: false,
        threads: Vec::new(),
        checkpoint_dir: None,
        checkpoint_every: 0,
        resume: false,
        partition: None,
        fast_math: false,
    }
}

/// Run a full in-process 3-rank cluster (coordinator + 2 worker threads on
/// loopback) over the given dataset recipe.
fn run_cluster(dataset: &str) -> dglmnet::coordinator::ClusterFitResult {
    run_cluster_with(dataset, None)
}

/// Same, with an explicit `--partition` strategy in the job spec.
fn run_cluster_with(
    dataset: &str,
    partition: Option<PartitionStrategy>,
) -> dglmnet::coordinator::ClusterFitResult {
    let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let w2 = TcpListener::bind("127.0.0.1:0").unwrap();
    let a1 = w1.local_addr().unwrap().to_string();
    let a2 = w2.local_addr().unwrap().to_string();
    let mut spec = base_spec(vec!["127.0.0.1:0".into(), a1, a2], dataset.to_string());
    spec.partition = partition;
    let h1 = std::thread::spawn(move || run_worker_on(w1, WorkerOverrides::default()).unwrap());
    let h2 = std::thread::spawn(move || run_worker_on(w2, WorkerOverrides::default()).unwrap());
    let fit = train_cluster(&spec, None).unwrap();
    h1.join().unwrap();
    h2.join().unwrap();
    fit
}

/// The headline acceptance test: convert → train from shards → compare with
/// the text-ingest cluster fit, and assert the per-rank out-of-core bounds.
#[test]
fn shard_cluster_matches_text_ingest_and_stays_out_of_core() {
    let dir = tmp_dir("parity");
    let report = shards::convert_recipe(
        "epsilon_like",
        SCALE,
        SEED,
        3,
        shards::PartitionKind::Hashed,
        &dir,
    )
    .expect("convert");
    assert_eq!(report.blocks, 3);

    let text = run_cluster("epsilon_like");
    let from_shards = run_cluster(&format!("shards:{}", dir.display()));

    // Objective parity: ≤ 1e-6 relative (in practice bit-identical — the
    // header partition equals the text path's hashed partition, so every
    // rank solves the same block in the same order).
    let gap = (from_shards.objective - text.objective).abs() / text.objective.abs().max(1e-12);
    assert!(
        gap < 1e-6,
        "shard-ingest objective {} vs text-ingest {} (gap {gap:.3e})",
        from_shards.objective,
        text.objective,
    );
    assert_eq!(from_shards.beta.len(), text.beta.len());
    for (j, (a, b)) in from_shards.beta.iter().zip(text.beta.iter()).enumerate() {
        assert!((a - b).abs() < 1e-9, "β[{j}]: shards {a} vs text {b}");
    }

    // Out-of-core bounds: every rank loaded exactly its header block —
    // strictly fewer columns than p (no rank materialized the full
    // p-column matrix) — and read fewer bytes than the full train CSC.
    let splits = dglmnet::harness::load_splits("epsilon_like", SCALE, SEED).unwrap();
    let p = splits.train.p();
    let full_bytes = splits.train.to_csc().storage_bytes() as u64;
    let partition = FeaturePartition::hashed(p, 3, SEED);
    assert_eq!(from_shards.per_rank.len(), 3);
    for (r, load) in from_shards.per_rank.iter().enumerate() {
        assert_eq!(load.rank, r);
        assert_eq!(
            load.loaded_cols,
            partition.blocks[r].len(),
            "rank {r} loaded-matrix width"
        );
        assert!(
            load.loaded_cols < p,
            "rank {r} materialized {} of {p} columns — not out-of-core",
            load.loaded_cols
        );
        assert!(load.loaded_bytes > 0, "rank {r} reported no bytes read");
        assert!(
            load.loaded_bytes < full_bytes,
            "rank {r} read {} bytes ≥ the full matrix footprint {full_bytes}",
            load.loaded_bytes
        );
    }
    // The text run, by contrast, charges every rank the full footprint.
    for load in text.per_rank.iter() {
        assert!(load.loaded_bytes >= full_bytes);
    }

    std::fs::remove_dir_all(&dir).ok();
}

/// A shard directory converted with `--partition cluster` pins the clustered
/// layout in its header: a cluster fed `shards:<dir>` with no partition flag
/// must reproduce the text-ingest run that asks for the same strategy
/// explicitly — bit-identical blocks, so β matches to 1e-9 — while an
/// explicit *conflicting* flag is rejected with a pointed error instead of
/// silently re-deriving a layout the block files don't have.
#[test]
fn shard_cluster_clustered_header_matches_text_run() {
    let dir = tmp_dir("clustered");
    let report = shards::convert_recipe(
        "epsilon_like",
        SCALE,
        SEED,
        3,
        shards::PartitionKind::Clustered,
        &dir,
    )
    .expect("convert");
    assert_eq!(report.blocks, 3);

    let text = run_cluster_with("epsilon_like", Some(PartitionStrategy::Clustered));
    let recipe = format!("shards:{}", dir.display());
    let from_shards = run_cluster(&recipe);

    let gap = (from_shards.objective - text.objective).abs() / text.objective.abs().max(1e-12);
    assert!(
        gap < 1e-6,
        "clustered shard-ingest objective {} vs text-ingest {} (gap {gap:.3e})",
        from_shards.objective,
        text.objective,
    );
    assert_eq!(from_shards.beta.len(), text.beta.len());
    for (j, (a, b)) in from_shards.beta.iter().zip(text.beta.iter()).enumerate() {
        assert!((a - b).abs() < 1e-9, "β[{j}]: shards {a} vs text {b}");
    }

    // A matching explicit flag is fine; a conflicting one must fail loudly.
    let matching = run_cluster_with(&recipe, Some(PartitionStrategy::Clustered));
    assert!((matching.objective - from_shards.objective).abs() < 1e-12);

    let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let a1 = w1.local_addr().unwrap().to_string();
    let mut spec = base_spec(vec!["127.0.0.1:0".into(), a1], recipe);
    spec.partition = Some(PartitionStrategy::Hashed);
    let h = std::thread::spawn(move || {
        let _ = run_worker_on(w1, WorkerOverrides::default());
    });
    let err = train_cluster(&spec, None).unwrap_err().to_string();
    assert!(
        err.contains("--partition") && err.contains("cluster"),
        "error must point at the header/flag conflict: {err}"
    );
    h.join().unwrap();

    std::fs::remove_dir_all(&dir).ok();
}

/// A shard directory converted for M blocks refuses to serve a cluster of a
/// different size — the partition is pinned to the block files.
#[test]
fn shard_cluster_rejects_mismatched_block_count() {
    let dir = tmp_dir("mismatch");
    shards::convert_recipe(
        "epsilon_like",
        SCALE,
        SEED,
        3,
        shards::PartitionKind::Hashed,
        &dir,
    )
    .expect("convert");

    let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let a1 = w1.local_addr().unwrap().to_string();
    let spec = base_spec(
        vec!["127.0.0.1:0".into(), a1],
        format!("shards:{}", dir.display()),
    );
    // The worker fails the same way the coordinator does; don't unwrap it.
    let h = std::thread::spawn(move || {
        let _ = run_worker_on(w1, WorkerOverrides::default());
    });
    let err = train_cluster(&spec, None).unwrap_err().to_string();
    assert!(
        err.contains("blocks") && err.contains("--blocks 2"),
        "error must point at the block-count mismatch and the fix: {err}"
    );
    h.join().unwrap();

    std::fs::remove_dir_all(&dir).ok();
}
