//! Transport-conformance suite: one parameterized set of contract tests
//! executed against BOTH interconnect backends — the in-process mailbox
//! fabric and the TCP mesh on loopback. The distributed solver's
//! correctness rests on these invariants being backend-independent (see
//! `cluster::transport` for the contract).

use dglmnet::cluster::allreduce::allreduce_max;
use dglmnet::cluster::{
    allreduce_scalar, allreduce_sum, bind_loopback, fabric, frame_bytes, transport_barrier,
    AllReduceAlgo, NetworkModel, TcpOptions, TcpTransport, Transport, TAG_STRIDE,
};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Backend parameterization
// ---------------------------------------------------------------------------

type Backend = (&'static str, fn(usize) -> Vec<Box<dyn Transport>>);

fn fabric_endpoints(m: usize) -> Vec<Box<dyn Transport>> {
    let (eps, _) = fabric(m, NetworkModel::default());
    eps.into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect()
}

fn tcp_endpoints(m: usize) -> Vec<Box<dyn Transport>> {
    let (addrs, listeners) = bind_loopback(m).expect("bind loopback");
    let mut out: Vec<Option<Box<dyn Transport>>> = (0..m).map(|_| None).collect();
    // Mesh formation blocks until every pair is connected, so all ranks
    // must build concurrently.
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (rank, listener) in listeners.into_iter().enumerate() {
            let addrs = addrs.clone();
            handles.push(s.spawn(move || {
                TcpTransport::with_listener(rank, &addrs, &listener, TcpOptions::default())
                    .expect("tcp mesh")
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            out[rank] = Some(Box::new(h.join().expect("mesh thread")));
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

const BACKENDS: [Backend; 2] = [("fabric", fabric_endpoints), ("tcp", tcp_endpoints)];

/// Run `f` SPMD: one thread per endpoint. Panics in any rank fail the test.
fn spmd(endpoints: Vec<Box<dyn Transport>>, f: impl Fn(&mut dyn Transport) + Send + Sync) {
    std::thread::scope(|s| {
        for mut ep in endpoints {
            let f = &f;
            s.spawn(move || f(ep.as_mut()));
        }
    });
}

// ---------------------------------------------------------------------------
// 1. Tagged delivery: out-of-order arrivals are parked, never lost;
//    same-tag messages stay FIFO.
// ---------------------------------------------------------------------------

#[test]
fn tagged_out_of_order_delivery() {
    for (name, make) in BACKENDS {
        spmd(make(2), |t| match t.rank() {
            1 => {
                t.send(0, 2, vec![2.0]).unwrap();
                t.send(0, 1, vec![1.0]).unwrap();
                t.send(0, 1, vec![1.5]).unwrap();
            }
            _ => {
                // Ask for tag 1 first: the tag-2 message must be parked.
                assert_eq!(t.recv_from(1, 1).unwrap(), vec![1.0], "{name}");
                // FIFO within a tag.
                assert_eq!(t.recv_from(1, 1).unwrap(), vec![1.5], "{name}");
                assert_eq!(t.recv_from(1, 2).unwrap(), vec![2.0], "{name}");
                // And nothing else is pending.
                assert_eq!(t.try_recv_from(1, 1).unwrap(), None, "{name}");
                assert_eq!(t.try_recv_from(1, 2).unwrap(), None, "{name}");
            }
        });
    }
}

#[test]
fn try_recv_eventually_sees_the_message() {
    for (name, make) in BACKENDS {
        spmd(make(2), |t| match t.rank() {
            1 => t.send(0, 9, vec![4.25]).unwrap(),
            _ => {
                // TCP delivery is asynchronous: poll until it lands.
                let mut got = None;
                for _ in 0..10_000 {
                    got = t.try_recv_from(1, 9).unwrap();
                    if got.is_some() {
                        break;
                    }
                    std::thread::yield_now();
                }
                assert_eq!(got, Some(vec![4.25]), "{name}");
            }
        });
    }
}

// ---------------------------------------------------------------------------
// 2. Barrier
// ---------------------------------------------------------------------------

#[test]
fn barrier_holds_until_all_ranks_arrive() {
    for (name, make) in BACKENDS {
        let m = 4;
        let arrived = Arc::new(AtomicUsize::new(0));
        let arrived2 = arrived.clone();
        spmd(make(m), move |t| {
            // Stagger arrivals so the barrier actually has to hold.
            std::thread::sleep(std::time::Duration::from_millis(10 * t.rank() as u64));
            arrived2.fetch_add(1, Ordering::SeqCst);
            transport_barrier(t, 0).unwrap();
            assert_eq!(arrived2.load(Ordering::SeqCst), m, "{name}");
            // Barriers are reusable on fresh tags.
            transport_barrier(t, TAG_STRIDE).unwrap();
        });
    }
}

// ---------------------------------------------------------------------------
// 3. AllReduce: naive and ring agree with the serial sum (and each other),
//    including ring's n < M fallback and non-divisible chunking.
// ---------------------------------------------------------------------------

#[test]
fn naive_and_ring_allreduce_agree() {
    for (name, make) in BACKENDS {
        for m in [1, 2, 4] {
            for n in [2, 7, 40] {
                // Deterministic per-rank input so every rank can compute the
                // expected global sum locally.
                let input = |rank: usize| -> Vec<f64> {
                    (0..n).map(|i| ((rank + 1) * (i + 3)) as f64 * 0.125).collect()
                };
                let want: Vec<f64> = (0..n)
                    .map(|i| (0..m).map(|r| input(r)[i]).sum())
                    .collect();
                spmd(make(m), move |t| {
                    let mut a = input(t.rank());
                    let mut b = input(t.rank());
                    allreduce_sum(t, 0, &mut a, AllReduceAlgo::Naive).unwrap();
                    allreduce_sum(t, TAG_STRIDE, &mut b, AllReduceAlgo::Ring).unwrap();
                    for i in 0..n {
                        assert!(
                            (a[i] - want[i]).abs() < 1e-12,
                            "{name} m={m} n={n} naive[{i}]: {} vs {}",
                            a[i],
                            want[i]
                        );
                        assert!(
                            (b[i] - want[i]).abs() < 1e-9,
                            "{name} m={m} n={n} ring[{i}]: {} vs {}",
                            b[i],
                            want[i]
                        );
                    }
                });
            }
        }
    }
}

#[test]
fn allreduce_max_returns_global_max_everywhere() {
    for (name, make) in BACKENDS {
        for m in [1, 3, 4] {
            spmd(make(m), move |t| {
                // Rank r contributes r·1.5 — rank 0's contribution is the
                // smallest, so the root must actually look at its peers.
                let mine = t.rank() as f64 * 1.5;
                let got = allreduce_max(t, 0, mine).unwrap();
                let want = (m - 1) as f64 * 1.5;
                assert_eq!(got, want, "{name} m={m} rank={}", t.rank());
            });
        }
    }
}

#[test]
fn scalar_reduction_is_algo_independent() {
    for (name, make) in BACKENDS {
        let m = 3;
        spmd(make(m), move |t| {
            let x = t.rank() as f64 + 0.5;
            let scalar = allreduce_scalar(t, 0, x).unwrap();
            let mut v1 = [x];
            allreduce_sum(t, TAG_STRIDE, &mut v1, AllReduceAlgo::Naive).unwrap();
            let mut v2 = [x];
            allreduce_sum(t, 2 * TAG_STRIDE, &mut v2, AllReduceAlgo::Ring).unwrap();
            assert_eq!(scalar, v1[0], "{name}");
            assert_eq!(scalar, v2[0], "{name}");
            assert_eq!(scalar, 0.5 + 1.5 + 2.5, "{name}");
        });
    }
}

// ---------------------------------------------------------------------------
// 4. Byte accounting: both backends charge exactly 16 + 8·len per message,
//    so collective traffic is predictable in closed form on either backend.
// ---------------------------------------------------------------------------

#[test]
fn byte_accounting_matches_closed_form() {
    for (name, make) in BACKENDS {
        // Naive allreduce, m = 3, n = 5: rank 0 receives 2 and broadcasts 2
        // (sends 2 messages of n); every other rank sends exactly 1.
        let m = 3;
        let n = 5;
        spmd(make(m), move |t| {
            let mut data = vec![1.0; n];
            allreduce_sum(t, 0, &mut data, AllReduceAlgo::Naive).unwrap();
            let (bytes, msgs) = t.sent();
            let want_msgs = if t.rank() == 0 { (m - 1) as u64 } else { 1 };
            assert_eq!(msgs, want_msgs, "{name} naive msgs rank {}", t.rank());
            assert_eq!(
                bytes,
                want_msgs * frame_bytes(n),
                "{name} naive bytes rank {}",
                t.rank()
            );
        });

        // Ring allreduce, m = 4, n = 8 (divisible): every rank sends
        // 2(M−1) chunks of n/M doubles — the Θ(n) per-node bound behind
        // the paper's Mn-doubles-per-iteration claim (Table 2).
        let m = 4;
        let n = 8;
        spmd(make(m), move |t| {
            let mut data = vec![1.0; n];
            allreduce_sum(t, 0, &mut data, AllReduceAlgo::Ring).unwrap();
            let (bytes, msgs) = t.sent();
            let want_msgs = 2 * (m - 1) as u64;
            assert_eq!(msgs, want_msgs, "{name} ring msgs rank {}", t.rank());
            assert_eq!(
                bytes,
                want_msgs * frame_bytes(n / m),
                "{name} ring bytes rank {}",
                t.rank()
            );
        });

        // Barriers cost one empty frame per participant direction.
        let m = 3;
        spmd(make(m), move |t| {
            transport_barrier(t, 0).unwrap();
            let (bytes, msgs) = t.sent();
            let want_msgs = if t.rank() == 0 { (m - 1) as u64 } else { 1 };
            assert_eq!(msgs, want_msgs, "{name} barrier msgs rank {}", t.rank());
            assert_eq!(bytes, want_msgs * frame_bytes(0), "{name} barrier bytes");
        });
    }
}

#[test]
fn per_tag_accounting_partitions_totals() {
    for (name, make) in BACKENDS {
        spmd(make(2), move |t| match t.rank() {
            1 => {
                t.send(0, 3, vec![1.0, 2.0]).unwrap();
                t.send(0, 3, vec![3.0]).unwrap();
                t.send(0, 10, vec![0.0; 4]).unwrap();
                // Ascending by tag, (tag, bytes, msgs).
                assert_eq!(
                    t.sent_by_tag(),
                    vec![
                        (3, frame_bytes(2) + frame_bytes(1), 2),
                        (10, frame_bytes(4), 1),
                    ],
                    "{name}"
                );
                // The per-tag rows partition the endpoint totals.
                let (bytes, msgs) = t.sent();
                let by_tag = t.sent_by_tag();
                assert_eq!(by_tag.iter().map(|e| e.1).sum::<u64>(), bytes, "{name}");
                assert_eq!(by_tag.iter().map(|e| e.2).sum::<u64>(), msgs, "{name}");
            }
            _ => {
                assert_eq!(t.recv_from(1, 3).unwrap(), vec![1.0, 2.0], "{name}");
                assert_eq!(t.recv_from(1, 3).unwrap(), vec![3.0], "{name}");
                assert_eq!(t.recv_from(1, 10).unwrap().len(), 4, "{name}");
                assert!(t.sent_by_tag().is_empty(), "{name}: receiver sent nothing");
            }
        });
    }
}

// ---------------------------------------------------------------------------
// 5. Rank/size identity
// ---------------------------------------------------------------------------

#[test]
fn ranks_and_sizes_are_consistent() {
    for (name, make) in BACKENDS {
        let m = 3;
        let seen = Arc::new(AtomicUsize::new(0));
        let seen2 = seen.clone();
        spmd(make(m), move |t| {
            assert_eq!(t.size(), m, "{name}");
            assert!(t.rank() < m, "{name}");
            // Fresh endpoints start with clean accounting.
            assert_eq!(t.sent(), (0, 0), "{name}");
            seen2.fetch_add(1 << (8 * t.rank()), Ordering::SeqCst);
        });
        // Every rank 0..m appeared exactly once.
        assert_eq!(seen.load(Ordering::SeqCst), 0x01_01_01, "{name}");
    }
}
