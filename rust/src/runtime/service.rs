//! The XLA runtime service — owns the PJRT CPU client and every compiled
//! executable, serving compute requests from the worker threads.
//!
//! The `xla` crate's wrapper types hold raw pointers and are neither `Send`
//! nor `Sync`, so the client lives on a dedicated service thread; workers
//! talk to it through a cloneable [`RuntimeHandle`] (mpsc request/reply).
//! PJRT's CPU backend parallelizes a single execution internally, so
//! serialized dispatch does not idle the cores.
//!
//! Artifacts are the HLO-text files `python/compile/aot.py` emits; each is
//! compiled once on first use and cached for the rest of the process
//! lifetime (Python never runs on this path).

use crate::glm::loss::LossKind;
// The PJRT bindings are aliased so the offline stub (`xla_stub`, which
// fails at runtime with a clear message) and the real `xla` crate are
// interchangeable here without touching the service code below.
use crate::runtime::xla_stub as xla;
use crate::util::json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};

/// Static description of the artifact set, parsed from manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Available example-axis block sizes, ascending.
    pub blocks: Vec<usize>,
    /// Line-search candidate count K baked into the linesearch artifacts.
    pub k_alphas: usize,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let k_alphas = v
            .get("k_alphas")
            .and_then(|j| j.as_f64())
            .ok_or_else(|| anyhow::anyhow!("manifest missing k_alphas"))? as usize;
        let mut blocks: Vec<usize> = match v.get("artifacts") {
            Some(json::Json::Arr(arts)) => arts
                .iter()
                .filter_map(|a| a.get("block").and_then(|b| b.as_f64()).map(|b| b as usize))
                .collect(),
            _ => return Err(anyhow::anyhow!("manifest missing artifacts")),
        };
        blocks.sort_unstable();
        blocks.dedup();
        if blocks.is_empty() {
            return Err(anyhow::anyhow!("manifest lists no artifacts"));
        }
        Ok(Manifest {
            dir,
            blocks,
            k_alphas,
        })
    }

    /// Smallest block ≥ n, or the largest block (caller chunks) if none fit.
    pub fn pick_block(&self, n: usize) -> usize {
        *self
            .blocks
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.blocks.last().unwrap())
    }

    pub fn artifact_path(&self, model: &str, kind: LossKind, block: usize) -> PathBuf {
        self.dir
            .join(format!("{model}_{}_{block}.hlo.txt", kind.name()))
    }
}

/// A stats request: (margins, y) → (w, z, loss_sum). Vectors are pre-padded
/// to `block` by the caller-side handle.
enum Request {
    Stats {
        kind: LossKind,
        block: usize,
        margins: Vec<f64>,
        y: Vec<f64>,
        mask: Vec<f64>,
        reply: Sender<anyhow::Result<(Vec<f64>, Vec<f64>, f64)>>,
    },
    LineSearch {
        kind: LossKind,
        block: usize,
        margins: Vec<f64>,
        dmargins: Vec<f64>,
        y: Vec<f64>,
        mask: Vec<f64>,
        alphas: Vec<f64>,
        reply: Sender<anyhow::Result<Vec<f64>>>,
    },
    Shutdown,
}

/// Cloneable handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
    manifest: Manifest,
}

// The Sender is Send; handing handles to worker threads is the whole point.
unsafe impl Sync for RuntimeHandle {}

/// The service thread plus its handle. Dropping `Runtime` shuts the thread
/// down.
pub struct Runtime {
    handle: RuntimeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Runtime {
    /// Start the service: loads the manifest eagerly, compiles executables
    /// lazily (first use per (model, kind, block)).
    pub fn start(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let (tx, rx) = channel::<Request>();
        let m2 = manifest.clone();
        let join = std::thread::Builder::new()
            .name("xla-runtime".into())
            .spawn(move || service_loop(m2, rx))
            .expect("spawn xla-runtime thread");
        Ok(Runtime {
            handle: RuntimeHandle { tx, manifest },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.handle.manifest
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl RuntimeHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute the stats graph on (already padded) block vectors.
    pub fn stats_block(
        &self,
        kind: LossKind,
        margins: Vec<f64>,
        y: Vec<f64>,
        mask: Vec<f64>,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>, f64)> {
        let block = margins.len();
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Stats {
                kind,
                block,
                margins,
                y,
                mask,
                reply: rtx,
            })
            .map_err(|_| anyhow::anyhow!("xla runtime thread is gone"))?;
        rrx.recv()
            .map_err(|_| anyhow::anyhow!("xla runtime dropped reply"))?
    }

    /// Execute the linesearch graph on (already padded) block vectors.
    #[allow(clippy::too_many_arguments)]
    pub fn linesearch_block(
        &self,
        kind: LossKind,
        margins: Vec<f64>,
        dmargins: Vec<f64>,
        y: Vec<f64>,
        mask: Vec<f64>,
        alphas: Vec<f64>,
    ) -> anyhow::Result<Vec<f64>> {
        let block = margins.len();
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::LineSearch {
                kind,
                block,
                margins,
                dmargins,
                y,
                mask,
                alphas,
                reply: rtx,
            })
            .map_err(|_| anyhow::anyhow!("xla runtime thread is gone"))?;
        rrx.recv()
            .map_err(|_| anyhow::anyhow!("xla runtime dropped reply"))?
    }
}

/// Executable cache key.
type Key = (&'static str, LossKind, usize);

fn service_loop(manifest: Manifest, rx: std::sync::mpsc::Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with a clear message.
            for req in rx {
                match req {
                    Request::Stats { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("PJRT client failed: {e}")));
                    }
                    Request::LineSearch { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("PJRT client failed: {e}")));
                    }
                    Request::Shutdown => return,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<Key, xla::PjRtLoadedExecutable> = HashMap::new();

    let compile = |cache: &mut HashMap<Key, xla::PjRtLoadedExecutable>,
                   model: &'static str,
                   kind: LossKind,
                   block: usize|
     -> anyhow::Result<()> {
        if cache.contains_key(&(model, kind, block)) {
            return Ok(());
        }
        let path = manifest.artifact_path(model, kind, block);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow::anyhow!("load {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e}"))?;
        cache.insert((model, kind, block), exe);
        Ok(())
    };

    for req in rx {
        match req {
            Request::Shutdown => break,
            Request::Stats {
                kind,
                block,
                margins,
                y,
                mask,
                reply,
            } => {
                let res = (|| -> anyhow::Result<(Vec<f64>, Vec<f64>, f64)> {
                    compile(&mut cache, "stats", kind, block)?;
                    let exe = &cache[&("stats", kind, block)];
                    let args = [
                        xla::Literal::vec1(&margins),
                        xla::Literal::vec1(&y),
                        xla::Literal::vec1(&mask),
                    ];
                    let out = exe
                        .execute::<xla::Literal>(&args)
                        .map_err(|e| anyhow::anyhow!("execute stats: {e}"))?[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow::anyhow!("fetch stats: {e}"))?;
                    let (w, z, lsum) = out
                        .to_tuple3()
                        .map_err(|e| anyhow::anyhow!("stats tuple: {e}"))?;
                    Ok((
                        w.to_vec::<f64>()
                            .map_err(|e| anyhow::anyhow!("w vec: {e}"))?,
                        z.to_vec::<f64>()
                            .map_err(|e| anyhow::anyhow!("z vec: {e}"))?,
                        lsum.to_vec::<f64>()
                            .map_err(|e| anyhow::anyhow!("loss vec: {e}"))?[0],
                    ))
                })();
                let _ = reply.send(res);
            }
            Request::LineSearch {
                kind,
                block,
                margins,
                dmargins,
                y,
                mask,
                alphas,
                reply,
            } => {
                let res = (|| -> anyhow::Result<Vec<f64>> {
                    compile(&mut cache, "linesearch", kind, block)?;
                    let exe = &cache[&("linesearch", kind, block)];
                    let args = [
                        xla::Literal::vec1(&margins),
                        xla::Literal::vec1(&dmargins),
                        xla::Literal::vec1(&y),
                        xla::Literal::vec1(&mask),
                        xla::Literal::vec1(&alphas),
                    ];
                    let out = exe
                        .execute::<xla::Literal>(&args)
                        .map_err(|e| anyhow::anyhow!("execute linesearch: {e}"))?[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow::anyhow!("fetch linesearch: {e}"))?;
                    let losses = out
                        .to_tuple1()
                        .map_err(|e| anyhow::anyhow!("ls tuple: {e}"))?;
                    losses
                        .to_vec::<f64>()
                        .map_err(|e| anyhow::anyhow!("ls vec: {e}"))
                })();
                let _ = reply.send(res);
            }
        }
    }
}
