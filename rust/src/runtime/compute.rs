//! [`XlaCompute`] — the [`GlmCompute`] implementation backed by the
//! AOT-compiled Pallas artifacts, used on the coordinator's hot path.
//!
//! Handles padding to the fixed artifact block sizes (mask = 0 on pad
//! lanes), chunking when n exceeds the largest compiled block, and chunking
//! of the candidate-α axis to the artifacts' K. Numerics match
//! `NativeCompute` to ~1e-9 (verified by the parity tests below and by the
//! python kernel-vs-ref suite).

use crate::glm::loss::LossKind;
use crate::runtime::service::RuntimeHandle;
use crate::solver::compute::GlmCompute;

pub struct XlaCompute {
    handle: RuntimeHandle,
    kind: LossKind,
}

impl XlaCompute {
    pub fn new(handle: RuntimeHandle, kind: LossKind) -> XlaCompute {
        XlaCompute { handle, kind }
    }

    /// Iterate over (start, len, block) chunks covering n examples.
    fn chunks(&self, n: usize) -> Vec<(usize, usize, usize)> {
        let manifest = self.handle.manifest();
        let max_block = *manifest.blocks.last().unwrap();
        let mut out = Vec::new();
        let mut start = 0;
        while start < n {
            let len = (n - start).min(max_block);
            out.push((start, len, manifest.pick_block(len)));
            start += len;
        }
        if out.is_empty() {
            out.push((0, 0, manifest.pick_block(1)));
        }
        out
    }

    fn pad(src: &[f64], block: usize) -> Vec<f64> {
        let mut v = Vec::with_capacity(block);
        v.extend_from_slice(src);
        v.resize(block, 0.0);
        v
    }

    fn mask(len: usize, block: usize) -> Vec<f64> {
        let mut m = vec![1.0; len];
        m.resize(block, 0.0);
        m
    }
}

impl GlmCompute for XlaCompute {
    fn kind(&self) -> LossKind {
        self.kind
    }

    fn stats(&self, y: &[f64], margins: &[f64], w: &mut [f64], z: &mut [f64]) -> f64 {
        let n = y.len();
        let mut total = 0.0;
        for (start, len, block) in self.chunks(n) {
            let (wb, zb, lsum) = self
                .handle
                .stats_block(
                    self.kind,
                    Self::pad(&margins[start..start + len], block),
                    Self::pad(&y[start..start + len], block),
                    Self::mask(len, block),
                )
                .expect("xla stats execution failed");
            w[start..start + len].copy_from_slice(&wb[..len]);
            z[start..start + len].copy_from_slice(&zb[..len]);
            total += lsum;
        }
        // Pad lanes were masked to w = 0; restore the floor semantics for
        // the *valid* lanes only (the kernel already floors them) — nothing
        // to do: mask multiplies w by 1 on valid lanes.
        total
    }

    fn loss_at_alphas(
        &self,
        y: &[f64],
        margins: &[f64],
        dmargins: &[f64],
        alphas: &[f64],
    ) -> Vec<f64> {
        let n = y.len();
        let k_max = self.handle.manifest().k_alphas;
        let mut out = vec![0.0; alphas.len()];
        for a_chunk_start in (0..alphas.len()).step_by(k_max) {
            let a_len = (alphas.len() - a_chunk_start).min(k_max);
            let mut a_pad = alphas[a_chunk_start..a_chunk_start + a_len].to_vec();
            a_pad.resize(k_max, 0.0);
            for (start, len, block) in self.chunks(n) {
                let losses = self
                    .handle
                    .linesearch_block(
                        self.kind,
                        Self::pad(&margins[start..start + len], block),
                        Self::pad(&dmargins[start..start + len], block),
                        Self::pad(&y[start..start + len], block),
                        Self::mask(len, block),
                        a_pad.clone(),
                    )
                    .expect("xla linesearch execution failed");
                for k in 0..a_len {
                    out[a_chunk_start + k] += losses[k];
                }
            }
        }
        out
    }

    fn grad_dot(&self, y: &[f64], margins: &[f64], dmargins: &[f64]) -> f64 {
        // g_i = -w_i z_i exactly (z = -g/w with the same floored w), so one
        // stats execution gives the gradient dot product.
        let n = y.len();
        let mut w = vec![0.0; n];
        let mut z = vec![0.0; n];
        self.stats(y, margins, &mut w, &mut z);
        let mut acc = 0.0;
        for i in 0..n {
            acc += -w[i] * z[i] * dmargins[i];
        }
        acc
    }
}

#[cfg(test)]
// Test-only skip notices, printed straight to the harness's stderr.
#[allow(clippy::disallowed_macros)]
mod tests {
    use super::*;
    use crate::runtime::service::Runtime;
    use crate::solver::compute::NativeCompute;
    use crate::util::prop::{self, all_close, close};
    use crate::util::rng::Rng;
    use std::sync::OnceLock;

    /// Shared runtime for all tests in this module (PJRT client startup is
    /// expensive; artifacts must have been built by `make artifacts`).
    fn runtime() -> Option<&'static Runtime> {
        static RT: OnceLock<Option<Runtime>> = OnceLock::new();
        RT.get_or_init(|| {
            let dir = artifacts_dir()?;
            match Runtime::start(&dir) {
                Ok(rt) => Some(rt),
                Err(e) => {
                    eprintln!("skipping xla tests: {e}");
                    None
                }
            }
        })
        .as_ref()
    }

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let candidates = [
            std::env::var("DGLMNET_ARTIFACTS").unwrap_or_default(),
            "artifacts".to_string(),
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string(),
        ];
        candidates
            .iter()
            .filter(|c| !c.is_empty())
            .map(std::path::PathBuf::from)
            .find(|p| p.join("manifest.json").exists())
    }

    const KINDS: [LossKind; 3] = [LossKind::Logistic, LossKind::Squared, LossKind::Probit];

    #[test]
    fn stats_matches_native() {
        let Some(rt) = runtime() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let mut rng = Rng::new(1);
        for kind in KINDS {
            let xc = XlaCompute::new(rt.handle(), kind);
            let nc = NativeCompute::new(kind);
            for n in [1usize, 100, 1024, 3000] {
                let margins = prop::dense_vec(&mut rng, n, 3.0);
                let y: Vec<f64> = (0..n)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect();
                let (mut w1, mut z1) = (vec![0.0; n], vec![0.0; n]);
                let (mut w2, mut z2) = (vec![0.0; n], vec![0.0; n]);
                let l1 = xc.stats(&y, &margins, &mut w1, &mut z1);
                let l2 = nc.stats(&y, &margins, &mut w2, &mut z2);
                close(l1, l2, 1e-9).unwrap_or_else(|e| panic!("{kind:?} n={n} loss: {e}"));
                all_close(&w1, &w2, 1e-9).unwrap_or_else(|e| panic!("{kind:?} n={n} w: {e}"));
                all_close(&z1, &z2, 1e-8).unwrap_or_else(|e| panic!("{kind:?} n={n} z: {e}"));
            }
        }
    }

    #[test]
    fn linesearch_matches_native() {
        let Some(rt) = runtime() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let mut rng = Rng::new(2);
        for kind in KINDS {
            let xc = XlaCompute::new(rt.handle(), kind);
            let nc = NativeCompute::new(kind);
            let n = 2500;
            let margins = prop::dense_vec(&mut rng, n, 2.0);
            let dmargins = prop::dense_vec(&mut rng, n, 1.0);
            let y: Vec<f64> = (0..n)
                .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                .collect();
            // More alphas than K forces alpha-axis chunking.
            let alphas: Vec<f64> = (0..100).map(|k| k as f64 / 100.0).collect();
            let got = xc.loss_at_alphas(&y, &margins, &dmargins, &alphas);
            let want = nc.loss_at_alphas(&y, &margins, &dmargins, &alphas);
            all_close(&got, &want, 1e-9).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        }
    }

    #[test]
    fn grad_dot_matches_native() {
        let Some(rt) = runtime() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let mut rng = Rng::new(3);
        for kind in KINDS {
            let xc = XlaCompute::new(rt.handle(), kind);
            let nc = NativeCompute::new(kind);
            let n = 700;
            let margins = prop::dense_vec(&mut rng, n, 2.0);
            let dmargins = prop::dense_vec(&mut rng, n, 1.0);
            let y: Vec<f64> = (0..n)
                .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                .collect();
            close(
                xc.grad_dot(&y, &margins, &dmargins),
                nc.grad_dot(&y, &margins, &dmargins),
                1e-8,
            )
            .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        }
    }

    #[test]
    fn concurrent_workers_share_runtime() {
        let Some(rt) = runtime() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let handle = rt.handle();
        crossbeam_utils::thread::scope(|s| {
            for t in 0..4 {
                let h = handle.clone();
                s.spawn(move |_| {
                    let xc = XlaCompute::new(h, LossKind::Logistic);
                    let nc = NativeCompute::new(LossKind::Logistic);
                    let mut rng = Rng::new(100 + t);
                    let n = 512;
                    let margins = prop::dense_vec(&mut rng, n, 2.0);
                    let y: Vec<f64> = (0..n)
                        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                        .collect();
                    let (mut w1, mut z1) = (vec![0.0; n], vec![0.0; n]);
                    let (mut w2, mut z2) = (vec![0.0; n], vec![0.0; n]);
                    let l1 = xc.stats(&y, &margins, &mut w1, &mut z1);
                    let l2 = nc.stats(&y, &margins, &mut w2, &mut z2);
                    close(l1, l2, 1e-9).unwrap();
                });
            }
        })
        .unwrap();
    }
}
