//! XLA/PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` (L2 JAX graphs wrapping L1 Pallas kernels),
//! compiles them once on a dedicated service thread, and exposes them to the
//! coordinator through the same [`GlmCompute`] trait the native Rust
//! implementation uses. Python is never on this path.
//!
//! [`GlmCompute`]: crate::solver::compute::GlmCompute

pub mod compute;
pub mod service;
pub mod xla_stub;

pub use compute::XlaCompute;
pub use service::{Manifest, Runtime, RuntimeHandle};
