//! Build-time stub for the `xla` (PJRT) bindings.
//!
//! The offline build environment has no `xla` crate, so this module mirrors
//! the exact API surface `runtime::service` uses and fails at *runtime* with
//! a clear message instead of failing the build. `PjRtClient::cpu()` returns
//! an error, which the service loop already handles by answering every
//! request with that error — so `--engine xla` degrades gracefully while the
//! default `--engine native` path is untouched. Swapping in the real
//! bindings is a one-line change in `runtime::service` (the `use ... as
//! xla` alias) plus a Cargo dependency; nothing else in the crate knows the
//! difference.

use std::fmt;

/// Error type standing in for the binding crate's; only `Display` matters
/// (the service wraps everything in `anyhow`).
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "xla/PJRT bindings are not built into this binary (offline build); \
         use --engine native"
            .into(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub; the service loop turns this into a
    /// per-request error.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal
    }

    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal), XlaError> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(err.to_string().contains("native"), "error should point at the fallback");
    }
}
