//! Experiment harness shared by the benches, the examples and the CLI:
//! builds the paper's three corpora, computes reference optima f*, runs each
//! algorithm with its paper configuration, and prints convergence tables in
//! the format of the paper's figures.

use crate::cluster::allreduce::AllReduceAlgo;
use crate::coordinator::{fit_distributed, ClusterFitResult, DistributedConfig, RankLoad};
use crate::data::{Corpus, Splits};
use crate::glm::loss::LossKind;
use crate::glm::regularizer::ElasticNet;
use crate::solver::admm::{fit_admm, AdmmConfig};
use crate::solver::compute::{GlmCompute, NativeCompute};
use crate::solver::dglmnet::{self, DGlmnetConfig};
use crate::solver::lbfgs::{fit_lbfgs, LbfgsConfig};
use crate::solver::online::{fit_online, OnlineConfig};
use crate::solver::trace::Trace;
use crate::util::bench::Table;

/// The three evaluation corpora at a given scale (1.0 ≈ laptop-size runs of
/// a few seconds per algorithm; see DESIGN.md §Substitutions).
pub fn corpora(scale: f64, seed: u64) -> Vec<(&'static str, Splits)> {
    vec![
        ("epsilon_like", Corpus::epsilon_like(scale, seed)),
        ("webspam_like", Corpus::webspam_like(scale, seed + 1)),
        ("clickstream", Corpus::clickstream(scale, seed + 2)),
    ]
}

/// Resolve a dataset argument: a named synthetic corpus, a binary shard
/// directory (`shards:<dir>`, assembled in full — cluster ranks instead load
/// only their block via `data::shards`), or a path to a libsvm file (split
/// 90/5/5). Deterministic in `(name, scale, seed)`, so every process of a
/// multi-node cluster materializes the identical data — the cluster runtime
/// (`cluster::process`) relies on this. Named corpora use the same per-name
/// seed derivation as [`corpora`] (`seed`, `seed+1`, `seed+2`), so a train
/// run and a bench run at one seed see the same data. `block_correlated`
/// (the partition-quality corpus, `seed+3`) is resolvable here but not part
/// of the [`corpora`] trio.
pub fn load_splits(name: &str, scale: f64, seed: u64) -> anyhow::Result<Splits> {
    match name {
        "epsilon_like" => Ok(Corpus::epsilon_like(scale, seed)),
        "webspam_like" => Ok(Corpus::webspam_like(scale, seed + 1)),
        "clickstream" => Ok(Corpus::clickstream(scale, seed + 2)),
        "block_correlated" => Ok(Corpus::block_correlated(scale, seed + 3)),
        recipe => {
            if let Some(dir) = crate::data::shards::shard_recipe(recipe) {
                return crate::data::shards::load_splits_full(std::path::Path::new(dir));
            }
            let path = recipe;
            let data = crate::sparse::libsvm::read_file(path)?;
            let n = data.y.len();
            let ds = crate::data::Dataset::new(
                std::path::Path::new(path)
                    .file_stem()
                    .map(|s| s.to_string_lossy().to_string())
                    .unwrap_or_else(|| "libsvm".into()),
                data.x,
                data.y,
            );
            let tenth = (n / 20).max(1);
            anyhow::ensure!(
                n > 2 * tenth,
                "libsvm file {path} has only {n} example(s) — too few to carve \
                 out test and validation splits (need at least 3)"
            );
            Ok(ds.split(tenth, tenth))
        }
    }
}

/// Regularization strengths per corpus, playing the role of the paper's
/// validation-set-tuned λ (kept fixed so runs are reproducible; the CLI
/// exposes a sweep).
pub fn default_lambda(dataset: &str, l1_mode: bool) -> ElasticNet {
    let l = match dataset {
        "epsilon_like" => 2.0,
        "webspam_like" => 1.0,
        _ => 1.0,
    };
    if l1_mode {
        ElasticNet::l1_only(l)
    } else {
        ElasticNet::l2_only(l)
    }
}

/// High-precision reference optimum f* (the paper ran liblinear / long
/// d-GLMNET). Single-process, many iterations, tight tolerance.
pub fn reference_optimum(splits: &Splits, kind: LossKind, pen: &ElasticNet) -> f64 {
    let compute = NativeCompute::new(kind);
    let cfg = DGlmnetConfig {
        nodes: 1,
        max_iters: 600,
        tol: 1e-13,
        patience: 5,
        eval_every: 0,
        ..Default::default()
    };
    dglmnet::fit(&splits.train, &compute, pen, &cfg, None).objective
}

/// Standard experiment knobs shared across algorithms in one comparison.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub kind: LossKind,
    pub pen: ElasticNet,
    pub nodes: usize,
    pub max_iters: usize,
    pub eval_every: usize,
    pub seed: u64,
}

/// d-GLMNET (BSP) on the simulated cluster.
pub fn run_dglmnet(
    splits: &Splits,
    rc: &RunConfig,
    compute: &dyn GlmCompute,
    alb: Option<f64>,
) -> ClusterFitResult {
    let cfg = DistributedConfig {
        nodes: rc.nodes,
        alb_kappa: alb,
        adaptive_mu: rc.pen.l1 > 0.0, // paper: adaptive μ for L1, μ=1 for L2
        max_iters: rc.max_iters,
        eval_every: rc.eval_every,
        seed: rc.seed,
        allreduce: AllReduceAlgo::Ring,
        tol: 1e-9,
        ..Default::default()
    };
    let mut res = fit_distributed(&splits.train, Some(&splits.test), compute, &rc.pen, &cfg);
    res.trace.algorithm = if alb.is_some() {
        "d-GLMNET-ALB".into()
    } else {
        "d-GLMNET".into()
    };
    res
}

/// ADMM with sharing.
pub fn run_admm(splits: &Splits, rc: &RunConfig, rho: f64) -> Trace {
    let cfg = AdmmConfig {
        kind: rc.kind,
        l1: rc.pen.l1,
        l2: rc.pen.l2,
        rho,
        nodes: rc.nodes,
        max_iters: rc.max_iters,
        eval_every: rc.eval_every,
        seed: rc.seed,
        ..Default::default()
    };
    let mut res = fit_admm(&splits.train, Some(&splits.test), &cfg);
    res.trace.algorithm = "ADMM".into();
    res.trace
}

/// Online truncated gradient (L1) / plain online (L2).
pub fn run_online(splits: &Splits, rc: &RunConfig) -> Trace {
    let cfg = OnlineConfig {
        kind: rc.kind,
        l1: rc.pen.l1,
        l2: rc.pen.l2,
        nodes: rc.nodes,
        epochs: rc.max_iters,
        trunc_period: if rc.pen.l1 > 0.0 { 10 } else { 0 },
        eval_every: rc.eval_every,
        seed: rc.seed,
        ..Default::default()
    };
    let mut res = fit_online(&splits.train, Some(&splits.test), &cfg);
    res.trace.algorithm = "online-TG".into();
    res.trace
}

/// Online-warmstarted L-BFGS (L2 only).
pub fn run_lbfgs(splits: &Splits, rc: &RunConfig) -> Trace {
    let cfg = LbfgsConfig {
        kind: rc.kind,
        l2: rc.pen.l2,
        nodes: rc.nodes,
        max_iters: rc.max_iters,
        warmstart_epochs: 1,
        eval_every: rc.eval_every,
        seed: rc.seed,
        ..Default::default()
    };
    let mut res = fit_lbfgs(&splits.train, Some(&splits.test), &cfg);
    res.trace.algorithm = "online+L-BFGS".into();
    res.trace
}

/// Print the paper-figure series for a set of traces: relative
/// suboptimality, test auPRC and nnz at each checkpoint time.
pub fn print_convergence(dataset: &str, traces: &[&Trace], f_star: f64) {
    crate::obs::log::emit(&format!(
        "\n== {dataset}: relative suboptimality (f - f*)/f* vs time =="
    ));
    let mut t = Table::new(&["algorithm", "t(s)", "rel.subopt", "auPRC", "nnz"]);
    for tr in traces {
        for p in checkpoints(&tr.points) {
            t.row(&[
                tr.algorithm.clone(),
                format!("{:.3}", p.t_sec),
                format!("{:.3e}", (p.objective - f_star) / f_star),
                p.auprc.map(|a| format!("{a:.4}")).unwrap_or_else(|| "-".into()),
                p.nnz.to_string(),
            ]);
        }
    }
    t.print();
}

/// Per-rank Table-2-style load report — the columns that stay meaningful
/// under asynchronous (ALB) runs: a straggler shows fewer CD updates and
/// non-zero cut-offs, and the sync-wait column is the BSP barrier cost ALB
/// exists to shrink. The trailing `cut` column is the protocol v8
/// cross-block co-occurrence fraction of the rank's feature block ("-" when
/// unknown, e.g. shard ranks that never see the full matrix). Shared by the
/// CLI and the chaos test suite.
pub fn print_rank_loads(ranks: &[RankLoad]) {
    if ranks.is_empty() {
        return;
    }
    crate::obs::log::emit("\n== per-rank load (Table 2, asynchronous-aware) ==");
    let mut t = Table::new(&[
        "rank",
        "cd updates",
        "passes",
        "cutoffs",
        "sent MiB",
        "msgs",
        "sync wait (s)",
        "threads",
        "upd/thread",
        "cut",
    ]);
    for r in ranks {
        // Per-thread update spread: single number on the classic path, a
        // min..max range when the rank ran hybrid sub-block threads.
        let upd_per_thread = if r.updates_per_thread.len() > 1 {
            let lo = r.updates_per_thread.iter().min().copied().unwrap_or(0);
            let hi = r.updates_per_thread.iter().max().copied().unwrap_or(0);
            format!("{lo}..{hi}")
        } else {
            r.cd_updates.to_string()
        };
        t.row(&[
            r.rank.to_string(),
            r.cd_updates.to_string(),
            r.full_passes.to_string(),
            r.cutoffs.to_string(),
            format!("{:.2}", r.sent_bytes as f64 / (1024.0 * 1024.0)),
            r.sent_msgs.to_string(),
            format!("{:.3}", r.sync_wait_secs),
            r.threads.max(1).to_string(),
            upd_per_thread,
            if r.cut < 0.0 {
                "-".to_string()
            } else {
                format!("{:.3}", r.cut)
            },
        ]);
    }
    t.print();
}

/// Per-λ table for a path sweep (single-process or distributed): the §8.2
/// selection protocol made visible — objective, sparsity, validation auPRC
/// and the CD-update cost of each point, with the validation-best marked.
/// Shared by `dglmnet path` and the path test suites.
pub fn print_path_table(res: &crate::solver::path::PathResult) {
    crate::obs::log::emit("\n== λ-path sweep (validation-selected, §8.2) ==");
    let mut t = Table::new(&["λ1", "objective", "nnz", "val auPRC", "iters", "cd updates", ""]);
    for (i, p) in res.points.iter().enumerate() {
        t.row(&[
            format!("{:.6}", p.lambda1),
            format!("{:.6}", p.objective),
            p.nnz.to_string(),
            format!("{:.4}", p.val_auprc),
            p.iters.to_string(),
            p.cd_updates.to_string(),
            if i == res.best { "<- best".into() } else { String::new() },
        ]);
    }
    t.print();
}

/// One-straggler delay schedule: rank `victim` of `m` sleeps `delay` per
/// pass, everyone else runs full speed (the chaos suite's standard shape).
pub fn delays_with_straggler(
    m: usize,
    victim: usize,
    delay: std::time::Duration,
) -> Vec<std::time::Duration> {
    assert!(victim < m, "straggler rank {victim} out of range for {m} nodes");
    let mut delays = vec![std::time::Duration::ZERO; m];
    delays[victim] = delay;
    delays
}

/// Fresh per-run checkpoint directory under the system temp dir — the chaos
/// suite's standard location for `--checkpoint-dir`-style runs. Unique per
/// (process, tag) so parallel tests never share state; the caller owns
/// cleanup.
pub fn checkpoint_dir_for(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("dglmnet-ckpt-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create checkpoint dir");
    dir
}

/// Subsample a trace to ≤ 8 display checkpoints (first, last, log-spaced).
fn checkpoints(points: &[crate::solver::trace::TracePoint]) -> Vec<&crate::solver::trace::TracePoint> {
    if points.len() <= 8 {
        return points.iter().collect();
    }
    let mut idx: Vec<usize> = (0..8)
        .map(|k| ((points.len() - 1) as f64 * (k as f64 / 7.0).powf(1.5)) as usize)
        .collect();
    idx.dedup();
    idx.iter().map(|&i| &points[i]).collect()
}

/// Re-time a trace under a wire cost model: iteration k's timestamp gains
/// k × (modeled transfer time for `bytes_per_iter` + `msgs_per_iter`
/// latencies). The in-process fabric moves bytes at memcpy speed, so the
/// wall-clock axis under-charges communication relative to the paper's
/// Gigabit cluster; this puts every algorithm on the paper's network.
/// Per-iteration byte counts per Table 2: d-GLMNET/ADMM Mn·8, online 2Mp·8,
/// L-BFGS Mp·8.
pub fn charge_network(
    trace: &Trace,
    bytes_per_iter: f64,
    msgs_per_iter: f64,
    model: &crate::cluster::fabric::NetworkModel,
) -> Trace {
    let per_iter =
        model.ns_per_byte * 1e-9 * bytes_per_iter + model.latency_us_per_msg * 1e-6 * msgs_per_iter;
    let mut out = trace.clone();
    for p in out.points.iter_mut() {
        p.t_sec += per_iter * p.iter as f64;
    }
    out
}

/// Best auPRC reached in a trace.
pub fn best_auprc(trace: &Trace) -> Option<f64> {
    trace
        .points
        .iter()
        .filter_map(|p| p.auprc)
        .fold(None, |acc, a| Some(acc.map_or(a, |b: f64| b.max(a))))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpora_have_expected_shapes() {
        let cs = corpora(0.05, 1);
        assert_eq!(cs.len(), 3);
        for (name, s) in &cs {
            assert!(s.train.n() > 0, "{name} empty train");
            assert!(s.test.n() > 0);
            assert_eq!(s.test.n(), s.validation.n());
        }
    }

    #[test]
    fn load_splits_uses_the_corpora_seed_derivation() {
        // Regression: load_splits seeded all three corpora with the plain
        // seed while corpora() used seed, seed+1, seed+2 — webspam_like and
        // clickstream materialized different data in train vs bench runs.
        // The derivation is pinned here: one seed, same data everywhere.
        let (scale, seed) = (0.05, 9);
        for (name, want) in corpora(scale, seed) {
            let got = load_splits(name, scale, seed).unwrap();
            assert_eq!(got.train.x, want.train.x, "{name} train matrix");
            assert_eq!(got.train.y, want.train.y, "{name} train labels");
            assert_eq!(got.test.x, want.test.x, "{name} test matrix");
            assert_eq!(got.validation.y, want.validation.y, "{name} validation labels");
        }
    }

    #[test]
    fn load_splits_rejects_tiny_libsvm_files() {
        // Regression: n ≤ 2 made `(n/20).max(1)` taken twice exhaust the
        // file, leaving an empty train split (and a panic in Dataset::split).
        let dir = std::env::temp_dir();
        for n in 1..=2usize {
            let path = dir.join(format!("dglmnet-tiny-{n}-{}.svm", std::process::id()));
            let body = "+1 1:0.5\n".repeat(n);
            std::fs::write(&path, body).unwrap();
            let err = load_splits(&path.to_string_lossy(), 1.0, 1).unwrap_err();
            assert!(
                err.to_string().contains("too few"),
                "n={n}: unexpected error {err}"
            );
            std::fs::remove_file(&path).ok();
        }
        // Three examples is the minimum that still yields a non-empty train.
        let path = dir.join(format!("dglmnet-tiny-3-{}.svm", std::process::id()));
        std::fs::write(&path, "+1 1:0.5\n-1 2:1.0\n+1 1:2.0\n").unwrap();
        let s = load_splits(&path.to_string_lossy(), 1.0, 1).unwrap();
        assert_eq!(s.train.n(), 1);
        assert_eq!(s.test.n(), 1);
        assert_eq!(s.validation.n(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reference_optimum_below_algorithm_runs() {
        let s = Corpus::epsilon_like(0.04, 5);
        let pen = ElasticNet::new(0.5, 0.1);
        let f_star = reference_optimum(&s, LossKind::Logistic, &pen);
        let rc = RunConfig {
            kind: LossKind::Logistic,
            pen,
            nodes: 2,
            max_iters: 5,
            eval_every: 0,
            seed: 1,
        };
        let compute = NativeCompute::new(LossKind::Logistic);
        let short = run_dglmnet(&s, &rc, &compute, None);
        assert!(f_star <= short.objective + 1e-9);
    }

    #[test]
    fn checkpoint_dir_is_created_and_tagged() {
        let d = checkpoint_dir_for("harness-unit");
        assert!(d.is_dir());
        assert!(d.file_name().unwrap().to_string_lossy().contains("harness-unit"));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn all_runners_produce_traces() {
        let s = Corpus::epsilon_like(0.04, 6);
        let rc = RunConfig {
            kind: LossKind::Logistic,
            pen: ElasticNet::new(0.3, 0.1),
            nodes: 2,
            max_iters: 3,
            eval_every: 1,
            seed: 2,
        };
        let compute = NativeCompute::new(LossKind::Logistic);
        let d = run_dglmnet(&s, &rc, &compute, None);
        let a = run_admm(&s, &rc, 1.0);
        let o = run_online(&s, &rc);
        let l = run_lbfgs(&s, &rc);
        for tr in [&d.trace, &a, &o, &l] {
            assert!(!tr.points.is_empty(), "{} empty", tr.algorithm);
        }
        print_convergence("epsilon_like", &[&d.trace, &a, &o, &l], 1.0);
    }
}
