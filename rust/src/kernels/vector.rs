//! The unrolled implementation of [`CdKernels`].
//!
//! Two flavors share the code here, selected by the `fast` field:
//!
//! * **strict** (`fast: false`, the process default) — 4-way unrolled
//!   loops with ONE sequential accumulator. The additions happen in the
//!   same left-to-right order as [`super::ScalarKernels`], so every result
//!   is bit-identical to the reference; the win is amortized loop control,
//!   per-call (not per-entry) bounds proof, and wider instruction-level
//!   parallelism on the independent multiply trees. Safe for the 1e-12
//!   hybrid/cluster oracles and the bit-exact `assert_eq!` suites.
//! * **fast-math** (`fast: true`, `--fast-math`) — the same unroll with
//!   FOUR independent accumulators per sum, combined `(a0+a1)+(a2+a3)`.
//!   Breaking the sequential-add dependency chain lets the CPU retire ~4
//!   adds per cycle instead of 1, at the cost of reassociation: results
//!   drift from strict by ≤ 1e-7 relative per primitive on finite inputs
//!   (pinned in `rust/tests/kernel_parity.rs`). Element-wise primitives and
//!   the exp-bound loss grid have no accumulation order to reassociate, so
//!   they share the strict path and stay bit-identical even here.
//!
//! The unroll width is `LANES = 4`: wide enough to fill two 256-bit FMA
//! pipes on x86-64 and the dual 128-bit units on aarch64 once the
//! const-bound lane loops are flattened (build with
//! `RUSTFLAGS="-C target-cpu=native"` to let the backend pick the widest
//! vectors), small enough that remainder handling stays cheap for the
//! short sparse columns that dominate power-law data.
//!
//! [`f32mode`] holds the experimental f32-margins/f64-accumulator helpers
//! (~2× memory bandwidth on the margin vectors); they are bench/parity
//! material only and not dispatched by the solver.

use super::{log1p_exp, sigmoid, CdKernels};

/// Unroll width of every kernel in this module.
pub const LANES: usize = 4;

/// Unrolled kernels; `fast: true` enables split-accumulator reassociation.
#[derive(Clone, Copy, Debug)]
pub struct VectorKernels {
    /// `false` = strict (bit-identical to scalar), `true` = fast-math.
    pub fast: bool,
}

impl CdKernels for VectorKernels {
    fn name(&self) -> &'static str {
        if self.fast {
            "vector-fast"
        } else {
            "vector-strict"
        }
    }

    unsafe fn sparse_dot(&self, rows: &[u32], vals: &[f64], dense: &[f64]) -> f64 {
        let n = rows.len();
        let mut i = 0;
        if self.fast {
            let mut acc = [0.0f64; LANES];
            while i + LANES <= n {
                for lane in 0..LANES {
                    let r = *rows.get_unchecked(i + lane) as usize;
                    acc[lane] += vals.get_unchecked(i + lane) * dense.get_unchecked(r);
                }
                i += LANES;
            }
            let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            while i < n {
                let r = *rows.get_unchecked(i) as usize;
                s += vals.get_unchecked(i) * dense.get_unchecked(r);
                i += 1;
            }
            s
        } else {
            let mut s = 0.0;
            while i + LANES <= n {
                // one sequential accumulator: same add order as scalar
                for lane in 0..LANES {
                    let r = *rows.get_unchecked(i + lane) as usize;
                    s += vals.get_unchecked(i + lane) * dense.get_unchecked(r);
                }
                i += LANES;
            }
            while i < n {
                let r = *rows.get_unchecked(i) as usize;
                s += vals.get_unchecked(i) * dense.get_unchecked(r);
                i += 1;
            }
            s
        }
    }

    unsafe fn axpy_col(&self, rows: &[u32], vals: &[f64], coef: f64, y: &mut [f64]) {
        // Element-wise scatter: no accumulation order, identical in all modes.
        let n = rows.len();
        let mut i = 0;
        while i + LANES <= n {
            for lane in 0..LANES {
                let r = *rows.get_unchecked(i + lane) as usize;
                *y.get_unchecked_mut(r) += coef * vals.get_unchecked(i + lane);
            }
            i += LANES;
        }
        while i < n {
            let r = *rows.get_unchecked(i) as usize;
            *y.get_unchecked_mut(r) += coef * vals.get_unchecked(i);
            i += 1;
        }
    }

    unsafe fn col_weighted_quad(
        &self,
        rows: &[u32],
        vals: &[f64],
        w: &[f64],
        z: &[f64],
        t: &[f64],
        mu: f64,
    ) -> (f64, f64) {
        let n = rows.len();
        let mut i = 0;
        if self.fast {
            let mut a1 = [0.0f64; LANES];
            let mut a2 = [0.0f64; LANES];
            while i + LANES <= n {
                for lane in 0..LANES {
                    let r = *rows.get_unchecked(i + lane) as usize;
                    let v = *vals.get_unchecked(i + lane);
                    let wx = w.get_unchecked(r) * v;
                    a1[lane] += wx * (z.get_unchecked(r) - mu * t.get_unchecked(r));
                    a2[lane] += wx * v;
                }
                i += LANES;
            }
            let mut s1 = (a1[0] + a1[1]) + (a1[2] + a1[3]);
            let mut s2 = (a2[0] + a2[1]) + (a2[2] + a2[3]);
            while i < n {
                let r = *rows.get_unchecked(i) as usize;
                let v = *vals.get_unchecked(i);
                let wx = w.get_unchecked(r) * v;
                s1 += wx * (z.get_unchecked(r) - mu * t.get_unchecked(r));
                s2 += wx * v;
                i += 1;
            }
            (s1, s2)
        } else {
            let mut s1 = 0.0;
            let mut s2 = 0.0;
            while i + LANES <= n {
                // one sequential accumulator pair: same add order as scalar
                for lane in 0..LANES {
                    let r = *rows.get_unchecked(i + lane) as usize;
                    let v = *vals.get_unchecked(i + lane);
                    let wx = w.get_unchecked(r) * v;
                    s1 += wx * (z.get_unchecked(r) - mu * t.get_unchecked(r));
                    s2 += wx * v;
                }
                i += LANES;
            }
            while i < n {
                let r = *rows.get_unchecked(i) as usize;
                let v = *vals.get_unchecked(i);
                let wx = w.get_unchecked(r) * v;
                s1 += wx * (z.get_unchecked(r) - mu * t.get_unchecked(r));
                s2 += wx * v;
                i += 1;
            }
            (s1, s2)
        }
    }

    fn sq_norm(&self, vals: &[f64]) -> f64 {
        let n = vals.len();
        let mut i = 0;
        if self.fast {
            let mut acc = [0.0f64; LANES];
            while i + LANES <= n {
                for lane in 0..LANES {
                    // SAFETY: i + lane < i + LANES <= n.
                    let v = unsafe { *vals.get_unchecked(i + lane) };
                    acc[lane] += v * v;
                }
                i += LANES;
            }
            let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            for v in &vals[i..] {
                s += v * v;
            }
            s
        } else {
            let mut s = 0.0;
            while i + LANES <= n {
                for lane in 0..LANES {
                    // SAFETY: i + lane < i + LANES <= n.
                    let v = unsafe { *vals.get_unchecked(i + lane) };
                    s += v * v;
                }
                i += LANES;
            }
            for v in &vals[i..] {
                s += v * v;
            }
            s
        }
    }

    fn margin_update_with_xdelta(&self, y: &mut [f64], d: &[f64], alpha: f64) {
        // Element-wise: no accumulation order, identical in all modes.
        assert_eq!(y.len(), d.len());
        let n = y.len();
        let mut i = 0;
        while i + LANES <= n {
            for lane in 0..LANES {
                // SAFETY: i + lane < i + LANES <= n == y.len() == d.len().
                unsafe {
                    *y.get_unchecked_mut(i + lane) += alpha * d.get_unchecked(i + lane);
                }
            }
            i += LANES;
        }
        while i < n {
            y[i] += alpha * d[i];
            i += 1;
        }
    }

    fn neg_wz_dot(&self, w: &[f64], z: &[f64], d: &[f64]) -> f64 {
        assert_eq!(w.len(), z.len());
        assert_eq!(w.len(), d.len());
        let n = w.len();
        let mut i = 0;
        if self.fast {
            let mut acc = [0.0f64; LANES];
            while i + LANES <= n {
                for lane in 0..LANES {
                    // SAFETY: i + lane < n and all three slices have len n.
                    unsafe {
                        acc[lane] += -w.get_unchecked(i + lane)
                            * z.get_unchecked(i + lane)
                            * d.get_unchecked(i + lane);
                    }
                }
                i += LANES;
            }
            let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            while i < n {
                s += -w[i] * z[i] * d[i];
                i += 1;
            }
            s
        } else {
            let mut s = 0.0;
            while i + LANES <= n {
                for lane in 0..LANES {
                    // SAFETY: i + lane < n and all three slices have len n.
                    unsafe {
                        s += -w.get_unchecked(i + lane)
                            * z.get_unchecked(i + lane)
                            * d.get_unchecked(i + lane);
                    }
                }
                i += LANES;
            }
            while i < n {
                s += -w[i] * z[i] * d[i];
                i += 1;
            }
            s
        }
    }

    fn neg_wz(&self, w: &[f64], z: &[f64], out: &mut [f64]) {
        // Element-wise: no accumulation order, identical in all modes.
        assert_eq!(w.len(), z.len());
        assert_eq!(w.len(), out.len());
        let n = w.len();
        let mut i = 0;
        while i + LANES <= n {
            for lane in 0..LANES {
                // SAFETY: i + lane < n and all three slices have len n.
                unsafe {
                    *out.get_unchecked_mut(i + lane) =
                        -w.get_unchecked(i + lane) * z.get_unchecked(i + lane);
                }
            }
            i += LANES;
        }
        while i < n {
            out[i] = -w[i] * z[i];
            i += 1;
        }
    }

    fn sigmoid_margins(&self, margins: &[f64], out: &mut [f64]) {
        // Element-wise exp-bound map: identical in all modes. The unroll
        // still helps by overlapping the independent exp pipelines.
        assert_eq!(margins.len(), out.len());
        let n = margins.len();
        let mut i = 0;
        while i + LANES <= n {
            for lane in 0..LANES {
                // SAFETY: i + lane < n == margins.len() == out.len().
                unsafe {
                    *out.get_unchecked_mut(i + lane) = sigmoid(*margins.get_unchecked(i + lane));
                }
            }
            i += LANES;
        }
        while i < n {
            out[i] = sigmoid(margins[i]);
            i += 1;
        }
    }

    fn logloss_sum(&self, y: &[f64], margins: &[f64]) -> f64 {
        assert_eq!(y.len(), margins.len());
        let n = y.len();
        let mut i = 0;
        if self.fast {
            let mut acc = [0.0f64; LANES];
            while i + LANES <= n {
                for lane in 0..LANES {
                    // SAFETY: i + lane < n and both slices have len n.
                    unsafe {
                        acc[lane] += log1p_exp(
                            -y.get_unchecked(i + lane) * margins.get_unchecked(i + lane),
                        );
                    }
                }
                i += LANES;
            }
            let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            while i < n {
                s += log1p_exp(-y[i] * margins[i]);
                i += 1;
            }
            s
        } else {
            let mut s = 0.0;
            while i + LANES <= n {
                for lane in 0..LANES {
                    // SAFETY: i + lane < n and both slices have len n.
                    unsafe {
                        s += log1p_exp(
                            -y.get_unchecked(i + lane) * margins.get_unchecked(i + lane),
                        );
                    }
                }
                i += LANES;
            }
            while i < n {
                s += log1p_exp(-y[i] * margins[i]);
                i += 1;
            }
            s
        }
    }

    fn logloss_grid(
        &self,
        y: &[f64],
        margins: &[f64],
        dmargins: &[f64],
        alphas: &[f64],
        out: &mut [f64],
    ) {
        // The grid is exp-bound and k-strided; reassociating the example
        // sum buys nothing here, so fast-math shares the strict path and
        // the line-search grid stays bit-identical in every mode.
        assert_eq!(y.len(), margins.len());
        assert_eq!(y.len(), dmargins.len());
        assert_eq!(alphas.len(), out.len());
        out.fill(0.0);
        for i in 0..y.len() {
            let yi = y[i];
            let mi = margins[i];
            let di = dmargins[i];
            for (k, a) in alphas.iter().enumerate() {
                let yh = mi + a * di;
                // SAFETY: k < alphas.len() == out.len().
                unsafe {
                    *out.get_unchecked_mut(k) += log1p_exp(-yi * yh);
                }
            }
        }
    }
}

/// Experimental f32-margins / f64-accumulator kernels (ROADMAP item 1's
/// "~2× memory bandwidth" mode). Margins live in f32 — halving the bytes
/// the margin sweeps stream — while every reduction still accumulates in
/// f64 so the sum does not lose ground to cancellation. f32's 1.2e-7
/// epsilon sits ON the fast-math tolerance tier, so this stays a
/// bench/parity playground rather than a solver dispatch mode; promote it
/// only with its own end-to-end tolerance study.
pub mod f32mode {
    use super::super::{log1p_exp, sigmoid};

    /// y ← y + α·d over f32 margin vectors.
    pub fn margin_update_f32(y: &mut [f32], d: &[f32], alpha: f32) {
        assert_eq!(y.len(), d.len());
        for (yi, di) in y.iter_mut().zip(d.iter()) {
            *yi += alpha * di;
        }
    }

    /// Σᵢ log(1 + exp(−yᵢ mᵢ)) with f32 margins and an f64 accumulator.
    pub fn logloss_sum_f32(y: &[f64], margins: &[f32]) -> f64 {
        assert_eq!(y.len(), margins.len());
        let mut acc = 0.0f64;
        for (yi, mi) in y.iter().zip(margins.iter()) {
            acc += log1p_exp(-yi * f64::from(*mi));
        }
        acc
    }

    /// outᵢ = σ(marginsᵢ) over f32 margins (computed in f64, rounded once).
    pub fn sigmoid_margins_f32(margins: &[f32], out: &mut [f32]) {
        assert_eq!(margins.len(), out.len());
        for (mi, oi) in margins.iter().zip(out.iter_mut()) {
            *oi = sigmoid(f64::from(*mi)) as f32;
        }
    }
}
