//! The scalar reference implementation of [`CdKernels`] — the pre-refactor
//! inner loops, verbatim. This is the bit-exactness baseline every other
//! implementation is held to (`rust/tests/kernel_parity.rs`), and the
//! denominator of the `BENCH_hotpath.json` throughput records.

use super::{log1p_exp, sigmoid, CdKernels};

/// Reference loops: one entry at a time, one sequential accumulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarKernels;

impl CdKernels for ScalarKernels {
    fn name(&self) -> &'static str {
        "scalar"
    }

    unsafe fn sparse_dot(&self, rows: &[u32], vals: &[f64], dense: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (r, v) in rows.iter().zip(vals.iter()) {
            acc += v * dense.get_unchecked(*r as usize);
        }
        acc
    }

    unsafe fn axpy_col(&self, rows: &[u32], vals: &[f64], coef: f64, y: &mut [f64]) {
        for (r, v) in rows.iter().zip(vals.iter()) {
            *y.get_unchecked_mut(*r as usize) += coef * v;
        }
    }

    unsafe fn col_weighted_quad(
        &self,
        rows: &[u32],
        vals: &[f64],
        w: &[f64],
        z: &[f64],
        t: &[f64],
        mu: f64,
    ) -> (f64, f64) {
        let mut s1 = 0.0;
        let mut s2 = 0.0;
        for (r, v) in rows.iter().zip(vals.iter()) {
            let i = *r as usize;
            let wx = w.get_unchecked(i) * v;
            s1 += wx * (z.get_unchecked(i) - mu * t.get_unchecked(i));
            s2 += wx * v;
        }
        (s1, s2)
    }

    fn sq_norm(&self, vals: &[f64]) -> f64 {
        let mut acc = 0.0;
        for v in vals {
            acc += v * v;
        }
        acc
    }

    fn margin_update_with_xdelta(&self, y: &mut [f64], d: &[f64], alpha: f64) {
        assert_eq!(y.len(), d.len());
        for (yi, di) in y.iter_mut().zip(d.iter()) {
            *yi += alpha * di;
        }
    }

    fn neg_wz_dot(&self, w: &[f64], z: &[f64], d: &[f64]) -> f64 {
        assert_eq!(w.len(), z.len());
        assert_eq!(w.len(), d.len());
        let mut acc = 0.0;
        for ((wi, zi), di) in w.iter().zip(z.iter()).zip(d.iter()) {
            acc += -wi * zi * di;
        }
        acc
    }

    fn neg_wz(&self, w: &[f64], z: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), z.len());
        assert_eq!(w.len(), out.len());
        for ((wi, zi), oi) in w.iter().zip(z.iter()).zip(out.iter_mut()) {
            *oi = -wi * zi;
        }
    }

    fn sigmoid_margins(&self, margins: &[f64], out: &mut [f64]) {
        assert_eq!(margins.len(), out.len());
        for (mi, oi) in margins.iter().zip(out.iter_mut()) {
            *oi = sigmoid(*mi);
        }
    }

    fn logloss_sum(&self, y: &[f64], margins: &[f64]) -> f64 {
        assert_eq!(y.len(), margins.len());
        let mut acc = 0.0;
        for (yi, mi) in y.iter().zip(margins.iter()) {
            acc += log1p_exp(-yi * mi);
        }
        acc
    }

    fn logloss_grid(
        &self,
        y: &[f64],
        margins: &[f64],
        dmargins: &[f64],
        alphas: &[f64],
        out: &mut [f64],
    ) {
        assert_eq!(y.len(), margins.len());
        assert_eq!(y.len(), dmargins.len());
        assert_eq!(alphas.len(), out.len());
        out.fill(0.0);
        // i-outer / k-inner, matching `NativeCompute::loss_at_alphas`: the
        // margin row is read once per example, and each out[k] accumulates
        // its terms in example order.
        for i in 0..y.len() {
            let yi = y[i];
            let mi = margins[i];
            let di = dmargins[i];
            for (k, a) in alphas.iter().enumerate() {
                let yh = mi + a * di;
                out[k] += log1p_exp(-yi * yh);
            }
        }
    }
}
