//! The unified inner-loop kernel seam — exactly one implementation of each
//! hot-path primitive (DESIGN.md §Kernels).
//!
//! Every loop the solver spends its time in — the fused Σwx²/Σwxz
//! gather of the CD subproblem, the column scatter, the margin/β step
//! applies, the −wz gradient passes, and the logistic sigmoid/loss sweeps —
//! lives behind the [`CdKernels`] trait. Call sites (`solver/subproblem.rs`,
//! `solver/compute.rs`, `coordinator/worker.rs`, `sparse/{csc,csr}.rs`,
//! `glm/loss.rs`) dispatch through [`active()`], so swapping the
//! implementation is a process-wide mode flip, not a code change.
//!
//! Three modes ([`KernelMode`]):
//!
//! * `ScalarStrict` — the readable reference loops (the pre-refactor code,
//!   verbatim). Bit-exact by definition.
//! * `VectorStrict` (default) — 4-way manually unrolled loops with ONE
//!   sequential accumulator. Every floating-point addition happens in the
//!   same left-to-right order as the scalar loop, so the results are
//!   **bit-identical** to `ScalarStrict` — the hybrid/cluster oracles that
//!   pin 1e-12 (and the bit-exact `assert_eq!` suites) hold unchanged. The
//!   speedup comes from amortized loop control and hoisted bounds checks,
//!   not from reassociation.
//! * `FastMath` — the same unroll with FOUR independent accumulators
//!   combined as `(a0+a1)+(a2+a3)`. Reassociating the sum breaks bit
//!   reproducibility (tolerance tier: ≤ 1e-7 relative per primitive on
//!   finite inputs; ~1e-4 end-to-end, see the cluster oracle), which is why
//!   it is opt-in behind `--fast-math` and pinned in the v9 job spec —
//!   ranks can never silently mix modes.
//!
//! Element-wise primitives (scatter, step apply, −wz, sigmoid) carry no
//! accumulation order, so all three modes produce identical bits for them.

pub mod scalar;
pub mod vector;

pub use scalar::ScalarKernels;
pub use vector::VectorKernels;

use std::sync::atomic::{AtomicU8, Ordering};

/// Which kernel implementation [`active()`] dispatches to (process-global).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum KernelMode {
    /// Reference scalar loops (bit-exact baseline).
    ScalarStrict = 0,
    /// Unrolled, sequential-accumulator loops — bit-identical to scalar.
    VectorStrict = 1,
    /// Unrolled with split accumulators — reordered sums, opt-in.
    FastMath = 2,
}

static MODE: AtomicU8 = AtomicU8::new(KernelMode::VectorStrict as u8);

static SCALAR: ScalarKernels = ScalarKernels;
static VECTOR_STRICT: VectorKernels = VectorKernels { fast: false };
static VECTOR_FAST: VectorKernels = VectorKernels { fast: true };

/// Set the process-global kernel mode. Ranks pin this from the job spec
/// (`fast_math`, protocol v9) before any solver code runs; flipping it
/// mid-fit would mix tolerance tiers and is never done by the drivers.
pub fn set_mode(mode: KernelMode) {
    MODE.store(mode as u8, Ordering::Relaxed);
}

/// Current process-global kernel mode.
pub fn mode() -> KernelMode {
    match MODE.load(Ordering::Relaxed) {
        0 => KernelMode::ScalarStrict,
        1 => KernelMode::VectorStrict,
        _ => KernelMode::FastMath,
    }
}

/// Pin the mode from a job spec's `fast_math` field: `true` selects
/// [`KernelMode::FastMath`], `false` the strict default.
pub fn set_fast_math(on: bool) {
    set_mode(if on {
        KernelMode::FastMath
    } else {
        KernelMode::VectorStrict
    });
}

/// Whether the reordered-accumulation fast path is active.
pub fn fast_math_enabled() -> bool {
    mode() == KernelMode::FastMath
}

/// The kernel implementation for the current process-global mode. Hoist the
/// returned reference outside hot loops (one atomic load + vtable per call).
pub fn active() -> &'static dyn CdKernels {
    match mode() {
        KernelMode::ScalarStrict => &SCALAR,
        KernelMode::VectorStrict => &VECTOR_STRICT,
        KernelMode::FastMath => &VECTOR_FAST,
    }
}

/// The inner-loop primitives of Algorithms 1–3. Sparse methods take the raw
/// `(rows, vals)` column/row slices of the CSC/CSR layouts; dense methods
/// take whole margin-length vectors.
///
/// The three sparse gather/scatter methods are `unsafe`: they elide
/// per-entry bounds checks in the hottest loops of the solver (§Perf), so
/// the caller must guarantee every index in `rows` is in bounds for every
/// dense slice — which `Csc`/`Csr` construction plus the entry asserts of
/// `cd_cycle`/`axpy_col` establish once per call instead of once per entry.
pub trait CdKernels: Sync {
    /// Implementation name (bench labels / trace banners).
    fn name(&self) -> &'static str;

    /// Σᵢ valsᵢ · dense[rowsᵢ] — the sparse column (or row) dot product.
    ///
    /// # Safety
    /// Every index in `rows` must be < `dense.len()`.
    unsafe fn sparse_dot(&self, rows: &[u32], vals: &[f64], dense: &[f64]) -> f64;

    /// y[rowsᵢ] += coef · valsᵢ — the column scatter (element-wise: all
    /// modes produce identical bits).
    ///
    /// # Safety
    /// Every index in `rows` must be < `y.len()`.
    unsafe fn axpy_col(&self, rows: &[u32], vals: &[f64], coef: f64, y: &mut [f64]);

    /// The fused Algorithm-2 gather over one column:
    /// `s1 = Σᵢ wᵢ xᵢ (zᵢ − μ tᵢ)`, `s2 = Σᵢ wᵢ xᵢ²` in ONE pass.
    ///
    /// # Safety
    /// Every index in `rows` must be < `w.len()`, `z.len()` and `t.len()`.
    unsafe fn col_weighted_quad(
        &self,
        rows: &[u32],
        vals: &[f64],
        w: &[f64],
        z: &[f64],
        t: &[f64],
        mu: f64,
    ) -> (f64, f64);

    /// Σᵢ valsᵢ² — squared L2 norm of a value slice.
    fn sq_norm(&self, vals: &[f64]) -> f64;

    /// y ← y + α·d over dense vectors — the fused margin/β step apply
    /// (merges the margin update with the line-search XΔβ accumulation;
    /// with α = 1 it is the exact hybrid-partial accumulate). Element-wise:
    /// identical bits in every mode.
    fn margin_update_with_xdelta(&self, y: &mut [f64], d: &[f64], alpha: f64);

    /// Σᵢ −wᵢ zᵢ dᵢ — ∇L(β)ᵀΔβ from the cached working set
    /// (gᵢ = −wᵢzᵢ exactly, z = −g/w with the same floored w).
    fn neg_wz_dot(&self, w: &[f64], z: &[f64], d: &[f64]) -> f64;

    /// outᵢ = −wᵢ zᵢ — the screening-gradient working vector
    /// (element-wise: identical bits in every mode).
    fn neg_wz(&self, w: &[f64], z: &[f64], out: &mut [f64]);

    /// outᵢ = σ(marginsᵢ) — the batched inverse logistic link
    /// (element-wise: identical bits in every mode).
    fn sigmoid_margins(&self, margins: &[f64], out: &mut [f64]);

    /// Σᵢ log(1 + exp(−yᵢ mᵢ)) — total logistic loss at the margins.
    fn logloss_sum(&self, y: &[f64], margins: &[f64]) -> f64;

    /// out[k] = Σᵢ log(1 + exp(−yᵢ (mᵢ + αₖ dᵢ))) — the batched
    /// line-search loss grid (i-outer/k-inner, matching the reference).
    fn logloss_grid(
        &self,
        y: &[f64],
        margins: &[f64],
        dmargins: &[f64],
        alphas: &[f64],
        out: &mut [f64],
    );
}

/// log(1 + exp(x)) computed without overflow for large |x| — the canonical
/// implementation (was duplicated across `util/stats.rs` and callers).
#[inline]
pub fn log1p_exp(x: f64) -> f64 {
    if x > 35.0 {
        x
    } else if x < -35.0 {
        x.exp() // ~0, but keeps derivative continuity in tests
    } else {
        x.exp().ln_1p()
    }
}

/// Numerically stable sigmoid — the canonical implementation (was
/// `util/stats.rs:72` AND an implicit duplicate inside `glm/loss.rs`).
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        let e = (-x).exp();
        1.0 / (1.0 + e)
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The mode cell is process-global and tests run multi-threaded in one
    /// process, so tests never flip it — they only check the default and
    /// the enum round trip on a value-level basis.
    #[test]
    fn default_mode_is_vector_strict() {
        assert_eq!(mode(), KernelMode::VectorStrict);
        assert!(!fast_math_enabled());
        assert_eq!(active().name(), "vector-strict");
    }

    #[test]
    fn mode_discriminants_roundtrip() {
        for m in [
            KernelMode::ScalarStrict,
            KernelMode::VectorStrict,
            KernelMode::FastMath,
        ] {
            let back = match m as u8 {
                0 => KernelMode::ScalarStrict,
                1 => KernelMode::VectorStrict,
                _ => KernelMode::FastMath,
            };
            assert_eq!(back, m);
        }
    }

    #[test]
    fn impl_names_distinct() {
        assert_eq!(ScalarKernels.name(), "scalar");
        assert_eq!(VectorKernels { fast: false }.name(), "vector-strict");
        assert_eq!(VectorKernels { fast: true }.name(), "vector-fast");
    }

    #[test]
    fn sigmoid_props() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!((sigmoid(100.0) - 1.0).abs() < 1e-15);
        assert!(sigmoid(-100.0) < 1e-15);
        for x in [-3.0, -1.0, 0.5, 2.0] {
            assert!((sigmoid(x) + sigmoid(-x) - 1.0).abs() < 1e-15);
        }
    }

    #[test]
    fn log1p_exp_stable() {
        assert!((log1p_exp(0.0) - std::f64::consts::LN_2).abs() < 1e-15);
        assert!((log1p_exp(1000.0) - 1000.0).abs() < 1e-9);
        assert!(log1p_exp(-1000.0).abs() < 1e-15);
        for x in [-20.0, -3.0, 0.7, 15.0] {
            assert!((log1p_exp(x) - log1p_exp(-x) - x).abs() < 1e-12);
        }
    }
}
