//! Named metrics registry: counters, gauges, and latency histograms.
//!
//! Thin and std-only: instruments get-or-create a named handle once
//! (one `Mutex`-guarded map lookup), then record through lock-free
//! atomics — [`Counter`] is a monotonic `AtomicU64`, [`Gauge`] stores
//! `f64` bits in an `AtomicU64`, and the histogram type is the existing
//! lock-free [`LatencyHistogram`](crate::metrics::latency::LatencyHistogram)
//! from the serving path. [`Registry::snapshot`] renders everything as one
//! JSON object — the payload behind the worker protocol's `stats` control
//! frame and `dglmnet serve`'s `{"op":"stats"}` admin endpoint.
//!
//! [`global()`] is the process-wide registry used by subsystems without a
//! natural owner (transport link health, worker job counts); components
//! with their own lifecycle can hold a private `Registry`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::metrics::latency::LatencyHistogram;
use crate::util::json::Json;

/// Monotonic counter handle (cheap to clone; clones share the cell).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge handle (bits in an `AtomicU64`).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Named counters/gauges/histograms with a consistent JSON snapshot.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histograms: Mutex<BTreeMap<String, Arc<LatencyHistogram>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Counter {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Counter(Arc::new(AtomicU64::new(0))))
            .clone()
    }

    /// Get or create the gauge `name` (initial value 0.0).
    pub fn gauge(&self, name: &str) -> Gauge {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Gauge(Arc::new(AtomicU64::new(0f64.to_bits()))))
            .clone()
    }

    /// Get or create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<LatencyHistogram> {
        Arc::clone(
            self.histograms
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(LatencyHistogram::new())),
        )
    }

    /// One JSON object over every instrument:
    /// `{"counters":{..},"gauges":{..},"histograms":{..}}`. Counters are
    /// monotone, so two snapshots taken around concurrent recording bound
    /// each counter's true value from below and above.
    pub fn snapshot(&self) -> Json {
        let mut counters = Json::obj();
        for (k, c) in self.counters.lock().unwrap().iter() {
            counters.set(k, c.get());
        }
        let mut gauges = Json::obj();
        for (k, g) in self.gauges.lock().unwrap().iter() {
            gauges.set(k, g.get());
        }
        let mut hists = Json::obj();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            hists.set(k, h.to_json());
        }
        let mut o = Json::obj();
        o.set("counters", counters).set("gauges", gauges).set("histograms", hists);
        o
    }
}

/// The process-wide registry.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("jobs");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same name → same cell.
        assert_eq!(r.counter("jobs").get(), 5);
        let g = r.gauge("objective");
        g.set(0.482913);
        assert_eq!(r.gauge("objective").get(), 0.482913);
    }

    #[test]
    fn snapshot_shape_is_parseable() {
        let r = Registry::new();
        r.counter("a").add(2);
        r.gauge("b").set(1.5);
        r.histogram("lat").record_ns(1_000_000);
        let s = r.snapshot().dump();
        let v = crate::util::json::parse(&s).unwrap();
        assert_eq!(v.get("counters").unwrap().get("a").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("gauges").unwrap().get("b").unwrap().as_f64(), Some(1.5));
        let lat = v.get("histograms").unwrap().get("lat").unwrap();
        assert_eq!(lat.get("count").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn snapshots_are_consistent_under_concurrent_recorders() {
        let r = Registry::new();
        let c = r.counter("hits");
        let threads = 4;
        let per_thread = 10_000u64;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..per_thread {
                        c.inc();
                    }
                });
            }
            // Snapshots taken mid-storm must be monotone non-decreasing
            // and never exceed the eventual total.
            let mut last = 0.0;
            for _ in 0..50 {
                let snap = r.snapshot();
                let v = snap
                    .get("counters")
                    .and_then(|c| c.get("hits"))
                    .and_then(|x| x.as_f64())
                    .unwrap();
                assert!(v >= last, "counter went backwards: {v} < {last}");
                assert!(v <= (threads * per_thread) as f64);
                last = v;
            }
        });
        assert_eq!(c.get(), threads * per_thread);
    }

    #[test]
    fn global_registry_is_shared() {
        let name = "obs.metrics.test.global";
        let c = global().counter(name);
        let before = c.get();
        global().counter(name).inc();
        assert_eq!(c.get(), before + 1);
    }
}
