//! Span tracing: monotonic-clock phase timings collected into a lock-free
//! per-rank ring-buffer journal.
//!
//! The worker loop opens one span per phase per outer iteration (`cd`,
//! `sync`, `linesearch`, `comm`, plus `cd_wave` sub-spans under hybrid
//! threading) and records the wall time and the transport bytes the phase
//! moved. Journals are bounded: a record past capacity is counted in
//! `dropped()` instead of reallocating — recording never blocks or
//! allocates, so the overhead per span is two `Instant::now()` calls and
//! one relaxed `fetch_add` (≪ 1 µs against multi-ms phases).
//!
//! At the end of a run each rank drains its journal into the
//! [`WorkerOutput`](crate::coordinator::WorkerOutput); multi-process
//! workers ship the records in the job-spec v5 done report, and the
//! coordinator merges all ranks into one run log (`--trace-out`,
//! rendered by `dglmnet trace-report` — see [`runlog`](super::runlog)).
//!
//! Timestamps are f64 seconds relative to the journal's creation (its
//! *epoch* — one per rank, all started at job begin), which survives the
//! JSON `f64` number model exactly, unlike nanosecond integers.

use std::cell::Cell;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::Instant;

use crate::util::json::Json;

/// One finished span.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRecord {
    /// Rank whose journal recorded this span.
    pub rank: usize,
    /// Outer iteration the span belongs to (0 = setup / initial eval).
    pub iter: u64,
    /// Phase name: `cd`, `cd_wave`, `sync`, `linesearch`, `comm`, ...
    pub phase: String,
    /// Start, seconds since the journal epoch.
    pub start_s: f64,
    /// Duration in seconds.
    pub dur_s: f64,
    /// Transport bytes attributed to the phase (0 when not measured).
    pub bytes: u64,
    /// Nesting depth at start (0 = top level) on the recording thread.
    pub depth: u32,
}

impl SpanRecord {
    /// Full object form, used for the merged run-log NDJSON.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("type", "span")
            .set("rank", self.rank)
            .set("iter", self.iter)
            .set("phase", self.phase.as_str())
            .set("t", self.start_s)
            .set("dur", self.dur_s)
            .set("bytes", self.bytes)
            .set("depth", self.depth as u64);
        o
    }

    pub fn from_json(v: &Json) -> Option<SpanRecord> {
        Some(SpanRecord {
            rank: v.get("rank")?.as_f64()? as usize,
            iter: v.get("iter")?.as_f64()? as u64,
            phase: v.get("phase")?.as_str()?.to_string(),
            start_s: v.get("t")?.as_f64()?,
            dur_s: v.get("dur")?.as_f64()?,
            bytes: v.get("bytes")?.as_f64()? as u64,
            depth: v.get("depth")?.as_f64()? as u32,
        })
    }

    /// Compact array form `[iter, phase, t, dur, bytes, depth]` for the
    /// done report (the rank is implied by the report's sender).
    pub fn to_compact(&self) -> Json {
        Json::Arr(vec![
            Json::from(self.iter),
            Json::from(self.phase.as_str()),
            Json::from(self.start_s),
            Json::from(self.dur_s),
            Json::from(self.bytes),
            Json::from(self.depth as u64),
        ])
    }

    pub fn from_compact(rank: usize, v: &Json) -> Option<SpanRecord> {
        let a = match v {
            Json::Arr(a) if a.len() == 6 => a,
            _ => return None,
        };
        Some(SpanRecord {
            rank,
            iter: a[0].as_f64()? as u64,
            phase: a[1].as_str()?.to_string(),
            start_s: a[2].as_f64()?,
            dur_s: a[3].as_f64()?,
            bytes: a[4].as_f64()? as u64,
            depth: a[5].as_f64()? as u32,
        })
    }
}

/// An open span: created by [`Journal::start`], closed by
/// [`Journal::finish`] (or `finish_with_bytes`). Start and finish must
/// happen on the same thread for the nesting depth to be meaningful.
#[must_use = "finish the span via Journal::finish"]
pub struct ActiveSpan {
    iter: u64,
    phase: &'static str,
    t0: Instant,
    depth: u32,
}

thread_local! {
    /// Per-thread open-span count: the depth recorded on each span.
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

struct Slot {
    filled: AtomicBool,
    rec: UnsafeCell<Option<SpanRecord>>,
}

/// Bounded multi-producer span journal: writers claim a slot with one
/// `fetch_add` and publish with a release store; `drain` reads with
/// acquire loads, so records written before a drain are fully visible.
pub struct Journal {
    rank: usize,
    epoch: Instant,
    slots: Box<[Slot]>,
    head: AtomicUsize,
    dropped: AtomicUsize,
}

// Slots are published through the per-slot `filled` release/acquire pair.
unsafe impl Sync for Journal {}

/// Default capacity: comfortably above max_iters × (phases + hybrid waves).
pub const DEFAULT_CAPACITY: usize = 1 << 14;

impl Journal {
    pub fn new(rank: usize, capacity: usize) -> Journal {
        assert!(capacity > 0, "journal capacity must be positive");
        Journal {
            rank,
            epoch: Instant::now(),
            slots: (0..capacity)
                .map(|_| Slot {
                    filled: AtomicBool::new(false),
                    rec: UnsafeCell::new(None),
                })
                .collect(),
            head: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
        }
    }

    pub fn with_default_capacity(rank: usize) -> Journal {
        Journal::new(rank, DEFAULT_CAPACITY)
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Seconds elapsed since the journal epoch.
    pub fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Open a span for `phase` of outer iteration `iter`.
    pub fn start(&self, iter: u64, phase: &'static str) -> ActiveSpan {
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v + 1);
            v
        });
        ActiveSpan {
            iter,
            phase,
            t0: Instant::now(),
            depth,
        }
    }

    pub fn finish(&self, span: ActiveSpan) {
        self.finish_with_bytes(span, 0);
    }

    /// Close `span`, attributing `bytes` of transport traffic to it.
    pub fn finish_with_bytes(&self, span: ActiveSpan, bytes: u64) {
        let dur_s = span.t0.elapsed().as_secs_f64();
        let start_s = span.t0.duration_since(self.epoch).as_secs_f64();
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        self.record(SpanRecord {
            rank: self.rank,
            iter: span.iter,
            phase: span.phase.to_string(),
            start_s,
            dur_s,
            bytes,
            depth: span.depth,
        });
    }

    /// Push a pre-built record (events, tests). Lock-free; drops past
    /// capacity.
    pub fn record(&self, rec: SpanRecord) {
        let idx = self.head.fetch_add(1, Ordering::Relaxed);
        if idx >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let slot = &self.slots[idx];
        // SAFETY: fetch_add hands each writer a distinct index, so this
        // slot is written exactly once; readers only look after `filled`
        // is set with release ordering.
        unsafe {
            *slot.rec.get() = Some(rec);
        }
        slot.filled.store(true, Ordering::Release);
    }

    /// Records accepted so far (excludes dropped).
    pub fn len(&self) -> usize {
        self.head.load(Ordering::Relaxed).min(self.slots.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Records rejected because the journal was full.
    pub fn dropped(&self) -> usize {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Snapshot every published record, ordered by start time. Records
    /// claimed but not yet published (a concurrent writer mid-`record`)
    /// are skipped, so draining is safe at any time.
    pub fn drain(&self) -> Vec<SpanRecord> {
        let n = self.len();
        let mut out = Vec::with_capacity(n);
        for slot in self.slots.iter().take(n) {
            if slot.filled.load(Ordering::Acquire) {
                // SAFETY: `filled` was set with release ordering after the
                // one-time write, so the record is fully initialized.
                if let Some(rec) = unsafe { (*slot.rec.get()).clone() } {
                    out.push(rec);
                }
            }
        }
        out.sort_by(|a, b| a.start_s.total_cmp(&b.start_s));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::ScopedPool;

    #[test]
    fn span_records_duration_and_depth() {
        let j = Journal::new(3, 16);
        let outer = j.start(1, "cd");
        let inner = j.start(1, "cd_wave");
        std::thread::sleep(std::time::Duration::from_millis(2));
        j.finish_with_bytes(inner, 40);
        j.finish(outer);
        let recs = j.drain();
        assert_eq!(recs.len(), 2);
        // Sorted by start: outer opened first.
        assert_eq!(recs[0].phase, "cd");
        assert_eq!(recs[0].depth, 0);
        assert_eq!(recs[1].phase, "cd_wave");
        assert_eq!(recs[1].depth, 1);
        assert_eq!(recs[1].bytes, 40);
        assert!(recs[1].dur_s >= 0.002);
        assert!(recs[0].dur_s >= recs[1].dur_s);
        assert_eq!(recs[0].rank, 3);
    }

    #[test]
    fn nesting_depth_restored_after_finish() {
        let j = Journal::new(0, 16);
        let a = j.start(1, "cd");
        j.finish(a);
        let b = j.start(2, "sync");
        j.finish(b);
        let recs = j.drain();
        assert_eq!(recs[0].depth, 0);
        assert_eq!(recs[1].depth, 0);
    }

    #[test]
    fn capacity_overflow_counts_drops() {
        let j = Journal::new(0, 4);
        for i in 0..10u64 {
            let s = j.start(i, "cd");
            j.finish(s);
        }
        assert_eq!(j.len(), 4);
        assert_eq!(j.dropped(), 6);
        assert_eq!(j.drain().len(), 4);
    }

    #[test]
    fn concurrent_recording_under_scoped_pool_loses_nothing() {
        let threads = 4;
        let per_thread = 50u64;
        let j = Journal::new(0, (threads as usize) * per_thread as usize);
        let pool = ScopedPool::new(threads as usize);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..threads)
            .map(|t| {
                let j = &j;
                Box::new(move || {
                    for i in 0..per_thread {
                        let s = j.start(i, "cd_wave");
                        j.finish_with_bytes(s, t as u64);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run(jobs);
        let recs = j.drain();
        assert_eq!(recs.len(), (threads * per_thread) as usize);
        assert_eq!(j.dropped(), 0);
        // Per-thread ordering: each thread's spans (keyed by its bytes
        // stamp) must appear in increasing iter order after the global
        // start_s sort — start times on one thread are monotone.
        for t in 0..threads {
            let iters: Vec<u64> = recs
                .iter()
                .filter(|r| r.bytes == t as u64)
                .map(|r| r.iter)
                .collect();
            assert_eq!(iters.len(), per_thread as usize);
            assert!(iters.windows(2).all(|w| w[0] < w[1]), "thread {t}: {iters:?}");
        }
    }

    #[test]
    fn json_and_compact_roundtrip() {
        let rec = SpanRecord {
            rank: 2,
            iter: 7,
            phase: "linesearch".into(),
            start_s: 1.25,
            dur_s: 0.03125,
            bytes: 4096,
            depth: 1,
        };
        assert_eq!(SpanRecord::from_json(&rec.to_json()).unwrap(), rec);
        let compact = rec.to_compact();
        let parsed = crate::util::json::parse(&compact.dump()).unwrap();
        assert_eq!(SpanRecord::from_compact(2, &parsed).unwrap(), rec);
        assert!(SpanRecord::from_compact(2, &Json::from(vec![1.0])).is_none());
    }
}
