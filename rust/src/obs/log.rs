//! Std-only leveled structured logger — the single narration channel for
//! library code (the `println!` family is clippy-banned outside `main.rs`
//! and the sanctioned sinks in this module; see `clippy.toml`).
//!
//! Records carry a target (subsystem name), a level, a message, and typed
//! key/value fields, and render either as aligned text or as NDJSON — one
//! JSON object per line — on stderr, so protocol stdout (the worker's
//! scrapeable `listening` line, serve's NDJSON responses) stays clean.
//! A per-process rank prefix makes multi-process cluster logs mergeable.
//!
//! Control surface:
//! * `DGLMNET_LOG=level[,json]` — e.g. `DGLMNET_LOG=debug` or
//!   `DGLMNET_LOG=trace,json` (read once, lazily).
//! * `--log-level` on the CLIs calls [`set_level`] and wins over the env.
//!
//! Call sites use the `obs_error!`/`obs_warn!`/`obs_info!`/`obs_debug!`/
//! `obs_trace!` macros: `crate::obs_warn!("tcp", "dropping link",
//! from = rank, len = len64);` — fields are anything `Into<Json>`.

use std::sync::atomic::{AtomicI64, AtomicU8, Ordering};
use std::sync::{Once, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Severity, ordered: a configured level enables itself and everything
/// more severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    /// Parse a level name (case-insensitive). `None` on unknown names.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

/// Output shape for log records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Text,
    Ndjson,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static FORMAT: AtomicU8 = AtomicU8::new(0); // 0 = text, 1 = ndjson
static RANK: AtomicI64 = AtomicI64::new(-1); // -1 = no rank prefix
static ENV_INIT: Once = Once::new();

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Apply `DGLMNET_LOG=level[,json]` once; later explicit `set_*` calls win.
fn ensure_env_init() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("DGLMNET_LOG") {
            for part in spec.split(',') {
                if let Some(l) = Level::parse(part) {
                    LEVEL.store(l as u8, Ordering::Relaxed);
                } else if part.trim().eq_ignore_ascii_case("json") {
                    FORMAT.store(1, Ordering::Relaxed);
                }
            }
        }
        // Pin the epoch so the first record's timestamp is ~0.
        let _ = epoch();
    });
}

pub fn set_level(l: Level) {
    ensure_env_init();
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    ensure_env_init();
    Level::from_u8(LEVEL.load(Ordering::Relaxed))
}

pub fn set_format(f: Format) {
    ensure_env_init();
    FORMAT.store(if f == Format::Ndjson { 1 } else { 0 }, Ordering::Relaxed);
}

/// Tag every subsequent record with this cluster rank.
pub fn set_rank(rank: usize) {
    RANK.store(rank as i64, Ordering::Relaxed);
}

/// Is `l` currently enabled? The macros check this before formatting.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Render one record as aligned text (no trailing newline).
pub fn format_text(
    t_s: f64,
    l: Level,
    rank: i64,
    target: &str,
    msg: &str,
    fields: &[(&str, Json)],
) -> String {
    let mut s = format!("[{t_s:9.3}] {:<5} ", l.name().to_ascii_uppercase());
    if rank >= 0 {
        s.push_str(&format!("[rank {rank}] "));
    }
    s.push_str(target);
    s.push_str(": ");
    s.push_str(msg);
    for (k, v) in fields {
        // Strings print bare (k=value); everything else as compact JSON.
        match v {
            Json::Str(x) => s.push_str(&format!(" {k}={x}")),
            other => s.push_str(&format!(" {k}={}", other.dump())),
        }
    }
    s
}

/// Render one record as a single NDJSON object (no trailing newline).
pub fn format_ndjson(
    t_s: f64,
    l: Level,
    rank: i64,
    target: &str,
    msg: &str,
    fields: &[(&str, Json)],
) -> String {
    let mut o = Json::obj();
    o.set("t", t_s).set("level", l.name()).set("target", target).set("msg", msg);
    if rank >= 0 {
        o.set("rank", rank);
    }
    for (k, v) in fields {
        o.set(k, v.clone());
    }
    o.dump()
}

/// Emit one structured record (the macros are the intended entry point).
pub fn log(l: Level, target: &str, msg: &str, fields: &[(&str, Json)]) {
    if !enabled(l) {
        return;
    }
    let t_s = epoch().elapsed().as_secs_f64();
    let rank = RANK.load(Ordering::Relaxed);
    let line = if FORMAT.load(Ordering::Relaxed) == 1 {
        format_ndjson(t_s, l, rank, target, msg, fields)
    } else {
        format_text(t_s, l, rank, target, msg, fields)
    };
    emit_stderr(&line);
}

/// Sanctioned stderr sink (log records, user-facing errors routed by lib
/// code). The one place stderr printing is allowed outside `main.rs`.
#[allow(clippy::disallowed_macros)]
pub fn emit_stderr(line: &str) {
    eprintln!("{line}");
}

/// Sanctioned stdout sink for *user-facing* output produced inside the
/// library: report tables, bench rows, and the worker's scrapeable
/// `listening` line. Diagnostic narration belongs in [`log`], not here.
#[allow(clippy::disallowed_macros)]
pub fn emit(line: &str) {
    println!("{line}");
}

/// Leveled structured logging: `obs_log!(level, target, msg, k = v, ...)`.
/// Prefer the per-level wrappers below.
#[macro_export]
macro_rules! obs_log {
    ($lvl:expr, $target:expr, $msg:expr $(, $k:ident = $v:expr)* $(,)?) => {
        if $crate::obs::log::enabled($lvl) {
            $crate::obs::log::log(
                $lvl,
                $target,
                &$msg,
                &[$((stringify!($k), $crate::util::json::Json::from($v))),*],
            );
        }
    };
}

#[macro_export]
macro_rules! obs_error {
    ($($a:tt)*) => { $crate::obs_log!($crate::obs::log::Level::Error, $($a)*) };
}
#[macro_export]
macro_rules! obs_warn {
    ($($a:tt)*) => { $crate::obs_log!($crate::obs::log::Level::Warn, $($a)*) };
}
#[macro_export]
macro_rules! obs_info {
    ($($a:tt)*) => { $crate::obs_log!($crate::obs::log::Level::Info, $($a)*) };
}
#[macro_export]
macro_rules! obs_debug {
    ($($a:tt)*) => { $crate::obs_log!($crate::obs::log::Level::Debug, $($a)*) };
}
#[macro_export]
macro_rules! obs_trace {
    ($($a:tt)*) => { $crate::obs_log!($crate::obs::log::Level::Trace, $($a)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn text_format_includes_rank_and_fields() {
        let s = format_text(
            1.5,
            Level::Warn,
            2,
            "tcp",
            "dropping link",
            &[("from", Json::from(3u64)), ("why", Json::from("corrupt"))],
        );
        assert!(s.contains("WARN"), "{s}");
        assert!(s.contains("[rank 2]"), "{s}");
        assert!(s.contains("tcp: dropping link"), "{s}");
        assert!(s.contains("from=3"), "{s}");
        assert!(s.contains("why=corrupt"), "{s}");
    }

    #[test]
    fn ndjson_format_is_parseable() {
        let s = format_ndjson(
            0.25,
            Level::Info,
            0,
            "worker",
            "done",
            &[("iters", Json::from(12u64))],
        );
        let v = crate::util::json::parse(&s).unwrap();
        assert_eq!(v.get("level").unwrap().as_str(), Some("info"));
        assert_eq!(v.get("target").unwrap().as_str(), Some("worker"));
        assert_eq!(v.get("iters").unwrap().as_f64(), Some(12.0));
        assert_eq!(v.get("rank").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn ndjson_format_omits_unset_rank() {
        let s = format_ndjson(0.0, Level::Error, -1, "t", "m", &[]);
        let v = crate::util::json::parse(&s).unwrap();
        assert!(v.get("rank").is_none());
    }

    #[test]
    fn enabled_gates_by_severity() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(prev);
    }
}
