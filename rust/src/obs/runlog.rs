//! The merged run log: one NDJSON file per cluster run, and the
//! `dglmnet trace-report` rendering over it.
//!
//! Line shapes (one JSON object per line, keyed by `"type"`):
//! * `run` — one header: dataset, cluster width, iterations, comm totals.
//! * `rank` — one per rank: the `RankLoad` aggregate (cd updates, passes,
//!   cutoffs, sent bytes/msgs, sync wait, threads).
//! * `span` — one per recorded span (see [`SpanRecord`]): rank, iter,
//!   phase, start, duration, bytes, depth.
//!
//! The coordinator writes this file via `--trace-out` after merging every
//! rank's journal (shipped in the job-spec v5 done report for real
//! processes, returned in `WorkerOutput` in-process). `trace-report`
//! parses it back and renders per-rank phase totals, the per-iteration ×
//! per-rank breakdown, the iteration skew table, and a reconciliation of
//! journal sync time against the `RankLoad` sync-wait column.

use std::collections::BTreeMap;

use crate::obs::span::SpanRecord;
use crate::util::bench::Table;
use crate::util::json::{self, Json};

/// The outer-loop phases every iteration is split into (top-level spans;
/// `cd_wave` sub-spans nest under `cd` and are excluded from totals).
pub const PHASES: [&str; 4] = ["cd", "sync", "linesearch", "comm"];

/// A parsed run log.
pub struct RunLog {
    pub header: Json,
    pub ranks: Vec<Json>,
    pub spans: Vec<SpanRecord>,
}

/// Render the NDJSON body: header line, rank lines, span lines.
pub fn render(header: &Json, ranks: &[Json], spans: &[SpanRecord]) -> String {
    let mut out = String::new();
    let mut h = header.clone();
    if h.get("type").is_none() {
        h.set("type", "run");
    }
    out.push_str(&h.dump());
    out.push('\n');
    for r in ranks {
        let mut r = r.clone();
        if r.get("type").is_none() {
            r.set("type", "rank");
        }
        out.push_str(&r.dump());
        out.push('\n');
    }
    for s in spans {
        out.push_str(&s.to_json().dump());
        out.push('\n');
    }
    out
}

/// Parse an NDJSON run log. Unknown record types are skipped (forward
/// compatibility); malformed JSON or malformed known records are errors.
pub fn parse(src: &str) -> Result<RunLog, String> {
    let mut header = None;
    let mut ranks = Vec::new();
    let mut spans = Vec::new();
    for (lineno, line) in src.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        match v.get("type").and_then(|t| t.as_str()) {
            Some("run") => header = Some(v),
            Some("rank") => ranks.push(v),
            Some("span") => spans.push(
                SpanRecord::from_json(&v)
                    .ok_or_else(|| format!("line {}: malformed span record", lineno + 1))?,
            ),
            Some(_) => {} // future record types
            None => return Err(format!("line {}: record without a type", lineno + 1)),
        }
    }
    let header = header.ok_or("missing run header record")?;
    ranks.sort_by_key(|r| r.get("rank").and_then(|x| x.as_f64()).unwrap_or(-1.0) as i64);
    Ok(RunLog { header, ranks, spans })
}

fn num(v: &Json, key: &str) -> f64 {
    v.get(key).and_then(|x| x.as_f64()).unwrap_or(0.0)
}

fn ms(secs: f64) -> String {
    format!("{:.3}", secs * 1e3)
}

/// Per-(iter, rank) phase durations, top-level spans only.
type PhaseGrid = BTreeMap<(u64, usize), [f64; PHASES.len()]>;

fn phase_grid(spans: &[SpanRecord]) -> PhaseGrid {
    let mut grid: PhaseGrid = BTreeMap::new();
    for s in spans {
        if s.depth != 0 {
            continue;
        }
        if let Some(p) = PHASES.iter().position(|p| *p == s.phase) {
            grid.entry((s.iter, s.rank)).or_default()[p] += s.dur_s;
        }
    }
    grid
}

/// Render the full `trace-report` text for a parsed run log.
pub fn report(log: &RunLog) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "trace-report: dataset={} nodes={} iters={} | {} spans from {} ranks\n",
        log.header.get("dataset").and_then(|d| d.as_str()).unwrap_or("?"),
        num(&log.header, "nodes"),
        num(&log.header, "iters"),
        log.spans.len(),
        log.ranks.len(),
    ));

    let grid = phase_grid(&log.spans);
    let ranks: Vec<usize> = {
        let mut r: Vec<usize> = grid.keys().map(|(_, rank)| *rank).collect();
        r.sort_unstable();
        r.dedup();
        r
    };

    // Per-rank phase totals + comm bytes attributed by spans.
    let mut totals: BTreeMap<usize, [f64; PHASES.len()]> = BTreeMap::new();
    let mut bytes_by_rank: BTreeMap<usize, u64> = BTreeMap::new();
    for ((_, rank), phases) in &grid {
        let t = totals.entry(*rank).or_default();
        for (i, d) in phases.iter().enumerate() {
            t[i] += d;
        }
    }
    for s in &log.spans {
        *bytes_by_rank.entry(s.rank).or_default() += s.bytes;
    }
    out.push_str("\n== per-rank phase totals (s) ==\n");
    let mut t = Table::new(&["rank", "cd", "sync", "linesearch", "comm", "total", "sent MiB"]);
    for rank in &ranks {
        let p = totals.get(rank).copied().unwrap_or_default();
        let total: f64 = p.iter().sum();
        t.row(&[
            rank.to_string(),
            format!("{:.3}", p[0]),
            format!("{:.3}", p[1]),
            format!("{:.3}", p[2]),
            format!("{:.3}", p[3]),
            format!("{total:.3}"),
            format!(
                "{:.2}",
                bytes_by_rank.get(rank).copied().unwrap_or(0) as f64 / (1024.0 * 1024.0)
            ),
        ]);
    }
    out.push_str(&t.render());

    // Reconciliation: the journal's sync total vs the RankLoad aggregate.
    for r in &log.ranks {
        let rank = num(r, "rank") as usize;
        let load_sync = num(r, "sync_wait_secs");
        let journal_sync = totals.get(&rank).map(|p| p[1]).unwrap_or(0.0);
        let delta_pct = if load_sync > 0.0 {
            (journal_sync - load_sync).abs() / load_sync * 100.0
        } else {
            0.0
        };
        out.push_str(&format!(
            "sync reconcile rank {rank}: journal {journal_sync:.4}s vs rank-load {load_sync:.4}s (Δ {delta_pct:.2}%)\n",
        ));
    }

    // Per-iteration × per-rank breakdown.
    out.push_str("\n== per-iteration per-rank phase breakdown (ms) ==\n");
    let mut t = Table::new(&["iter", "rank", "cd", "sync", "linesearch", "comm", "total"]);
    for ((iter, rank), p) in &grid {
        let total: f64 = p.iter().sum();
        t.row(&[
            iter.to_string(),
            rank.to_string(),
            ms(p[0]),
            ms(p[1]),
            ms(p[2]),
            ms(p[3]),
            ms(total),
        ]);
    }
    out.push_str(&t.render());

    // Iteration skew: the BSP straggler story, per iteration.
    out.push_str("\n== iteration skew (max-min rank total, ms) ==\n");
    let mut t = Table::new(&["iter", "fastest", "slowest", "skew", "slow rank"]);
    let iters: Vec<u64> = {
        let mut v: Vec<u64> = grid.keys().map(|(it, _)| *it).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    for iter in iters {
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut slow_rank = 0usize;
        for rank in &ranks {
            if let Some(p) = grid.get(&(iter, *rank)) {
                let total: f64 = p.iter().sum();
                min = min.min(total);
                if total > max {
                    max = total;
                    slow_rank = *rank;
                }
            }
        }
        if !min.is_finite() || !max.is_finite() {
            continue;
        }
        t.row(&[
            iter.to_string(),
            ms(min),
            ms(max),
            ms(max - min),
            slow_rank.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(rank: usize, iter: u64, phase: &str, start_s: f64, dur_s: f64) -> SpanRecord {
        SpanRecord {
            rank,
            iter,
            phase: phase.to_string(),
            start_s,
            dur_s,
            bytes: 128,
            depth: 0,
        }
    }

    fn sample_log() -> (Json, Vec<Json>, Vec<SpanRecord>) {
        let mut header = Json::obj();
        header.set("dataset", "epsilon_like").set("nodes", 2usize).set("iters", 2usize);
        let ranks = (0..2usize)
            .map(|r| {
                let mut o = Json::obj();
                o.set("rank", r).set("sync_wait_secs", 0.010).set("cd_updates", 100usize);
                o
            })
            .collect();
        let mut spans = Vec::new();
        for rank in 0..2usize {
            for iter in 1..=2u64 {
                let base = iter as f64;
                spans.push(span(rank, iter, "cd", base, 0.020));
                spans.push(span(rank, iter, "sync", base + 0.02, 0.005));
                spans.push(span(rank, iter, "linesearch", base + 0.025, 0.003));
                spans.push(span(rank, iter, "comm", base + 0.028, 0.002));
            }
        }
        (header, ranks, spans)
    }

    #[test]
    fn ndjson_roundtrip_preserves_everything() {
        let (header, ranks, spans) = sample_log();
        let body = render(&header, &ranks, &spans);
        let log = parse(&body).unwrap();
        assert_eq!(log.ranks.len(), 2);
        assert_eq!(log.spans.len(), spans.len());
        assert_eq!(log.spans, spans);
        assert_eq!(
            log.header.get("dataset").unwrap().as_str(),
            Some("epsilon_like")
        );
        // Render → parse → render is a fixed point.
        assert_eq!(render(&log.header, &log.ranks, &log.spans), body);
    }

    #[test]
    fn parse_rejects_garbage_and_missing_header() {
        assert!(parse("not json\n").is_err());
        assert!(parse("{\"type\":\"span\"}\n").is_err(), "malformed span");
        let only_rank = "{\"rank\":0,\"type\":\"rank\"}\n";
        assert!(parse(only_rank).is_err(), "missing run header");
        // Unknown types are tolerated once a header exists.
        let ok = "{\"type\":\"run\"}\n{\"type\":\"future-thing\",\"x\":1}\n";
        assert!(parse(ok).is_ok());
    }

    #[test]
    fn report_contains_breakdown_and_skew() {
        let (header, ranks, spans) = sample_log();
        let log = parse(&render(&header, &ranks, &spans)).unwrap();
        let rep = report(&log);
        assert!(rep.contains("per-rank phase totals"), "{rep}");
        assert!(rep.contains("per-iteration per-rank phase breakdown"), "{rep}");
        assert!(rep.contains("iteration skew"), "{rep}");
        assert!(rep.contains("linesearch"), "{rep}");
        // Both ranks report 5 ms journal sync vs 10 ms rank-load sync per
        // iteration... journal total = 2 iters × 5 ms = 10 ms → Δ 0%.
        assert!(rep.contains("sync reconcile rank 0"), "{rep}");
        assert!(rep.contains("(Δ 0.00%)"), "{rep}");
    }

    #[test]
    fn nested_spans_do_not_double_count_totals() {
        let (header, ranks, mut spans) = sample_log();
        let mut wave = span(0, 1, "cd", 1.001, 0.019);
        wave.phase = "cd_wave".into();
        wave.depth = 1;
        spans.push(wave);
        let log = parse(&render(&header, &ranks, &spans)).unwrap();
        let grid = phase_grid(&log.spans);
        assert_eq!(grid[&(1, 0)][0], 0.020, "cd total must exclude nested waves");
    }
}
