//! Observability: the cluster-side telemetry layer.
//!
//! Four pieces, threaded through every layer of the stack:
//!
//! * [`log`] — std-only leveled structured logger (text or NDJSON on
//!   stderr, per-rank prefix, `DGLMNET_LOG`/`--log-level` control) behind
//!   the `obs_error!`/`obs_warn!`/`obs_info!`/`obs_debug!`/`obs_trace!`
//!   macros. The `println!` family is clippy-banned in library code
//!   (`clippy.toml`); `log::emit` is the sanctioned stdout sink for
//!   user-facing tables.
//! * [`span`] — monotonic-clock span tracing with a lock-free per-rank
//!   ring-buffer journal; the worker loop times each outer iteration's
//!   phases (`cd`, `sync`, `linesearch`, `comm`, hybrid `cd_wave`s) and
//!   attributes transport bytes to them.
//! * [`metrics`] — named counters/gauges plus the serving path's
//!   lock-free latency histogram, snapshot as JSON; behind the worker
//!   protocol's `stats` control frame and serve's `{"op":"stats"}` op.
//! * [`runlog`] — the merged per-run NDJSON file (`--trace-out`) and the
//!   `dglmnet trace-report` renderer over it.
//!
//! Instrumentation call sites should `use crate::obs::prelude::*;` and get
//! everything in one line.

pub mod log;
pub mod metrics;
pub mod runlog;
pub mod span;

/// One-line import for instrumentation call sites.
pub mod prelude {
    pub use super::log::{self as obslog, Format as LogFormat, Level};
    pub use super::metrics::{global as global_metrics, Counter, Gauge, Registry};
    pub use super::runlog;
    pub use super::span::{ActiveSpan, Journal, SpanRecord};
}
