//! Separable regularizers R(β) = Σ_j r(β_j) and their one-dimensional
//! penalized-quadratic solves.
//!
//! The d-GLMNET coordinate update minimizes, for one coordinate,
//!     q(u) = (A/2)·u² − B·u + r(u)
//! where A = μ Σ w x² + ν  and  B collects the linear terms (Section 3,
//! eq. 11). For elastic net this has the soft-threshold closed form; the
//! `Penalty1D` trait lets the same machinery run SCAD and bridge penalties —
//! the paper's §9 extension — via closed forms / safeguarded 1-D solves.

/// Elastic-net regularizer λ1‖β‖₁ + (λ2/2)‖β‖².
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ElasticNet {
    pub l1: f64,
    pub l2: f64,
}

/// Soft-threshold operator T(x, a) = sgn(x)·max(|x| − a, 0).
#[inline]
pub fn soft_threshold(x: f64, a: f64) -> f64 {
    if x > a {
        x - a
    } else if x < -a {
        x + a
    } else {
        0.0
    }
}

impl ElasticNet {
    pub fn new(l1: f64, l2: f64) -> ElasticNet {
        assert!(l1 >= 0.0 && l2 >= 0.0);
        ElasticNet { l1, l2 }
    }

    pub fn l1_only(l1: f64) -> ElasticNet {
        ElasticNet::new(l1, 0.0)
    }

    pub fn l2_only(l2: f64) -> ElasticNet {
        ElasticNet::new(0.0, l2)
    }

    /// R(β) over a weight slice.
    pub fn value(&self, beta: &[f64]) -> f64 {
        let mut l1 = 0.0;
        let mut l2 = 0.0;
        for b in beta {
            l1 += b.abs();
            l2 += b * b;
        }
        self.l1 * l1 + 0.5 * self.l2 * l2
    }

    /// R(β + αΔβ) over slices, without materializing the sum.
    pub fn value_shifted(&self, beta: &[f64], delta: &[f64], alpha: f64) -> f64 {
        debug_assert_eq!(beta.len(), delta.len());
        let mut l1 = 0.0;
        let mut l2 = 0.0;
        for (b, d) in beta.iter().zip(delta.iter()) {
            let u = b + alpha * d;
            l1 += u.abs();
            l2 += u * u;
        }
        self.l1 * l1 + 0.5 * self.l2 * l2
    }

    /// Minimize (A/2)u² − B·u + λ1|u| + (λ2/2)u² over u.
    /// This is the closed form behind update rule (11):
    ///   u* = T(B, λ1) / (A + λ2).
    #[inline]
    pub fn solve_1d(&self, quad: f64, lin: f64) -> f64 {
        debug_assert!(quad > 0.0);
        soft_threshold(lin, self.l1) / (quad + self.l2)
    }
}

/// One-dimensional separable penalty: r(u) plus the penalized-quadratic
/// solve argmin_u (A/2)u² − B·u + r(u). Implementors beyond elastic net
/// demonstrate the paper's §9 claim that any separable penalty plugs in.
pub trait Penalty1D: Send + Sync {
    fn value_1d(&self, u: f64) -> f64;
    /// argmin_u (quad/2)·u² − lin·u + r(u), quad > 0.
    fn solve_penalized_quad(&self, quad: f64, lin: f64) -> f64;

    fn value(&self, beta: &[f64]) -> f64 {
        beta.iter().map(|&b| self.value_1d(b)).sum()
    }
}

impl Penalty1D for ElasticNet {
    fn value_1d(&self, u: f64) -> f64 {
        self.l1 * u.abs() + 0.5 * self.l2 * u * u
    }

    fn solve_penalized_quad(&self, quad: f64, lin: f64) -> f64 {
        self.solve_1d(quad, lin)
    }
}

/// SCAD penalty (Fan & Li 2001) with the standard a > 2 shape parameter.
/// Piecewise: λ|u| for |u| ≤ λ; quadratic blend to a constant (a+1)λ²/2.
#[derive(Clone, Copy, Debug)]
pub struct Scad {
    pub lambda: f64,
    pub a: f64,
}

impl Scad {
    pub fn new(lambda: f64, a: f64) -> Scad {
        assert!(lambda >= 0.0 && a > 2.0);
        Scad { lambda, a }
    }
}

impl Penalty1D for Scad {
    fn value_1d(&self, u: f64) -> f64 {
        let (l, a, x) = (self.lambda, self.a, u.abs());
        if x <= l {
            l * x
        } else if x <= a * l {
            // -(x² - 2aλx + λ²) / (2(a-1))
            (2.0 * a * l * x - x * x - l * l) / (2.0 * (a - 1.0))
        } else {
            (a + 1.0) * l * l / 2.0
        }
    }

    /// Exact minimizer per region with a final global comparison — the SCAD
    /// penalized quadratic is non-convex so candidate minima are compared by
    /// objective value.
    fn solve_penalized_quad(&self, quad: f64, lin: f64) -> f64 {
        let (l, a) = (self.lambda, self.a);
        let obj = |u: f64| 0.5 * quad * u * u - lin * u + self.value_1d(u);
        let mut best = 0.0;
        let mut best_val = obj(0.0);
        let mut consider = |u: f64| {
            let v = obj(u);
            if v < best_val {
                best_val = v;
                best = u;
            }
        };
        // Region 1: |u| <= λ, gradient quad·u − lin ± λ = 0.
        let u1 = soft_threshold(lin, l) / quad;
        if u1.abs() <= l {
            consider(u1);
        } else {
            consider(l.copysign(u1));
        }
        // Region 2: λ < |u| <= aλ, r'(u) = (aλ sgn u − u)/(a−1).
        let denom = quad - 1.0 / (a - 1.0);
        if denom.abs() > 1e-12 {
            for s in [1.0f64, -1.0] {
                let u2 = (lin - s * a * l / (a - 1.0)) / denom * 1.0;
                // derivative: quad·u − lin + (aλ·s − u)/(a−1) = 0
                // => u (quad − 1/(a−1)) = lin − aλ s/(a−1)
                if u2 * s > l && u2 * s <= a * l {
                    consider(u2);
                }
            }
        }
        // Region 3: |u| > aλ, penalty constant → u = lin/quad.
        let u3 = lin / quad;
        if u3.abs() > a * l {
            consider(u3);
        }
        consider(l.copysign(lin));
        consider((a * l).copysign(lin));
        best
    }
}

/// Bridge penalty λ|u|^γ with 0 < γ < 1 (Fu 1998). Non-convex, non-smooth at
/// zero; solved by safeguarded Newton on the smooth branch + compare with 0.
#[derive(Clone, Copy, Debug)]
pub struct Bridge {
    pub lambda: f64,
    pub gamma: f64,
}

impl Bridge {
    pub fn new(lambda: f64, gamma: f64) -> Bridge {
        assert!(lambda >= 0.0 && gamma > 0.0 && gamma < 1.0);
        Bridge { lambda, gamma }
    }
}

impl Penalty1D for Bridge {
    fn value_1d(&self, u: f64) -> f64 {
        self.lambda * u.abs().powf(self.gamma)
    }

    fn solve_penalized_quad(&self, quad: f64, lin: f64) -> f64 {
        if lin == 0.0 {
            return 0.0;
        }
        let sign = lin.signum();
        let b = lin.abs();
        let (l, g) = (self.lambda, self.gamma);
        // minimize over x>0: (quad/2)x² − b·x + λ x^γ ; compare with x=0.
        // Newton from the unpenalized minimum b/quad, safeguarded to stay > 0.
        let mut x = b / quad;
        for _ in 0..60 {
            let f1 = quad * x - b + l * g * x.powf(g - 1.0);
            let f2 = quad + l * g * (g - 1.0) * x.powf(g - 2.0);
            let mut step = if f2.abs() > 1e-300 { f1 / f2 } else { f1 };
            // keep iterate positive
            if x - step <= 0.0 {
                step = x / 2.0;
            }
            x -= step;
            if step.abs() < 1e-14 * (1.0 + x.abs()) {
                break;
            }
        }
        let obj = |u: f64| 0.5 * quad * u * u - b * u + l * u.powf(g);
        if x > 0.0 && obj(x) < 0.0 {
            sign * x
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, close};

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn elastic_net_value() {
        let r = ElasticNet::new(2.0, 4.0);
        // 2*(1+2) + 2*(1+4) = 6 + 10
        assert_eq!(r.value(&[1.0, -2.0]), 16.0);
    }

    #[test]
    fn value_shifted_matches_materialized() {
        let r = ElasticNet::new(0.3, 0.7);
        let beta = [1.0, -2.0, 0.0];
        let delta = [0.5, 0.5, -1.0];
        let alpha = 0.6;
        let shifted: Vec<f64> = beta
            .iter()
            .zip(delta.iter())
            .map(|(b, d)| b + alpha * d)
            .collect();
        assert!(close(r.value_shifted(&beta, &delta, alpha), r.value(&shifted), 1e-12).is_ok());
    }

    /// Brute-force 1-D minimizer over a fine grid for oracle comparison.
    fn grid_min(obj: impl Fn(f64) -> f64, lo: f64, hi: f64, steps: usize) -> f64 {
        let mut best = lo;
        let mut best_v = obj(lo);
        for i in 0..=steps {
            let u = lo + (hi - lo) * i as f64 / steps as f64;
            let v = obj(u);
            if v < best_v {
                best_v = v;
                best = u;
            }
        }
        best
    }

    #[test]
    fn prop_elastic_net_1d_solve_is_minimum() {
        prop::check("enet solve_1d = grid argmin", 200, |rng| {
            let r = ElasticNet::new(rng.range_f64(0.0, 2.0), rng.range_f64(0.0, 2.0));
            let quad = rng.range_f64(0.1, 5.0);
            let lin = rng.range_f64(-5.0, 5.0);
            let got = r.solve_1d(quad, lin);
            let obj = |u: f64| 0.5 * quad * u * u - lin * u + r.value_1d(u);
            let approx = grid_min(&obj, -60.0, 60.0, 40_000);
            // compare objective values, not argmins (flat regions)
            if obj(got) <= obj(approx) + 1e-6 {
                Ok(())
            } else {
                Err(format!(
                    "solve_1d obj {} > grid obj {} (u_got={got}, u_grid={approx})",
                    obj(got),
                    obj(approx)
                ))
            }
        });
    }

    #[test]
    fn prop_scad_solve_beats_grid() {
        prop::check("scad solve <= grid min", 200, |rng| {
            let p = Scad::new(rng.range_f64(0.1, 2.0), 3.7);
            let quad = rng.range_f64(0.2, 4.0);
            let lin = rng.range_f64(-6.0, 6.0);
            let got = p.solve_penalized_quad(quad, lin);
            let obj = |u: f64| 0.5 * quad * u * u - lin * u + p.value_1d(u);
            let approx = grid_min(&obj, -40.0, 40.0, 40_000);
            if obj(got) <= obj(approx) + 1e-5 {
                Ok(())
            } else {
                Err(format!(
                    "scad obj(got={got}) = {} > obj(grid={approx}) = {}",
                    obj(got),
                    obj(approx)
                ))
            }
        });
    }

    #[test]
    fn prop_bridge_solve_beats_grid() {
        prop::check("bridge solve <= grid min", 200, |rng| {
            let p = Bridge::new(rng.range_f64(0.1, 2.0), rng.range_f64(0.3, 0.8));
            let quad = rng.range_f64(0.2, 4.0);
            let lin = rng.range_f64(-6.0, 6.0);
            let got = p.solve_penalized_quad(quad, lin);
            let obj = |u: f64| 0.5 * quad * u * u - lin * u + p.value_1d(u);
            let approx = grid_min(&obj, -40.0, 40.0, 40_000);
            if obj(got) <= obj(approx) + 1e-5 {
                Ok(())
            } else {
                Err(format!(
                    "bridge obj(got={got}) = {} > obj(grid={approx}) = {}",
                    obj(got),
                    obj(approx)
                ))
            }
        });
    }

    #[test]
    fn scad_matches_lasso_inside_first_region() {
        // For small |solution| SCAD == lasso.
        let p = Scad::new(1.0, 3.7);
        let e = ElasticNet::l1_only(1.0);
        let (quad, lin) = (2.0, 1.5); // lasso solution 0.25 < λ=1
        assert!(close(
            p.solve_penalized_quad(quad, lin),
            e.solve_penalized_quad(quad, lin),
            1e-12
        )
        .is_ok());
    }

    #[test]
    fn scad_unbiased_for_large_signals() {
        // For big coefficients SCAD penalty is constant => solution = OLS.
        let p = Scad::new(0.5, 3.7);
        let (quad, lin) = (1.0, 10.0);
        assert!(close(p.solve_penalized_quad(quad, lin), 10.0, 1e-9).is_ok());
    }

    #[test]
    fn bridge_thresholds_small_signals_to_zero() {
        let p = Bridge::new(2.0, 0.5);
        assert_eq!(p.solve_penalized_quad(1.0, 0.2), 0.0);
        assert_eq!(p.solve_penalized_quad(1.0, 0.0), 0.0);
    }

    #[test]
    fn penalty_trait_value_sums() {
        let e = ElasticNet::new(1.0, 0.0);
        assert_eq!(Penalty1D::value(&e, &[1.0, -1.0, 2.0]), 4.0);
    }
}
