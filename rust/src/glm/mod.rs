//! Generalized linear model core: loss families (margin derivatives +
//! Appendix-B Hessian bounds) and separable regularizers with their 1-D
//! penalized-quadratic solves.

pub mod loss;
pub mod model;
pub mod regularizer;

pub use loss::{total_loss, LossKind};
pub use model::GlmModel;
pub use regularizer::{soft_threshold, Bridge, ElasticNet, Penalty1D, Scad};
