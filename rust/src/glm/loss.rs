//! Example-wise loss functions ℓ(y, ŷ) for generalized linear models.
//!
//! The solver only needs the margin derivatives: value ℓ, first derivative
//! g = ∂ℓ/∂ŷ and second derivative w = ∂²ℓ/∂ŷ². The quadratic-model
//! working response is z = -g/w (Section 2 of the paper). Appendix B's
//! second-derivative upper bounds — which make the CGD convergence theorem
//! apply — are exposed as `hessian_bound()` and verified by tests.

use crate::kernels::{log1p_exp, sigmoid};
use crate::util::stats::{normal_cdf, normal_pdf};

/// Supported loss families (paper §5: convergence proved for these three;
/// Poisson is the §9 "any separable one-dimensional" extension and carries a
/// documented Hessian cap to satisfy (15)).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LossKind {
    /// ℓ(y, ŷ) = log(1 + exp(-y ŷ)), y ∈ {-1, +1}.
    Logistic,
    /// ℓ(y, ŷ) = ½ (y - ŷ)².
    Squared,
    /// ℓ(y, ŷ) = -log Φ(y ŷ), y ∈ {-1, +1}.
    Probit,
    /// ℓ(y, ŷ) = exp(ŷ) - y ŷ (Poisson NLL up to const); Hessian capped.
    Poisson,
}

/// Cap for the Poisson Hessian so assumption (15) (bounded ∂²ℓ/∂ŷ²) holds;
/// equivalent to trusting the quadratic model only within a margin range.
pub const POISSON_HESSIAN_CAP: f64 = 20.0;

/// Floor for w when forming z = -g/w, preventing division blowup where the
/// true curvature vanishes (e.g. saturated sigmoid). Same role as the 1e-6
/// floor in GLMNET's IRLS weights.
pub const W_FLOOR: f64 = 1e-10;

impl LossKind {
    pub fn parse(s: &str) -> Option<LossKind> {
        match s {
            "logistic" => Some(LossKind::Logistic),
            "squared" => Some(LossKind::Squared),
            "probit" => Some(LossKind::Probit),
            "poisson" => Some(LossKind::Poisson),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Logistic => "logistic",
            LossKind::Squared => "squared",
            LossKind::Probit => "probit",
            LossKind::Poisson => "poisson",
        }
    }

    /// ℓ(y, ŷ).
    #[inline]
    pub fn value(&self, y: f64, yhat: f64) -> f64 {
        match self {
            LossKind::Logistic => log1p_exp(-y * yhat),
            LossKind::Squared => 0.5 * (y - yhat) * (y - yhat),
            LossKind::Probit => {
                let c = normal_cdf(y * yhat);
                // Guard log(0) for extreme margins; the asymptotic expansion
                // -log Φ(t) ≈ t²/2 + log(|t|√(2π)) for t << 0.
                if c > 1e-300 {
                    -c.ln()
                } else {
                    let t = y * yhat; // t << 0 here
                    0.5 * t * t + (t.abs() * (2.0 * std::f64::consts::PI).sqrt()).ln()
                }
            }
            LossKind::Poisson => yhat.exp() - y * yhat,
        }
    }

    /// First derivative g = ∂ℓ/∂ŷ.
    #[inline]
    pub fn d1(&self, y: f64, yhat: f64) -> f64 {
        match self {
            LossKind::Logistic => -y * sigmoid(-y * yhat),
            LossKind::Squared => yhat - y,
            LossKind::Probit => {
                let t = y * yhat;
                -y * mills_ratio_inv(t)
            }
            LossKind::Poisson => yhat.exp() - y,
        }
    }

    /// Second derivative w = ∂²ℓ/∂ŷ² (capped for Poisson).
    #[inline]
    pub fn d2(&self, y: f64, yhat: f64) -> f64 {
        match self {
            LossKind::Logistic => {
                let p = sigmoid(yhat);
                p * (1.0 - p)
            }
            LossKind::Squared => 1.0,
            LossKind::Probit => {
                // ∂²ℓ/∂ŷ² = t·φ/Φ + (φ/Φ)², t = yŷ (Appendix B).
                let t = y * yhat;
                let r = mills_ratio_inv(t);
                t * r + r * r
            }
            LossKind::Poisson => yhat.exp().min(POISSON_HESSIAN_CAP),
        }
    }

    /// Appendix B upper bound on the second derivative (15).
    pub fn hessian_bound(&self) -> f64 {
        match self {
            LossKind::Logistic => 0.25,
            LossKind::Squared => 1.0,
            // Paper derives ≤ max(2p(1) + 4p(0), 3) with p = N(0,1) pdf;
            // 2·p(1) + 4·p(0) ≈ 2.0796 < 3.
            LossKind::Probit => 3.0,
            LossKind::Poisson => POISSON_HESSIAN_CAP,
        }
    }

    /// Working response z = -g/w with floored w (Section 2).
    #[inline]
    pub fn working_response(&self, y: f64, yhat: f64) -> (f64, f64) {
        let g = self.d1(y, yhat);
        let w = self.d2(y, yhat).max(W_FLOOR);
        (w, -g / w)
    }

    /// Predicted positive-class probability (for classification losses).
    #[inline]
    pub fn prob(&self, yhat: f64) -> f64 {
        match self {
            LossKind::Logistic => sigmoid(yhat),
            LossKind::Probit => normal_cdf(yhat),
            // For squared/poisson fall back to the raw score squashed —
            // only used by ranking metrics where monotonicity is all that
            // matters.
            LossKind::Squared | LossKind::Poisson => sigmoid(yhat),
        }
    }
}

/// φ(t)/Φ(t) — the inverse Mills ratio, computed stably for t << 0 using the
/// continued-fraction tail of Φ (Φ(t) ≈ φ(t)·(|t|/(t²+1)) for t → -∞).
#[inline]
fn mills_ratio_inv(t: f64) -> f64 {
    if t < -30.0 {
        // φ/Φ → |t| + 1/|t| asymptotically.
        let a = -t;
        a + 1.0 / a
    } else {
        let c = normal_cdf(t);
        if c < 1e-300 {
            let a = -t;
            a + 1.0 / a
        } else {
            normal_pdf(t) / c
        }
    }
}

/// Sum of losses over a margin vector: L(β) given ŷ = Xβ.
pub fn total_loss(kind: LossKind, y: &[f64], yhat: &[f64]) -> f64 {
    debug_assert_eq!(y.len(), yhat.len());
    if kind == LossKind::Logistic {
        // The hot-path family goes through the kernel seam (strict mode is
        // bit-identical to the generic loop below).
        return crate::kernels::active().logloss_sum(y, yhat);
    }
    let mut acc = 0.0;
    for (yi, mi) in y.iter().zip(yhat.iter()) {
        acc += kind.value(*yi, *mi);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, close};

    const KINDS: [LossKind; 4] = [
        LossKind::Logistic,
        LossKind::Squared,
        LossKind::Probit,
        LossKind::Poisson,
    ];

    fn label_for(kind: LossKind, rng: &mut crate::util::rng::Rng) -> f64 {
        match kind {
            LossKind::Squared => rng.range_f64(-2.0, 2.0),
            LossKind::Poisson => rng.below(5) as f64,
            _ => {
                if rng.bernoulli(0.5) {
                    1.0
                } else {
                    -1.0
                }
            }
        }
    }

    #[test]
    fn prop_d1_matches_finite_difference() {
        prop::check("d1 = finite diff", 300, |rng| {
            for kind in KINDS {
                let y = label_for(kind, rng);
                let m = rng.range_f64(-4.0, 4.0);
                let h = 1e-6;
                let fd = (kind.value(y, m + h) - kind.value(y, m - h)) / (2.0 * h);
                close(kind.d1(y, m), fd, 1e-5)
                    .map_err(|e| format!("{} at y={y} m={m}: {e}", kind.name()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_d2_matches_finite_difference() {
        prop::check("d2 = finite diff of d1", 300, |rng| {
            for kind in KINDS {
                let y = label_for(kind, rng);
                // Stay away from the Poisson cap kink.
                let m = match kind {
                    LossKind::Poisson => rng.range_f64(-3.0, 2.5),
                    _ => rng.range_f64(-4.0, 4.0),
                };
                let h = 1e-6;
                let fd = (kind.d1(y, m + h) - kind.d1(y, m - h)) / (2.0 * h);
                close(kind.d2(y, m), fd, 1e-4)
                    .map_err(|e| format!("{} at y={y} m={m}: {e}", kind.name()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_hessian_bound_holds() {
        prop::check("d2 <= Appendix B bound", 500, |rng| {
            for kind in KINDS {
                let y = label_for(kind, rng);
                let m = rng.range_f64(-30.0, 30.0);
                let w = kind.d2(y, m);
                if w < -1e-12 {
                    return Err(format!("{}: negative curvature {w}", kind.name()));
                }
                if w > kind.hessian_bound() + 1e-9 {
                    return Err(format!(
                        "{}: d2({y},{m}) = {w} > bound {}",
                        kind.name(),
                        kind.hessian_bound()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn logistic_known_values() {
        let k = LossKind::Logistic;
        assert!(close(k.value(1.0, 0.0), std::f64::consts::LN_2, 1e-12).is_ok());
        assert!(close(k.d2(1.0, 0.0), 0.25, 1e-12).is_ok());
        // symmetric in y sign
        assert!(close(k.value(1.0, 1.5), k.value(-1.0, -1.5), 1e-12).is_ok());
    }

    #[test]
    fn probit_extreme_margins_finite() {
        let k = LossKind::Probit;
        for m in [-50.0, -10.0, 10.0, 50.0] {
            for y in [-1.0, 1.0] {
                assert!(k.value(y, m).is_finite(), "value({y},{m})");
                assert!(k.d1(y, m).is_finite(), "d1({y},{m})");
                assert!(k.d2(y, m).is_finite(), "d2({y},{m})");
                assert!(k.d2(y, m) >= 0.0);
            }
        }
    }

    #[test]
    fn probit_loss_decreasing_in_correct_margin() {
        let k = LossKind::Probit;
        let mut prev = f64::INFINITY;
        let mut m = -5.0;
        while m <= 5.0 {
            let v = k.value(1.0, m);
            assert!(v < prev);
            prev = v;
            m += 0.25;
        }
    }

    #[test]
    fn working_response_squared_is_residual() {
        // For squared loss: w = 1, z = y - ŷ.
        let k = LossKind::Squared;
        let (w, z) = k.working_response(3.0, 1.0);
        assert_eq!(w, 1.0);
        assert_eq!(z, 2.0);
    }

    #[test]
    fn total_loss_sums() {
        let y = [1.0, -1.0];
        let m = [0.0, 0.0];
        assert!(
            (total_loss(LossKind::Logistic, &y, &m) - 2.0 * std::f64::consts::LN_2).abs() < 1e-12
        );
    }

    #[test]
    fn prob_monotone() {
        for kind in [LossKind::Logistic, LossKind::Probit] {
            let mut prev = 0.0;
            let mut m = -6.0;
            while m <= 6.0 {
                let p = kind.prob(m);
                assert!((0.0..=1.0).contains(&p));
                assert!(p >= prev);
                prev = p;
                m += 0.1;
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        for kind in KINDS {
            assert_eq!(LossKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(LossKind::parse("bogus"), None);
    }
}
