//! Trained-model container with JSON persistence and prediction — the
//! deployment half of the launcher (`dglmnet predict`).
//!
//! Weights are stored sparsely (index/value pairs) so an L1 model over 10⁷
//! features serializes at the size of its support, matching how the paper's
//! C++ implementation ships models.

use crate::glm::loss::LossKind;
use crate::sparse::Csr;
use crate::util::json::{self, Json};

/// A trained GLM: loss family (defines the inverse link for probabilities)
/// plus the weight vector.
#[derive(Clone, Debug, PartialEq)]
pub struct GlmModel {
    pub kind: LossKind,
    pub p: usize,
    pub beta: Vec<f64>,
    /// Provenance metadata (dataset, λ, nodes, …) — free-form.
    pub meta: Vec<(String, String)>,
}

#[derive(Debug, thiserror::Error)]
pub enum ModelError {
    #[error("json: {0}")]
    Json(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed model: {0}")]
    Malformed(String),
}

impl GlmModel {
    pub fn new(kind: LossKind, beta: Vec<f64>) -> GlmModel {
        GlmModel {
            kind,
            p: beta.len(),
            beta,
            meta: Vec::new(),
        }
    }

    pub fn with_meta(mut self, key: &str, value: impl ToString) -> GlmModel {
        self.meta.push((key.to_string(), value.to_string()));
        self
    }

    /// Margins ŷ = Xβ for a batch of examples.
    pub fn margins(&self, x: &Csr) -> Vec<f64> {
        assert!(
            x.ncols <= self.p,
            "feature space {} wider than model {}",
            x.ncols,
            self.p
        );
        (0..x.nrows).map(|i| x.dot_row(i, &self.beta)).collect()
    }

    /// Positive-class probabilities through the model's inverse link.
    pub fn predict_proba(&self, x: &Csr) -> Vec<f64> {
        self.margins(x)
            .into_iter()
            .map(|m| self.kind.prob(m))
            .collect()
    }

    pub fn nnz(&self) -> usize {
        crate::metrics::nnz_weights(&self.beta)
    }

    /// The sparse support: (feature, weight) pairs for the non-zero β —
    /// the serialized form, and the unit the registry reports.
    pub fn support(&self) -> Vec<(u32, f64)> {
        self.beta
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0.0)
            .map(|(j, &b)| (j as u32, b))
            .collect()
    }

    /// Densify β into a scoring weight vector of at least `width` slots
    /// (zero-padded past `p`). The serving scorer builds this once per
    /// model version so every request is a gather against dense weights.
    pub fn dense_weights(&self, width: usize) -> Vec<f64> {
        let mut w = self.beta.clone();
        if width > w.len() {
            w.resize(width, 0.0);
        }
        w
    }

    /// Margin for a single sparse row of (feature, value) pairs.
    ///
    /// Panics if a feature index is ≥ `p` — this is the trusted-input
    /// helper; request-path callers should go through `serve::Scorer`,
    /// which reports `ScoreError::FeatureOutOfRange` instead.
    pub fn margin_sparse(&self, feats: &[(u32, f64)]) -> f64 {
        feats
            .iter()
            .map(|&(j, v)| {
                assert!((j as usize) < self.p, "feature {j} outside model space {}", self.p);
                self.beta[j as usize] * v
            })
            .sum()
    }

    /// Serialize to JSON (sparse weight encoding).
    pub fn to_json(&self) -> Json {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (j, &b) in self.beta.iter().enumerate() {
            if b != 0.0 {
                idx.push(j as f64);
                val.push(b);
            }
        }
        let mut meta = Json::obj();
        for (k, v) in &self.meta {
            meta.set(k, v.as_str());
        }
        let mut o = Json::obj();
        o.set("format", "dglmnet-model-v1")
            .set("loss", self.kind.name())
            .set("p", self.p)
            .set("indices", idx)
            .set("values", val)
            .set("meta", meta);
        o
    }

    pub fn from_json(j: &Json) -> Result<GlmModel, ModelError> {
        let get = |k: &str| {
            j.get(k)
                .ok_or_else(|| ModelError::Malformed(format!("missing field '{k}'")))
        };
        let fmt = get("format")?
            .as_str()
            .ok_or_else(|| ModelError::Malformed("format not a string".into()))?;
        if fmt != "dglmnet-model-v1" {
            return Err(ModelError::Malformed(format!("unknown format '{fmt}'")));
        }
        let kind = get("loss")?
            .as_str()
            .and_then(LossKind::parse)
            .ok_or_else(|| ModelError::Malformed("bad loss kind".into()))?;
        let p = get("p")?
            .as_f64()
            .ok_or_else(|| ModelError::Malformed("bad p".into()))? as usize;
        let arr = |k: &str| -> Result<Vec<f64>, ModelError> {
            match get(k)? {
                Json::Arr(xs) => xs
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .ok_or_else(|| ModelError::Malformed(format!("non-number in {k}")))
                    })
                    .collect(),
                _ => Err(ModelError::Malformed(format!("{k} not an array"))),
            }
        };
        let idx = arr("indices")?;
        let val = arr("values")?;
        if idx.len() != val.len() {
            return Err(ModelError::Malformed("indices/values length mismatch".into()));
        }
        let mut beta = vec![0.0; p];
        let mut seen = std::collections::HashSet::with_capacity(idx.len());
        for (i, v) in idx.iter().zip(val.iter()) {
            // `as usize` saturates (negative → 0), so validate before casting
            // or a corrupt index silently lands on another feature's weight.
            if *i < 0.0 || i.fract() != 0.0 || !i.is_finite() {
                return Err(ModelError::Malformed(format!("bad index {i}")));
            }
            let j = *i as usize;
            if j >= p {
                return Err(ModelError::Malformed(format!("index {j} out of range {p}")));
            }
            if !seen.insert(j) {
                return Err(ModelError::Malformed(format!("duplicate index {j}")));
            }
            beta[j] = *v;
        }
        let mut meta = Vec::new();
        if let Some(Json::Obj(m)) = j.get("meta") {
            for (k, v) in m {
                if let Some(s) = v.as_str() {
                    meta.push((k.clone(), s.to_string()));
                }
            }
        }
        Ok(GlmModel {
            kind,
            p,
            beta,
            meta,
        })
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<(), ModelError> {
        Ok(std::fs::write(path, self.to_json().dump())?)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<GlmModel, ModelError> {
        let text = std::fs::read_to_string(path)?;
        let j = json::parse(&text).map_err(ModelError::Json)?;
        GlmModel::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;

    fn model() -> GlmModel {
        let mut beta = vec![0.0; 10];
        beta[2] = 1.5;
        beta[7] = -0.25;
        GlmModel::new(LossKind::Logistic, beta)
            .with_meta("dataset", "toy")
            .with_meta("l1", 0.5)
    }

    #[test]
    fn json_roundtrip() {
        let m = model();
        let j = m.to_json();
        let back = GlmModel::from_json(&j).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn file_roundtrip() {
        let m = model();
        let path = std::env::temp_dir().join(format!("dglmnet_model_{}.json", std::process::id()));
        m.save(&path).unwrap();
        let back = GlmModel::load(&path).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sparse_encoding_size() {
        // Only the support is serialized.
        let m = model();
        let s = m.to_json().dump();
        assert!(s.contains("[2,7]"), "{s}");
    }

    #[test]
    fn predict_proba_monotone_in_margin() {
        let m = model();
        let x = Csr::from_rows(10, &[vec![(2, 1.0)], vec![(2, 2.0)], vec![(7, 4.0)]]);
        let p = m.predict_proba(&x);
        assert!(p[1] > p[0]); // larger positive margin
        assert!(p[2] < 0.5); // negative margin
        assert!(p.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn malformed_inputs_rejected() {
        let cases = [
            r#"{"format":"wrong"}"#,
            r#"{"format":"dglmnet-model-v1","loss":"bogus","p":1,"indices":[],"values":[]}"#,
            r#"{"format":"dglmnet-model-v1","loss":"logistic","p":1,"indices":[5],"values":[1.0]}"#,
            r#"{"format":"dglmnet-model-v1","loss":"logistic","p":1,"indices":[0],"values":[]}"#,
            r#"{"format":"dglmnet-model-v1","loss":"logistic","p":4,"indices":[-1],"values":[1.0]}"#,
            r#"{"format":"dglmnet-model-v1","loss":"logistic","p":4,"indices":[1.5],"values":[1.0]}"#,
            r#"{"format":"dglmnet-model-v1","loss":"logistic","p":4,"indices":[2,2],"values":[1.0,2.0]}"#,
        ];
        for c in cases {
            let j = crate::util::json::parse(c).unwrap();
            assert!(GlmModel::from_json(&j).is_err(), "accepted: {c}");
        }
    }

    #[test]
    fn support_and_dense_weights() {
        let m = model();
        assert_eq!(m.support(), vec![(2, 1.5), (7, -0.25)]);
        // Densify wider than p: zero-padded serving space.
        let w = m.dense_weights(16);
        assert_eq!(w.len(), 16);
        assert_eq!(&w[..10], m.beta.as_slice());
        assert!(w[10..].iter().all(|&v| v == 0.0));
        // Never narrower than p.
        assert_eq!(m.dense_weights(3).len(), 10);
        assert_eq!(m.margin_sparse(&[(2, 2.0), (7, 4.0)]), 3.0 - 1.0);
    }

    #[test]
    fn roundtrip_preserves_sparse_support() {
        let m = model();
        let back = GlmModel::from_json(&m.to_json()).unwrap();
        assert_eq!(back.support(), m.support());
        assert_eq!(back.beta, m.beta);
    }

    #[test]
    fn narrower_feature_space_accepted() {
        let m = model();
        let x = Csr::from_rows(3, &[vec![(2, 1.0)]]);
        assert_eq!(m.margins(&x), vec![1.5]);
    }

    #[test]
    #[should_panic(expected = "wider")]
    fn wider_feature_space_rejected() {
        let m = model();
        let x = Csr::from_rows(20, &[vec![(15, 1.0)]]);
        m.margins(&x);
    }
}
