//! Driver: builds the feature partition, shards the data, wires the
//! transport / ALB mode, spawns one worker thread per node and assembles
//! the global model from the per-node blocks.
//!
//! Two entry points share all of the above through the [`Transport`] seam:
//! [`fit_distributed`] (in-process fabric, the simulation substrate with
//! modeled wire time) and [`fit_distributed_tcp`] (one thread per rank,
//! each talking real length-prefixed TCP over loopback — the
//! single-process proof of the socket backend; `dglmnet train --cluster`
//! runs the same worker across separate OS processes). Both support ALB:
//! the fabric wires the shared-memory [`AlbController`] special case, the
//! TCP path the transport-level per-iteration quorum — the worker cannot
//! tell them apart behind `AlbMode`.

use crate::cluster::alb::{AlbController, AlbMode};
use crate::cluster::allreduce::AllReduceAlgo;
use crate::cluster::fabric::{fabric, NetworkModel};
use crate::cluster::tcp::{bind_loopback, TcpOptions, TcpTransport};
use crate::data::{Dataset, Splits};
use crate::glm::regularizer::Penalty1D;
use crate::solver::compute::GlmCompute;
use crate::solver::linesearch::LineSearchConfig;
use crate::solver::path::{PathPoint, PathResult};
use crate::solver::trace::Trace;
use crate::sparse::{Csc, FeaturePartition, PartitionStrategy};
use crate::coordinator::worker::{
    run_worker, run_worker_path, PathJob, PathWorkerOutput, WorkerConfig, WorkerOutput,
    WorkerShared,
};
use std::time::Duration;

/// Configuration of a distributed fit.
#[derive(Clone, Debug)]
pub struct DistributedConfig {
    pub nodes: usize,
    /// ALB quorum fraction κ; None = synchronous BSP (plain d-GLMNET).
    pub alb_kappa: Option<f64>,
    pub adaptive_mu: bool,
    pub mu0: f64,
    pub eta1: f64,
    pub eta2: f64,
    pub nu: f64,
    pub max_iters: usize,
    pub tol: f64,
    pub patience: usize,
    pub seed: u64,
    pub linesearch: LineSearchConfig,
    pub eval_every: usize,
    pub allreduce: AllReduceAlgo,
    pub network: NetworkModel,
    /// Injected per-pass delays, one per rank (slow-node experiments).
    pub straggler_delays: Vec<Duration>,
    /// Fast-node extra passes cap under ALB.
    pub max_passes: usize,
    /// Stop-flag poll granularity (coordinates).
    pub chunk: usize,
    /// Intra-rank CD threads T (hybrid mode): every rank splits its block
    /// into T sub-blocks run as pool waves — the cluster behaves like M·T
    /// feature blocks. 1 = classic single-threaded ranks.
    pub threads: usize,
    /// Virtual cluster clock: trace timestamps = max-over-nodes thread CPU
    /// time (× per-node slow factors) + modeled wire time. Required for
    /// meaningful scaling numbers when the host has fewer cores than M.
    pub virtual_time: bool,
    /// Per-node compute-speed multipliers under the virtual clock.
    pub slow_factors: Vec<f64>,
    /// Where rank 0 persists per-iteration checkpoints (None = off). See
    /// `cluster::checkpoint` for the format and DESIGN.md §Failure model.
    pub checkpoint_dir: Option<String>,
    /// Checkpoint every k-th outer iteration (0 = off). SPMD-identical:
    /// it gates a collective gather.
    pub checkpoint_every: usize,
    /// How features map to ranks — resolved once per run through
    /// [`PartitionStrategy::resolve`] (the seam; see DESIGN.md
    /// §Partitioning). Default = hashed, the historical layout.
    pub partition: PartitionStrategy,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            nodes: 8,
            alb_kappa: None,
            adaptive_mu: true,
            mu0: 1.0,
            eta1: 2.0,
            eta2: 2.0,
            nu: 1e-6,
            max_iters: 100,
            tol: 1e-7,
            patience: 2,
            seed: 0x5EED,
            linesearch: LineSearchConfig::default(),
            eval_every: 1,
            allreduce: AllReduceAlgo::Ring,
            network: NetworkModel::default(),
            straggler_delays: Vec::new(),
            max_passes: 4,
            chunk: 64,
            threads: 1,
            virtual_time: false,
            slow_factors: Vec::new(),
            checkpoint_dir: None,
            checkpoint_every: 0,
            partition: PartitionStrategy::default(),
        }
    }
}

/// Per-rank load accounting — the Table-2 columns that stay meaningful
/// under asynchronous (ALB) runs, where ranks no longer perform identical
/// work: a straggler shows fewer CD updates and non-zero cut-offs.
#[derive(Clone, Debug, Default)]
pub struct RankLoad {
    pub rank: usize,
    /// Coordinate updates performed across the run.
    pub cd_updates: u64,
    /// Full passes over S^m completed.
    pub full_passes: u64,
    /// Iterations this rank was cut off before completing one pass.
    pub cutoffs: u64,
    pub sent_bytes: u64,
    pub sent_msgs: u64,
    /// Time spent blocked in the post-CD XΔβ synchronization.
    pub sync_wait_secs: f64,
    /// Effective intra-rank CD threads (sub-block count; 1 = classic).
    pub threads: usize,
    /// Coordinate updates per sub-block thread (single entry = classic).
    pub updates_per_thread: Vec<u64>,
    /// Feature columns this rank materialized (protocol v7 out-of-core
    /// ingestion: a shards:<dir> rank loads only its own block, so this is
    /// strictly below p on any multi-rank cluster). 0 on fabric runs.
    pub loaded_cols: usize,
    /// Bytes read to ingest this rank's data (block file + labels for a
    /// shard dataset; the full CSC footprint for a text recipe). 0 on
    /// fabric runs.
    pub loaded_bytes: u64,
    /// Cross-block co-occurrence fraction of this rank's block (protocol
    /// v8; see `FeaturePartition::cut_fractions`): of the sampled nonzero
    /// slots co-active with this block's features, the share living in
    /// OTHER blocks. −1.0 = unknown (shard ranks never see the full
    /// matrix).
    pub cut: f64,
}

impl RankLoad {
    pub fn from_output(o: &WorkerOutput) -> RankLoad {
        RankLoad {
            rank: o.rank,
            cd_updates: o.cd_updates,
            full_passes: o.full_passes,
            cutoffs: o.cutoffs,
            sent_bytes: o.sent_bytes,
            sent_msgs: o.sent_msgs,
            sync_wait_secs: o.sync_wait_secs,
            threads: o.threads,
            updates_per_thread: o.updates_per_thread.clone(),
            // Ingestion accounting is a process-cluster concept (protocol
            // v7); in-process fabric ranks share one materialized matrix.
            loaded_cols: 0,
            loaded_bytes: 0,
            // The worker never sees the full matrix; whoever planned the
            // partition fills the cut in (−1 = unknown until then).
            cut: -1.0,
        }
    }

    /// The run-log `rank` record (one `--trace-out` NDJSON line; see
    /// `obs::runlog`).
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("type", "rank")
            .set("rank", self.rank)
            .set("cd_updates", self.cd_updates)
            .set("full_passes", self.full_passes)
            .set("cutoffs", self.cutoffs)
            .set("sent_bytes", self.sent_bytes)
            .set("sent_msgs", self.sent_msgs)
            .set("sync_wait_secs", self.sync_wait_secs)
            .set("threads", self.threads)
            .set("loaded_cols", self.loaded_cols)
            .set("loaded_bytes", self.loaded_bytes)
            .set("cut", self.cut);
        o.set(
            "updates_per_thread",
            crate::util::json::Json::from(self.updates_per_thread.clone()),
        );
        o
    }
}

/// Result of a distributed fit.
#[derive(Clone, Debug)]
pub struct ClusterFitResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    pub iters: usize,
    pub trace: Trace,
    /// Total fabric traffic during training.
    pub comm_bytes: u64,
    pub comm_msgs: u64,
    /// Modeled wire time under the configured `NetworkModel`.
    pub sim_wire_secs: f64,
    /// Cumulative time all ranks spent blocked in the post-CD XΔβ
    /// synchronization — the BSP "barrier wait" stragglers inflate and ALB
    /// cuts (straggler diagnosis).
    pub barrier_wait_secs: f64,
    /// Per-node memory footprint in f64 slots: the paper's 3n + 2|S^m|
    /// claim, reported as measured vector lengths (max over nodes).
    pub peak_node_f64_slots: usize,
    /// Per-rank pass / cut-off / traffic accounting (index = rank).
    pub per_rank: Vec<RankLoad>,
    /// Merged span journals from every rank (per-iteration phase timings;
    /// the run-log pipeline behind `--trace-out`).
    pub spans: Vec<crate::obs::span::SpanRecord>,
    /// Sent traffic attributed to solver phases, merged across ranks:
    /// `(phase, bytes, msgs)` sorted by phase name.
    pub comm_by_phase: Vec<(String, u64, u64)>,
}

/// Shared prep: partition, shards, and the per-worker base config.
struct ClusterPlan {
    partition: FeaturePartition,
    shards: Vec<Csc>,
    test_shards: Option<Vec<Csc>>,
    worker_cfg_base: WorkerConfig,
    /// Per-rank cross-block co-occurrence fractions under the resolved
    /// partition (protocol v8 diagnostic; index = rank).
    cuts: Vec<f64>,
}

fn plan_cluster(
    train: &Dataset,
    test: Option<&Dataset>,
    cfg: &DistributedConfig,
) -> ClusterPlan {
    // The virtual clock charges each rank's main-thread CPU time; hybrid
    // pool compute is invisible to it. Enforced here (the seam every driver
    // goes through), not just at the CLI/job-spec shells, so embedders and
    // benches cannot silently produce under-counted scaling numbers.
    assert!(
        !(cfg.virtual_time && cfg.threads > 1),
        "virtual_time does not support hybrid threads (> 1): pool compute \
         is not charged to the virtual clock yet"
    );
    let x_csc = train.to_csc();
    // The single partition-resolution call site for the in-process drivers
    // (fabric and loopback TCP).
    let partition = cfg.partition.resolve(&x_csc, cfg.nodes, cfg.seed);
    let cuts = partition.cut_fractions(&x_csc, cfg.seed);
    let shards: Vec<Csc> = (0..cfg.nodes).map(|m| partition.shard(&x_csc, m)).collect();
    let test_shards: Option<Vec<Csc>> = test.map(|t| {
        let tx = t.to_csc();
        (0..cfg.nodes).map(|m| partition.shard(&tx, m)).collect()
    });
    let worker_cfg_base = WorkerConfig {
        adaptive_mu: cfg.adaptive_mu,
        mu0: cfg.mu0,
        eta1: cfg.eta1,
        eta2: cfg.eta2,
        nu: cfg.nu,
        max_iters: cfg.max_iters,
        tol: cfg.tol,
        patience: cfg.patience,
        linesearch: cfg.linesearch,
        eval_every: cfg.eval_every,
        allreduce: cfg.allreduce,
        max_passes: if cfg.alb_kappa.is_some() {
            cfg.max_passes
        } else {
            1
        },
        chunk: cfg.chunk,
        threads: cfg.threads.max(1),
        straggler_delay: Duration::ZERO,
        virtual_time: cfg.virtual_time,
        slow_factor: 1.0,
        network: cfg.network,
        checkpoint_dir: cfg.checkpoint_dir.clone(),
        checkpoint_every: cfg.checkpoint_every,
        die_after_iters: None,
    };
    ClusterPlan {
        partition,
        shards,
        test_shards,
        worker_cfg_base,
        cuts,
    }
}

/// Per-rank worker config: the base plus this rank's injected chaos.
fn rank_cfg(base: &WorkerConfig, cfg: &DistributedConfig, rank: usize) -> WorkerConfig {
    let mut wcfg = base.clone();
    if let Some(d) = cfg.straggler_delays.get(rank) {
        wcfg.straggler_delay = *d;
    }
    if let Some(f) = cfg.slow_factors.get(rank) {
        wcfg.slow_factor = *f;
    }
    wcfg
}

/// Assemble the per-node blocks into the global result. Communication
/// totals come from the workers' transport accounting, so the numbers are
/// identical across backends.
fn assemble_result(
    train: &Dataset,
    partition: &FeaturePartition,
    cuts: &[f64],
    outputs: Vec<WorkerOutput>,
    sim_wire_secs: f64,
) -> ClusterFitResult {
    let n = train.n();
    let block_weights: Vec<Vec<f64>> = outputs.iter().map(|o| o.beta_local.clone()).collect();
    let beta = partition.unshard_weights(&block_weights);

    let comm_bytes: u64 = outputs.iter().map(|o| o.sent_bytes).sum();
    let comm_msgs: u64 = outputs.iter().map(|o| o.sent_msgs).sum();
    let barrier_wait_secs: f64 = outputs.iter().map(|o| o.sync_wait_secs).sum();
    let per_rank: Vec<RankLoad> = outputs
        .iter()
        .map(|o| {
            let mut load = RankLoad::from_output(o);
            // The planner saw the full matrix, so it fills the cut in.
            load.cut = cuts.get(o.rank).copied().unwrap_or(-1.0);
            load
        })
        .collect();
    let spans: Vec<crate::obs::span::SpanRecord> =
        outputs.iter().flat_map(|o| o.spans.iter().cloned()).collect();
    let comm_by_phase = merge_comm_by_phase(&outputs);

    let mut trace = outputs
        .iter()
        .find_map(|o| o.trace.clone())
        .expect("rank 0 must produce a trace");
    trace.dataset = train.name.clone();
    trace.comm_bytes = comm_bytes;

    // Peak per-node memory: 4 n-vectors (margins, dmargins, w, z) + 2 local
    // weight vectors; the paper counts 3n + 2|S^m| (it streams w,z fused
    // with the data pass — we hold them, +1n, see DESIGN.md).
    let max_block = partition.blocks.iter().map(|b| b.len()).max().unwrap_or(0);
    let peak = 4 * n + 2 * max_block;

    ClusterFitResult {
        objective: trace.final_objective(),
        iters: outputs[0].iters,
        beta,
        trace,
        comm_bytes,
        comm_msgs,
        sim_wire_secs,
        barrier_wait_secs,
        peak_node_f64_slots: peak,
        per_rank,
        spans,
        comm_by_phase,
    }
}

/// Sum every rank's per-phase traffic attribution into one cluster-wide
/// `(phase, bytes, msgs)` breakdown, sorted by phase name.
fn merge_comm_by_phase(outputs: &[WorkerOutput]) -> Vec<(String, u64, u64)> {
    let mut acc: std::collections::BTreeMap<&str, (u64, u64)> =
        std::collections::BTreeMap::new();
    for o in outputs {
        for (phase, bytes, msgs) in &o.comm_by_phase {
            let e = acc.entry(phase.as_str()).or_insert((0, 0));
            e.0 += bytes;
            e.1 += msgs;
        }
    }
    acc.into_iter()
        .map(|(p, (b, m))| (p.to_string(), b, m))
        .collect()
}

/// Train d-GLMNET (or d-GLMNET-ALB when `alb_kappa` is set) on a simulated
/// cluster of `cfg.nodes` threads over the in-process fabric.
pub fn fit_distributed(
    train: &Dataset,
    test: Option<&Dataset>,
    compute: &dyn GlmCompute,
    penalty: &dyn Penalty1D,
    cfg: &DistributedConfig,
) -> ClusterFitResult {
    let plan = plan_cluster(train, test, cfg);
    let (endpoints, stats) = fabric(cfg.nodes, cfg.network);
    // The fabric's thin special case: a shared-memory controller whose
    // per-iteration reset is claimed via generation CAS — no barrier.
    let alb = cfg
        .alb_kappa
        .map(|kappa| AlbController::new(cfg.nodes, kappa));

    let mut outputs: Vec<Option<WorkerOutput>> = (0..cfg.nodes).map(|_| None).collect();

    crossbeam_utils::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (rank, ep) in endpoints.into_iter().enumerate() {
            let shard = &plan.shards[rank];
            let test_shard = plan.test_shards.as_ref().map(|ts| &ts[rank]);
            let wcfg = rank_cfg(&plan.worker_cfg_base, cfg, rank);
            let alb_ref = alb.as_ref();
            let y = train.y.as_slice();
            let test_y = test.map(|t| t.y.as_slice());
            handles.push(scope.spawn(move |_| {
                let nodes = cfg.nodes;
                let shared = WorkerShared {
                    compute,
                    penalty,
                    y,
                    test_y,
                    alb: alb_ref.map(AlbMode::Shared),
                    cfg: &wcfg,
                    nodes,
                };
                let mut ep = ep;
                // In-process ranks share our fate: a dead peer here means a
                // panicked thread, which the join below already surfaces.
                run_worker(rank, shard, test_shard, &mut ep, &shared, None)
                    .expect("in-process peer hung up")
            }));
        }
        for h in handles {
            let out = h.join().expect("worker panicked");
            let rank = out.rank;
            outputs[rank] = Some(out);
        }
    })
    .expect("cluster scope failed");

    let outputs: Vec<WorkerOutput> = outputs.into_iter().map(|o| o.unwrap()).collect();
    debug_assert_eq!(
        outputs.iter().map(|o| o.sent_bytes).sum::<u64>(),
        stats.total_bytes(),
        "fabric global accounting must equal the sum of per-endpoint sends"
    );
    assemble_result(train, &plan.partition, &plan.cuts, outputs, stats.sim_wire_secs())
}

/// Train d-GLMNET over real TCP sockets on loopback: one thread per rank,
/// each owning a [`TcpTransport`] endpoint of a full mesh — the same worker
/// code as [`fit_distributed`], exercising the wire protocol end to end.
/// ALB included: `alb_kappa` runs the transport-level per-iteration quorum,
/// exactly what separate OS processes (`dglmnet train --cluster`) use.
pub fn fit_distributed_tcp(
    train: &Dataset,
    test: Option<&Dataset>,
    compute: &dyn GlmCompute,
    penalty: &dyn Penalty1D,
    cfg: &DistributedConfig,
) -> anyhow::Result<ClusterFitResult> {
    let plan = plan_cluster(train, test, cfg);
    let (addrs, listeners) = bind_loopback(cfg.nodes)?;

    let mut outputs: Vec<Option<WorkerOutput>> = (0..cfg.nodes).map(|_| None).collect();

    crossbeam_utils::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (rank, listener) in listeners.into_iter().enumerate() {
            let shard = &plan.shards[rank];
            let test_shard = plan.test_shards.as_ref().map(|ts| &ts[rank]);
            let wcfg = rank_cfg(&plan.worker_cfg_base, cfg, rank);
            let y = train.y.as_slice();
            let test_y = test.map(|t| t.y.as_slice());
            let addrs = addrs.clone();
            handles.push(scope.spawn(move |_| {
                let mut t =
                    TcpTransport::with_listener(rank, &addrs, &listener, TcpOptions::default())
                        .expect("tcp mesh formation failed");
                let shared = WorkerShared {
                    compute,
                    penalty,
                    y,
                    test_y,
                    alb: cfg.alb_kappa.map(|kappa| AlbMode::Transport { kappa }),
                    cfg: &wcfg,
                    nodes: cfg.nodes,
                };
                run_worker(rank, shard, test_shard, &mut t, &shared, None)
                    .expect("in-process peer hung up")
            }));
        }
        for h in handles {
            let out = h.join().expect("worker panicked");
            let rank = out.rank;
            outputs[rank] = Some(out);
        }
    })
    .expect("cluster scope failed");

    let outputs: Vec<WorkerOutput> = outputs.into_iter().map(|o| o.unwrap()).collect();
    Ok(assemble_result(train, &plan.partition, &plan.cuts, outputs, 0.0))
}

/// Result of a distributed λ-path sweep: the reassembled per-λ models plus
/// the transport accounting for the whole sweep.
#[derive(Clone, Debug)]
pub struct ClusterPathResult {
    pub path: PathResult,
    pub comm_bytes: u64,
    pub comm_msgs: u64,
}

/// Reassemble one full-width model per λ from rank 0's summary points and
/// the per-λ per-rank β blocks (`blocks[k][r]`). The summary columns
/// (objective, auPRC, nnz, iters, updates) are SPMD-identical across
/// ranks, so rank 0's copies are authoritative. Shared by the in-process
/// drivers and the multi-process coordinator (`process::path_cluster`).
pub(crate) fn assemble_path_points(
    partition: &FeaturePartition,
    summary: &[crate::coordinator::worker::PathPointLocal],
    blocks: &[Vec<Vec<f64>>],
    l2: f64,
) -> Vec<PathPoint> {
    debug_assert_eq!(summary.len(), blocks.len());
    summary
        .iter()
        .zip(blocks.iter())
        .map(|(p, bl)| PathPoint {
            lambda1: p.lambda1,
            lambda2: l2,
            beta: partition.unshard_weights(bl),
            objective: p.objective,
            nnz: p.nnz,
            val_auprc: p.val_auprc,
            iters: p.iters,
            cd_updates: p.cd_updates,
        })
        .collect()
}

/// Reassemble per-rank path outputs into full-width per-λ models.
fn assemble_path(
    partition: &FeaturePartition,
    outputs: Vec<PathWorkerOutput>,
    l2: f64,
) -> ClusterPathResult {
    let comm_bytes: u64 = outputs.iter().map(|o| o.sent_bytes).sum();
    let comm_msgs: u64 = outputs.iter().map(|o| o.sent_msgs).sum();
    let k_pts = outputs[0].points.len();
    let blocks: Vec<Vec<Vec<f64>>> = (0..k_pts)
        .map(|k| outputs.iter().map(|o| o.points[k].beta_local.clone()).collect())
        .collect();
    let points = assemble_path_points(partition, &outputs[0].points, &blocks, l2);
    ClusterPathResult {
        path: PathResult {
            points,
            best: outputs[0].best,
        },
        comm_bytes,
        comm_msgs,
    }
}

/// Sweep the λ1 grid once over a simulated cluster of `cfg.nodes` threads on
/// the in-process fabric: the data is sharded ONCE, every rank sweeps the
/// grid descending with warm starts + KKT screening, and the driver
/// reassembles the per-λ models (see [`run_worker_path`]). Validation
/// selection uses `splits.validation` — the paper's §8.2 protocol at
/// cluster scale. BSP only; errors on an empty grid or an ALB config.
pub fn fit_path_distributed(
    splits: &Splits,
    compute: &dyn GlmCompute,
    lambdas: &[f64],
    l2: f64,
    cfg: &DistributedConfig,
    screen: bool,
) -> anyhow::Result<ClusterPathResult> {
    anyhow::ensure!(!lambdas.is_empty(), "λ-path sweep given an empty λ1 grid");
    anyhow::ensure!(
        cfg.alb_kappa.is_none(),
        "λ-path sweep is BSP-only (ALB applies to single long fits)"
    );
    anyhow::ensure!(
        cfg.straggler_delays.is_empty() && cfg.slow_factors.is_empty() && !cfg.virtual_time,
        "λ-path sweep does not support straggler/slow-factor chaos or the virtual clock"
    );
    let plan = plan_cluster(&splits.train, Some(&splits.validation), cfg);
    let val_shards = plan.test_shards.as_ref().expect("validation shards");
    let (endpoints, _stats) = fabric(cfg.nodes, cfg.network);

    let mut outputs: Vec<Option<PathWorkerOutput>> = (0..cfg.nodes).map(|_| None).collect();
    crossbeam_utils::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (rank, ep) in endpoints.into_iter().enumerate() {
            let shard = &plan.shards[rank];
            let val_shard = &val_shards[rank];
            let wcfg = plan.worker_cfg_base.clone();
            let y = splits.train.y.as_slice();
            let val_y = splits.validation.y.as_slice();
            handles.push(scope.spawn(move |_| {
                let mut ep = ep;
                let job = PathJob {
                    lambdas,
                    l2,
                    val_x: val_shard,
                    val_y,
                    screen,
                };
                run_worker_path(rank, shard, &mut ep, compute, y, &wcfg, &job)
                    .expect("in-process peer hung up")
            }));
        }
        for h in handles {
            let out = h.join().expect("path worker panicked");
            let rank = out.rank;
            outputs[rank] = Some(out);
        }
    })
    .expect("cluster scope failed");

    let outputs: Vec<PathWorkerOutput> = outputs.into_iter().map(|o| o.unwrap()).collect();
    Ok(assemble_path(&plan.partition, outputs, l2))
}

/// [`fit_path_distributed`] over real TCP sockets on loopback — one thread
/// per rank, each owning a [`TcpTransport`] endpoint of a full mesh: the
/// single-process proof of the wire protocol the multi-process
/// `dglmnet path --cluster` runtime speaks.
pub fn fit_path_distributed_tcp(
    splits: &Splits,
    compute: &dyn GlmCompute,
    lambdas: &[f64],
    l2: f64,
    cfg: &DistributedConfig,
    screen: bool,
) -> anyhow::Result<ClusterPathResult> {
    anyhow::ensure!(!lambdas.is_empty(), "λ-path sweep given an empty λ1 grid");
    anyhow::ensure!(
        cfg.alb_kappa.is_none(),
        "λ-path sweep is BSP-only (ALB applies to single long fits)"
    );
    anyhow::ensure!(
        cfg.straggler_delays.is_empty() && cfg.slow_factors.is_empty() && !cfg.virtual_time,
        "λ-path sweep does not support straggler/slow-factor chaos or the virtual clock"
    );
    let plan = plan_cluster(&splits.train, Some(&splits.validation), cfg);
    let val_shards = plan.test_shards.as_ref().expect("validation shards");
    let (addrs, listeners) = bind_loopback(cfg.nodes)?;

    let mut outputs: Vec<Option<PathWorkerOutput>> = (0..cfg.nodes).map(|_| None).collect();
    crossbeam_utils::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (rank, listener) in listeners.into_iter().enumerate() {
            let shard = &plan.shards[rank];
            let val_shard = &val_shards[rank];
            let wcfg = plan.worker_cfg_base.clone();
            let y = splits.train.y.as_slice();
            let val_y = splits.validation.y.as_slice();
            let addrs = addrs.clone();
            handles.push(scope.spawn(move |_| {
                let mut t =
                    TcpTransport::with_listener(rank, &addrs, &listener, TcpOptions::default())
                        .expect("tcp mesh formation failed");
                let job = PathJob {
                    lambdas,
                    l2,
                    val_x: val_shard,
                    val_y,
                    screen,
                };
                run_worker_path(rank, shard, &mut t, compute, y, &wcfg, &job)
                    .expect("in-process peer hung up")
            }));
        }
        for h in handles {
            let out = h.join().expect("path worker panicked");
            let rank = out.rank;
            outputs[rank] = Some(out);
        }
    })
    .expect("cluster scope failed");

    let outputs: Vec<PathWorkerOutput> = outputs.into_iter().map(|o| o.unwrap()).collect();
    Ok(assemble_path(&plan.partition, outputs, l2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::glm::loss::LossKind;
    use crate::glm::regularizer::ElasticNet;
    use crate::solver::compute::NativeCompute;
    use crate::solver::dglmnet::{self, DGlmnetConfig};

    fn ds(n: usize, p: usize, seed: u64) -> crate::data::Dataset {
        synth::epsilon_like(&synth::SynthConfig { n, p, seed })
    }

    #[test]
    fn distributed_matches_single_process_reference() {
        // Same partition seed + BSP schedule ⇒ identical iterates to the
        // sequential reference implementation.
        let train = ds(120, 12, 11);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.3, 0.1);
        let dcfg = DistributedConfig {
            nodes: 4,
            max_iters: 15,
            eval_every: 0,
            tol: 0.0,
            ..Default::default()
        };
        let scfg = DGlmnetConfig {
            nodes: 4,
            max_iters: 15,
            eval_every: 0,
            tol: 0.0,
            seed: dcfg.seed,
            ..Default::default()
        };
        let dist = fit_distributed(&train, None, &compute, &pen, &dcfg);
        let seq = dglmnet::fit(&train, &compute, &pen, &scfg, None);
        assert!(
            (dist.objective - seq.objective).abs() / seq.objective < 1e-9,
            "dist {} vs seq {}",
            dist.objective,
            seq.objective
        );
        for (a, b) in dist.beta.iter().zip(seq.beta.iter()) {
            assert!((a - b).abs() < 1e-9, "beta mismatch {a} vs {b}");
        }
    }

    #[test]
    fn every_partition_strategy_fits_and_reports_cuts() {
        // Protocol v8: the strategy seam — any resolvable layout trains to
        // a finite objective with Σ updates = iters × p, and every rank's
        // cut diagnostic is a real fraction (the in-process planner sees
        // the full matrix).
        let train = ds(120, 12, 11);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.3, 0.1);
        for strat in PartitionStrategy::ALL {
            let cfg = DistributedConfig {
                nodes: 3,
                max_iters: 5,
                eval_every: 0,
                tol: 0.0,
                partition: strat,
                ..Default::default()
            };
            let fit = fit_distributed(&train, None, &compute, &pen, &cfg);
            assert!(fit.objective.is_finite(), "{} objective", strat.name());
            let total: u64 = fit.per_rank.iter().map(|l| l.cd_updates).sum();
            assert_eq!(total, 5 * train.p() as u64, "{} updates", strat.name());
            for load in &fit.per_rank {
                assert!(
                    (0.0..=1.0).contains(&load.cut),
                    "{} rank {} cut {}",
                    strat.name(),
                    load.rank,
                    load.cut
                );
            }
        }
    }

    #[test]
    fn objective_monotone_under_bsp() {
        let train = ds(150, 20, 12);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.5, 0.0);
        let cfg = DistributedConfig {
            nodes: 4,
            max_iters: 20,
            eval_every: 0,
            ..Default::default()
        };
        let fit = fit_distributed(&train, None, &compute, &pen, &cfg);
        let objs: Vec<f64> = fit.trace.points.iter().map(|p| p.objective).collect();
        for w in objs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "objective rose {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn alb_converges_to_same_optimum() {
        let train = ds(200, 16, 13);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.2, 0.1);
        let bsp_cfg = DistributedConfig {
            nodes: 4,
            max_iters: 150,
            tol: 1e-10,
            patience: 3,
            eval_every: 0,
            ..Default::default()
        };
        let alb_cfg = DistributedConfig {
            alb_kappa: Some(0.75),
            ..bsp_cfg.clone()
        };
        let bsp = fit_distributed(&train, None, &compute, &pen, &bsp_cfg);
        let alb = fit_distributed(&train, None, &compute, &pen, &alb_cfg);
        assert!(
            (bsp.objective - alb.objective).abs() / bsp.objective < 1e-3,
            "bsp {} vs alb {}",
            bsp.objective,
            alb.objective
        );
    }

    #[test]
    fn alb_beats_bsp_with_injected_straggler() {
        // One node much slower: ALB should cut it off and finish the same
        // iteration count in much less wall-clock time.
        let train = ds(300, 40, 14);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.2, 0.1);
        let mut delays = vec![Duration::ZERO; 4];
        delays[2] = Duration::from_millis(40);
        let base = DistributedConfig {
            nodes: 4,
            max_iters: 8,
            tol: 0.0,
            eval_every: 0,
            straggler_delays: delays,
            chunk: 4,
            ..Default::default()
        };
        let alb_cfg = DistributedConfig {
            alb_kappa: Some(0.75),
            ..base.clone()
        };
        let t0 = std::time::Instant::now();
        let _bsp = fit_distributed(&train, None, &compute, &pen, &base);
        let bsp_time = t0.elapsed();
        let t1 = std::time::Instant::now();
        let _alb = fit_distributed(&train, None, &compute, &pen, &alb_cfg);
        let alb_time = t1.elapsed();
        assert!(
            alb_time < bsp_time,
            "ALB {alb_time:?} should beat BSP {bsp_time:?} with a straggler"
        );
    }

    #[test]
    fn slow_factor_scales_the_virtual_clock() {
        // The virtual cluster clock charges max-over-nodes CPU × slow
        // factor: a heavily handicapped rank must dominate the simulated
        // time axis even though wall-clock is unchanged.
        let train = ds(1500, 60, 16);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.2, 0.1);
        let sim_t = |factors: Vec<f64>| {
            let cfg = DistributedConfig {
                nodes: 2,
                max_iters: 6,
                tol: 0.0,
                eval_every: 0,
                virtual_time: true,
                slow_factors: factors,
                ..Default::default()
            };
            let fit = fit_distributed(&train, None, &compute, &pen, &cfg);
            fit.trace.points.last().unwrap().t_sec
        };
        let even = sim_t(vec![1.0, 1.0]);
        let skewed = sim_t(vec![1.0, 200.0]);
        assert!(even > 0.0, "virtual clock must advance ({even})");
        assert!(
            skewed > 5.0 * even,
            "200× slow factor should dominate the virtual clock: {skewed} vs {even}"
        );
    }

    #[test]
    fn per_rank_loads_are_uniform_under_bsp() {
        let train = ds(150, 20, 18);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.3, 0.1);
        let cfg = DistributedConfig {
            nodes: 3,
            max_iters: 5,
            tol: 0.0,
            eval_every: 0,
            ..Default::default()
        };
        let fit = fit_distributed(&train, None, &compute, &pen, &cfg);
        assert_eq!(fit.per_rank.len(), 3);
        for (r, load) in fit.per_rank.iter().enumerate() {
            assert_eq!(load.rank, r);
            assert_eq!(load.full_passes, 5, "BSP: one pass per iteration");
            assert_eq!(load.cutoffs, 0);
        }
        let total: u64 = fit.per_rank.iter().map(|l| l.cd_updates).sum();
        assert_eq!(total, 5 * train.p() as u64, "Σ updates = iters × p");
    }

    #[test]
    fn comm_bytes_scale_like_mn() {
        // Algorithm 4's communication is Θ(Mn) per iteration (ring moves
        // ~2n per node). Doubling M should roughly double total bytes.
        let train = ds(400, 30, 15);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.2, 0.0);
        let bytes_for = |nodes: usize| {
            let cfg = DistributedConfig {
                nodes,
                max_iters: 5,
                tol: 0.0,
                eval_every: 0,
                ..Default::default()
            };
            fit_distributed(&train, None, &compute, &pen, &cfg).comm_bytes as f64
        };
        let b4 = bytes_for(4);
        let b8 = bytes_for(8);
        let ratio = b8 / b4;
        assert!(
            ratio > 1.5 && ratio < 3.0,
            "bytes ratio M=8/M=4 was {ratio} (b4={b4}, b8={b8})"
        );
    }

    #[test]
    fn test_eval_produces_auprc_series() {
        let splits = synth::Corpus::epsilon_like(0.04, 16);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.1, 0.1);
        let cfg = DistributedConfig {
            nodes: 3,
            max_iters: 6,
            eval_every: 2,
            tol: 0.0,
            ..Default::default()
        };
        let fit = fit_distributed(&splits.train, Some(&splits.test), &compute, &pen, &cfg);
        let evals: Vec<f64> = fit.trace.points.iter().filter_map(|p| p.auprc).collect();
        assert!(!evals.is_empty());
        assert!(evals.iter().all(|a| (0.0..=1.0).contains(a)));
    }

    #[test]
    fn path_rejects_empty_grid_and_alb() {
        let splits = synth::Corpus::epsilon_like(0.04, 19);
        let compute = NativeCompute::new(LossKind::Logistic);
        let cfg = DistributedConfig {
            nodes: 2,
            max_iters: 5,
            eval_every: 0,
            ..Default::default()
        };
        assert!(fit_path_distributed(&splits, &compute, &[], 0.0, &cfg, true).is_err());
        let alb_cfg = DistributedConfig {
            alb_kappa: Some(0.75),
            ..cfg
        };
        assert!(fit_path_distributed(&splits, &compute, &[0.5], 0.0, &alb_cfg, true).is_err());
    }

    #[test]
    fn path_sweep_runs_on_the_fabric() {
        let splits = synth::Corpus::epsilon_like(0.05, 20);
        let compute = NativeCompute::new(LossKind::Logistic);
        let cfg = DistributedConfig {
            nodes: 3,
            max_iters: 40,
            tol: 1e-9,
            eval_every: 0,
            ..Default::default()
        };
        let res =
            fit_path_distributed(&splits, &compute, &[2.0, 0.5, 0.125], 0.1, &cfg, true).unwrap();
        assert_eq!(res.path.points.len(), 3);
        assert!(res.comm_bytes > 0, "three ranks must have talked");
        let best = res.path.best_point();
        assert!(best.objective.is_finite());
        for p in &res.path.points {
            assert_eq!(p.beta.len(), splits.train.p());
            assert!((0.0..=1.0).contains(&p.val_auprc), "auPRC {}", p.val_auprc);
            assert!(p.val_auprc <= best.val_auprc + 1e-12);
        }
        // Warm descending path: nnz grows (roughly) as λ shrinks.
        assert!(res.path.points[2].nnz + 2 >= res.path.points[0].nnz);
    }

    #[test]
    fn hybrid_threads_report_per_rank_accounting_and_are_deterministic() {
        let train = ds(150, 24, 19);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.2, 0.1);
        let cfg = DistributedConfig {
            nodes: 2,
            threads: 3,
            max_iters: 5,
            tol: 0.0,
            eval_every: 0,
            ..Default::default()
        };
        let fit = fit_distributed(&train, None, &compute, &pen, &cfg);
        assert!(fit.objective.is_finite());
        assert_eq!(fit.per_rank.len(), 2);
        for load in &fit.per_rank {
            assert_eq!(load.threads, 3, "rank {} thread count", load.rank);
            assert_eq!(load.updates_per_thread.len(), 3);
            assert_eq!(
                load.updates_per_thread.iter().sum::<u64>(),
                load.cd_updates,
                "per-thread accounting must total the rank's updates"
            );
            assert_eq!(load.full_passes, 5, "hybrid BSP: one pass per iteration");
        }
        // Deterministic ordered reduction: a second run is bit-identical.
        let again = fit_distributed(&train, None, &compute, &pen, &cfg);
        assert_eq!(fit.beta, again.beta);
        assert_eq!(fit.objective, again.objective);
    }

    #[test]
    fn hybrid_path_sweep_runs_and_is_deterministic() {
        let splits = synth::Corpus::epsilon_like(0.05, 25);
        let compute = NativeCompute::new(LossKind::Logistic);
        let cfg = DistributedConfig {
            nodes: 2,
            threads: 2,
            max_iters: 30,
            tol: 1e-9,
            eval_every: 0,
            ..Default::default()
        };
        let res =
            fit_path_distributed(&splits, &compute, &[2.0, 0.5, 0.125], 0.1, &cfg, true).unwrap();
        assert_eq!(res.path.points.len(), 3);
        for p in &res.path.points {
            assert!(p.objective.is_finite());
            assert!((0.0..=1.0).contains(&p.val_auprc));
        }
        let again =
            fit_path_distributed(&splits, &compute, &[2.0, 0.5, 0.125], 0.1, &cfg, true).unwrap();
        for (a, b) in res.path.points.iter().zip(again.path.points.iter()) {
            assert_eq!(a.beta, b.beta, "hybrid path sweep must be deterministic");
        }
        assert_eq!(res.path.best, again.path.best);
    }

    #[test]
    fn spans_cover_every_rank_iteration_and_reconcile_sync() {
        let train = ds(150, 20, 21);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.3, 0.1);
        let cfg = DistributedConfig {
            nodes: 3,
            max_iters: 4,
            tol: 0.0,
            eval_every: 0,
            ..Default::default()
        };
        let fit = fit_distributed(&train, None, &compute, &pen, &cfg);
        // Every rank × iteration records all four top-level phases.
        for rank in 0..3usize {
            for iter in 1..=4u64 {
                for phase in crate::obs::runlog::PHASES {
                    assert!(
                        fit.spans.iter().any(|s| s.rank == rank
                            && s.iter == iter
                            && s.phase == phase
                            && s.depth == 0),
                        "missing span {phase} for rank {rank} iter {iter}"
                    );
                }
            }
        }
        // The journal's sync total reconciles with the RankLoad sync-wait
        // aggregate within 1% (plus a tiny absolute slack: the span wraps
        // the timed region by two extra Instant reads).
        for load in &fit.per_rank {
            let journal_sync: f64 = fit
                .spans
                .iter()
                .filter(|s| s.rank == load.rank && s.phase == "sync" && s.depth == 0)
                .map(|s| s.dur_s)
                .sum();
            let diff = (journal_sync - load.sync_wait_secs).abs();
            assert!(
                diff <= 0.01 * load.sync_wait_secs.max(1e-6) + 2e-4,
                "rank {}: journal sync {journal_sync}s vs rank-load {}s",
                load.rank,
                load.sync_wait_secs
            );
            // Top-level span byte deltas telescope to the rank's sent total.
            let span_bytes: u64 = fit
                .spans
                .iter()
                .filter(|s| s.rank == load.rank && s.depth == 0)
                .map(|s| s.bytes)
                .sum();
            assert_eq!(span_bytes, load.sent_bytes, "rank {}", load.rank);
        }
        // The per-phase traffic attribution partitions the cluster totals.
        let phase_bytes: u64 = fit.comm_by_phase.iter().map(|e| e.1).sum();
        let phase_msgs: u64 = fit.comm_by_phase.iter().map(|e| e.2).sum();
        assert_eq!(phase_bytes, fit.comm_bytes);
        assert_eq!(phase_msgs, fit.comm_msgs);
    }

    #[test]
    fn single_node_cluster_works() {
        let train = ds(80, 6, 17);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.1, 0.1);
        let cfg = DistributedConfig {
            nodes: 1,
            max_iters: 30,
            eval_every: 0,
            ..Default::default()
        };
        let fit = fit_distributed(&train, None, &compute, &pen, &cfg);
        assert!(fit.objective.is_finite());
        assert_eq!(fit.comm_bytes, 0); // M=1: no traffic at all
    }
}
