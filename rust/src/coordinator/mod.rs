//! The distributed d-GLMNET coordinator (L3) — Algorithm 4 running SPMD over
//! the simulated cluster substrate: one OS thread per node, feature-sharded
//! data, AllReduce of `XΔβ`, redundant global line search on every node, and
//! optional ALB straggler cut-off.

pub mod driver;
pub mod worker;

pub use driver::{
    fit_distributed, fit_distributed_tcp, fit_path_distributed, fit_path_distributed_tcp,
    ClusterFitResult, ClusterPathResult, DistributedConfig, RankLoad,
};
