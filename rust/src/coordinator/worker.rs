//! Per-node worker: owns one feature shard X^m (CSC), the local weights β^m
//! and Δβ^m, and a synchronized copy of the margin vector Xβ — exactly the
//! paper's per-node state (memory footprint O(n) vectors + 2|S^m| weights,
//! Algorithm 4 note 2).
//!
//! The worker executes Algorithm 4 steps SPMD-style: every node runs the
//! same code, the only communication is AllReduce (XΔβ, regularizer partial
//! sums, test-margin partial sums), and control decisions (line-search α,
//! convergence) are re-derived identically on every node from the reduced
//! values — no master.
//!
//! All communication goes through the [`Transport`] seam, so the identical
//! worker drives both the in-process fabric (threads) and the TCP mesh
//! (separate OS processes, `dglmnet worker`). ALB included: each outer
//! iteration begins one fresh [`AlbQuorum`] on a tag from the worker's
//! `TAG_STRIDE` allocator, so there is no generation reset and no barrier —
//! the asynchronous path works across real processes.

use crate::cluster::alb::{AlbMode, AlbQuorum};
use crate::cluster::allreduce::{
    allreduce_max, allreduce_scalar, allreduce_sum, AllReduceAlgo, TAG_STRIDE,
};
use crate::cluster::checkpoint::{Checkpoint, RankBlock, ResumePoint};
use crate::cluster::transport::{Transport, TransportError};
use crate::glm::regularizer::{ElasticNet, Penalty1D};
use crate::metrics;
use crate::obs::span::{Journal, SpanRecord};
use crate::solver::compute::GlmCompute;
use crate::solver::linesearch::{line_search, LineSearchConfig};
use crate::solver::path;
use crate::solver::subproblem::{cd_cycle, CycleBudget, HybridCd, SubproblemState};
use crate::solver::trace::{Trace, TracePoint};
use crate::sparse::Csc;
use std::cell::{Cell, RefCell};
use std::time::{Duration, Instant};

/// Immutable per-run parameters shared by all workers.
pub struct WorkerShared<'a> {
    pub compute: &'a dyn GlmCompute,
    pub penalty: &'a dyn Penalty1D,
    pub y: &'a [f64],
    pub test_y: Option<&'a [f64]>,
    /// ALB quorum source; `None` = synchronous BSP. Must be the same
    /// variant on every rank (SPMD uniformity).
    pub alb: Option<AlbMode<'a>>,
    pub cfg: &'a WorkerConfig,
    /// Total node count M (for SPMD-uniform per-node traffic estimates).
    pub nodes: usize,
}

impl WorkerShared<'_> {
    fn cfg_nodes(&self) -> f64 {
        self.nodes.max(1) as f64
    }
}

/// Algorithm parameters (the distributed mirror of `DGlmnetConfig`).
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub adaptive_mu: bool,
    pub mu0: f64,
    pub eta1: f64,
    pub eta2: f64,
    pub nu: f64,
    pub max_iters: usize,
    pub tol: f64,
    pub patience: usize,
    pub linesearch: LineSearchConfig,
    pub eval_every: usize,
    pub allreduce: AllReduceAlgo,
    /// Under ALB, cap on full passes a fast node may run per iteration
    /// ("two or more updates of each weight", paper §7).
    pub max_passes: usize,
    /// Coordinates between stop-flag polls / straggler sleeps (capped at
    /// the block size so every pass polls the quorum at least once).
    pub chunk: usize,
    /// Intra-rank CD threads T (hybrid mode): T ≥ 2 splits the rank's block
    /// into T sub-blocks run as pool waves against a frozen margin snapshot
    /// — the global block structure becomes M·T, same Theorem 1 line-search
    /// merge. 1 = the classic coupled single-thread cycle.
    pub threads: usize,
    /// Injected per-pass compute delay for this node (slow-node simulation).
    pub straggler_delay: Duration,
    /// Virtual cluster clock (see util::cputime): trace timestamps become
    /// max-over-nodes per-thread CPU time (× slow_factor) plus modeled wire
    /// time, instead of host wall-clock. Essential when the host has fewer
    /// cores than simulated nodes.
    pub virtual_time: bool,
    /// Compute-speed multiplier for this node under the virtual clock
    /// (2.0 = half speed).
    pub slow_factor: f64,
    /// Wire model used to charge communication under the virtual clock.
    pub network: crate::cluster::fabric::NetworkModel,
    /// Where rank 0 persists iteration checkpoints (None = rank 0 does not
    /// write; non-zero ranks never write regardless — they only feed the
    /// gather).
    pub checkpoint_dir: Option<String>,
    /// Checkpoint every k-th outer iteration (0 = checkpointing off). Must
    /// be SPMD-identical across ranks: it gates a collective gather.
    pub checkpoint_every: usize,
    /// Chaos injection: abort training right after the k-th outer
    /// iteration, simulating an abrupt crash of this rank (the caller
    /// drops the transport; peers observe a hang-up mid-collective).
    pub die_after_iters: Option<usize>,
}

/// The result each worker returns to the driver.
pub struct WorkerOutput {
    pub rank: usize,
    /// Final local weights β^m (indexed like the shard's columns).
    pub beta_local: Vec<f64>,
    /// Only rank 0 fills the trace.
    pub trace: Option<Trace>,
    pub iters: usize,
    /// This endpoint's sent traffic during the run (transport accounting).
    pub sent_bytes: u64,
    pub sent_msgs: u64,
    /// Coordinate updates this rank performed across all iterations — the
    /// Table-2 load column that exposes straggler cut-offs under ALB.
    pub cd_updates: u64,
    /// Full passes over S^m completed (BSP: one per iteration).
    pub full_passes: u64,
    /// Iterations where the κ quorum cut this rank off before it finished
    /// a single pass.
    pub cutoffs: u64,
    /// Time this rank spent inside the post-CD XΔβ AllReduce — under BSP
    /// this is the barrier wait fast nodes pay for stragglers; ALB exists
    /// to shrink it.
    pub sync_wait_secs: f64,
    /// Effective intra-rank CD threads (sub-block count; 1 = classic).
    pub threads: usize,
    /// Coordinate updates per sub-block thread across the run (a single
    /// entry equal to `cd_updates` on the classic path).
    pub updates_per_thread: Vec<u64>,
    /// Span journal drained at the end of the run: per-iteration phase
    /// timings (`cd`/`sync`/`linesearch`/`comm`, hybrid `cd_wave`s) with
    /// transport bytes attributed to each top-level span.
    pub spans: Vec<SpanRecord>,
    /// Sent traffic attributed to solver phases via the tag-allocation log
    /// and the transport's per-tag accounting: `(phase, bytes, msgs)`.
    pub comm_by_phase: Vec<(String, u64, u64)>,
}

/// Outcome of one iteration's ALB subproblem (see [`run_alb_subproblem`]).
pub struct AlbOutcome {
    /// Coordinate updates performed this iteration.
    pub updates: usize,
    /// Full passes over the block completed this iteration.
    pub full_passes: usize,
    /// Whether this rank reported a completed pass to the quorum (false =
    /// it was cut off mid-pass, the paper's straggler case).
    pub reported: bool,
}

/// One outer iteration's local subproblem under ALB: chunks of coordinate
/// descent with the quorum polled between chunks (and, in the shared-memory
/// special case, a per-coordinate stop flag inside the chunk). Always runs
/// at least one chunk, mirroring `cd_cycle`'s at-least-one-update rule, so
/// a pre-fired quorum still makes progress on every rank and the cyclic
/// cursor keeps advancing — the straggler resumes mid-block next iteration.
/// With `hybrid` the chunks become pool waves (`chunk` coordinates per
/// sub-block), the quorum polled between waves.
#[allow(clippy::too_many_arguments)]
pub fn run_alb_subproblem(
    x: &Csc,
    beta: &[f64],
    w: &[f64],
    z: &[f64],
    mu: f64,
    penalty: &dyn Penalty1D,
    cfg: &WorkerConfig,
    state: &mut SubproblemState,
    hybrid: Option<&mut HybridCd>,
    quorum: &mut AlbQuorum<'_>,
    t: &mut dyn Transport,
    journal: Option<(&Journal, u64)>,
) -> Result<AlbOutcome, TransportError> {
    let p_local = x.ncols;
    if p_local == 0 {
        // An empty block is a trivially complete pass: report it so this
        // rank never starves the κ quorum (possible when p < M).
        quorum.report_full_pass(t)?;
        return Ok(AlbOutcome {
            updates: 0,
            full_passes: 1,
            reported: true,
        });
    }
    if let Some(h) = hybrid {
        return run_alb_subproblem_hybrid(h, beta, w, z, mu, penalty, cfg, state, quorum, t, journal);
    }
    let max_updates = cfg.max_passes.max(1) * p_local;
    let mut updates = 0usize;
    let mut reported = false;
    loop {
        let chunk = cfg.chunk.max(1).min(p_local).min(max_updates - updates);
        inject_delay(cfg, chunk, p_local);
        let out = cd_cycle(
            x,
            beta,
            w,
            z,
            mu,
            cfg.nu,
            penalty,
            state,
            CycleBudget {
                max_updates: chunk,
                stop: quorum.stop_flag(),
                active: None,
            },
        );
        updates += out.updates;
        if !reported && updates >= p_local {
            quorum.report_full_pass(t)?;
            reported = true;
        }
        if out.updates < chunk {
            break; // the shared stop flag fired mid-chunk
        }
        if updates >= max_updates || quorum.should_stop(t)? {
            break;
        }
    }
    Ok(AlbOutcome {
        updates,
        full_passes: updates / p_local,
        reported,
    })
}

/// The hybrid variant of the ALB subproblem: waves of up to `chunk`
/// coordinates per sub-block with the quorum polled between waves (and, on
/// the shared-memory fabric, the per-coordinate stop flag inside each
/// wave). Partials are merged into `state` by the ordered reduction when
/// the iteration's CD work is over, so the caller's post-CD flow (allreduce
/// of `state.t`, line search over `state.delta_beta`) is unchanged.
#[allow(clippy::too_many_arguments)]
fn run_alb_subproblem_hybrid(
    h: &mut HybridCd,
    beta: &[f64],
    w: &[f64],
    z: &[f64],
    mu: f64,
    penalty: &dyn Penalty1D,
    cfg: &WorkerConfig,
    state: &mut SubproblemState,
    quorum: &mut AlbQuorum<'_>,
    t: &mut dyn Transport,
    journal: Option<(&Journal, u64)>,
) -> Result<AlbOutcome, TransportError> {
    let p_local: usize = h.ranges.iter().map(|r| r.len()).sum();
    let max_passes = cfg.max_passes.max(1);
    h.reset();
    let mut sub_done = vec![0usize; h.threads()];
    let mut updates = 0usize;
    let mut reported = false;
    loop {
        // Per-wave budget: up to `chunk` coordinates per sub-block, capped
        // by each sub-block's remaining pass allowance.
        let budgets: Vec<usize> = h
            .ranges
            .iter()
            .zip(sub_done.iter())
            .map(|(r, &done)| {
                let cap = max_passes * r.len();
                cfg.chunk.max(1).min(r.len()).min(cap.saturating_sub(done))
            })
            .collect();
        let wave_budget: usize = budgets.iter().sum();
        if wave_budget == 0 {
            break; // every sub-block exhausted its pass allowance
        }
        inject_delay(cfg, wave_budget, p_local);
        let wave_span = journal.map(|(j, it)| j.start(it, "cd_wave"));
        let outs = h.wave(beta, w, z, mu, cfg.nu, penalty, &budgets, None, quorum.stop_flag());
        if let (Some((j, _)), Some(sp)) = (journal, wave_span) {
            j.finish(sp);
        }
        let mut cut_mid_wave = false;
        for (k, o) in outs.iter().enumerate() {
            sub_done[k] += o.updates;
            updates += o.updates;
            if budgets[k] > 0 && o.updates < budgets[k] {
                cut_mid_wave = true; // the shared stop flag fired inside the wave
            }
        }
        if !reported && updates >= p_local {
            quorum.report_full_pass(t)?;
            reported = true;
        }
        if cut_mid_wave || quorum.should_stop(t)? {
            break;
        }
    }
    h.reduce_into(state);
    Ok(AlbOutcome {
        updates,
        full_passes: updates / p_local,
        reported,
    })
}

/// Run the full training loop for one node. `x` is the node's shard X^m;
/// `test_x` the same feature block of the test matrix (for auPRC traces).
/// `transport` is the node's attachment to the cluster — fabric endpoint or
/// TCP mesh, the worker cannot tell.
///
/// `resume` restarts the loop mid-fit from a [`Checkpoint`]-derived
/// [`ResumePoint`] (same value on every rank modulo the per-rank block):
/// with an unchanged cluster shape the continuation is bit-identical to
/// the uninterrupted run (DESIGN.md §Failure model). A peer dying mid-fit
/// surfaces as `Err(TransportError)` — the coordinator's recovery loop,
/// not the worker, decides whether that is fatal.
pub fn run_worker(
    rank: usize,
    x: &Csc,
    test_x: Option<&Csc>,
    transport: &mut dyn Transport,
    shared: &WorkerShared<'_>,
    resume: Option<&ResumePoint>,
) -> Result<WorkerOutput, TransportError> {
    debug_assert_eq!(rank, transport.rank());
    let cfg = shared.cfg;
    let n = x.nrows;
    let p_local = x.ncols;
    let y = shared.y;
    debug_assert_eq!(y.len(), n);

    let mut beta = vec![0.0; p_local];
    let mut margins = vec![0.0; n];
    let mut w = vec![0.0; n];
    let mut z = vec![0.0; n];
    let mut mu = cfg.mu0;
    let mut state = SubproblemState::new(p_local, n);
    // Hybrid mode: T ≥ 2 decomposes the block into T sub-blocks run as one
    // pool wave per pass (DESIGN.md §Hybrid parallelism). The rank-level
    // `state` stays the single source of truth for the post-CD flow — the
    // waves merge into it via the deterministic ordered reduction.
    let mut hybrid = (cfg.threads > 1 && p_local > 0).then(|| HybridCd::new(x, cfg.threads));
    // Restore checkpointed state: β, the synced margins, μ, and the cyclic
    // cursors. Working stats (w, z) and the regularizer are re-derived
    // below by the same deterministic code an uninterrupted run uses.
    let start_iter = match resume {
        None => 0,
        Some(rp) => {
            assert_eq!(
                rp.beta.len(),
                p_local,
                "resume β block does not match this rank's shard"
            );
            assert_eq!(rp.margins.len(), n, "resume margins do not match dataset");
            beta.copy_from_slice(&rp.beta);
            margins.copy_from_slice(&rp.margins);
            mu = rp.mu;
            state.cursor = rp.cursor;
            if let Some(h) = hybrid.as_mut() {
                // Only a shape-identical resume restores mid-block cursors;
                // a re-sharded or re-threaded continuation starts its
                // cursors at 0 (still correct, no longer bit-identical).
                if rp.sub_cursors.len() == h.states.len() {
                    for (s, &c) in h.states.iter_mut().zip(rp.sub_cursors.iter()) {
                        s.cursor = c;
                    }
                }
            }
            rp.iter
        }
    };
    let started = Instant::now();
    // Virtual cluster clock state.
    let mut sim_clock = 0.0f64;
    let mut cpu_mark = crate::util::cputime::thread_cpu_secs();
    let mut bytes_mark = 0u64;
    let mut msgs_mark = 0u64;
    // Table-2 load accounting.
    let mut cd_updates = 0u64;
    let mut full_passes = 0u64;
    let mut cutoffs = 0u64;
    let mut sync_wait = Duration::ZERO;
    // Sliding window of retired ALB tags, re-drained every iteration so
    // late straggler frames don't pile up in the transport's pending map
    // (a frame can arrive after its tag was first drained).
    let mut retired_alb_tags: Vec<u64> = Vec::new();

    // Tag allocator: SPMD-deterministic (every rank performs the identical
    // sequence of collectives). Each allocation is logged with the solver
    // phase it was made in so the transport's per-tag accounting can be
    // attributed back to phases at the end of the run.
    let tag = Cell::new(0u64);
    let phase = Cell::new("init");
    let tag_phases: RefCell<Vec<(u64, &'static str)>> = RefCell::new(Vec::new());
    let next_tag = || {
        let t = tag.get();
        tag.set(t + TAG_STRIDE);
        tag_phases.borrow_mut().push((t, phase.get()));
        t
    };

    let ep_cell = RefCell::new(transport);

    // Span journal: every outer iteration's phases are timed and drained
    // into the WorkerOutput (the run-log pipeline behind `--trace-out`).
    let journal = Journal::with_default_capacity(rank);

    // --- initial objective ---
    let init_span = journal.start(start_iter as u64, "init");
    let mut loss = shared.compute.stats(y, &margins, &mut w, &mut z);
    let mut reg = {
        let mut r = [shared.penalty.value(&beta)];
        allreduce_sum(*ep_cell.borrow_mut(), next_tag(), &mut r, AllReduceAlgo::Naive)?;
        r[0]
    };
    // On resume the checkpointed objective is authoritative (it equals the
    // recomputed value bit-for-bit when the cluster shape is unchanged, and
    // keeps the convergence test exact when it is not).
    let mut f_cur = match resume {
        Some(rp) => rp.f_cur,
        None => loss + reg,
    };

    let mut trace = (rank == 0).then(|| Trace::new("d-glmnet-dist", "distributed"));
    record_point(
        &mut trace,
        &started,
        None,
        start_iter,
        f_cur,
        &beta,
        1.0,
        mu,
        &ep_cell,
        &next_tag,
        test_x,
        shared,
    )?;
    journal.finish_with_bytes(init_span, ep_cell.borrow().sent().0);

    let mut stall = resume.map_or(0, |rp| rp.stall);
    let mut iters = start_iter;
    for it in (start_iter + 1)..=cfg.max_iters {
        iters = it;
        let itn = it as u64;
        // Chaos injection: die mid-protocol. Peers are (or will be) blocked
        // in this iteration's collectives and see the hang-up as a typed
        // error once the caller drops the transport.
        if cfg.die_after_iters.is_some_and(|k| it > k) {
            return Err(TransportError::PeerGone { peer: rank });
        }
        // ---- Algorithm 4 step 4: local subproblem (with optional ALB) ----
        phase.set("cd");
        let mut bytes_before = ep_cell.borrow().sent().0;
        let cd_span = journal.start(itn, "cd");
        state.reset();
        match shared.alb {
            None => {
                // BSP: exactly one full pass (as one pool wave over the
                // sub-blocks in hybrid mode).
                match hybrid.as_mut() {
                    None => {
                        if p_local > 0 {
                            inject_delay(cfg, p_local, p_local);
                            cd_cycle(
                                x,
                                &beta,
                                &w,
                                &z,
                                mu,
                                cfg.nu,
                                shared.penalty,
                                &mut state,
                                CycleBudget::full_cycle(p_local),
                            );
                        }
                    }
                    Some(h) => {
                        inject_delay(cfg, p_local, p_local);
                        let wave = journal.start(itn, "cd_wave");
                        h.bsp_pass(&beta, &w, &z, mu, cfg.nu, shared.penalty, &mut state);
                        journal.finish(wave);
                    }
                }
                cd_updates += p_local as u64;
                full_passes += 1;
            }
            Some(mode) => {
                // Fresh quorum on a fresh tag every iteration: late frames
                // from stragglers land on a retired tag and are never
                // replayed, so there is nothing to reset. Re-drain the
                // recent retired tags so those frames don't accumulate.
                for &old in &retired_alb_tags {
                    crate::cluster::alb::drain_retired_tag(*ep_cell.borrow_mut(), old);
                }
                let alb_tag = next_tag();
                if retired_alb_tags.len() == crate::cluster::alb::RETIRED_TAG_WINDOW {
                    retired_alb_tags.remove(0);
                }
                retired_alb_tags.push(alb_tag);
                let mut quorum = mode.begin_iteration(shared.nodes, alb_tag);
                let out = run_alb_subproblem(
                    x,
                    &beta,
                    &w,
                    &z,
                    mu,
                    shared.penalty,
                    cfg,
                    &mut state,
                    hybrid.as_mut(),
                    &mut quorum,
                    *ep_cell.borrow_mut(),
                    Some((&journal, itn)),
                )?;
                cd_updates += out.updates as u64;
                full_passes += out.full_passes as u64;
                if !out.reported {
                    cutoffs += 1;
                }
            }
        }

        {
            let b = ep_cell.borrow().sent().0;
            journal.finish_with_bytes(cd_span, b - bytes_before);
            bytes_before = b;
        }

        // ---- step 6: AllReduce XΔβ ----
        // Timed: under BSP this blocking collective is where fast ranks
        // wait out stragglers (the "barrier wait" the comm report exposes).
        // The span covers exactly the region summed into `sync_wait`, so
        // trace-report can reconcile the journal against the RankLoad sum.
        phase.set("sync");
        let sync_span = journal.start(itn, "sync");
        let sync_t0 = Instant::now();
        let mut dmargins = state.t.clone();
        allreduce_sum(*ep_cell.borrow_mut(), next_tag(), &mut dmargins, cfg.allreduce)?;
        sync_wait += sync_t0.elapsed();
        {
            let b = ep_cell.borrow().sent().0;
            journal.finish_with_bytes(sync_span, b - bytes_before);
            bytes_before = b;
        }

        // ---- step 7: global line search (redundant on every node) ----
        phase.set("linesearch");
        let ls_span = journal.start(itn, "linesearch");
        // ∇L(β)ᵀΔβ from the cached working set: g_i = −w_i z_i exactly
        // (z = −g/w with the same floored w), so no extra stats pass.
        let ker = crate::kernels::active();
        let grad_dot = ker.neg_wz_dot(&w, &z, &dmargins);
        // The line-search callback cannot return a Result through the
        // solver seam, so a transport failure inside it is stashed and
        // re-raised as soon as the search returns (the zeros handed back
        // in the meantime are discarded with the whole iteration).
        let ls_err: Cell<Option<TransportError>> = Cell::new(None);
        let reg_ray = |alphas: &[f64]| -> Vec<f64> {
            let mut out = vec![0.0; alphas.len()];
            for (local, d) in state.delta_beta.iter().enumerate() {
                let b = beta[local];
                for (k, &a) in alphas.iter().enumerate() {
                    out[k] += shared.penalty.value_1d(b + a * d);
                }
            }
            if let Err(e) =
                allreduce_sum(*ep_cell.borrow_mut(), next_tag(), &mut out, AllReduceAlgo::Naive)
            {
                ls_err.set(Some(e));
                return vec![0.0; alphas.len()];
            }
            out
        };
        let ls = line_search(
            shared.compute,
            &cfg.linesearch,
            y,
            &margins,
            &dmargins,
            f_cur,
            reg,
            grad_dot,
            &reg_ray,
        );
        if let Some(e) = ls_err.take() {
            return Err(e);
        }

        // ---- steps 8-9: apply the step ----
        if ls.alpha > 0.0 {
            ker.margin_update_with_xdelta(&mut beta, &state.delta_beta, ls.alpha);
            ker.margin_update_with_xdelta(&mut margins, &dmargins, ls.alpha);
        }
        if cfg.adaptive_mu {
            if ls.alpha < 1.0 {
                mu *= cfg.eta1;
            } else {
                mu = (mu / cfg.eta2).max(1.0);
            }
        }
        {
            let b = ep_cell.borrow().sent().0;
            journal.finish_with_bytes(ls_span, b - bytes_before);
            bytes_before = b;
        }

        // ---- bookkeeping: new stats + objective (SPMD-identical) ----
        phase.set("comm");
        let comm_span = journal.start(itn, "comm");
        loss = shared.compute.stats(y, &margins, &mut w, &mut z);
        reg = {
            let mut r = [shared.penalty.value(&beta)];
            allreduce_sum(*ep_cell.borrow_mut(), next_tag(), &mut r, AllReduceAlgo::Naive)?;
            r[0]
        };
        let f_new = loss + reg;
        let rel_drop = (f_cur - f_new) / f_cur.abs().max(1e-12);
        f_cur = f_new;

        // ---- virtual clock: slowest node's compute + modeled wire ----
        let t_override = if cfg.virtual_time {
            let cpu_now = crate::util::cputime::thread_cpu_secs();
            let my_compute = (cpu_now - cpu_mark) * cfg.slow_factor;
            cpu_mark = cpu_now;
            let slowest = allreduce_max(*ep_cell.borrow_mut(), next_tag(), my_compute)?;
            // Per-node wire traffic this iteration. When the backend can
            // observe all links (fabric), charge the SPMD-uniform share:
            // global delta divided by M (each node's sends are sequential).
            // Otherwise (TCP) fall back to this endpoint's own sends.
            let ((b_now, m_now), share) = {
                let t = ep_cell.borrow();
                match t.global_traffic() {
                    Some(g) => (g, shared.cfg_nodes()),
                    None => (t.sent(), 1.0),
                }
            };
            let db = (b_now - bytes_mark) as f64 / share;
            let dm = (m_now - msgs_mark) as f64 / share;
            bytes_mark = b_now;
            msgs_mark = m_now;
            let wire = cfg.network.ns_per_byte * 1e-9 * db
                + cfg.network.latency_us_per_msg * 1e-6 * dm;
            sim_clock += slowest + wire;
            Some(sim_clock)
        } else {
            None
        };

        record_point(
            &mut trace,
            &started,
            t_override,
            it,
            f_cur,
            &beta,
            ls.alpha,
            mu,
            &ep_cell,
            &next_tag,
            test_x,
            shared,
        )?;
        journal.finish_with_bytes(comm_span, ep_cell.borrow().sent().0 - bytes_before);

        // ---- convergence (identical decision on every node) ----
        if rel_drop.abs() < cfg.tol {
            stall += 1;
        } else {
            stall = 0;
        }
        let stop = stall >= cfg.patience;

        // ---- iteration checkpoint (collective gather to rank 0) ----
        // `checkpoint_every` is SPMD-identical, so every rank takes this
        // branch together; rank 0 assembles the full `Checkpoint` and
        // persists it atomically. Disk trouble is survivable (warn and keep
        // training); peer death during the gather is not (typed error, like
        // any other collective).
        if cfg.checkpoint_every > 0 && it % cfg.checkpoint_every == 0 && !stop {
            phase.set("ckpt");
            let ck_span = journal.start(itn, "ckpt");
            let ck_tag = next_tag();
            let sub_cursors: Vec<usize> = match &hybrid {
                Some(h) => h.states.iter().map(|s| s.cursor).collect(),
                None => Vec::new(),
            };
            if rank == 0 {
                let mut ranks = vec![RankBlock {
                    cursor: state.cursor,
                    sub_cursors,
                    beta: beta.clone(),
                }];
                let mut ok = true;
                for from in 1..shared.nodes {
                    let p = ep_cell.borrow_mut().recv_from(from, ck_tag)?;
                    match decode_rank_block(&p) {
                        Some(b) => ranks.push(b),
                        None => {
                            crate::obs_warn!(
                                "ckpt",
                                format!("rank {from} sent a malformed checkpoint block"),
                                iter = it
                            );
                            ok = false;
                        }
                    }
                }
                if ok {
                    if let Some(dir) = cfg.checkpoint_dir.as_deref() {
                        let ck = Checkpoint {
                            iter: it,
                            stall,
                            mu,
                            f_cur,
                            lambda_idx: 0,
                            margins: margins.clone(),
                            ranks,
                        };
                        match ck.write_atomic(std::path::Path::new(dir)) {
                            Ok(path) => {
                                crate::obs::metrics::global().counter("ckpt.written").inc();
                                crate::obs_debug!(
                                    "ckpt",
                                    format!("wrote checkpoint {}", path.display()),
                                    iter = it
                                );
                            }
                            Err(e) => crate::obs_warn!(
                                "ckpt",
                                format!("checkpoint write failed: {e}"),
                                iter = it
                            ),
                        }
                    }
                }
            } else {
                let mut payload = Vec::with_capacity(2 + sub_cursors.len() + beta.len());
                payload.push(state.cursor as f64);
                payload.push(sub_cursors.len() as f64);
                payload.extend(sub_cursors.iter().map(|&c| c as f64));
                payload.extend_from_slice(&beta);
                ep_cell.borrow_mut().send(0, ck_tag, payload)?;
            }
            journal.finish(ck_span);
        }

        if stop {
            break;
        }
    }

    let (sent_bytes, sent_msgs) = ep_cell.borrow().sent();
    let comm_by_phase =
        attribute_comm_to_phases(&tag_phases.borrow(), ep_cell.borrow().sent_by_tag());
    let spans = journal.drain();
    let (threads, updates_per_thread) = match &hybrid {
        Some(h) => (h.threads(), h.updates_per_thread.clone()),
        None => (1, vec![cd_updates]),
    };
    Ok(WorkerOutput {
        rank,
        beta_local: beta,
        trace,
        iters,
        sent_bytes,
        sent_msgs,
        cd_updates,
        full_passes,
        cutoffs,
        sync_wait_secs: sync_wait.as_secs_f64(),
        threads,
        updates_per_thread,
        spans,
        comm_by_phase,
    })
}

/// Decode one rank's checkpoint-gather payload
/// `[cursor, k, sub_cursors[0..k], beta...]`. Returns `None` on anything
/// malformed so the coordinator skips the write instead of persisting a
/// corrupt checkpoint.
fn decode_rank_block(p: &[f64]) -> Option<RankBlock> {
    let as_count = |v: f64| -> Option<usize> {
        if v.is_finite() && v >= 0.0 && v.fract() == 0.0 && v < (1u64 << 40) as f64 {
            Some(v as usize)
        } else {
            None
        }
    };
    let cursor = as_count(*p.first()?)?;
    let k = as_count(*p.get(1)?)?;
    let sub = p.get(2..2 + k)?;
    let sub_cursors = sub.iter().map(|&c| as_count(c)).collect::<Option<Vec<_>>>()?;
    let beta = p.get(2 + k..)?.to_vec();
    Some(RankBlock { cursor, sub_cursors, beta })
}

/// Map the transport's per-tag accounting onto solver phases using the
/// worker's tag-allocation log (ascending `(tag, phase)` pairs): a sent tag
/// belongs to the phase that allocated the greatest logged tag ≤ it. Tags
/// outside the log (none in practice — every collective tag comes from
/// `next_tag`) fall into `"other"`.
fn attribute_comm_to_phases(
    tag_phases: &[(u64, &'static str)],
    by_tag: Vec<(u64, u64, u64)>,
) -> Vec<(String, u64, u64)> {
    let mut acc: std::collections::BTreeMap<&str, (u64, u64)> =
        std::collections::BTreeMap::new();
    for (tag, bytes, msgs) in by_tag {
        let idx = tag_phases.partition_point(|e| e.0 <= tag);
        let phase = if idx == 0 { "other" } else { tag_phases[idx - 1].1 };
        let e = acc.entry(phase).or_insert((0, 0));
        e.0 += bytes;
        e.1 += msgs;
    }
    acc.into_iter()
        .map(|(p, (b, m))| (p.to_string(), b, m))
        .collect()
}

/// Inputs of one distributed λ-path sweep (job-spec v3 `path` mode): the λ1
/// grid (descending, so warm starts and strong-rule screening pay off), the
/// fixed λ2, the validation feature shard this rank scores, the full
/// validation labels, and the screening switch.
pub struct PathJob<'a> {
    pub lambdas: &'a [f64],
    pub l2: f64,
    pub val_x: &'a Csc,
    pub val_y: &'a [f64],
    pub screen: bool,
}

/// One λ point as every rank sees it: the SPMD-identical summary plus this
/// rank's own β block.
pub struct PathPointLocal {
    pub lambda1: f64,
    pub objective: f64,
    pub val_auprc: f64,
    pub nnz: usize,
    pub iters: usize,
    /// Global (allreduced) coordinate updates spent on this point.
    pub cd_updates: u64,
    pub beta_local: Vec<f64>,
}

/// What one rank returns from a path sweep.
pub struct PathWorkerOutput {
    pub rank: usize,
    pub points: Vec<PathPointLocal>,
    /// Validation-best index — SPMD-identical on every rank (NaN-safe:
    /// degenerate validation splits select deterministically, never panic).
    pub best: usize,
    /// This rank's own CD updates across the whole sweep (load accounting).
    pub cd_updates_local: u64,
    pub sent_bytes: u64,
    pub sent_msgs: u64,
}

/// Run the full λ-path sweep for one node — the distributed mirror of
/// `solver::path::l1_path` (same math point for point): the shard is built
/// ONCE, then the grid is swept descending with β, margins and the
/// `SubproblemState` buffers carried warm across λ points instead of
/// re-fitting cold. Per point this rank:
///
/// 1. screens its own block with the sequential strong rule (the floored
///    bound of `path::strong_rule_threshold`; screening is embarrassingly
///    parallel under feature sharding — the gradient only needs the synced
///    margins),
/// 2. runs the BSP d-GLMNET loop restricted to the active set,
/// 3. re-checks the exact KKT conditions on everything it screened out and
///    re-cycles while ANY rank still has violations (the decision is
///    allreduced, keeping the collective schedule SPMD-uniform),
/// 4. scores the validation split through an allreduce of partial margins
///    and derives the auPRC — identically on every rank, so the best-point
///    selection needs no extra coordination.
///
/// BSP only: the sweep's inner fits run one pass per iteration (ALB applies
/// to single long fits, not the many short warm fits of a path).
pub fn run_worker_path(
    rank: usize,
    x: &Csc,
    transport: &mut dyn Transport,
    compute: &dyn GlmCompute,
    y: &[f64],
    cfg: &WorkerConfig,
    job: &PathJob<'_>,
) -> Result<PathWorkerOutput, TransportError> {
    debug_assert_eq!(rank, transport.rank());
    assert!(!job.lambdas.is_empty(), "path sweep needs a non-empty λ grid");
    let n = x.nrows;
    let p_local = x.ncols;
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(job.val_x.ncols, p_local);
    debug_assert_eq!(job.val_x.nrows, job.val_y.len());

    let mut beta = vec![0.0; p_local];
    let mut margins = vec![0.0; n];
    let mut w = vec![0.0; n];
    let mut z = vec![0.0; n];
    // Warm state carried across λ points: β, margins, and the Δβ/t buffers.
    // The cursor restarts whenever the active set changes shape.
    let mut state = SubproblemState::new(p_local, n);
    // Hybrid mode (threads ≥ 2): the sweep's screened passes run as pool
    // waves over the rank's sub-blocks, exactly like the train loop.
    let mut hybrid = (cfg.threads > 1 && p_local > 0).then(|| HybridCd::new(x, cfg.threads));

    let tag = Cell::new(0u64);
    let next_tag = || {
        let t = tag.get();
        tag.set(t + TAG_STRIDE);
        t
    };
    let ep_cell = RefCell::new(transport);
    // One kernel-mode lookup for the whole sweep — the mode was pinned from
    // the job spec before this rank started solving.
    let ker = crate::kernels::active();

    let mut points: Vec<PathPointLocal> = Vec::with_capacity(job.lambdas.len());
    let mut lambda_prev: Option<f64> = None;
    let mut cd_updates_total = 0u64;

    for &l1 in job.lambdas {
        let pen = ElasticNet::new(l1, job.l2);
        // Working stats at the warm start (margins are in sync across
        // ranks: every applied step came from the allreduced XΔβ).
        let mut loss = compute.stats(y, &margins, &mut w, &mut z);
        let thresh = if job.screen {
            path::strong_rule_threshold(l1, lambda_prev)
        } else {
            None
        };
        // Gradient pass only when a discard bound exists (mirrors
        // `l1_path`: the unscreened sweep does no extra O(nnz) work).
        let mut active: Vec<usize> = if thresh.is_some() {
            let mut g = vec![0.0; n];
            ker.neg_wz(&w, &z, &mut g);
            let grads = x.tmul_vec(&g);
            path::screen_columns(&beta, &grads, thresh)
        } else {
            (0..p_local).collect()
        };
        state.cursor = 0;
        let mut per_active = hybrid.as_ref().map(|h| h.split_active(&active));
        if let Some(h) = hybrid.as_mut() {
            h.reset_cursors();
        }

        let mut reg = {
            let mut r = [pen.value(&beta)];
            allreduce_sum(*ep_cell.borrow_mut(), next_tag(), &mut r, AllReduceAlgo::Naive)?;
            r[0]
        };
        let mut f_cur = loss + reg;
        let mut iters = 0usize;
        let mut updates_local = 0u64;

        // Fit + exact-KKT re-cycle loop (mirrors `path::l1_path`). The
        // active sets only grow, so the loop terminates.
        loop {
            let mut mu = cfg.mu0;
            let mut stall = 0usize;
            for _ in 1..=cfg.max_iters {
                iters += 1;
                state.reset();
                let did = match hybrid.as_mut() {
                    None => {
                        cd_cycle(
                            x,
                            &beta,
                            &w,
                            &z,
                            mu,
                            cfg.nu,
                            &pen,
                            &mut state,
                            CycleBudget::screened(&active),
                        )
                        .updates
                    }
                    Some(h) => h.screened_pass(
                        &beta,
                        &w,
                        &z,
                        mu,
                        cfg.nu,
                        &pen,
                        per_active.as_ref().expect("hybrid active split"),
                        &mut state,
                    ),
                };
                updates_local += did as u64;
                let mut dmargins = state.t.clone();
                allreduce_sum(*ep_cell.borrow_mut(), next_tag(), &mut dmargins, cfg.allreduce)?;
                let grad_dot = ker.neg_wz_dot(&w, &z, &dmargins);
                // Same stash-and-reraise dance as the train loop: the
                // line-search callback has no Result channel of its own.
                let ls_err: Cell<Option<TransportError>> = Cell::new(None);
                let reg_ray = |alphas: &[f64]| -> Vec<f64> {
                    let mut out = vec![0.0; alphas.len()];
                    for (local, d) in state.delta_beta.iter().enumerate() {
                        let b = beta[local];
                        for (k, &a) in alphas.iter().enumerate() {
                            out[k] += pen.value_1d(b + a * d);
                        }
                    }
                    if let Err(e) = allreduce_sum(
                        *ep_cell.borrow_mut(),
                        next_tag(),
                        &mut out,
                        AllReduceAlgo::Naive,
                    ) {
                        ls_err.set(Some(e));
                        return vec![0.0; alphas.len()];
                    }
                    out
                };
                let ls = line_search(
                    compute,
                    &cfg.linesearch,
                    y,
                    &margins,
                    &dmargins,
                    f_cur,
                    reg,
                    grad_dot,
                    &reg_ray,
                );
                if let Some(e) = ls_err.take() {
                    return Err(e);
                }
                if ls.alpha > 0.0 {
                    ker.margin_update_with_xdelta(&mut beta, &state.delta_beta, ls.alpha);
                    ker.margin_update_with_xdelta(&mut margins, &dmargins, ls.alpha);
                }
                if cfg.adaptive_mu {
                    if ls.alpha < 1.0 {
                        mu *= cfg.eta1;
                    } else {
                        mu = (mu / cfg.eta2).max(1.0);
                    }
                }
                loss = compute.stats(y, &margins, &mut w, &mut z);
                reg = {
                    let mut r = [pen.value(&beta)];
                    allreduce_sum(
                        *ep_cell.borrow_mut(),
                        next_tag(),
                        &mut r,
                        AllReduceAlgo::Naive,
                    )?;
                    r[0]
                };
                let f_new = loss + reg;
                let rel = (f_cur - f_new) / f_cur.abs().max(1e-12);
                f_cur = f_new;
                if rel.abs() < cfg.tol {
                    stall += 1;
                    if stall >= cfg.patience {
                        break;
                    }
                } else {
                    stall = 0;
                }
            }
            if !job.screen {
                break;
            }
            // Exact KKT re-check on this rank's screened-out coordinates.
            // Any rank's violation re-cycles everyone (allreduced count),
            // so screening stays exact AND the schedule stays SPMD-uniform.
            let viol = {
                let mut g = vec![0.0; n];
                ker.neg_wz(&w, &z, &mut g);
                let grads = x.tmul_vec(&g);
                path::kkt_violations(&active, &grads, l1, path::KKT_SLACK)
            };
            let total =
                allreduce_scalar(*ep_cell.borrow_mut(), next_tag(), viol.len() as f64)?;
            if total == 0.0 {
                break;
            }
            active.extend(viol);
            active.sort_unstable();
            state.cursor = 0;
            if let Some(h) = hybrid.as_mut() {
                per_active = Some(h.split_active(&active));
                h.reset_cursors();
            }
        }

        // Validation scoring: partial margins X_val^m β^m, allreduced, then
        // the auPRC derived identically on every rank (SPMD selection).
        let mut vscores = job.val_x.mul_vec(&beta);
        allreduce_sum(*ep_cell.borrow_mut(), next_tag(), &mut vscores, cfg.allreduce)?;
        let val_auprc = metrics::auprc(job.val_y, &vscores);
        // Global nnz + update count in one small collective.
        let mut acc = [metrics::nnz_weights(&beta) as f64, updates_local as f64];
        allreduce_sum(*ep_cell.borrow_mut(), next_tag(), &mut acc, AllReduceAlgo::Naive)?;
        cd_updates_total += updates_local;
        points.push(PathPointLocal {
            lambda1: l1,
            objective: f_cur,
            val_auprc,
            nnz: acc[0] as usize,
            iters,
            cd_updates: acc[1] as u64,
            beta_local: beta.clone(),
        });
        lambda_prev = Some(l1);
    }

    let auprcs: Vec<f64> = points.iter().map(|p| p.val_auprc).collect();
    let best = path::nan_safe_argmax(&auprcs).expect("non-empty grid");
    let (sent_bytes, sent_msgs) = ep_cell.borrow().sent();
    Ok(PathWorkerOutput {
        rank,
        points,
        best,
        cd_updates_local: cd_updates_total,
        sent_bytes,
        sent_msgs,
    })
}

/// Injected straggler sleep, prorated to the fraction of a pass executed.
pub(crate) fn inject_delay(cfg: &WorkerConfig, updates: usize, p_local: usize) {
    if cfg.straggler_delay != Duration::ZERO && p_local > 0 {
        let frac = updates as f64 / p_local as f64;
        std::thread::sleep(Duration::from_secs_f64(
            cfg.straggler_delay.as_secs_f64() * frac,
        ));
    }
}

/// Record a trace point on rank 0; all ranks join the nnz / test-margin
/// collectives so the communication pattern stays SPMD-uniform.
#[allow(clippy::too_many_arguments)]
fn record_point(
    trace: &mut Option<Trace>,
    started: &Instant,
    t_override: Option<f64>,
    iter: usize,
    objective: f64,
    beta_local: &[f64],
    alpha: f64,
    mu: f64,
    ep_cell: &RefCell<&mut dyn Transport>,
    next_tag: &dyn Fn() -> u64,
    test_x: Option<&Csc>,
    shared: &WorkerShared<'_>,
) -> Result<(), TransportError> {
    // Global nnz: allreduce the local count.
    let mut nnz = [metrics::nnz_weights(beta_local) as f64];
    allreduce_sum(*ep_cell.borrow_mut(), next_tag(), &mut nnz, AllReduceAlgo::Naive)?;

    // Test scores: allreduce partial margins X_test^m β^m.
    let auprc = match (test_x, shared.test_y) {
        (Some(tx), Some(ty))
            if shared.cfg.eval_every > 0 && iter % shared.cfg.eval_every == 0 =>
        {
            let mut scores = tx.mul_vec(beta_local);
            allreduce_sum(
                *ep_cell.borrow_mut(),
                next_tag(),
                &mut scores,
                shared.cfg.allreduce,
            )?;
            Some(metrics::auprc(ty, &scores))
        }
        _ => None,
    };

    if let Some(t) = trace.as_mut() {
        t.push(TracePoint {
            t_sec: t_override.unwrap_or_else(|| started.elapsed().as_secs_f64()),
            iter,
            objective,
            nnz: nnz[0] as usize,
            alpha,
            mu,
            auprc,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_with_delay(ms: u64) -> WorkerConfig {
        WorkerConfig {
            adaptive_mu: true,
            mu0: 1.0,
            eta1: 2.0,
            eta2: 2.0,
            nu: 1e-6,
            max_iters: 1,
            tol: 0.0,
            patience: 1,
            linesearch: LineSearchConfig::default(),
            eval_every: 0,
            allreduce: AllReduceAlgo::Naive,
            max_passes: 1,
            chunk: 64,
            threads: 1,
            straggler_delay: Duration::from_millis(ms),
            virtual_time: false,
            slow_factor: 1.0,
            network: crate::cluster::fabric::NetworkModel::default(),
            checkpoint_dir: None,
            checkpoint_every: 0,
            die_after_iters: None,
        }
    }

    #[test]
    fn inject_delay_is_prorated_to_pass_fraction() {
        let cfg = cfg_with_delay(400);
        // Sleeps guarantee only a minimum, so avoid absolute upper bounds:
        // assert the floors plus the relative property that a quarter pass
        // sleeps strictly less than a full pass measured on the same box —
        // an unprorated implementation would sleep the full delay both
        // times and fail the comparison.
        let t0 = Instant::now();
        inject_delay(&cfg, 25, 100);
        let quarter = t0.elapsed();
        let t0 = Instant::now();
        inject_delay(&cfg, 100, 100);
        let full = t0.elapsed();
        assert!(
            quarter >= Duration::from_millis(100),
            "quarter pass slept {quarter:?}"
        );
        assert!(full >= Duration::from_millis(400), "full pass slept {full:?}");
        assert!(
            quarter < full,
            "proration broken: quarter {quarter:?} vs full {full:?}"
        );
    }

    #[test]
    fn comm_attribution_maps_tags_to_allocating_phase() {
        let log: [(u64, &'static str); 4] =
            [(0, "init"), (64, "cd"), (128, "sync"), (192, "comm")];
        let by_tag = vec![
            (0, 100, 2),  // exact allocation
            (64, 50, 1),  // exact allocation
            (70, 10, 1),  // between allocations → the phase that owns tag 64
            (128, 40, 1),
            (200, 8, 1), // after the last allocation → last phase
        ];
        let got = attribute_comm_to_phases(&log, by_tag);
        assert_eq!(
            got,
            vec![
                ("cd".to_string(), 60, 2),
                ("comm".to_string(), 8, 1),
                ("init".to_string(), 100, 2),
                ("sync".to_string(), 40, 1),
            ]
        );
    }

    #[test]
    fn inject_delay_noop_without_delay_or_block() {
        let t0 = Instant::now();
        inject_delay(&cfg_with_delay(0), 10, 10);
        inject_delay(&cfg_with_delay(40), 10, 0); // empty block: no proration
        assert!(t0.elapsed() < Duration::from_millis(20));
    }
}
