//! Real-socket [`Transport`] backend — the multi-process interconnect.
//!
//! One [`TcpTransport`] per rank, a full mesh of duplex TCP connections
//! (rank i dials every j < i and accepts every j > i, so each pair shares
//! exactly one connection). Dials retry with exponential backoff because
//! peers start at different times. Every connection opens with a fixed
//! 16-byte handshake — magic, protocol version, sender rank, cluster size —
//! and both sides reject mismatches, so a worker from a differently-sized
//! (or differently-versioned) job can never splice into a running cluster.
//!
//! Data frames are length-prefixed little-endian binary:
//!
//! ```text
//! [tag: u64][len: u64][len × f64]
//! ```
//!
//! i.e. exactly 16 + 8·len wire bytes — the same formula the in-process
//! fabric charges, so per-link accounting (and the Table 2 reproduction) is
//! backend-independent.
//!
//! Threading: each peer connection gets one reader thread (parses frames,
//! pushes them into a shared mailbox) and one writer thread (drains an
//! unbounded queue). Sends therefore never block the solver, which rules out
//! the classic ring-allreduce deadlock where every rank's blocking send
//! waits on a full socket buffer. Receive-side tag parking is identical to
//! the fabric's.

use crate::cluster::transport::{frame_bytes, Transport, TransportError};
use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// Handshake magic ("dGLM" little-endian) — rejects strangers early.
const MAGIC: u32 = 0x4D4C_4764;
/// Bump on any wire-format change; both sides must agree. v2: the job spec
/// gained the ALB / straggler-chaos fields (alb_kappa, max_passes, chunk,
/// straggler_delays, slow_factors). v3: the job spec gained the `mode`
/// field (`train` | `path`) plus the path-sweep fields (lambda_grid,
/// screen) — a `path` job sweeps the λ1 grid with warm starts + KKT
/// screening and gathers one β per grid point. v4: per-rank `threads`
/// (hybrid intra-rank CD pool) plus per-thread update accounting in the
/// done report. v5: the done report gained the span journal (`spans`, the
/// per-iteration phase timings each rank recorded) and the per-phase comm
/// breakdown (`comm_by_phase`), and the control port answers a `stats`
/// op with a metrics-registry snapshot. v6: elastic fault tolerance — the
/// job spec gained `checkpoint_dir`/`checkpoint_every` plus a `resume`
/// flag (the coordinator re-ships a resume job from the latest complete
/// checkpoint after a rank failure; resume state travels on the reserved
/// RESUME tag), the control port answers a `ping` liveness op, and peer
/// death surfaces as a typed `TransportError` instead of a panic. v7:
/// out-of-core ingestion — the `dataset` recipe may name a binary shard
/// directory (`shards:<dir>`), in which case each rank loads only its own
/// feature-block file plus the shared labels, and the done report gains
/// `loaded_cols`/`loaded_bytes` per-rank ingestion accounting. v8: the
/// partition-strategy seam — the job spec gained an optional `partition`
/// field (`hashed|contiguous|nnz|cluster`; absent = hashed for text
/// datasets, header-pinned for shard datasets) and the done report a `cut`
/// cross-block co-occurrence diagnostic per rank. v9: the kernel-mode pin —
/// the job spec gained a `fast_math` flag (reordered-accumulation kernels,
/// `--fast-math`); every rank sets its process-global kernel mode from the
/// spec before solving and a worker pinned to the other mode rejects the
/// job, so a cluster can never silently mix strict and fast-math ranks.
pub const PROTOCOL_VERSION: u32 = 9;

/// Dial / handshake tuning.
#[derive(Clone, Copy, Debug)]
pub struct TcpOptions {
    /// Give up dialing a peer after this long.
    pub connect_timeout: Duration,
    /// First retry delay; doubles per attempt up to `max_backoff`.
    pub backoff: Duration,
    pub max_backoff: Duration,
}

impl Default for TcpOptions {
    fn default() -> Self {
        TcpOptions {
            connect_timeout: Duration::from_secs(30),
            backoff: Duration::from_millis(20),
            max_backoff: Duration::from_millis(500),
        }
    }
}

struct Inbound {
    from: usize,
    tag: u64,
    data: Vec<f64>,
}

/// Reserved tag a dying reader thread posts to the inbox so receivers can
/// tell "peer gone" from "message not here yet". Never collides with user
/// tags (the worker's allocator hands out multiples of `TAG_STRIDE`; the
/// gather tag is `u64::MAX - 8`).
const POISON_TAG: u64 = u64::MAX;

/// One rank's attachment to the TCP mesh.
pub struct TcpTransport {
    rank: usize,
    size: usize,
    /// Per-peer writer queues (`None` at our own rank).
    writers: Vec<Option<Sender<(u64, Vec<f64>)>>>,
    inbox: Receiver<Inbound>,
    pending: HashMap<(usize, u64), Vec<Inbound>>,
    /// Peers whose reader thread has exited (connection closed or corrupt).
    dead: Vec<bool>,
    /// Per-destination sent accounting (bytes, msgs), index = peer rank.
    sent_bytes: Vec<u64>,
    sent_msgs: Vec<u64>,
    /// Per-tag sent accounting: tag → (bytes, msgs). Lets the worker
    /// attribute traffic to solver phases (tags are phase-scoped).
    sent_tags: BTreeMap<u64, (u64, u64)>,
    /// Kept so Drop can shut the read halves down and wake the readers.
    streams: Vec<Option<TcpStream>>,
    reader_threads: Vec<std::thread::JoinHandle<()>>,
    writer_threads: Vec<std::thread::JoinHandle<()>>,
}

/// Bind `m` loopback listeners on ephemeral ports; returns the resolved
/// `host:port` list (index = rank) plus the listeners to hand to
/// [`TcpTransport::with_listener`]. Test/demo helper.
pub fn bind_loopback(m: usize) -> std::io::Result<(Vec<String>, Vec<TcpListener>)> {
    let mut addrs = Vec::with_capacity(m);
    let mut listeners = Vec::with_capacity(m);
    for _ in 0..m {
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(l.local_addr()?.to_string());
        listeners.push(l);
    }
    Ok((addrs, listeners))
}

fn write_handshake(s: &mut TcpStream, rank: usize, size: usize) -> std::io::Result<()> {
    let mut buf = [0u8; 16];
    buf[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    buf[4..8].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    buf[8..12].copy_from_slice(&(rank as u32).to_le_bytes());
    buf[12..16].copy_from_slice(&(size as u32).to_le_bytes());
    s.write_all(&buf)?;
    s.flush()
}

/// Read and validate a peer handshake; returns the peer's rank.
fn read_handshake(s: &mut TcpStream, size: usize) -> anyhow::Result<usize> {
    let mut buf = [0u8; 16];
    s.read_exact(&mut buf)?;
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let rank = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    let peer_size = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
    if magic != MAGIC {
        anyhow::bail!("handshake: bad magic {magic:#x} (not a dglmnet peer)");
    }
    if version != PROTOCOL_VERSION {
        anyhow::bail!(
            "handshake: protocol version {version} != {PROTOCOL_VERSION}"
        );
    }
    if peer_size != size {
        anyhow::bail!("handshake: peer cluster size {peer_size} != ours {size}");
    }
    if rank >= size {
        anyhow::bail!("handshake: peer rank {rank} out of range for size {size}");
    }
    Ok(rank)
}

/// Dial `addr`, retrying with exponential backoff until `connect_timeout`
/// elapses — peers of a forming cluster come up at different times. Each
/// attempt is itself bounded (`connect_timeout` is a hard overall budget:
/// a SYN-dropping firewalled host must not stall us for the OS's
/// minutes-long SYN retry cycle).
pub fn dial_with_backoff(addr: &str, opts: &TcpOptions) -> anyhow::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let deadline = Instant::now() + opts.connect_timeout;
    let mut backoff = opts.backoff;
    loop {
        let attempt = addr
            .to_socket_addrs()
            .map_err(anyhow::Error::from)
            .and_then(|mut it| {
                it.next()
                    .ok_or_else(|| anyhow::anyhow!("'{addr}' resolves to no addresses"))
            })
            .and_then(|sa| {
                let remaining = deadline.saturating_duration_since(Instant::now());
                let per_attempt = remaining
                    .min(Duration::from_secs(5))
                    .max(Duration::from_millis(10));
                TcpStream::connect_timeout(&sa, per_attempt).map_err(anyhow::Error::from)
            });
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() + backoff >= deadline {
                    anyhow::bail!("dial {addr}: {e} (gave up after {:?})", opts.connect_timeout);
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(opts.max_backoff);
            }
        }
    }
}

/// Set SO_RCVTIMEO, surfacing failure instead of swallowing it: a socket
/// that silently keeps blocking reads would turn the bounded handshake
/// back into an unexplained hang.
fn set_read_timeout_logged(s: &TcpStream, who: &str, dur: Option<Duration>) {
    if let Err(e) = s.set_read_timeout(dur) {
        crate::obs_warn!("net", format!("{who}: set_read_timeout({dur:?}) failed: {e}"));
    }
}

/// Accept one connection, giving up at `deadline` — a peer that died
/// before dialing in must not hang mesh formation forever.
fn accept_with_deadline(listener: &TcpListener, deadline: Instant) -> anyhow::Result<TcpStream> {
    listener.set_nonblocking(true).ok();
    let res = loop {
        match listener.accept() {
            Ok((s, _)) => {
                // Some platforms hand the accepted socket down nonblocking.
                s.set_nonblocking(false).ok();
                break Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    break Err(anyhow::anyhow!(
                        "timed out waiting for a peer to dial in"
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => break Err(e.into()),
        }
    };
    listener.set_nonblocking(false).ok();
    res
}

impl TcpTransport {
    /// Bind `addrs[rank]` and form the mesh. `addrs` must list every rank's
    /// listen address, identically ordered on every process.
    pub fn connect(rank: usize, addrs: &[String], opts: TcpOptions) -> anyhow::Result<TcpTransport> {
        let listener = TcpListener::bind(&addrs[rank])
            .map_err(|e| anyhow::anyhow!("bind {}: {e}", addrs[rank]))?;
        Self::with_listener(rank, addrs, &listener, opts)
    }

    /// Form the mesh over an already-bound listener (the worker runtime
    /// reuses its control listener for mesh accepts — and, since `--rejoin`,
    /// keeps it alive across jobs, hence the borrow).
    pub fn with_listener(
        rank: usize,
        addrs: &[String],
        listener: &TcpListener,
        opts: TcpOptions,
    ) -> anyhow::Result<TcpTransport> {
        let size = addrs.len();
        assert!(rank < size, "rank {rank} out of range for {size} addrs");
        let mut conns: Vec<Option<TcpStream>> = (0..size).map(|_| None).collect();

        // Dial every lower rank (they are already listening or soon will
        // be — hence the backoff), then accept every higher rank.
        for peer in 0..rank {
            let mut s = dial_with_backoff(&addrs[peer], &opts)?;
            s.set_nodelay(true).ok();
            // Bounded handshake: a dead peer must not hang mesh formation.
            set_read_timeout_logged(&s, "mesh handshake (dial)", Some(opts.connect_timeout));
            write_handshake(&mut s, rank, size)?;
            let got = read_handshake(&mut s, size)?;
            if got != peer {
                anyhow::bail!("dialed {} expecting rank {peer}, got rank {got}", addrs[peer]);
            }
            set_read_timeout_logged(&s, "mesh handshake (dial)", None);
            conns[peer] = Some(s);
        }
        let accept_deadline = Instant::now() + opts.connect_timeout;
        for _ in rank + 1..size {
            let mut s = accept_with_deadline(listener, accept_deadline)?;
            s.set_nodelay(true).ok();
            set_read_timeout_logged(&s, "mesh handshake (accept)", Some(opts.connect_timeout));
            let peer = read_handshake(&mut s, size)?;
            if peer <= rank {
                anyhow::bail!("accepted unexpected dial from lower rank {peer}");
            }
            if conns[peer].is_some() {
                anyhow::bail!("rank {peer} connected twice");
            }
            write_handshake(&mut s, rank, size)?;
            set_read_timeout_logged(&s, "mesh handshake (accept)", None);
            conns[peer] = Some(s);
        }

        // Spawn one reader + one writer per peer connection.
        let (inbox_tx, inbox_rx) = channel::<Inbound>();
        let mut writers: Vec<Option<Sender<(u64, Vec<f64>)>>> =
            (0..size).map(|_| None).collect();
        let mut reader_threads = Vec::new();
        let mut writer_threads = Vec::new();
        let mut streams: Vec<Option<TcpStream>> = (0..size).map(|_| None).collect();
        for (peer, conn) in conns.into_iter().enumerate() {
            let Some(stream) = conn else { continue };
            let read_half = stream.try_clone()?;
            let write_half = stream.try_clone()?;
            streams[peer] = Some(stream);

            let tx = inbox_tx.clone();
            reader_threads.push(
                std::thread::Builder::new()
                    .name(format!("tcp-rx-{rank}-{peer}"))
                    .spawn(move || reader_loop(read_half, peer, tx))?,
            );

            let (wtx, wrx) = channel::<(u64, Vec<f64>)>();
            writers[peer] = Some(wtx);
            writer_threads.push(
                std::thread::Builder::new()
                    .name(format!("tcp-tx-{rank}-{peer}"))
                    .spawn(move || writer_loop(write_half, wrx))?,
            );
        }
        drop(inbox_tx);

        Ok(TcpTransport {
            rank,
            size,
            writers,
            inbox: inbox_rx,
            pending: HashMap::new(),
            dead: vec![false; size],
            sent_bytes: vec![0; size],
            sent_msgs: vec![0; size],
            sent_tags: BTreeMap::new(),
            streams,
            reader_threads,
            writer_threads,
        })
    }

    /// Bytes this endpoint has sent to `to` (per-link accounting).
    pub fn link_sent(&self, to: usize) -> (u64, u64) {
        (self.sent_bytes[to], self.sent_msgs[to])
    }

    fn take_pending(&mut self, key: (usize, u64)) -> Option<Vec<f64>> {
        if let Some(q) = self.pending.get_mut(&key) {
            if !q.is_empty() {
                let msg = q.remove(0);
                if q.is_empty() {
                    self.pending.remove(&key);
                }
                return Some(msg.data);
            }
        }
        None
    }
}

/// Upper bound on doubles per frame (1 GiB payload) — far above any XΔβ
/// vector; a length beyond it can only be a corrupt or hostile header, and
/// trusting it would mean a huge allocation or a desynced frame stream.
const MAX_FRAME_DOUBLES: u64 = 1 << 27;

fn reader_loop(mut s: TcpStream, from: usize, tx: Sender<Inbound>) {
    let mut header = [0u8; 16];
    loop {
        if s.read_exact(&mut header).is_err() {
            break; // peer closed (or our Drop shut the socket down)
        }
        let tag = u64::from_le_bytes(header[0..8].try_into().unwrap());
        let len64 = u64::from_le_bytes(header[8..16].try_into().unwrap());
        if len64 > MAX_FRAME_DOUBLES {
            crate::obs_warn!("tcp", format!("dropping link to rank {from}: corrupt frame length {len64}"));
            break;
        }
        let len = len64 as usize;
        let mut payload = vec![0u8; 8 * len];
        if s.read_exact(&mut payload).is_err() {
            break;
        }
        let data: Vec<f64> = payload
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        if tx.send(Inbound { from, tag, data }).is_err() {
            return; // transport dropped; no one left to poison
        }
    }
    // Post a poison marker so a rank blocked on this peer fails loudly
    // instead of waiting forever (the fabric backend panics likewise).
    let _ = tx.send(Inbound {
        from,
        tag: POISON_TAG,
        data: Vec::new(),
    });
}

fn writer_loop(s: TcpStream, rx: Receiver<(u64, Vec<f64>)>) {
    let mut out = std::io::BufWriter::new(s);
    for (tag, data) in rx {
        let mut header = [0u8; 16];
        header[0..8].copy_from_slice(&tag.to_le_bytes());
        header[8..16].copy_from_slice(&(data.len() as u64).to_le_bytes());
        if out.write_all(&header).is_err() {
            return;
        }
        for v in &data {
            if out.write_all(&v.to_le_bytes()).is_err() {
                return;
            }
        }
        // Frames gate collectives, so latency beats batching: flush each.
        if out.flush().is_err() {
            return;
        }
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, to: usize, tag: u64, data: Vec<f64>) -> Result<(), TransportError> {
        assert!(to != self.rank, "self-send over TCP");
        let bytes = frame_bytes(data.len());
        let sent = match self.writers[to].as_ref() {
            // A closed queue means the writer thread exited on a broken
            // stream: the peer is gone.
            Some(w) => w.send((tag, data)).is_ok(),
            None => false,
        };
        if !sent {
            self.dead[to] = true;
            return Err(TransportError::PeerGone { peer: to });
        }
        self.sent_bytes[to] += bytes;
        self.sent_msgs[to] += 1;
        let e = self.sent_tags.entry(tag).or_insert((0, 0));
        e.0 += bytes;
        e.1 += 1;
        Ok(())
    }

    fn recv_from(&mut self, from: usize, tag: u64) -> Result<Vec<f64>, TransportError> {
        if let Some(data) = self.take_pending((from, tag)) {
            return Ok(data);
        }
        if self.dead[from] {
            return Err(TransportError::PeerGone { peer: from });
        }
        loop {
            let msg = match self.inbox.recv() {
                Ok(m) => m,
                Err(_) => return Err(TransportError::AllPeersGone),
            };
            if msg.tag == POISON_TAG {
                self.dead[msg.from] = true;
                if msg.from == from {
                    return Err(TransportError::PeerGone { peer: from });
                }
                continue;
            }
            if msg.from == from && msg.tag == tag {
                return Ok(msg.data);
            }
            self.pending.entry((msg.from, msg.tag)).or_default().push(msg);
        }
    }

    fn try_recv_from(
        &mut self,
        from: usize,
        tag: u64,
    ) -> Result<Option<Vec<f64>>, TransportError> {
        if let Some(data) = self.take_pending((from, tag)) {
            return Ok(Some(data));
        }
        while let Ok(msg) = self.inbox.try_recv() {
            if msg.tag == POISON_TAG {
                self.dead[msg.from] = true;
                continue;
            }
            if msg.from == from && msg.tag == tag {
                return Ok(Some(msg.data));
            }
            self.pending.entry((msg.from, msg.tag)).or_default().push(msg);
        }
        // The reader posts its poison strictly after every real frame, so
        // once the flag is set with nothing pending the peer can never
        // satisfy this request.
        if self.dead[from] {
            return Err(TransportError::PeerGone { peer: from });
        }
        Ok(None)
    }

    fn sent(&self) -> (u64, u64) {
        (
            self.sent_bytes.iter().sum(),
            self.sent_msgs.iter().sum(),
        )
    }

    fn sent_by_tag(&self) -> Vec<(u64, u64, u64)> {
        self.sent_tags
            .iter()
            .map(|(&tag, &(bytes, msgs))| (tag, bytes, msgs))
            .collect()
    }

    fn global_traffic(&self) -> Option<(u64, u64)> {
        None // a TCP endpoint only observes its own links
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // 1. Close the writer queues and join the writers: they drain and
        //    flush every queued frame before exiting, so messages already
        //    sent (e.g. the final β gather) are guaranteed delivered before
        //    the socket goes away.
        for w in self.writers.iter_mut() {
            w.take();
        }
        for h in self.writer_threads.drain(..) {
            let _ = h.join();
        }
        // 2. Only now shut the sockets down — this wakes our blocking
        //    readers and signals EOF to peers still reading.
        for s in self.streams.iter().flatten() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in self.reader_threads.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handshake_rejects_wrong_size() {
        let (addrs, mut listeners) = bind_loopback(2).unwrap();
        let l1 = listeners.pop().unwrap();
        let l0 = listeners.pop().unwrap();
        drop(l0);
        // Rank 1 of a 2-cluster dials rank 0, but the "rank 0" answering
        // believes the cluster has 3 nodes → both sides must fail.
        let a1 = addrs[1].clone();
        let h = std::thread::spawn(move || {
            // fake rank-0 side with size 3 accepting on rank 1's slot
            let (mut s, _) = l1.accept().unwrap();
            let r = read_handshake(&mut s, 3);
            assert!(r.is_err(), "size mismatch must be rejected: {r:?}");
        });
        let mut s = dial_with_backoff(&a1, &TcpOptions::default()).unwrap();
        write_handshake(&mut s, 1, 2).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn handshake_rejects_bad_magic() {
        let (addrs, mut listeners) = bind_loopback(1).unwrap();
        let l0 = listeners.pop().unwrap();
        let a0 = addrs[0].clone();
        let h = std::thread::spawn(move || {
            let (mut s, _) = l0.accept().unwrap();
            assert!(read_handshake(&mut s, 2).is_err());
        });
        let mut s = TcpStream::connect(&a0).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\n").unwrap();
        h.join().unwrap();
    }

    #[test]
    fn two_rank_roundtrip_with_accounting() {
        let (addrs, listeners) = bind_loopback(2).unwrap();
        let mut ts = mesh(&addrs, listeners);
        let mut t1 = ts.pop().unwrap();
        let mut t0 = ts.pop().unwrap();
        std::thread::scope(|sc| {
            sc.spawn(move || {
                t1.send(0, 7, vec![1.0, 2.0, 3.0]).unwrap();
                let back = t1.recv_from(0, 8).unwrap();
                assert_eq!(back, vec![6.0]);
                assert_eq!(t1.sent(), (16 + 24, 1));
                assert_eq!(t1.sent_by_tag(), vec![(7, 16 + 24, 1)]);
            });
            let got = t0.recv_from(1, 7).unwrap();
            assert_eq!(got, vec![1.0, 2.0, 3.0]);
            t0.send(1, 8, vec![got.iter().sum()]).unwrap();
            assert_eq!(t0.sent(), (16 + 8, 1));
        });
    }

    /// Form a full mesh over pre-bound listeners; returns transports by rank.
    fn mesh(addrs: &[String], listeners: Vec<TcpListener>) -> Vec<TcpTransport> {
        let mut ts: Vec<Option<TcpTransport>> = (0..addrs.len()).map(|_| None).collect();
        std::thread::scope(|sc| {
            let mut handles = Vec::new();
            for (rank, l) in listeners.into_iter().enumerate() {
                handles.push(sc.spawn(move || {
                    TcpTransport::with_listener(rank, addrs, &l, TcpOptions::default()).unwrap()
                }));
            }
            for (rank, h) in handles.into_iter().enumerate() {
                ts[rank] = Some(h.join().unwrap());
            }
        });
        ts.into_iter().map(|o| o.unwrap()).collect()
    }

    #[test]
    fn peer_death_is_a_typed_error_and_pending_data_survives() {
        let (addrs, listeners) = bind_loopback(2).unwrap();
        let mut ts = mesh(&addrs, listeners);
        let mut t1 = ts.pop().unwrap();
        let mut t0 = ts.pop().unwrap();
        // Rank 1 sends one last frame, then dies (Drop flushes the queue
        // before shutting the socket down).
        t1.send(0, 5, vec![9.0]).unwrap();
        drop(t1);
        // The frame already on the wire is still delivered...
        assert_eq!(t0.recv_from(1, 5).unwrap(), vec![9.0]);
        // ...then the death surfaces as a typed error, not a panic, on
        // every receive flavor — and sticks.
        assert_eq!(
            t0.recv_from(1, 5),
            Err(TransportError::PeerGone { peer: 1 })
        );
        assert_eq!(
            t0.try_recv_from(1, 6),
            Err(TransportError::PeerGone { peer: 1 })
        );
        assert_eq!(
            t0.recv_from(1, 7),
            Err(TransportError::PeerGone { peer: 1 })
        );
    }

    #[test]
    fn dial_backoff_waits_for_late_listener() {
        // Bind rank 1's port, release it, and only re-bind after a delay;
        // rank 1's dial of rank 0 must succeed thanks to backoff.
        let (addrs, mut listeners) = bind_loopback(2).unwrap();
        let l1 = listeners.pop().unwrap();
        let l0 = listeners.pop().unwrap();
        let addr0 = addrs[0].clone();
        drop(l0); // rank 0 not listening yet
        let addrs1 = addrs.clone();
        let h1 = std::thread::spawn(move || {
            TcpTransport::with_listener(1, &addrs1, &l1, TcpOptions::default()).unwrap()
        });
        std::thread::sleep(Duration::from_millis(150));
        let l0 = TcpListener::bind(&addr0).unwrap();
        let t0 =
            TcpTransport::with_listener(0, &addrs, &l0, TcpOptions::default()).unwrap();
        let mut t1 = h1.join().unwrap();
        let mut t0 = t0;
        t0.send(1, 1, vec![42.0]).unwrap();
        assert_eq!(t1.recv_from(0, 1).unwrap(), vec![42.0]);
    }
}
