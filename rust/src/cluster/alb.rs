//! Asynchronous Load Balancing (Section 7) — the paper's answer to the
//! slow-node problem.
//!
//! Every node reports when it has finished one full pass over its block
//! S^m. As soon as at least ⌈κ·M⌉ nodes have reported, the controller raises
//! a stop flag that the coordinate-descent inner loop polls between updates:
//! stragglers cut their pass short, fast nodes stop their extra cycles, and
//! everyone proceeds to the AllReduce. Because updates are cyclic with a
//! persistent cursor, a straggler resumes exactly where it stopped on the
//! next iteration — no weight is starved (paper: "on the next iteration a
//! node resumes optimization starting from the next weight in S^m").

use crate::cluster::transport::Transport;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Shared-memory ALB controller — used when all nodes are threads in one
/// process (the fabric backend). For separate OS processes, the same quorum
/// decision is carried by tiny pass-done broadcasts: see [`RemoteQuorum`].
pub struct AlbController {
    nodes: usize,
    /// Minimum full-pass reports before cutting off the iteration.
    threshold: usize,
    done: AtomicUsize,
    stop: AtomicBool,
}

impl AlbController {
    /// κ is the fraction of nodes that must complete a full pass
    /// (paper uses κ = 0.75).
    pub fn new(nodes: usize, kappa: f64) -> AlbController {
        assert!(nodes > 0);
        assert!(kappa > 0.0 && kappa <= 1.0);
        let threshold = ((kappa * nodes as f64).ceil() as usize).clamp(1, nodes);
        AlbController {
            nodes,
            threshold,
            done: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
        }
    }

    /// A node reports completion of one full pass over its block.
    pub fn report_full_pass(&self) {
        let now = self.done.fetch_add(1, Ordering::AcqRel) + 1;
        if now >= self.threshold {
            self.stop.store(true, Ordering::Release);
        }
    }

    /// The stop flag polled by `cd_cycle`.
    pub fn stop_flag(&self) -> &AtomicBool {
        &self.stop
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// Reset for the next outer iteration (call after the barrier, once all
    /// workers have stopped reading the flag).
    pub fn reset(&self) {
        self.done.store(0, Ordering::Release);
        self.stop.store(false, Ordering::Release);
    }

    pub fn threshold(&self) -> usize {
        self.threshold
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }
}

/// Transport-level ALB quorum: the distributed analogue of
/// [`AlbController`], built only on [`Transport`] so it works across OS
/// processes. A node that finishes a full pass broadcasts an empty
/// pass-done frame to every peer on the iteration's ALB tag; `should_stop`
/// polls (non-blocking) for peers' frames and raises once ⌈κ·M⌉ reports —
/// own pass included — have been seen.
///
/// One `RemoteQuorum` serves one outer iteration: construct it with a fresh
/// tag per iteration (a single tag from the worker's `TAG_STRIDE` allocator
/// suffices, since pass-done frames are the only traffic on it). Late
/// frames from stragglers that report after the quorum fired simply park in
/// the transport's pending map for that retired tag — a few empty frames
/// per iteration, never replayed into a later quorum.
pub struct RemoteQuorum {
    tag: u64,
    threshold: usize,
    /// seen[r] = rank r's pass-done frame observed (or r == self after
    /// `report_full_pass`).
    seen: Vec<bool>,
    reports: usize,
}

impl RemoteQuorum {
    pub fn new(nodes: usize, kappa: f64, tag: u64) -> RemoteQuorum {
        assert!(nodes > 0);
        assert!(kappa > 0.0 && kappa <= 1.0);
        let threshold = ((kappa * nodes as f64).ceil() as usize).clamp(1, nodes);
        RemoteQuorum {
            tag,
            threshold,
            seen: vec![false; nodes],
            reports: 0,
        }
    }

    /// This node finished one full pass over its block: broadcast it.
    pub fn report_full_pass(&mut self, t: &mut dyn Transport) {
        let me = t.rank();
        if !self.seen[me] {
            self.seen[me] = true;
            self.reports += 1;
            for to in (0..t.size()).filter(|&r| r != me) {
                t.send(to, self.tag, Vec::new());
            }
        }
    }

    /// Poll peers' pass-done frames; true once the κ quorum is met.
    pub fn should_stop(&mut self, t: &mut dyn Transport) -> bool {
        let me = t.rank();
        for from in (0..t.size()).filter(|&r| r != me) {
            while !self.seen[from] && t.try_recv_from(from, self.tag).is_some() {
                self.seen[from] = true;
                self.reports += 1;
            }
        }
        self.reports >= self.threshold
    }

    pub fn threshold(&self) -> usize {
        self.threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn threshold_rounding() {
        assert_eq!(AlbController::new(16, 0.75).threshold(), 12);
        assert_eq!(AlbController::new(4, 0.75).threshold(), 3);
        assert_eq!(AlbController::new(3, 0.75).threshold(), 3); // ceil(2.25)
        assert_eq!(AlbController::new(1, 0.75).threshold(), 1);
        assert_eq!(AlbController::new(8, 1.0).threshold(), 8);
    }

    #[test]
    fn stop_fires_exactly_at_threshold() {
        let c = AlbController::new(4, 0.75); // threshold 3
        assert!(!c.should_stop());
        c.report_full_pass();
        c.report_full_pass();
        assert!(!c.should_stop());
        c.report_full_pass();
        assert!(c.should_stop());
    }

    #[test]
    fn reset_clears_state() {
        let c = AlbController::new(2, 0.5);
        c.report_full_pass();
        assert!(c.should_stop());
        c.reset();
        assert!(!c.should_stop());
        c.report_full_pass();
        assert!(c.should_stop());
    }

    #[test]
    fn concurrent_reports_fire_once_threshold_met() {
        let c = Arc::new(AlbController::new(8, 0.75));
        let mut handles = Vec::new();
        for _ in 0..6 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || c.report_full_pass()));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.should_stop());
    }

    #[test]
    fn remote_quorum_fires_at_threshold_over_fabric() {
        use crate::cluster::fabric::{fabric, NetworkModel};
        use crate::cluster::transport::Transport as _;
        let m = 4; // κ = 0.75 → threshold 3
        let (eps, _) = fabric(m, NetworkModel::default());
        let mut handles = Vec::new();
        for ep in eps {
            handles.push(std::thread::spawn(move || {
                let mut ep = ep;
                let rank = ep.rank();
                let mut q = RemoteQuorum::new(m, 0.75, 77);
                assert_eq!(q.threshold(), 3);
                if rank < 3 {
                    // Three fast nodes report; each must observe the quorum.
                    q.report_full_pass(&mut ep);
                    while !q.should_stop(&mut ep) {
                        std::thread::yield_now();
                    }
                } else {
                    // The straggler never reports but still sees the stop.
                    while !q.should_stop(&mut ep) {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn straggler_cut_off_in_cd_cycle() {
        // Integration with the subproblem budget: a pre-raised flag limits a
        // big block to a single update.
        use crate::glm::regularizer::ElasticNet;
        use crate::solver::subproblem::{cd_cycle, CycleBudget, SubproblemState};
        use crate::sparse::Csc;
        let x = Csc::from_triplets(
            4,
            10,
            (0..10).map(|j| (j % 4, j, 1.0)).collect::<Vec<_>>(),
        );
        let c = AlbController::new(2, 0.5);
        c.report_full_pass(); // the other node finished: threshold met
        let pen = ElasticNet::new(0.01, 0.0);
        let mut st = SubproblemState::new(10, 4);
        let out = cd_cycle(
            &x,
            &vec![0.0; 10],
            &vec![1.0; 4],
            &vec![1.0; 4],
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget {
                max_updates: 10,
                stop: Some(c.stop_flag()),
            },
        );
        assert_eq!(out.updates, 1);
        assert_eq!(st.cursor, 1); // resumes at weight 1 next iteration
    }
}
