//! Asynchronous Load Balancing (Section 7) — the paper's answer to the
//! slow-node problem.
//!
//! Every node reports when it has finished one full pass over its block
//! S^m. As soon as at least ⌈κ·M⌉ nodes have reported, a stop signal is
//! raised that the coordinate-descent inner loop polls between updates:
//! stragglers cut their pass short, fast nodes stop their extra cycles, and
//! everyone proceeds to the AllReduce. Because updates are cyclic with a
//! persistent cursor, a straggler resumes exactly where it stopped on the
//! next iteration — no weight is starved (paper: "on the next iteration a
//! node resumes optimization starting from the next weight in S^m").
//!
//! The worker is written against one per-iteration handle, [`AlbQuorum`],
//! with two implementations behind it:
//!
//! * [`RemoteQuorum`] — the transport-level κ-quorum: pass-done broadcasts
//!   on a tag that is fresh every outer iteration, so there is nothing to
//!   reset and no barrier anywhere. This is the path real multi-process
//!   clusters use, and it runs unchanged over the in-process fabric.
//! * [`AlbController`] — the shared-memory special case for nodes that are
//!   threads of one process: zero wire frames and a per-coordinate
//!   [`AtomicBool`] stop flag for the CD hot loop. Its per-iteration reset
//!   is claimed through a generation CAS in [`AlbController::
//!   begin_iteration`] — safe without a barrier because no rank can start
//!   iteration k+1 before every rank has left iteration k's CD loop (the
//!   XΔβ AllReduce between them completes only once all ranks contribute).

use crate::cluster::transport::{Transport, TransportError};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// The κ→threshold rule shared by every quorum implementation: at least
/// ⌈κ·M⌉ full-pass reports end the iteration, clamped into [1, M]. A single
/// source of truth so the shared-memory and transport paths can never
/// disagree on when an iteration ends (rounding parity is pinned by unit
/// tests below).
pub fn quorum_threshold(nodes: usize, kappa: f64) -> usize {
    assert!(nodes > 0, "quorum needs at least one node");
    assert!(
        kappa > 0.0 && kappa <= 1.0,
        "κ must be in (0, 1], got {kappa}"
    );
    ((kappa * nodes as f64).ceil() as usize).clamp(1, nodes)
}

/// Shared-memory ALB controller — used when all nodes are threads in one
/// process (the fabric backend). For separate OS processes, the same quorum
/// decision is carried by tiny pass-done broadcasts: see [`RemoteQuorum`].
pub struct AlbController {
    nodes: usize,
    /// Minimum full-pass reports before cutting off the iteration.
    threshold: usize,
    done: AtomicUsize,
    stop: AtomicBool,
    /// Latest generation some rank has claimed (and begun resetting).
    gen_claim: AtomicU64,
    /// Latest generation whose reset is published; ranks spin on this in
    /// [`begin_iteration`](Self::begin_iteration) until the winner is done.
    gen_ready: AtomicU64,
}

impl AlbController {
    /// κ is the fraction of nodes that must complete a full pass
    /// (paper uses κ = 0.75).
    pub fn new(nodes: usize, kappa: f64) -> AlbController {
        AlbController {
            nodes,
            threshold: quorum_threshold(nodes, kappa),
            done: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            gen_claim: AtomicU64::new(0),
            gen_ready: AtomicU64::new(0),
        }
    }

    /// Start a new outer iteration identified by a strictly increasing
    /// generation number (the worker passes its per-iteration ALB tag).
    /// Every rank calls this; exactly one wins the claim and resets the
    /// counters, the rest wait until the reset is published. Replaces the
    /// old barrier-guarded `reset`: by the time any rank calls this for
    /// generation g, every rank has left generation g−1's CD loop (they all
    /// contributed to the XΔβ AllReduce in between), so nobody can still be
    /// reading the flag being cleared, and no stale g−1 report can land
    /// after the reset.
    pub fn begin_iteration(&self, gen: u64) {
        let mut cur = self.gen_claim.load(Ordering::Acquire);
        loop {
            if cur >= gen {
                break; // this (or a later) generation is already claimed
            }
            match self.gen_claim.compare_exchange(
                cur,
                gen,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    self.done.store(0, Ordering::Release);
                    self.stop.store(false, Ordering::Release);
                    self.gen_ready.store(gen, Ordering::Release);
                    return;
                }
                Err(now) => cur = now,
            }
        }
        while self.gen_ready.load(Ordering::Acquire) < gen {
            // The winner only has two stores left, but it may have been
            // preempted between the claim and the publish — yield so an
            // oversubscribed host (nodes > cores) reschedules it instead of
            // burning whole quanta in a pure spin.
            std::thread::yield_now();
        }
    }

    /// A node reports completion of one full pass over its block.
    pub fn report_full_pass(&self) {
        let now = self.done.fetch_add(1, Ordering::AcqRel) + 1;
        if now >= self.threshold {
            self.stop.store(true, Ordering::Release);
        }
    }

    /// The stop flag polled by `cd_cycle`.
    pub fn stop_flag(&self) -> &AtomicBool {
        &self.stop
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// Unconditional reset (single-owner embedders and tests; the worker
    /// path goes through [`begin_iteration`](Self::begin_iteration)).
    pub fn reset(&self) {
        self.done.store(0, Ordering::Release);
        self.stop.store(false, Ordering::Release);
    }

    pub fn threshold(&self) -> usize {
        self.threshold
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }
}

/// Transport-level ALB quorum: the distributed analogue of
/// [`AlbController`], built only on [`Transport`] so it works across OS
/// processes. A node that finishes a full pass broadcasts an empty
/// pass-done frame to every peer on the iteration's ALB tag; `should_stop`
/// polls (non-blocking) for peers' frames and raises once ⌈κ·M⌉ reports —
/// own pass included — have been seen.
///
/// One `RemoteQuorum` serves one outer iteration: construct it with a fresh
/// tag per iteration (a single tag from the worker's `TAG_STRIDE` allocator
/// suffices, since pass-done frames are the only traffic on it). Late
/// frames from stragglers that report after the quorum fired simply park in
/// the transport's pending map for that retired tag — a few empty frames
/// per iteration, never replayed into a later quorum.
pub struct RemoteQuorum {
    tag: u64,
    kappa: f64,
    threshold: usize,
    /// seen[r] = rank r's pass-done frame observed (or r == self after
    /// `report_full_pass`).
    seen: Vec<bool>,
    /// excluded[r] = rank r is known permanently lost — it no longer counts
    /// toward the quorum universe and is never polled or broadcast to.
    excluded: Vec<bool>,
    reports: usize,
}

impl RemoteQuorum {
    pub fn new(nodes: usize, kappa: f64, tag: u64) -> RemoteQuorum {
        RemoteQuorum {
            tag,
            kappa,
            threshold: quorum_threshold(nodes, kappa),
            seen: vec![false; nodes],
            excluded: vec![false; nodes],
            reports: 0,
        }
    }

    /// Exclude a permanently lost rank from the quorum: it stops counting
    /// toward (and being counted in) the threshold, which is recomputed as
    /// ⌈κ·survivors⌉ — the same rule over the shrunken cluster, so a fit
    /// that re-shards a dead rank's block across survivors keeps the same
    /// slow-node protection. A report already observed from the rank is
    /// discarded (its pass can no longer contribute to the iteration).
    /// Idempotent; excluding every peer leaves a self-quorum of one.
    pub fn exclude(&mut self, rank: usize) {
        if self.excluded[rank] {
            return;
        }
        self.excluded[rank] = true;
        if self.seen[rank] {
            self.seen[rank] = false;
            self.reports -= 1;
        }
        let survivors = self.excluded.iter().filter(|&&e| !e).count();
        self.threshold = quorum_threshold(survivors.max(1), self.kappa);
    }

    /// Ranks this quorum has written off as permanently lost.
    pub fn excluded_ranks(&self) -> Vec<usize> {
        (0..self.excluded.len())
            .filter(|&r| self.excluded[r])
            .collect()
    }

    /// This node finished one full pass over its block: broadcast it.
    /// Idempotent — repeated calls neither re-broadcast nor re-count.
    /// A peer whose link is down is excluded on the spot rather than
    /// failing the broadcast — the quorum keeps serving the survivors
    /// (the iteration's blocking collective is where its death is fatal).
    pub fn report_full_pass(&mut self, t: &mut dyn Transport) -> Result<(), TransportError> {
        let me = t.rank();
        if !self.seen[me] {
            self.seen[me] = true;
            self.reports += 1;
            for to in (0..t.size()).filter(|&r| r != me) {
                if self.excluded[to] {
                    continue;
                }
                if let Err(TransportError::PeerGone { peer }) = t.send(to, self.tag, Vec::new()) {
                    self.exclude(peer);
                }
            }
        }
        Ok(())
    }

    /// Poll peers' pass-done frames; `Ok(true)` once the κ quorum is met.
    /// Duplicate frames from one rank are drained but never double-counted.
    /// A peer observed dead mid-poll is excluded (see [`exclude`]); only a
    /// transport with no live peer left at all errors out.
    ///
    /// [`exclude`]: Self::exclude
    pub fn should_stop(&mut self, t: &mut dyn Transport) -> Result<bool, TransportError> {
        let me = t.rank();
        for from in (0..t.size()).filter(|&r| r != me) {
            if self.excluded[from] {
                continue;
            }
            loop {
                match t.try_recv_from(from, self.tag) {
                    Ok(Some(_)) => {
                        if !self.seen[from] {
                            self.seen[from] = true;
                            self.reports += 1;
                        }
                    }
                    Ok(None) => break,
                    Err(TransportError::PeerGone { peer }) => {
                        self.exclude(peer);
                        break;
                    }
                    Err(e @ TransportError::AllPeersGone) => return Err(e),
                }
            }
        }
        Ok(self.reports >= self.threshold)
    }

    /// Distinct ranks whose full pass this quorum has observed so far.
    pub fn reports(&self) -> usize {
        self.reports
    }

    pub fn threshold(&self) -> usize {
        self.threshold
    }
}

/// Discard any pass-done frames still parked (or newly arrived) on a
/// retired quorum tag. The worker keeps a sliding window of its last
/// [`RETIRED_TAG_WINDOW`] ALB tags and drains all of them every iteration,
/// so a late straggler frame only escapes the drain if it stays in flight
/// for more than that many full outer iterations — each of which contains
/// several blocking collectives with every rank — which bounds the
/// transport's pending map in any real execution.
pub fn drain_retired_tag(t: &mut dyn Transport, tag: u64) {
    let me = t.rank();
    for from in (0..t.size()).filter(|&r| r != me) {
        // A dead peer errors once its pending frames are exhausted — which
        // for a drain is success, not failure: there is nothing left to
        // discard and never will be.
        while let Ok(Some(_)) = t.try_recv_from(from, tag) {}
    }
}

/// How many retired ALB tags the worker keeps draining (see
/// [`drain_retired_tag`]).
pub const RETIRED_TAG_WINDOW: usize = 4;

/// How a run obtains its per-iteration ALB quorum — carried by
/// `WorkerShared` and turned into one fresh [`AlbQuorum`] per outer
/// iteration by the worker.
#[derive(Clone, Copy)]
pub enum AlbMode<'a> {
    /// Shared-memory controller: all nodes are threads of one process (the
    /// fabric driver). Thin special case — zero wire frames and a
    /// per-coordinate stop flag for the CD hot loop.
    Shared(&'a AlbController),
    /// Transport-level κ-quorum on a fresh per-iteration tag: works across
    /// OS processes (TCP mesh) and over the fabric alike.
    Transport { kappa: f64 },
}

impl<'a> AlbMode<'a> {
    /// Begin one outer iteration: `tag` must come from the worker's
    /// SPMD-deterministic `TAG_STRIDE` allocator (strictly increasing, the
    /// same value on every rank).
    pub fn begin_iteration(&self, nodes: usize, tag: u64) -> AlbQuorum<'a> {
        match self {
            AlbMode::Shared(c) => {
                c.begin_iteration(tag);
                AlbQuorum::Shared(c)
            }
            AlbMode::Transport { kappa } => {
                AlbQuorum::Remote(RemoteQuorum::new(nodes, *kappa, tag))
            }
        }
    }
}

/// One outer iteration's ALB stop decision — the unified handle the worker
/// (and the chaos suite) is written against. The shared-memory controller
/// is the fabric special case; the transport quorum is the general one.
pub enum AlbQuorum<'a> {
    Shared(&'a AlbController),
    Remote(RemoteQuorum),
}

impl AlbQuorum<'_> {
    pub fn report_full_pass(&mut self, t: &mut dyn Transport) -> Result<(), TransportError> {
        match self {
            AlbQuorum::Shared(c) => {
                c.report_full_pass();
                Ok(())
            }
            AlbQuorum::Remote(q) => q.report_full_pass(t),
        }
    }

    pub fn should_stop(&mut self, t: &mut dyn Transport) -> Result<bool, TransportError> {
        match self {
            AlbQuorum::Shared(c) => Ok(c.should_stop()),
            AlbQuorum::Remote(q) => q.should_stop(t),
        }
    }

    /// Per-coordinate stop flag for `cd_cycle` — only the shared-memory
    /// special case can offer one; the transport path polls between chunks.
    pub fn stop_flag(&self) -> Option<&AtomicBool> {
        match self {
            AlbQuorum::Shared(c) => Some(c.stop_flag()),
            AlbQuorum::Remote(_) => None,
        }
    }

    pub fn threshold(&self) -> usize {
        match self {
            AlbQuorum::Shared(c) => c.threshold(),
            AlbQuorum::Remote(q) => q.threshold(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn threshold_rounding() {
        assert_eq!(AlbController::new(16, 0.75).threshold(), 12);
        assert_eq!(AlbController::new(4, 0.75).threshold(), 3);
        assert_eq!(AlbController::new(3, 0.75).threshold(), 3); // ceil(2.25)
        assert_eq!(AlbController::new(1, 0.75).threshold(), 1);
        assert_eq!(AlbController::new(8, 1.0).threshold(), 8);
    }

    #[test]
    fn threshold_parity_between_controller_and_remote_quorum() {
        // The shared helper is the single source of truth: both fronts must
        // agree bit-for-bit on every (M, κ) cell of the test matrix.
        for m in [1usize, 3, 4, 8, 16] {
            for kappa in [0.5, 0.75, 1.0] {
                let want = quorum_threshold(m, kappa);
                assert_eq!(
                    AlbController::new(m, kappa).threshold(),
                    want,
                    "controller M={m} κ={kappa}"
                );
                assert_eq!(
                    RemoteQuorum::new(m, kappa, 0).threshold(),
                    want,
                    "remote M={m} κ={kappa}"
                );
                // ⌈κM⌉ by construction, clamped into [1, M].
                assert_eq!(want, ((kappa * m as f64).ceil() as usize).clamp(1, m));
            }
        }
        // Pinned values across the matrix (ceil, not round/floor).
        assert_eq!(quorum_threshold(3, 0.5), 2); // ceil(1.5)
        assert_eq!(quorum_threshold(4, 0.5), 2);
        assert_eq!(quorum_threshold(8, 0.75), 6);
        assert_eq!(quorum_threshold(16, 0.5), 8);
        assert_eq!(quorum_threshold(1, 0.5), 1); // clamp low
        assert_eq!(quorum_threshold(16, 1.0), 16);
    }

    #[test]
    #[should_panic(expected = "κ must be in (0, 1]")]
    fn threshold_rejects_kappa_above_one() {
        quorum_threshold(4, 1.5);
    }

    #[test]
    fn stop_fires_exactly_at_threshold() {
        let c = AlbController::new(4, 0.75); // threshold 3
        assert!(!c.should_stop());
        c.report_full_pass();
        c.report_full_pass();
        assert!(!c.should_stop());
        c.report_full_pass();
        assert!(c.should_stop());
    }

    #[test]
    fn reset_clears_state() {
        let c = AlbController::new(2, 0.5);
        c.report_full_pass();
        assert!(c.should_stop());
        c.reset();
        assert!(!c.should_stop());
        c.report_full_pass();
        assert!(c.should_stop());
    }

    #[test]
    fn begin_iteration_resets_once_per_generation() {
        let c = AlbController::new(2, 0.5);
        c.begin_iteration(100);
        c.report_full_pass();
        assert!(c.should_stop());
        // Second caller of the same generation must NOT wipe the quorum.
        c.begin_iteration(100);
        assert!(c.should_stop());
        // A later generation does.
        c.begin_iteration(200);
        assert!(!c.should_stop());
        // A stale (lower) generation is a no-op.
        c.begin_iteration(150);
        assert!(!c.should_stop());
        c.report_full_pass();
        assert!(c.should_stop());
    }

    #[test]
    fn begin_iteration_races_resolve_to_one_reset() {
        // Many threads begin the same generation concurrently after the
        // previous one fired: everyone must come out seeing a cleared flag.
        for round in 0..20u64 {
            let c = Arc::new(AlbController::new(8, 0.5));
            c.begin_iteration(round * 1000 + 1);
            for _ in 0..4 {
                c.report_full_pass();
            }
            assert!(c.should_stop());
            let gen = round * 1000 + 2;
            let mut handles = Vec::new();
            for _ in 0..8 {
                let c = c.clone();
                handles.push(std::thread::spawn(move || {
                    c.begin_iteration(gen);
                    assert!(!c.should_stop(), "stale stop leaked into gen {gen}");
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        }
    }

    #[test]
    fn concurrent_reports_fire_once_threshold_met() {
        let c = Arc::new(AlbController::new(8, 0.75));
        let mut handles = Vec::new();
        for _ in 0..6 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || c.report_full_pass()));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.should_stop());
    }

    #[test]
    fn remote_quorum_fires_at_threshold_over_fabric() {
        use crate::cluster::fabric::{fabric, NetworkModel};
        use crate::cluster::transport::Transport as _;
        let m = 4; // κ = 0.75 → threshold 3
        let (eps, _) = fabric(m, NetworkModel::default());
        let mut handles = Vec::new();
        for ep in eps {
            handles.push(std::thread::spawn(move || {
                let mut ep = ep;
                let rank = ep.rank();
                let mut q = RemoteQuorum::new(m, 0.75, 77);
                assert_eq!(q.threshold(), 3);
                if rank < 3 {
                    // Three fast nodes report; each must observe the quorum.
                    q.report_full_pass(&mut ep).unwrap();
                    while !q.should_stop(&mut ep).unwrap() {
                        std::thread::yield_now();
                    }
                } else {
                    // The straggler never reports but still sees the stop.
                    while !q.should_stop(&mut ep).unwrap() {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn drain_retired_tag_discards_parked_frames() {
        use crate::cluster::fabric::{fabric, NetworkModel};
        use crate::cluster::transport::Transport as _;
        let (mut eps, _) = fabric(2, NetworkModel::default());
        let (e1, e0) = (eps.pop().unwrap(), eps.pop().unwrap());
        let mut e0 = e0;
        // Three late straggler frames on a retired tag, one on a live tag.
        e1.send(0, 100, Vec::new()).unwrap();
        e1.send(0, 100, Vec::new()).unwrap();
        e1.send(0, 100, Vec::new()).unwrap();
        e1.send(0, 200, vec![1.0]).unwrap();
        drain_retired_tag(&mut e0, 100);
        assert_eq!(
            e0.try_recv_from(1, 100).unwrap(),
            None,
            "retired frames discarded"
        );
        assert_eq!(
            e0.try_recv_from(1, 200).unwrap(),
            Some(vec![1.0]),
            "live-tag frames survive the drain"
        );
    }

    #[test]
    fn alb_quorum_unifies_both_variants() {
        use crate::cluster::fabric::{fabric, NetworkModel};
        let (mut eps, _) = fabric(1, NetworkModel::default());
        let mut ep = eps.pop().unwrap();

        let ctrl = AlbController::new(2, 0.5);
        let mode = AlbMode::Shared(&ctrl);
        let mut q = mode.begin_iteration(2, 10);
        assert_eq!(q.threshold(), 1);
        assert!(q.stop_flag().is_some());
        assert!(!q.should_stop(&mut ep).unwrap());
        q.report_full_pass(&mut ep).unwrap();
        assert!(q.should_stop(&mut ep).unwrap());

        // M = 1 remote quorum: own report is the whole quorum.
        let mode = AlbMode::Transport { kappa: 1.0 };
        let mut q = mode.begin_iteration(1, 20);
        assert!(q.stop_flag().is_none());
        assert!(!q.should_stop(&mut ep).unwrap());
        q.report_full_pass(&mut ep).unwrap();
        assert!(q.should_stop(&mut ep).unwrap());
    }

    #[test]
    fn exclusion_shrinks_the_quorum_universe() {
        // M = 4, κ = 0.75 → threshold 3. Excluding one rank recomputes the
        // rule over 3 survivors: ⌈0.75·3⌉ = 3 (every survivor must report).
        let mut q = RemoteQuorum::new(4, 0.75, 0);
        assert_eq!(q.threshold(), 3);
        q.exclude(3);
        assert_eq!(q.threshold(), 3);
        assert_eq!(q.excluded_ranks(), vec![3]);
        // κ = 0.5: 4 → 2, exclude → ⌈0.5·3⌉ = 2, exclude again → ⌈0.5·2⌉ = 1.
        let mut q = RemoteQuorum::new(4, 0.5, 0);
        assert_eq!(q.threshold(), 2);
        q.exclude(1);
        assert_eq!(q.threshold(), 2);
        q.exclude(2);
        assert_eq!(q.threshold(), 1);
        q.exclude(2); // idempotent
        assert_eq!(q.threshold(), 1);
        assert_eq!(q.excluded_ranks(), vec![1, 2]);
    }

    #[test]
    fn exclusion_discards_the_dead_ranks_report() {
        use crate::cluster::fabric::{fabric, NetworkModel};
        let m = 4;
        let (mut eps, _) = fabric(m, NetworkModel::default());
        let mut e0 = eps.remove(0);
        // Ranks 1 and 2 report, then rank 1 is written off: its counted
        // report must be withdrawn, and with κ = 1.0 over 3 survivors the
        // quorum needs all three — one live report is not enough.
        let mut q1 = RemoteQuorum::new(m, 1.0, 9);
        let mut q2 = RemoteQuorum::new(m, 1.0, 9);
        q1.report_full_pass(&mut eps[0]).unwrap();
        q2.report_full_pass(&mut eps[1]).unwrap();
        let mut q = RemoteQuorum::new(m, 1.0, 9);
        assert!(!q.should_stop(&mut e0).unwrap());
        assert_eq!(q.reports(), 2);
        q.exclude(1);
        assert_eq!(q.reports(), 1);
        assert_eq!(q.threshold(), 3);
        assert!(!q.should_stop(&mut e0).unwrap());
    }

    #[test]
    fn dead_peer_is_auto_excluded_on_broadcast() {
        use crate::cluster::fabric::{fabric, NetworkModel};
        // 2 ranks, κ = 1.0 → threshold 2. Rank 1 dies before reporting;
        // rank 0's broadcast notices, excludes it, and its own report then
        // satisfies the recomputed self-quorum of 1 — the job survives.
        let (mut eps, _) = fabric(2, NetworkModel::default());
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        drop(e1);
        let mut q = RemoteQuorum::new(2, 1.0, 5);
        assert_eq!(q.threshold(), 2);
        q.report_full_pass(&mut e0).unwrap();
        assert_eq!(q.excluded_ranks(), vec![1]);
        assert_eq!(q.threshold(), 1);
        assert!(q.should_stop(&mut e0).unwrap());
    }

    #[test]
    fn straggler_cut_off_in_cd_cycle() {
        // Integration with the subproblem budget: a pre-raised flag limits a
        // big block to a single update.
        use crate::glm::regularizer::ElasticNet;
        use crate::solver::subproblem::{cd_cycle, CycleBudget, SubproblemState};
        use crate::sparse::Csc;
        let x = Csc::from_triplets(
            4,
            10,
            (0..10).map(|j| (j % 4, j, 1.0)).collect::<Vec<_>>(),
        );
        let c = AlbController::new(2, 0.5);
        c.report_full_pass(); // the other node finished: threshold met
        let pen = ElasticNet::new(0.01, 0.0);
        let mut st = SubproblemState::new(10, 4);
        let out = cd_cycle(
            &x,
            &vec![0.0; 10],
            &vec![1.0; 4],
            &vec![1.0; 4],
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget {
                max_updates: 10,
                stop: Some(c.stop_flag()),
                active: None,
            },
        );
        assert_eq!(out.updates, 1);
        assert_eq!(st.cursor, 1); // resumes at weight 1 next iteration
    }
}
