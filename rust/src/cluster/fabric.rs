//! In-process message fabric — the simulated cluster interconnect.
//!
//! Each simulated node (one OS thread) owns an [`Endpoint`]: a mailbox
//! (mpsc receiver) plus senders to every peer. Messages are tagged so
//! collectives can match out-of-order arrivals. All traffic is accounted
//! per-link (bytes + messages) and an optional latency model charges
//! simulated wire time — the counters feed the Table 2 communication-cost
//! reproduction and the DESIGN.md substitution argument (we replace the
//! paper's Gigabit Ethernet by an accounted in-memory fabric).

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::cluster::transport::TransportError;

/// A tagged message between nodes. Payloads are f64 vectors (the only thing
/// d-GLMNET ever ships: XΔβ chunks, regularizer partial sums, scalars).
#[derive(Debug)]
pub struct Msg {
    pub from: usize,
    pub tag: u64,
    pub data: Vec<f64>,
}

/// Cost model of the simulated wire (per message + per byte), matching the
/// α-β model commonly used for MPI collectives. Zero by default: pure
/// accounting without slowing the simulation down.
#[derive(Clone, Copy, Debug, Default)]
pub struct NetworkModel {
    pub latency_us_per_msg: f64,
    pub ns_per_byte: f64,
    /// If true, `send` actually sleeps the modeled duration, making
    /// wall-clock reflect the simulated network (used by the comm-bound
    /// ablation benches).
    pub sleep: bool,
}

impl NetworkModel {
    /// ~Gigabit Ethernet: 50 µs per message, 8 ns/byte (≈ 1 Gb/s usable).
    pub fn gigabit() -> NetworkModel {
        NetworkModel {
            latency_us_per_msg: 50.0,
            ns_per_byte: 8.0,
            sleep: false,
        }
    }

    pub fn cost_secs(&self, bytes: usize) -> f64 {
        self.latency_us_per_msg * 1e-6 + self.ns_per_byte * 1e-9 * bytes as f64
    }
}

/// Shared traffic counters.
#[derive(Debug)]
pub struct FabricStats {
    nodes: usize,
    /// bytes[from * nodes + to]
    bytes: Vec<AtomicU64>,
    msgs: Vec<AtomicU64>,
    /// Modeled wire time in nanoseconds (sum over links).
    sim_wire_ns: AtomicU64,
}

impl FabricStats {
    fn new(nodes: usize) -> FabricStats {
        FabricStats {
            nodes,
            bytes: (0..nodes * nodes).map(|_| AtomicU64::new(0)).collect(),
            msgs: (0..nodes * nodes).map(|_| AtomicU64::new(0)).collect(),
            sim_wire_ns: AtomicU64::new(0),
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.msgs.iter().map(|m| m.load(Ordering::Relaxed)).sum()
    }

    pub fn link_bytes(&self, from: usize, to: usize) -> u64 {
        self.bytes[from * self.nodes + to].load(Ordering::Relaxed)
    }

    pub fn link_msgs(&self, from: usize, to: usize) -> u64 {
        self.msgs[from * self.nodes + to].load(Ordering::Relaxed)
    }

    /// `(bytes, messages)` sent by one rank across all of its outgoing links.
    pub fn sent_by(&self, from: usize) -> (u64, u64) {
        let mut bytes = 0;
        let mut msgs = 0;
        for to in 0..self.nodes {
            bytes += self.link_bytes(from, to);
            msgs += self.link_msgs(from, to);
        }
        (bytes, msgs)
    }

    /// Total modeled wire time (seconds).
    pub fn sim_wire_secs(&self) -> f64 {
        self.sim_wire_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn reset(&self) {
        for b in &self.bytes {
            b.store(0, Ordering::Relaxed);
        }
        for m in &self.msgs {
            m.store(0, Ordering::Relaxed);
        }
        self.sim_wire_ns.store(0, Ordering::Relaxed);
    }
}

/// One node's attachment to the fabric.
pub struct Endpoint {
    pub rank: usize,
    pub nodes: usize,
    senders: Vec<Sender<Msg>>,
    receiver: Receiver<Msg>,
    /// Out-of-order messages parked until someone asks for their (from, tag).
    pending: HashMap<(usize, u64), Vec<Msg>>,
    stats: Arc<FabricStats>,
    model: NetworkModel,
    /// Per-tag sent accounting: tag → (bytes, msgs). `RefCell` because the
    /// inherent `send` takes `&self`; an endpoint is owned by one thread.
    sent_tags: RefCell<BTreeMap<u64, (u64, u64)>>,
}

/// Build a fabric of `nodes` endpoints.
pub fn fabric(nodes: usize, model: NetworkModel) -> (Vec<Endpoint>, Arc<FabricStats>) {
    assert!(nodes > 0);
    let stats = Arc::new(FabricStats::new(nodes));
    let mut senders = Vec::with_capacity(nodes);
    let mut receivers = Vec::with_capacity(nodes);
    for _ in 0..nodes {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let endpoints = receivers
        .into_iter()
        .enumerate()
        .map(|(rank, receiver)| {
            // Replace the self-sender with a disconnected one: no collective
            // self-sends (the TCP backend asserts the same), and it means a
            // mailbox's live senders are exactly the surviving peers — so a
            // fully-dead peer set surfaces as `AllPeersGone` instead of a
            // hang on a channel the rank itself keeps alive.
            let mut senders = senders.clone();
            let (dead_tx, _) = channel();
            senders[rank] = dead_tx;
            Endpoint {
                rank,
                nodes,
                senders,
                receiver,
                pending: HashMap::new(),
                stats: Arc::clone(&stats),
                model,
                sent_tags: RefCell::new(BTreeMap::new()),
            }
        })
        .collect();
    (endpoints, stats)
}

impl Endpoint {
    /// Send a tagged payload to `to`. Accounts bytes under the shared
    /// [`frame_bytes`](crate::cluster::transport::frame_bytes) formula
    /// (8 per f64 + a fixed 16-byte header, mirroring an MPI envelope).
    /// A dropped peer endpoint (its mailbox receiver is gone) surfaces as
    /// [`TransportError::PeerGone`]; nothing is accounted for failed sends.
    pub fn send(&self, to: usize, tag: u64, data: Vec<f64>) -> Result<(), TransportError> {
        let bytes = crate::cluster::transport::frame_bytes(data.len()) as usize;
        if self
            .senders[to]
            .send(Msg {
                from: self.rank,
                tag,
                data,
            })
            .is_err()
        {
            return Err(TransportError::PeerGone { peer: to });
        }
        let idx = self.rank * self.nodes + to;
        self.stats.bytes[idx].fetch_add(bytes as u64, Ordering::Relaxed);
        self.stats.msgs[idx].fetch_add(1, Ordering::Relaxed);
        {
            let mut tags = self.sent_tags.borrow_mut();
            let e = tags.entry(tag).or_insert((0, 0));
            e.0 += bytes as u64;
            e.1 += 1;
        }
        let cost = self.model.cost_secs(bytes);
        self.stats
            .sim_wire_ns
            .fetch_add((cost * 1e9) as u64, Ordering::Relaxed);
        if self.model.sleep && cost > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(cost));
        }
        Ok(())
    }

    /// Pop the oldest parked message for `(from, tag)`, if any.
    fn take_pending(&mut self, key: (usize, u64)) -> Option<Vec<f64>> {
        let q = self.pending.get_mut(&key)?;
        if q.is_empty() {
            return None;
        }
        let msg = q.remove(0);
        if q.is_empty() {
            self.pending.remove(&key);
        }
        Some(msg.data)
    }

    /// Blocking receive of the next message from `from` with tag `tag`;
    /// other messages arriving meanwhile are parked. When every peer
    /// endpoint has been dropped (the shared mailbox has no live senders)
    /// this errors with [`TransportError::AllPeersGone`] — the mpsc fabric
    /// cannot attribute the hang-up to one rank, only observe that nothing
    /// can ever arrive again. Parked messages stay deliverable regardless.
    pub fn recv_from(&mut self, from: usize, tag: u64) -> Result<Vec<f64>, TransportError> {
        if let Some(data) = self.take_pending((from, tag)) {
            return Ok(data);
        }
        loop {
            let msg = match self.receiver.recv() {
                Ok(m) => m,
                Err(_) => return Err(TransportError::AllPeersGone),
            };
            if msg.from == from && msg.tag == tag {
                return Ok(msg.data);
            }
            self.pending
                .entry((msg.from, msg.tag))
                .or_default()
                .push(msg);
        }
    }

    /// Non-blocking receive: drains the mailbox, parking mismatches, and
    /// returns the first message matching `(from, tag)` if one has arrived.
    /// `Ok(None)` means nothing yet; [`TransportError::AllPeersGone`] means
    /// nothing pending matches and no sender is left alive to produce more.
    pub fn try_recv_from(
        &mut self,
        from: usize,
        tag: u64,
    ) -> Result<Option<Vec<f64>>, TransportError> {
        if let Some(data) = self.take_pending((from, tag)) {
            return Ok(Some(data));
        }
        loop {
            match self.receiver.try_recv() {
                Ok(msg) => {
                    if msg.from == from && msg.tag == tag {
                        return Ok(Some(msg.data));
                    }
                    self.pending
                        .entry((msg.from, msg.tag))
                        .or_default()
                        .push(msg);
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => return Ok(None),
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    return Err(TransportError::AllPeersGone)
                }
            }
        }
    }

    pub fn stats(&self) -> &Arc<FabricStats> {
        &self.stats
    }
}

impl crate::cluster::transport::Transport for Endpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.nodes
    }

    fn send(&mut self, to: usize, tag: u64, data: Vec<f64>) -> Result<(), TransportError> {
        Endpoint::send(self, to, tag, data)
    }

    fn recv_from(&mut self, from: usize, tag: u64) -> Result<Vec<f64>, TransportError> {
        Endpoint::recv_from(self, from, tag)
    }

    fn try_recv_from(
        &mut self,
        from: usize,
        tag: u64,
    ) -> Result<Option<Vec<f64>>, TransportError> {
        Endpoint::try_recv_from(self, from, tag)
    }

    fn sent(&self) -> (u64, u64) {
        self.stats.sent_by(self.rank)
    }

    fn sent_by_tag(&self) -> Vec<(u64, u64, u64)> {
        self.sent_tags
            .borrow()
            .iter()
            .map(|(&tag, &(bytes, msgs))| (tag, bytes, msgs))
            .collect()
    }

    fn global_traffic(&self) -> Option<(u64, u64)> {
        Some((self.stats.total_bytes(), self.stats.total_msgs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crossbeam_utils::thread;

    #[test]
    fn point_to_point_roundtrip() {
        let (mut eps, stats) = fabric(2, NetworkModel::default());
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        thread::scope(|s| {
            s.spawn(move |_| {
                e1.send(0, 7, vec![1.0, 2.0, 3.0]).unwrap();
                let back = e1.recv_from(0, 8).unwrap();
                assert_eq!(back, vec![6.0]);
            });
            let got = e0.recv_from(1, 7).unwrap();
            assert_eq!(got, vec![1.0, 2.0, 3.0]);
            e0.send(1, 8, vec![got.iter().sum()]).unwrap();
        })
        .unwrap();
        // 2 messages: 16+24 and 16+8 bytes.
        assert_eq!(stats.total_msgs(), 2);
        assert_eq!(stats.total_bytes(), 40 + 24);
        assert_eq!(stats.link_bytes(1, 0), 40);
        assert_eq!(stats.link_bytes(0, 1), 24);
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        let (mut eps, _) = fabric(2, NetworkModel::default());
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        thread::scope(|s| {
            s.spawn(move |_| {
                // Send tag 2 first, then tag 1.
                e1.send(0, 2, vec![2.0]).unwrap();
                e1.send(0, 1, vec![1.0]).unwrap();
            });
            // Ask for tag 1 first: tag-2 message must be parked, not lost.
            assert_eq!(e0.recv_from(1, 1).unwrap(), vec![1.0]);
            assert_eq!(e0.recv_from(1, 2).unwrap(), vec![2.0]);
        })
        .unwrap();
    }

    #[test]
    fn multiple_same_tag_fifo() {
        let (mut eps, _) = fabric(2, NetworkModel::default());
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        thread::scope(|s| {
            s.spawn(move |_| {
                e1.send(0, 5, vec![1.0]).unwrap();
                e1.send(0, 5, vec![2.0]).unwrap();
                // force parking by sending an unrelated tag in between reads
                e1.send(0, 9, vec![9.0]).unwrap();
            });
            assert_eq!(e0.recv_from(1, 9).unwrap(), vec![9.0]); // parks both tag-5 msgs
            assert_eq!(e0.recv_from(1, 5).unwrap(), vec![1.0]);
            assert_eq!(e0.recv_from(1, 5).unwrap(), vec![2.0]);
        })
        .unwrap();
    }

    #[test]
    fn network_model_cost() {
        let m = NetworkModel::gigabit();
        let c = m.cost_secs(1_000_000);
        // 50us + 8ms
        assert!((c - 0.00805).abs() < 1e-6, "cost {c}");
    }

    #[test]
    fn sim_wire_time_accumulates() {
        let model = NetworkModel {
            latency_us_per_msg: 100.0,
            ns_per_byte: 0.0,
            sleep: false,
        };
        let (mut eps, stats) = fabric(2, model);
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        thread::scope(|s| {
            s.spawn(move |_| {
                for _ in 0..10 {
                    e1.send(0, 1, vec![0.0]).unwrap();
                }
            });
            for _ in 0..10 {
                e0.recv_from(1, 1).unwrap();
            }
        })
        .unwrap();
        assert!((stats.sim_wire_secs() - 10.0 * 100e-6).abs() < 1e-6);
    }

    #[test]
    fn stats_reset() {
        let (eps, stats) = fabric(2, NetworkModel::default());
        eps[0].send(1, 0, vec![1.0]).unwrap();
        assert!(stats.total_bytes() > 0);
        stats.reset();
        assert_eq!(stats.total_bytes(), 0);
        assert_eq!(stats.total_msgs(), 0);
    }

    #[test]
    fn dropped_endpoint_is_a_typed_error_and_pending_data_survives() {
        let (mut eps, stats) = fabric(2, NetworkModel::default());
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // Rank 1 parks one frame in rank 0's mailbox, then dies.
        e1.send(0, 3, vec![7.0]).unwrap();
        drop(e1);
        // Sends to the dead endpoint fail typed, with no accounting.
        let before = stats.total_msgs();
        assert_eq!(
            e0.send(1, 1, vec![0.0]),
            Err(TransportError::PeerGone { peer: 1 })
        );
        assert_eq!(stats.total_msgs(), before);
        // Already-shipped data is still deliverable...
        assert_eq!(e0.recv_from(1, 3).unwrap(), vec![7.0]);
        // ...then a drained, sender-less mailbox surfaces as AllPeersGone
        // (both blocking and non-blocking flavors; never a panic).
        assert_eq!(e0.recv_from(1, 3), Err(TransportError::AllPeersGone));
        assert_eq!(e0.try_recv_from(1, 4), Err(TransportError::AllPeersGone));
    }
}
