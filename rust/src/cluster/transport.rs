//! The `Transport` seam — the interconnect abstraction every collective and
//! coordinator call site is written against.
//!
//! d-GLMNET's communication needs are tiny (tagged point-to-point sends of
//! f64 vectors; everything else — AllReduce, barriers, gathers — is built on
//! top), so the trait is deliberately minimal. Two backends implement it:
//!
//! * [`fabric::Endpoint`](crate::cluster::fabric::Endpoint) — the in-process
//!   mailbox fabric (one thread per simulated node, shared counters, optional
//!   modeled wire time). This is the simulation substrate used by the bench
//!   harness and by `fit_distributed`.
//! * [`tcp::TcpTransport`](crate::cluster::tcp::TcpTransport) — real sockets:
//!   a full mesh of per-peer TCP connections speaking length-prefixed binary
//!   frames, used by the `dglmnet worker` / `dglmnet train --cluster`
//!   multi-process runtime.
//!
//! Contract (verified by `rust/tests/transport_conformance.rs` against both
//! backends):
//!
//! 1. **Ordered per (peer, tag)**: messages from one sender with one tag are
//!    received in send order (FIFO).
//! 2. **Tag isolation**: `recv_from(from, tag)` never returns a message with
//!    a different `(from, tag)`; mismatching arrivals are parked, not lost.
//! 3. **Accounting**: every `send` of `k` doubles adds exactly
//!    `16 + 8·k` bytes and one message to this endpoint's [`sent`] counters
//!    (16 bytes = the frame header: tag + length, mirroring an MPI
//!    envelope). Both backends use the same formula, so the Table 2
//!    communication numbers are backend-independent.
//!
//! [`sent`]: Transport::sent

/// A cluster interconnect endpoint owned by one rank.
///
/// All methods take `&mut self`: backends keep per-endpoint receive state
/// (the out-of-order parking map), and the SPMD solver never shares an
/// endpoint between threads.
pub trait Transport: Send {
    /// This endpoint's rank in `0..size()`.
    fn rank(&self) -> usize;

    /// Number of endpoints in the cluster (the paper's M).
    fn size(&self) -> usize;

    /// Send a tagged payload to rank `to`. Must not deadlock against a peer
    /// that is not currently receiving (backends buffer or queue).
    fn send(&mut self, to: usize, tag: u64, data: Vec<f64>);

    /// Blocking receive of the next message from `from` with tag `tag`.
    /// Messages with other `(from, tag)` keys arriving meanwhile are parked.
    fn recv_from(&mut self, from: usize, tag: u64) -> Vec<f64>;

    /// Non-blocking variant: returns `None` when no matching message has
    /// arrived yet (used by the transport-level ALB quorum).
    fn try_recv_from(&mut self, from: usize, tag: u64) -> Option<Vec<f64>>;

    /// `(bytes, messages)` sent by this endpoint since creation, under the
    /// shared 16 + 8·len accounting formula.
    fn sent(&self) -> (u64, u64);

    /// Per-tag send accounting: `(tag, bytes, messages)` for every tag this
    /// endpoint sent on, ascending by tag and summing to [`sent`]. Backends
    /// that do not track tags return an empty vec (the default); both
    /// in-tree backends override it, which is what lets the worker
    /// attribute traffic to solver phases (the comm-by-phase breakdown).
    fn sent_by_tag(&self) -> Vec<(u64, u64, u64)> {
        Vec::new()
    }

    /// Cluster-wide `(bytes, messages)` across all links, when the backend
    /// can observe them (the in-process fabric can; TCP endpoints only see
    /// their own traffic and return `None`).
    fn global_traffic(&self) -> Option<(u64, u64)>;
}

/// Wire-accounting cost of one payload: the shared 16-byte envelope plus
/// 8 bytes per double. Single source of truth for both backends.
#[inline]
pub fn frame_bytes(len: usize) -> u64 {
    16 + 8 * len as u64
}
