//! The `Transport` seam — the interconnect abstraction every collective and
//! coordinator call site is written against.
//!
//! d-GLMNET's communication needs are tiny (tagged point-to-point sends of
//! f64 vectors; everything else — AllReduce, barriers, gathers — is built on
//! top), so the trait is deliberately minimal. Two backends implement it:
//!
//! * [`fabric::Endpoint`](crate::cluster::fabric::Endpoint) — the in-process
//!   mailbox fabric (one thread per simulated node, shared counters, optional
//!   modeled wire time). This is the simulation substrate used by the bench
//!   harness and by `fit_distributed`.
//! * [`tcp::TcpTransport`](crate::cluster::tcp::TcpTransport) — real sockets:
//!   a full mesh of per-peer TCP connections speaking length-prefixed binary
//!   frames, used by the `dglmnet worker` / `dglmnet train --cluster`
//!   multi-process runtime.
//!
//! Contract (verified by `rust/tests/transport_conformance.rs` against both
//! backends):
//!
//! 1. **Ordered per (peer, tag)**: messages from one sender with one tag are
//!    received in send order (FIFO).
//! 2. **Tag isolation**: `recv_from(from, tag)` never returns a message with
//!    a different `(from, tag)`; mismatching arrivals are parked, not lost.
//! 3. **Accounting**: every `send` of `k` doubles adds exactly
//!    `16 + 8·k` bytes and one message to this endpoint's [`sent`] counters
//!    (16 bytes = the frame header: tag + length, mirroring an MPI
//!    envelope). Both backends use the same formula, so the Table 2
//!    communication numbers are backend-independent.
//! 4. **Typed peer-death errors**: a hung-up, crashed, or poisoned peer is
//!    reported as `Err(TransportError)` from `send`/`recv_from`/
//!    `try_recv_from` — never a panic. Messages already parked from a peer
//!    remain deliverable after it dies; the error fires only once the
//!    pending data for the requested `(peer, tag)` is exhausted. This is
//!    what lets the coordinator observe a dead rank as a recoverable event
//!    (checkpoint/resume) instead of a process abort.
//!
//! [`sent`]: Transport::sent

/// A peer-failure event observed at the transport layer.
///
/// Both backends map their native failure signals onto these variants: the
/// TCP mesh's poison frames (a reader thread observing EOF / a broken
/// stream) and closed writer channels, and the fabric's disconnected mpsc
/// channels. The solver and coordinator treat them as "rank X is gone" —
/// recoverable via checkpoint/resume when enabled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportError {
    /// A specific peer hung up (socket closed, process died, endpoint
    /// dropped) and no pending data from it can satisfy the request.
    PeerGone { peer: usize },
    /// Every peer is gone: the shared inbox has no live senders left, so no
    /// request against any rank can ever complete.
    AllPeersGone,
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::PeerGone { peer } => write!(f, "peer rank {peer} hung up"),
            TransportError::AllPeersGone => write!(f, "all peers hung up"),
        }
    }
}

impl std::error::Error for TransportError {}

/// A cluster interconnect endpoint owned by one rank.
///
/// All methods take `&mut self`: backends keep per-endpoint receive state
/// (the out-of-order parking map), and the SPMD solver never shares an
/// endpoint between threads.
pub trait Transport: Send {
    /// This endpoint's rank in `0..size()`.
    fn rank(&self) -> usize;

    /// Number of endpoints in the cluster (the paper's M).
    fn size(&self) -> usize;

    /// Send a tagged payload to rank `to`. Must not deadlock against a peer
    /// that is not currently receiving (backends buffer or queue). Errors
    /// with [`TransportError::PeerGone`] when the peer's link is down.
    fn send(&mut self, to: usize, tag: u64, data: Vec<f64>) -> Result<(), TransportError>;

    /// Blocking receive of the next message from `from` with tag `tag`.
    /// Messages with other `(from, tag)` keys arriving meanwhile are parked.
    /// Errors once `from` is known dead and nothing pending matches.
    fn recv_from(&mut self, from: usize, tag: u64) -> Result<Vec<f64>, TransportError>;

    /// Non-blocking variant: `Ok(None)` when no matching message has
    /// arrived yet (used by the transport-level ALB quorum);
    /// `Err(PeerGone)` once `from` is known dead with nothing pending.
    fn try_recv_from(&mut self, from: usize, tag: u64)
        -> Result<Option<Vec<f64>>, TransportError>;

    /// `(bytes, messages)` sent by this endpoint since creation, under the
    /// shared 16 + 8·len accounting formula.
    fn sent(&self) -> (u64, u64);

    /// Per-tag send accounting: `(tag, bytes, messages)` for every tag this
    /// endpoint sent on, ascending by tag and summing to [`sent`]. Backends
    /// that do not track tags return an empty vec (the default); both
    /// in-tree backends override it, which is what lets the worker
    /// attribute traffic to solver phases (the comm-by-phase breakdown).
    fn sent_by_tag(&self) -> Vec<(u64, u64, u64)> {
        Vec::new()
    }

    /// Cluster-wide `(bytes, messages)` across all links, when the backend
    /// can observe them (the in-process fabric can; TCP endpoints only see
    /// their own traffic and return `None`).
    fn global_traffic(&self) -> Option<(u64, u64)>;
}

/// Wire-accounting cost of one payload: the shared 16-byte envelope plus
/// 8 bytes per double. Single source of truth for both backends.
#[inline]
pub fn frame_bytes(len: usize) -> u64 {
    16 + 8 * len as u64
}
