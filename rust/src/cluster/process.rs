//! Multi-process cluster runtime: one coordinator + M−1 worker *processes*
//! running Algorithm 1 over the TCP transport — the operational shape of the
//! paper's real d-GLMNET deployment (one JVM/MPI process per node), replacing
//! the single-process thread simulation.
//!
//! Protocol (all over the worker's single listen socket):
//!
//! 1. **Control**: the coordinator dials each worker in rank order and sends
//!    one newline-terminated JSON [`JobSpec`] — rank assignment, the full
//!    cluster address list, dataset recipe, and solver hyper-parameters.
//!    The worker acks with `{"ok":true,"rank":r}`. Dialing in rank order
//!    guarantees the control connection is the first thing each worker's
//!    listener sees (mesh dials from rank j to rank i < j can only start
//!    after j received its spec, which the coordinator sent after dialing i).
//! 2. **Mesh**: every process forms the [`TcpTransport`] full mesh through
//!    the same listener (handshake-checked rank/size/protocol-version).
//! 3. **Train**: each process materializes the *identical* dataset from the
//!    spec's deterministic recipe, shards its own feature block S^m, and
//!    runs the SPMD worker. Training traffic is the AllReduce plus, under
//!    ALB (`alb_kappa`), the per-iteration pass-done quorum frames — the
//!    asynchronous path needs no barrier, so it runs across real processes.
//! 4. **Gather**: workers send β^m to rank 0 on a reserved tag; the
//!    coordinator reassembles the global model. Each worker finally reports
//!    its transport accounting plus its pass/cut-off/sync-wait load on the
//!    control connection, so the coordinator's Table-2 numbers cover all
//!    links and stay meaningful for asynchronous runs.
//!
//! Protocol v3 adds a second job mode: `mode: "path"` sweeps the spec's
//! `lambda_grid` descending inside ONE mesh session (warm starts + KKT
//! screening, validation-auPRC selection — see `run_worker_path`), and the
//! gather step becomes one β frame per grid point on the same reserved tag
//! (FIFO per (peer, tag) keeps grid order). Path jobs are BSP-only.
//!
//! Protocol v4 adds per-rank `threads`: rank r splits its feature block
//! into `threads[r]` sub-blocks run by an intra-rank pool (hybrid
//! parallelism, DESIGN.md §Hybrid parallelism); the done report gains the
//! effective thread count and the per-thread update accounting.
//!
//! Protocol v5 adds observability (DESIGN.md §Observability): the train
//! done report carries each rank's span journal (`spans`, compact
//! `[iter, phase, t, dur, bytes, depth]` rows — the per-iteration phase
//! timings behind `dglmnet trace-report`) and its per-phase transport
//! breakdown (`comm_by_phase`), and an idle worker's control port answers
//! a `{"op":"stats"}` line with a metrics-registry snapshot instead of
//! treating it as a garbage job spec.
//!
//! Protocol v6 makes peer death survivable (DESIGN.md §Failure model): the
//! transport returns typed [`TransportError`]s instead of panicking, the
//! spec gains `checkpoint_dir`/`checkpoint_every` (rank 0 persists
//! deterministic per-iteration checkpoints) and `resume` (rank 0 ships each
//! rank its slice of the latest complete checkpoint on [`RESUME_TAG`] right
//! after mesh formation), an idle worker's control port answers
//! `{"op":"ping"}` liveness probes, and the coordinator reacts to a lost
//! rank by re-shipping a resume job — re-sharding the feature blocks of any
//! rank that never rejoins across the survivors.
//!
//! Protocol v7 makes ingestion out-of-core (DESIGN.md §Shard format): a
//! `dataset` recipe of `shards:<dir>` points every rank at a binary shard
//! directory written by `dglmnet convert`. Each rank then reads *only its
//! own feature-block file plus the shared labels* — no rank parses the text
//! or materializes the full p-column matrix — and the global
//! [`FeaturePartition`] comes from the shard header instead of being
//! re-derived, so the cluster size must equal the directory's block count.
//! The train done report gains `loaded_cols`/`loaded_bytes` so the
//! coordinator can account per-rank ingestion. Shard datasets pin the
//! partition to the block files: exclusion-style recovery (re-sharding
//! across survivors) is rejected for them, while full-cluster resume works
//! unchanged. Path jobs stay text-only.
//!
//! Protocol v8 threads the partition-strategy seam (DESIGN.md
//! §Partitioning) through the wire: the spec gains an optional `partition`
//! field naming a [`PartitionStrategy`] (`hashed|contiguous|nnz|cluster`).
//! Absent means hashed for text datasets and header-pinned for shard
//! datasets; an explicit strategy that contradicts a shard header is
//! rejected with a pointed error instead of silently re-deriving. Every
//! rank resolves the partition through `PartitionStrategy::resolve` — one
//! call site per run mode — and the train done report gains a `cut`
//! cross-block co-occurrence fraction so the coordinator's per-rank table
//! can show how much coupling the layout left across blocks.
//!
//! Datasets are recipes, not payloads: synthetic corpora are deterministic
//! in `(name, scale, seed)`, and libsvm paths must be readable by every
//! process. Engine is native-only here (the XLA runtime is per-process and
//! orthogonal to the transport). Straggler chaos ships in the spec:
//! per-rank `straggler_delays` (injected per-pass sleeps) and
//! `slow_factors` (virtual-clock handicaps), each rank picking its own
//! entry; `dglmnet worker` can additionally override both locally.

use crate::cluster::alb::AlbMode;
use crate::cluster::allreduce::AllReduceAlgo;
use crate::cluster::checkpoint::{Checkpoint, ResumePoint, RESUME_TAG};
use crate::cluster::tcp::{dial_with_backoff, TcpOptions, TcpTransport, PROTOCOL_VERSION};
use crate::cluster::transport::{Transport, TransportError};
use crate::coordinator::driver::{ClusterFitResult, ClusterPathResult, RankLoad};
use crate::coordinator::worker::{
    run_worker, run_worker_path, PathJob, PathWorkerOutput, WorkerConfig, WorkerOutput,
    WorkerShared,
};
use crate::data::Splits;
use crate::glm::loss::LossKind;
use crate::glm::regularizer::ElasticNet;
use crate::obs::span::SpanRecord;
use crate::solver::compute::NativeCompute;
use crate::solver::linesearch::LineSearchConfig;
use crate::solver::path::PathResult;
use crate::sparse::{Csc, FeaturePartition, PartitionStrategy};
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::time::{Duration, Instant};

/// Reserved tag for the final β^m gather — far above anything the worker's
/// `TAG_STRIDE` allocator can reach within a run. Path jobs send their
/// per-λ blocks as consecutive frames on this same tag (the transport is
/// FIFO per (peer, tag), so λ order is preserved on the wire).
pub const GATHER_TAG: u64 = u64::MAX - 8;

/// Upper bound on λ-grid length a path job accepts — bounds the gather
/// traffic and catches garbage specs early.
pub const MAX_PATH_POINTS: usize = 128;

/// Upper bound on a per-rank intra-rank CD thread count — the protocol v4
/// contract shared by the job-spec validator and every CLI spelling
/// (`train/path --threads`, `worker --threads`).
pub const MAX_THREADS_PER_RANK: usize = 1024;

/// Shared range check for one per-rank thread count.
pub fn thread_count_in_range(t: usize) -> bool {
    (1..=MAX_THREADS_PER_RANK).contains(&t)
}

/// Hard ceiling on one injected straggler delay, in seconds. Keeps specs
/// honest AND keeps `Duration::from_secs_f64` away from its panic domain
/// (it panics on huge finite inputs, not just NaN/negative).
pub const MAX_STRAGGLER_DELAY_SECS: f64 = 3_600.0;

/// Upper bound on `checkpoint_every` — catches garbage specs early.
pub const MAX_CHECKPOINT_EVERY: usize = 1 << 30;

/// How many times the coordinator re-ships a resume job after losing a
/// peer mid-training before giving up.
pub const MAX_RECOVERY_ATTEMPTS: usize = 2;

/// Saturating seconds→`Duration` for chaos delays. Every spec built
/// in-process (CLI flags, tests) bypasses `from_json` validation, and
/// `Duration::from_secs_f64` panics on NaN, negative, or huge finite
/// input — this is the single conversion point all of them go through.
pub fn bounded_delay(secs: f64) -> Duration {
    if secs.is_finite() && secs > 0.0 {
        Duration::from_secs_f64(secs.min(MAX_STRAGGLER_DELAY_SECS))
    } else {
        Duration::ZERO
    }
}

/// How long the coordinator's recovery sweep waits for workers to answer a
/// rejoin probe. Overridable via `DGLMNET_REJOIN_WINDOW_SECS` (tests and
/// impatient operators), clamped to [0, `MAX_STRAGGLER_DELAY_SECS`].
pub fn rejoin_window() -> Duration {
    std::env::var("DGLMNET_REJOIN_WINDOW_SECS")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .map(bounded_delay)
        .unwrap_or(Duration::from_secs(10))
}

/// What a job spec asks the cluster to do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobMode {
    /// One fit at the spec's (l1, l2) — the PR 2/3 behaviour.
    Train,
    /// Sweep `lambda_grid` descending with warm starts + KKT screening and
    /// gather one β per grid point (§8.2 hyper-parameter search).
    Path,
}

impl JobMode {
    pub fn name(&self) -> &'static str {
        match self {
            JobMode::Train => "train",
            JobMode::Path => "path",
        }
    }

    pub fn parse(s: &str) -> Option<JobMode> {
        match s {
            "train" => Some(JobMode::Train),
            "path" => Some(JobMode::Path),
            _ => None,
        }
    }
}

/// Mesh-formation budget for process clusters. Deliberately much larger
/// than `TcpOptions::default()`: between the job ack and the first mesh
/// dial every process materializes its dataset from the recipe, and a big
/// libsvm load must not trip the accept/handshake deadline.
fn mesh_options() -> TcpOptions {
    TcpOptions {
        connect_timeout: Duration::from_secs(600),
        ..TcpOptions::default()
    }
}

/// One training job, as shipped to every rank.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// This process's rank (0 = coordinator).
    pub rank: usize,
    /// Listen addresses of all ranks, index = rank.
    pub cluster: Vec<String>,
    /// Dataset recipe: corpus name or libsvm path (see `harness::load_splits`).
    pub dataset: String,
    pub scale: f64,
    pub seed: u64,
    pub loss: String,
    pub l1: f64,
    pub l2: f64,
    pub max_iters: usize,
    pub mu0: f64,
    pub adaptive_mu: bool,
    pub tol: f64,
    pub patience: usize,
    /// Test-metric cadence (0 = never; avoids shipping test margins).
    pub eval_every: usize,
    pub allreduce: AllReduceAlgo,
    /// ALB quorum fraction κ; None = synchronous BSP.
    pub alb_kappa: Option<f64>,
    /// Fast-node extra passes cap under ALB.
    pub max_passes: usize,
    /// Quorum poll granularity in coordinates.
    pub chunk: usize,
    /// Injected per-pass delay in seconds, one entry per rank (missing
    /// entries mean zero) — the deterministic straggler schedule.
    pub straggler_delays: Vec<f64>,
    /// Virtual cluster clock: trace timestamps become max-over-ranks CPU
    /// time (× slow factors) plus modeled wire time. Without it the
    /// `slow_factors` have nothing to scale.
    pub virtual_time: bool,
    /// Per-rank virtual-clock compute handicaps (missing entries mean 1.0).
    pub slow_factors: Vec<f64>,
    /// What to run (protocol v3): a single fit or a λ-path sweep.
    pub mode: JobMode,
    /// The λ1 grid for `mode == Path` (descending for warm starts); `l1` is
    /// ignored in path mode, `l2` stays the fixed ridge term.
    pub lambda_grid: Vec<f64>,
    /// KKT strong-rule screening switch for path jobs.
    pub screen: bool,
    /// Intra-rank CD threads, one entry per rank (protocol v4; missing
    /// entries mean 1 = classic single-threaded). Rank r splits its block
    /// into `threads[r]` sub-blocks run as pool waves.
    pub threads: Vec<usize>,
    /// Protocol v6: where rank 0 persists per-iteration checkpoints (see
    /// `cluster::checkpoint`). Only rank 0 touches the path; it still ships
    /// to every rank so a promoted survivor knows where to look.
    pub checkpoint_dir: Option<String>,
    /// Protocol v6: checkpoint every k-th outer iteration (0 = off). Gates
    /// a collective gather, so it must be SPMD-identical — it ships in the
    /// spec and never via local overrides.
    pub checkpoint_every: usize,
    /// Protocol v6: this job continues from the latest complete checkpoint.
    /// Rank 0 ships each rank its resume slice on [`RESUME_TAG`] right
    /// after mesh formation; every worker blocks on its own before
    /// training.
    pub resume: bool,
    /// Protocol v8: how features map to ranks. `None` keeps the historical
    /// behavior — hashed for text datasets, header-pinned for shard
    /// datasets. `Some(s)` resolves `s` on every rank; on a shards dataset
    /// it must name the header's own strategy (the block files ARE the
    /// partition) or ingestion fails with a pointed error.
    pub partition: Option<PartitionStrategy>,
    /// Protocol v9: run the reordered-accumulation fast-math kernels
    /// (`kernels::KernelMode::FastMath`) instead of the bit-reproducible
    /// strict default. Every rank pins its process-global kernel mode from
    /// this flag before solving; a worker whose operator pinned the other
    /// mode (`worker --fast-math on|off`) rejects the job outright — mixed
    /// modes across ranks would break the deterministic-reduction story.
    pub fast_math: bool,
}

impl JobSpec {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("proto", PROTOCOL_VERSION as u64)
            .set("rank", self.rank)
            .set(
                "cluster",
                Json::Arr(self.cluster.iter().map(|a| Json::Str(a.clone())).collect()),
            )
            .set("dataset", self.dataset.as_str())
            .set("scale", self.scale)
            // As a string: JSON numbers are f64 here, and a seed above 2^53
            // would silently round — a worker would then build a different
            // dataset than the coordinator.
            .set("seed", self.seed.to_string())
            .set("loss", self.loss.as_str())
            .set("l1", self.l1)
            .set("l2", self.l2)
            .set("max_iters", self.max_iters)
            .set("mu0", self.mu0)
            .set("adaptive_mu", self.adaptive_mu)
            .set("tol", self.tol)
            .set("patience", self.patience)
            .set("eval_every", self.eval_every)
            .set("allreduce", self.allreduce.name())
            .set("max_passes", self.max_passes)
            .set("chunk", self.chunk)
            .set("virtual_time", self.virtual_time)
            .set(
                "straggler_delays",
                Json::Arr(self.straggler_delays.iter().map(|&d| Json::Num(d)).collect()),
            )
            .set(
                "slow_factors",
                Json::Arr(self.slow_factors.iter().map(|&f| Json::Num(f)).collect()),
            )
            .set("mode", self.mode.name())
            .set(
                "lambda_grid",
                Json::Arr(self.lambda_grid.iter().map(|&l| Json::Num(l)).collect()),
            )
            .set("screen", self.screen)
            .set(
                "threads",
                Json::Arr(self.threads.iter().map(|&t| Json::Num(t as f64)).collect()),
            )
            .set("checkpoint_every", self.checkpoint_every)
            .set("resume", self.resume)
            .set("fast_math", self.fast_math);
        if let Some(kappa) = self.alb_kappa {
            o.set("alb_kappa", kappa);
        }
        if let Some(dir) = &self.checkpoint_dir {
            o.set("checkpoint_dir", dir.as_str());
        }
        if let Some(strat) = self.partition {
            o.set("partition", strat.name());
        }
        o
    }

    pub fn from_json(text: &str) -> Result<JobSpec, String> {
        let v = json::parse(text.trim())?;
        let num = |k: &str| -> Result<f64, String> {
            v.get(k)
                .and_then(|j| j.as_f64())
                .ok_or_else(|| format!("job spec missing numeric '{k}'"))
        };
        let s = |k: &str| -> Result<String, String> {
            v.get(k)
                .and_then(|j| j.as_str())
                .map(str::to_string)
                .ok_or_else(|| format!("job spec missing string '{k}'"))
        };
        let num_list = |k: &str| -> Result<Vec<f64>, String> {
            match v.get(k) {
                Some(Json::Arr(xs)) => xs
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .ok_or_else(|| format!("non-numeric entry in '{k}'"))
                    })
                    .collect(),
                _ => Err(format!("job spec missing list '{k}'")),
            }
        };
        let proto = num("proto")? as u32;
        if proto != PROTOCOL_VERSION {
            return Err(format!(
                "job spec protocol version {proto} != {PROTOCOL_VERSION}"
            ));
        }
        let cluster = match v.get("cluster") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| "non-string cluster entry".to_string())
                })
                .collect::<Result<Vec<_>, _>>()?,
            _ => return Err("job spec missing 'cluster' list".into()),
        };
        if cluster.is_empty() {
            return Err("job spec has an empty cluster".into());
        }
        let adaptive_mu = matches!(v.get("adaptive_mu"), Some(Json::Bool(true)));
        let allreduce_name = s("allreduce")?;
        let allreduce = AllReduceAlgo::parse(&allreduce_name)
            .ok_or_else(|| format!("unknown allreduce algo '{allreduce_name}'"))?;
        let seed_str = s("seed")?;
        let seed: u64 = seed_str
            .parse()
            .map_err(|e| format!("bad seed '{seed_str}': {e}"))?;
        let alb_kappa = match v.get("alb_kappa") {
            None => None,
            Some(j) => {
                let kappa = j
                    .as_f64()
                    .ok_or_else(|| "non-numeric 'alb_kappa'".to_string())?;
                if !(kappa > 0.0 && kappa <= 1.0) {
                    return Err(format!("alb_kappa {kappa} outside (0, 1]"));
                }
                Some(kappa)
            }
        };
        let straggler_delays = num_list("straggler_delays")?;
        if straggler_delays
            .iter()
            .any(|d| !d.is_finite() || *d < 0.0 || *d > MAX_STRAGGLER_DELAY_SECS)
        {
            return Err(format!(
                "straggler_delays must be finite, non-negative, and at most \
                 {MAX_STRAGGLER_DELAY_SECS}s"
            ));
        }
        let slow_factors = num_list("slow_factors")?;
        if slow_factors.iter().any(|f| !f.is_finite() || *f <= 0.0) {
            return Err("slow_factors must be finite and positive".into());
        }
        let mode_name = s("mode")?;
        let mode = JobMode::parse(&mode_name)
            .ok_or_else(|| format!("unknown job mode '{mode_name}'"))?;
        let lambda_grid = num_list("lambda_grid")?;
        if mode == JobMode::Path {
            if lambda_grid.is_empty() {
                return Err("path job with an empty lambda_grid".into());
            }
            if lambda_grid.len() > MAX_PATH_POINTS {
                return Err(format!(
                    "lambda_grid has {} points (max {MAX_PATH_POINTS})",
                    lambda_grid.len()
                ));
            }
            if lambda_grid.iter().any(|l| !l.is_finite() || *l <= 0.0) {
                return Err("lambda_grid entries must be finite and positive".into());
            }
            if v.get("alb_kappa").is_some() {
                return Err("path jobs are BSP-only (alb_kappa not allowed)".into());
            }
            // The sweep's short warm fits run no chaos injection either —
            // reject rather than silently ignore a straggler schedule.
            if !straggler_delays.is_empty() || !slow_factors.is_empty() {
                return Err(
                    "path jobs do not support straggler_delays/slow_factors".into(),
                );
            }
            if matches!(v.get("virtual_time"), Some(Json::Bool(true))) {
                return Err("path jobs do not support virtual_time".into());
            }
            // Protocol v7: out-of-core ingestion is train-mode only.
            if crate::data::shards::shard_recipe(&s("dataset")?).is_some() {
                return Err(
                    "path jobs do not support shards:<dir> datasets (train-mode only)".into(),
                );
            }
        }
        let threads_raw = num_list("threads")?;
        let mut threads = Vec::with_capacity(threads_raw.len());
        for t in threads_raw {
            // `as usize` after the fract/finite check saturates negatives
            // to 0 and huge values to usize::MAX — both out of range.
            if !t.is_finite() || t.fract() != 0.0 || !thread_count_in_range(t as usize) {
                return Err(format!(
                    "threads entry {t} must be an integer in [1, {MAX_THREADS_PER_RANK}]"
                ));
            }
            threads.push(t as usize);
        }
        // The virtual clock charges per-thread CPU time of the rank's main
        // thread; hybrid pool compute is not charged yet — reject rather
        // than silently under-count.
        if matches!(v.get("virtual_time"), Some(Json::Bool(true)))
            && threads.iter().any(|&t| t > 1)
        {
            return Err("virtual_time does not support hybrid threads (> 1)".into());
        }
        let checkpoint_dir = match v.get("checkpoint_dir") {
            None => None,
            Some(j) => Some(
                j.as_str()
                    .ok_or_else(|| "non-string 'checkpoint_dir'".to_string())?
                    .to_string(),
            ),
        };
        let ck_every = num("checkpoint_every")?;
        if !ck_every.is_finite()
            || ck_every < 0.0
            || ck_every.fract() != 0.0
            || ck_every > MAX_CHECKPOINT_EVERY as f64
        {
            return Err(format!(
                "checkpoint_every {ck_every} must be an integer in [0, {MAX_CHECKPOINT_EVERY}]"
            ));
        }
        let checkpoint_every = ck_every as usize;
        let resume = matches!(v.get("resume"), Some(Json::Bool(true)));
        // Protocol v8: optional partition strategy; an unknown name is a
        // spec error, not a silent hashed fallback.
        let partition = match v.get("partition") {
            None => None,
            Some(j) => {
                let name = j
                    .as_str()
                    .ok_or_else(|| "non-string 'partition'".to_string())?;
                Some(PartitionStrategy::parse(name).ok_or_else(|| {
                    format!("unknown partition strategy '{name}' (hashed | contiguous | nnz | cluster)")
                })?)
            }
        };
        if mode == JobMode::Path && (checkpoint_every > 0 || checkpoint_dir.is_some() || resume)
        {
            return Err("path jobs do not support checkpoint/resume".into());
        }
        let spec = JobSpec {
            rank: num("rank")? as usize,
            cluster,
            dataset: s("dataset")?,
            scale: num("scale")?,
            seed,
            loss: s("loss")?,
            l1: num("l1")?,
            l2: num("l2")?,
            max_iters: num("max_iters")? as usize,
            mu0: num("mu0")?,
            adaptive_mu,
            tol: num("tol")?,
            patience: num("patience")? as usize,
            eval_every: num("eval_every")? as usize,
            allreduce,
            alb_kappa,
            max_passes: num("max_passes")? as usize,
            chunk: num("chunk")? as usize,
            virtual_time: matches!(v.get("virtual_time"), Some(Json::Bool(true))),
            straggler_delays,
            slow_factors,
            mode,
            lambda_grid,
            screen: matches!(v.get("screen"), Some(Json::Bool(true))),
            threads,
            checkpoint_dir,
            checkpoint_every,
            resume,
            partition,
            fast_math: matches!(v.get("fast_math"), Some(Json::Bool(true))),
        };
        if spec.rank >= spec.cluster.len() {
            return Err(format!(
                "rank {} out of range for cluster of {}",
                spec.rank,
                spec.cluster.len()
            ));
        }
        Ok(spec)
    }

    /// This rank's worker config: shared hyper-parameters plus the rank's
    /// own entry of the chaos schedule.
    fn worker_config(&self) -> WorkerConfig {
        WorkerConfig {
            adaptive_mu: self.adaptive_mu,
            mu0: self.mu0,
            eta1: 2.0,
            eta2: 2.0,
            nu: 1e-6,
            max_iters: self.max_iters,
            tol: self.tol,
            patience: self.patience,
            linesearch: LineSearchConfig::default(),
            eval_every: self.eval_every,
            allreduce: self.allreduce,
            max_passes: if self.alb_kappa.is_some() {
                self.max_passes.max(1)
            } else {
                1
            },
            chunk: self.chunk.max(1),
            threads: self.threads.get(self.rank).copied().unwrap_or(1).max(1),
            straggler_delay: bounded_delay(
                self.straggler_delays.get(self.rank).copied().unwrap_or(0.0),
            ),
            virtual_time: self.virtual_time,
            slow_factor: self.slow_factors.get(self.rank).copied().unwrap_or(1.0),
            network: crate::cluster::fabric::NetworkModel::default(),
            checkpoint_dir: self.checkpoint_dir.clone(),
            checkpoint_every: self.checkpoint_every,
            die_after_iters: None,
        }
    }
}

/// Local chaos knobs a `dglmnet worker` process can apply on top of the
/// coordinator's spec (its own rank only) — lets an operator handicap one
/// node without the coordinator's cooperation.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerOverrides {
    /// Replace this rank's spec slow factor.
    pub slow_factor: Option<f64>,
    /// Replace this rank's spec per-pass straggler delay.
    pub straggler_delay: Option<Duration>,
    /// Replace this rank's spec intra-rank CD thread count (hybrid mode) —
    /// lets an operator right-size one node to its core count without the
    /// coordinator's cooperation.
    pub threads: Option<usize>,
    /// Chaos injection: abort this rank's training loop right after the
    /// k-th outer iteration, simulating an abrupt crash (the transport is
    /// dropped, peers observe a hang-up). Drives the fault-tolerance tests
    /// without an external `kill`.
    pub die_after_iters: Option<usize>,
    /// Pin this worker's kernel mode (`worker --fast-math on|off`). Unlike
    /// the other overrides this never *changes* the job — the kernel mode
    /// is SPMD-critical, so a job spec that disagrees with the pin is
    /// rejected in the accept loop (`serve_one_job`) before the ack.
    /// `None` follows whatever the spec says.
    pub fast_math: Option<bool>,
}

impl WorkerOverrides {
    fn apply(&self, cfg: &mut WorkerConfig) {
        if let Some(f) = self.slow_factor {
            cfg.slow_factor = f;
        }
        if let Some(d) = self.straggler_delay {
            cfg.straggler_delay = d;
        }
        if let Some(t) = self.threads {
            cfg.threads = t.max(1);
        }
        if let Some(k) = self.die_after_iters {
            cfg.die_after_iters = Some(k);
        }
    }
}

/// Everything one rank produces: the worker output and the still-open mesh
/// (for the gather).
struct RankRun {
    output: WorkerOutput,
    transport: TcpTransport,
}

/// Everything one rank needs to train, however the dataset was ingested.
/// Text recipes materialize the full splits and slice out this rank's
/// block; `shards:<dir>` recipes (protocol v7) read only this rank's block
/// file plus the shared labels, so no rank ever holds the full p-column
/// matrix.
struct RankData {
    /// This rank's feature block of the train matrix, column-sharded.
    shard: Csc,
    /// Full train labels (shared by every rank).
    y: Vec<f64>,
    /// This rank's feature block of the test matrix, when `eval_every > 0`.
    test_shard: Option<Csc>,
    test_y: Option<Vec<f64>>,
    /// The global feature partition — identical on every rank.
    partition: FeaturePartition,
    /// Number of training rows.
    n: usize,
    /// Train-split display name (threaded into the trace).
    train_name: String,
    /// Ingestion accounting: columns this rank materialized...
    loaded_cols: usize,
    /// ...and the bytes it read (block + labels [+ test rows]) to do so.
    loaded_bytes: u64,
    /// Protocol v8: this rank's cross-block co-occurrence fraction (see
    /// `FeaturePartition::cut_fractions`); −1.0 = unknown (shard ranks
    /// never hold the full matrix the statistic needs).
    cut: f64,
}

/// Build one rank's training inputs from the spec's dataset recipe.
///
/// `shards:<dir>` (protocol v7): open the checksummed header, require the
/// directory's block count to match the cluster size, and read exactly this
/// rank's block file + the shared label shard (+ the test row shard when
/// the spec evaluates). The partition comes from the header, not from
/// re-hashing, so every rank agrees with the converter byte-for-byte; a
/// spec that names a *different* strategy is rejected (protocol v8) — the
/// block files ARE the partition.
///
/// Anything else: materialize the splits (or borrow `preloaded` when the
/// caller already did), resolve the spec's partition strategy (absent =
/// hashed) through the seam, and slice.
fn prepare_rank_data(spec: &JobSpec, preloaded: Option<&Splits>) -> anyhow::Result<RankData> {
    let m = spec.cluster.len();
    if let Some(dir) = crate::data::shards::shard_recipe(&spec.dataset) {
        let dir = Path::new(dir);
        let header = crate::data::shards::open_header(dir)?;
        if let Some(strat) = spec.partition {
            anyhow::ensure!(
                strat == header.kind,
                "job spec asks for --partition {} but shard directory {} was \
                 converted with --partition {} — a shards dataset pins the \
                 partition to its block files; drop the flag or re-run \
                 `dglmnet convert ... --partition {}`",
                strat.name(),
                dir.display(),
                header.kind.name(),
                strat.name(),
            );
        }
        anyhow::ensure!(
            header.num_blocks() == m,
            "shard directory {} holds {} feature blocks but the cluster has {m} ranks — \
             a shards dataset pins the partition to its block files; \
             re-run `dglmnet convert ... --blocks {m}`",
            dir.display(),
            header.num_blocks(),
        );
        let (shard, block_stats) = header.load_block(dir, spec.rank)?;
        let (y, label_stats) = header.load_labels(dir)?;
        let mut loaded_bytes = block_stats.bytes_read + label_stats.bytes_read;
        let (test_shard, test_y) = if spec.eval_every > 0 {
            let (test, stats) = header.load_rows(dir, "test")?;
            loaded_bytes += stats.bytes_read;
            let tx = test.to_csc();
            (Some(header.partition.shard(&tx, spec.rank)), Some(test.y))
        } else {
            (None, None)
        };
        let loaded_cols = shard.ncols;
        crate::obs_info!(
            "shards",
            format!(
                "rank {} loaded block {}/{m} from {}: {} of {} columns, {} bytes",
                spec.rank,
                spec.rank,
                dir.display(),
                loaded_cols,
                header.p,
                loaded_bytes,
            )
        );
        Ok(RankData {
            shard,
            y,
            test_shard,
            test_y,
            n: header.n,
            train_name: format!("{}-train", header.name),
            partition: header.partition,
            loaded_cols,
            loaded_bytes,
            // No rank holds the full matrix, so the cut is unobservable.
            cut: -1.0,
        })
    } else {
        let owned;
        let splits = match preloaded {
            Some(s) => s,
            None => {
                owned = crate::harness::load_splits(&spec.dataset, spec.scale, spec.seed)?;
                &owned
            }
        };
        let x_csc = splits.train.to_csc();
        // The single partition-resolution call site for a text-dataset
        // rank (protocol v8): absent `partition` means hashed, matching
        // every pre-v8 run bit-for-bit.
        let partition = spec
            .partition
            .unwrap_or_default()
            .resolve(&x_csc, m, spec.seed);
        // The text path materializes the whole matrix before slicing —
        // exactly the cost the shard format exists to avoid — so its
        // "bytes read" is the full CSC footprint.
        let loaded_bytes = x_csc.storage_bytes() as u64;
        let cut = partition.cut_fractions(&x_csc, spec.seed)[spec.rank];
        let shard = partition.shard(&x_csc, spec.rank);
        let (test_shard, test_y) = if spec.eval_every > 0 {
            let tx = splits.test.to_csc();
            (
                Some(partition.shard(&tx, spec.rank)),
                Some(splits.test.y.clone()),
            )
        } else {
            (None, None)
        };
        Ok(RankData {
            loaded_cols: shard.ncols,
            shard,
            y: splits.train.y.clone(),
            test_shard,
            test_y,
            n: splits.train.n(),
            train_name: splits.train.name.clone(),
            partition,
            loaded_bytes,
            cut,
        })
    }
}

/// Run the SPMD training loop over the mesh with this rank's prepared
/// block (see [`prepare_rank_data`]).
fn solve_rank(
    spec: &JobSpec,
    listener: &TcpListener,
    data: &RankData,
    overrides: &WorkerOverrides,
) -> anyhow::Result<RankRun> {
    let m = spec.cluster.len();
    let kind = LossKind::parse(&spec.loss)
        .ok_or_else(|| anyhow::anyhow!("unknown loss '{}'", spec.loss))?;
    let compute = NativeCompute::new(kind);
    let penalty = ElasticNet::new(spec.l1, spec.l2);
    // Protocol v9: pin the kernel mode before any solver code touches a
    // margin (mode-mismatched workers never reach this point — the accept
    // loop rejected the job).
    crate::kernels::set_fast_math(spec.fast_math);

    let mut transport =
        TcpTransport::with_listener(spec.rank, &spec.cluster, listener, mesh_options())?;
    let mut wcfg = spec.worker_config();
    overrides.apply(&mut wcfg);

    // Protocol v6 resume: right after mesh formation (before any training
    // collective), rank 0 reads the latest complete checkpoint and ships
    // each rank its slice; every other rank blocks on its own.
    let resume: Option<ResumePoint> = if spec.resume {
        Some(if spec.rank == 0 {
            let points = load_resume_points(spec, &data.partition)?;
            for (r, rp) in points.iter().enumerate().skip(1) {
                transport.send(r, RESUME_TAG, rp.flatten())?;
            }
            points.into_iter().next().expect("m >= 1 resume slices")
        } else {
            let payload = transport.recv_from(0, RESUME_TAG)?;
            ResumePoint::unflatten(&payload)
                .map_err(|e| anyhow::anyhow!("bad resume payload from rank 0: {e}"))?
        })
    } else {
        None
    };

    let shared = WorkerShared {
        compute: &compute,
        penalty: &penalty,
        y: &data.y,
        test_y: data.test_y.as_deref(),
        alb: spec.alb_kappa.map(|kappa| AlbMode::Transport { kappa }),
        cfg: &wcfg,
        nodes: m,
    };
    let output = run_worker(
        spec.rank,
        &data.shard,
        data.test_shard.as_ref(),
        &mut transport,
        &shared,
        resume.as_ref(),
    )?;
    Ok(RankRun { output, transport })
}

/// Rank 0's side of a resume: load the latest complete checkpoint and cut
/// it into one [`ResumePoint`] per current rank. When the cluster shape is
/// unchanged this restores every rank bit-identically (β blocks, margins,
/// μ, cursors). When ranks were lost, the full β is reassembled under the
/// checkpoint's partition and re-sharded across the survivors — margins
/// are global (Xβ with β unchanged), so the objective continues exactly;
/// only the cyclic cursors restart. Shard datasets (protocol v7) cannot
/// re-shard — their partition is pinned to the block files — so only the
/// same-shape path is allowed for them.
fn load_resume_points(
    spec: &JobSpec,
    partition: &FeaturePartition,
) -> anyhow::Result<Vec<ResumePoint>> {
    let m = spec.cluster.len();
    let p = partition.num_features();
    let dir = spec
        .checkpoint_dir
        .as_deref()
        .ok_or_else(|| anyhow::anyhow!("resume job without checkpoint_dir"))?;
    let (path, ck) = Checkpoint::latest(Path::new(dir))
        .ok_or_else(|| anyhow::anyhow!("no complete checkpoint under {dir}"))?;
    crate::obs_info!(
        "ckpt",
        format!(
            "resuming from {} (iteration {}, {} rank blocks, cluster of {m})",
            path.display(),
            ck.iter,
            ck.ranks.len()
        )
    );
    if ck.ranks.len() == m {
        return Ok((0..m).map(|r| ck.resume_point(r)).collect());
    }
    // Re-shard: the checkpoint was written by a different cluster shape.
    anyhow::ensure!(
        crate::data::shards::shard_recipe(&spec.dataset).is_none(),
        "checkpoint {} was written by a {}-rank cluster but this one has {m} ranks, \
         and a shards:<dir> dataset pins the feature partition to its block files — \
         re-run `dglmnet convert ... --blocks {}` or restore the full cluster",
        path.display(),
        ck.ranks.len(),
        ck.ranks.len(),
    );
    // Rebuild the partition the checkpoint was written under: the same
    // strategy the spec resolves, at the OLD cluster size. Data-dependent
    // strategies need the matrix back — a recovery-only cost the dimension
    // formulas avoid for hashed/contiguous.
    let strat = spec.partition.unwrap_or_default();
    let old = match strat.resolve_dims(p, ck.ranks.len(), spec.seed) {
        Some(fp) => fp,
        None => {
            let splits = crate::harness::load_splits(&spec.dataset, spec.scale, spec.seed)?;
            strat.resolve(&splits.train.to_csc(), ck.ranks.len(), spec.seed)
        }
    };
    anyhow::ensure!(
        old.blocks
            .iter()
            .zip(ck.ranks.iter())
            .all(|(b, rb)| b.len() == rb.beta.len()),
        "checkpoint {} does not match dataset width {p}",
        path.display()
    );
    let blocks: Vec<Vec<f64>> = ck.ranks.iter().map(|rb| rb.beta.clone()).collect();
    let full = old.unshard_weights(&blocks);
    Ok((0..m)
        .map(|r| ResumePoint {
            iter: ck.iter,
            stall: ck.stall,
            mu: ck.mu,
            f_cur: ck.f_cur,
            margins: ck.margins.clone(),
            cursor: 0,
            sub_cursors: Vec::new(),
            beta: partition.blocks[r].iter().map(|&j| full[j]).collect(),
        })
        .collect())
}

/// Everything one rank of a path job produces: the per-λ outputs, the
/// still-open mesh (for the per-λ gather), and the partition (for assembly).
struct PathRankRun {
    output: PathWorkerOutput,
    transport: TcpTransport,
    partition: FeaturePartition,
}

/// Shard this rank's feature block ONCE and sweep the spec's λ grid over
/// the mesh (see [`run_worker_path`]): validation comes from the recipe's
/// validation split, scored SPMD on every rank.
fn solve_rank_path(
    spec: &JobSpec,
    listener: &TcpListener,
    splits: &Splits,
    overrides: &WorkerOverrides,
) -> anyhow::Result<PathRankRun> {
    let m = spec.cluster.len();
    let kind = LossKind::parse(&spec.loss)
        .ok_or_else(|| anyhow::anyhow!("unknown loss '{}'", spec.loss))?;
    let compute = NativeCompute::new(kind);
    // Protocol v9: pin the kernel mode before the sweep (same contract as
    // solve_rank).
    crate::kernels::set_fast_math(spec.fast_math);

    let x_csc = splits.train.to_csc();
    // The single partition-resolution call site for a path-job rank
    // (protocol v8; path jobs are text-only, so no header to defer to).
    let partition = spec
        .partition
        .unwrap_or_default()
        .resolve(&x_csc, m, spec.seed);
    let shard = partition.shard(&x_csc, spec.rank);
    let val_csc = splits.validation.to_csc();
    let val_shard = partition.shard(&val_csc, spec.rank);

    let mut transport =
        TcpTransport::with_listener(spec.rank, &spec.cluster, listener, mesh_options())?;
    let mut wcfg = spec.worker_config();
    // Only the capacity override applies to path jobs (chaos injection is
    // rejected for them — see run_worker_process).
    if let Some(t) = overrides.threads {
        wcfg.threads = t.max(1);
    }
    let job = PathJob {
        lambdas: &spec.lambda_grid,
        l2: spec.l2,
        val_x: &val_shard,
        val_y: &splits.validation.y,
        screen: spec.screen,
    };
    let output = run_worker_path(
        spec.rank,
        &shard,
        &mut transport,
        &compute,
        &splits.train.y,
        &wcfg,
        &job,
    )?;
    Ok(PathRankRun {
        output,
        transport,
        partition,
    })
}

fn write_line(s: &mut TcpStream, j: &Json) -> std::io::Result<()> {
    s.write_all(j.dump().as_bytes())?;
    s.write_all(b"\n")?;
    s.flush()
}

/// Answer for an admin control frame on an idle worker's listen port
/// (protocol v5), or `None` if the line is not one. `{"op":"stats"}` gets
/// the process-wide metrics-registry snapshot — same payload as the serve
/// admin endpoint — so operators can poll workers between jobs.
fn control_reply(line: &str) -> Option<Json> {
    let v = json::parse(line.trim()).ok()?;
    match v.get("op").and_then(|j| j.as_str())? {
        "stats" => {
            let mut reply = Json::obj();
            reply
                .set("ok", true)
                .set("metrics", crate::obs::metrics::global().snapshot());
            Some(reply)
        }
        // Protocol v6: liveness probe — the coordinator's recovery sweep
        // uses it to tell a rejoined worker from a permanently lost rank.
        "ping" => {
            let mut reply = Json::obj();
            reply.set("ok", true).set("op", "ping");
            Some(reply)
        }
        op => {
            let mut reply = Json::obj();
            reply.set("ok", false).set("error", format!("unknown op '{op}'"));
            Some(reply)
        }
    }
}

/// Surface a setsockopt failure instead of swallowing it: a socket whose
/// reads cannot be bounded can wedge the owner on a half-dead peer, and
/// that is worth a log line even when training proceeds.
fn set_read_timeout_logged(s: &TcpStream, who: &str, dur: Option<Duration>) {
    if let Err(e) = s.set_read_timeout(dur) {
        crate::obs_warn!("net", format!("{who}: set_read_timeout({dur:?}) failed: {e}"));
    }
}

/// `dglmnet worker --listen ADDR`: serve one training job — or, with
/// `rejoin`, keep serving until a job completes cleanly — then exit.
/// Returns the last job's rank on success.
pub fn run_worker_process(
    listen: &str,
    overrides: WorkerOverrides,
    rejoin: bool,
) -> anyhow::Result<usize> {
    let listener = TcpListener::bind(listen)
        .map_err(|e| anyhow::anyhow!("bind {listen}: {e}"))?;
    if rejoin {
        run_worker_rejoin(listener, overrides)
    } else {
        run_worker_on(listener, overrides)
    }
}

/// The rejoin handshake (protocol v6): serve jobs on the same listener
/// until one completes cleanly. A job that dies of peer loss sends the
/// worker back to the accept loop — same address, same port — where it
/// answers the coordinator's `{"op":"ping"}` recovery probe and waits for
/// the re-shipped resume job instead of killing the process. Any error
/// that is NOT a typed transport error stays fatal (a broken dataset
/// recipe will not get better by retrying).
pub fn run_worker_rejoin(
    listener: TcpListener,
    overrides: WorkerOverrides,
) -> anyhow::Result<usize> {
    loop {
        match serve_one_job(&listener, &overrides) {
            Ok(rank) => return Ok(rank),
            Err(e) if e.downcast_ref::<TransportError>().is_some() => {
                crate::obs::metrics::global().counter("worker.rejoins").inc();
                crate::obs_warn!(
                    "worker",
                    format!("job failed ({e}); rejoining for a resume job")
                );
            }
            Err(e) => return Err(e),
        }
    }
}

/// Serve one job on an already-bound listener (lets tests and embedders
/// hold the port from the start instead of bind-drop-rebind racing).
pub fn run_worker_on(
    listener: TcpListener,
    overrides: WorkerOverrides,
) -> anyhow::Result<usize> {
    serve_one_job(&listener, &overrides)
}

fn serve_one_job(listener: &TcpListener, overrides: &WorkerOverrides) -> anyhow::Result<usize> {
    // Emitted (and flushed) before accepting so launchers can scrape the
    // resolved port when listening on :0 — this exact line is part of the
    // worker's stdout contract, so it bypasses the leveled logger.
    crate::obs::log::emit(&format!("worker: listening on {}", listener.local_addr()?));
    std::io::stdout().flush().ok();

    // Keep accepting until a valid job spec arrives: a stray connection
    // (port scanner, health checker) must neither wedge the worker (reads
    // are bounded — SO_RCVTIMEO is per socket, so setting it via the write
    // half covers the reader clone) nor kill it. A `{"op":"stats"}` line
    // (protocol v5) is answered with a metrics snapshot and the worker
    // keeps waiting for a job.
    let (spec, mut ctrl_w) = loop {
        let (ctrl, peer) = listener.accept()?;
        let mut ctrl_r = BufReader::new(ctrl.try_clone()?);
        let mut ctrl_w = ctrl;
        set_read_timeout_logged(&ctrl_w, "worker control", Some(Duration::from_secs(60)));
        let mut line = String::new();
        let parsed = ctrl_r
            .read_line(&mut line)
            .map_err(|e| format!("read failed: {e}"))
            .and_then(|_| JobSpec::from_json(&line));
        match parsed {
            Ok(spec) if spec.rank != 0 => {
                set_read_timeout_logged(&ctrl_w, "worker control", None);
                break (spec, ctrl_w);
            }
            Ok(_) => crate::obs_warn!(
                "worker",
                format!("ignoring job from {peer}: assigned coordinator rank 0")
            ),
            Err(e) => {
                if let Some(reply) = control_reply(&line) {
                    write_line(&mut ctrl_w, &reply).ok();
                } else {
                    crate::obs_warn!("worker", format!("ignoring connection from {peer}: {e}"));
                }
            }
        }
    };
    crate::obs::log::set_rank(spec.rank);
    // Protocol v9: an operator kernel-mode pin that disagrees with the spec
    // rejects the job BEFORE the ack — the mode is SPMD-critical, and a
    // rank running the other mode would silently break the cluster's
    // deterministic-reduction (strict) or tolerance-tier (fast-math) story.
    if let Some(pinned) = overrides.fast_math {
        if pinned != spec.fast_math {
            let tier = |on: bool| if on { "fast-math" } else { "strict" };
            let msg = format!(
                "worker is pinned to {} kernels (--fast-math {}) but the job spec says {}: \
                 re-ship the job with the matching --fast-math setting or restart the \
                 worker without the pin",
                tier(pinned),
                if pinned { "on" } else { "off" },
                tier(spec.fast_math),
            );
            let mut nack = Json::obj();
            nack.set("ok", false).set("rank", spec.rank).set("error", msg.as_str());
            write_line(&mut ctrl_w, &nack)?;
            anyhow::bail!("{msg}");
        }
    }
    crate::obs::metrics::global().counter("worker.jobs_accepted").inc();
    let mut ack = Json::obj();
    ack.set("ok", true).set("rank", spec.rank);
    write_line(&mut ctrl_w, &ack)?;
    crate::obs_info!(
        "worker",
        format!(
            "rank {}/{} | mode={} dataset={} scale={} loss={} λ1={} λ2={} alb={} kernels={}",
            spec.rank,
            spec.cluster.len(),
            spec.mode.name(),
            spec.dataset,
            spec.scale,
            spec.loss,
            spec.l1,
            spec.l2,
            spec.alb_kappa
                .map(|k| format!("κ={k}"))
                .unwrap_or_else(|| "off".into()),
            if spec.fast_math { "fast-math" } else { "strict" },
        )
    );

    match spec.mode {
        JobMode::Train => {
            // Protocol v7: ingestion happens per rank — a shards:<dir>
            // recipe reads only this rank's block file + the labels.
            let data = prepare_rank_data(&spec, None)?;
            let run = solve_rank(&spec, listener, &data, overrides)?;
            let mut transport = run.transport;
            transport.send(0, GATHER_TAG, run.output.beta_local.clone())?;
            // Report traffic AFTER the gather send so the coordinator's
            // totals really cover every frame this rank put on the wire.
            let (sent_bytes, sent_msgs) = transport.sent();

            let mut done = Json::obj();
            done.set("ok", true)
                .set("rank", spec.rank)
                .set("iters", run.output.iters)
                .set("sent_bytes", sent_bytes)
                .set("sent_msgs", sent_msgs)
                .set("cd_updates", run.output.cd_updates)
                .set("full_passes", run.output.full_passes)
                .set("cutoffs", run.output.cutoffs)
                .set("sync_wait_secs", run.output.sync_wait_secs)
                .set("threads", run.output.threads)
                // Protocol v7: per-rank ingestion accounting.
                .set("loaded_cols", data.loaded_cols)
                .set("loaded_bytes", data.loaded_bytes)
                // Protocol v8: cross-block co-occurrence (−1 = unknown).
                .set("cut", data.cut)
                .set(
                    "updates_per_thread",
                    Json::Arr(
                        run.output
                            .updates_per_thread
                            .iter()
                            .map(|&u| Json::Num(u as f64))
                            .collect(),
                    ),
                )
                // Protocol v5: the span journal (rank implied by sender) and
                // the per-phase transport breakdown.
                .set(
                    "spans",
                    Json::Arr(run.output.spans.iter().map(SpanRecord::to_compact).collect()),
                )
                .set(
                    "comm_by_phase",
                    Json::Arr(
                        run.output
                            .comm_by_phase
                            .iter()
                            .map(|(p, b, m)| {
                                Json::Arr(vec![
                                    Json::from(p.as_str()),
                                    Json::from(*b),
                                    Json::from(*m),
                                ])
                            })
                            .collect(),
                    ),
                );
            write_line(&mut ctrl_w, &done)?;
            drop(transport); // joins the writer threads: the gather frame is flushed
            crate::obs_info!(
                "worker",
                format!("rank {} done after {} iterations", spec.rank, run.output.iters),
            );
        }
        JobMode::Path => {
            if overrides.slow_factor.is_some() || overrides.straggler_delay.is_some() {
                crate::obs_warn!(
                    "worker",
                    "--slow-factor/--straggler-delay-ms do not apply to \
                     path jobs (BSP sweep, no chaos injection) — ignoring"
                );
            }
            // Path jobs are text-only (from_json rejects shards:<dir>).
            let splits = crate::harness::load_splits(&spec.dataset, spec.scale, spec.seed)?;
            let run = solve_rank_path(&spec, listener, &splits, overrides)?;
            let mut transport = run.transport;
            // One frame per λ point, in grid order, all on the gather tag
            // (FIFO per (peer, tag) keeps them ordered on the wire).
            for pt in &run.output.points {
                transport.send(0, GATHER_TAG, pt.beta_local.clone())?;
            }
            let (sent_bytes, sent_msgs) = transport.sent();
            let total_iters: usize = run.output.points.iter().map(|p| p.iters).sum();

            let mut done = Json::obj();
            done.set("ok", true)
                .set("rank", spec.rank)
                .set("iters", total_iters)
                .set("sent_bytes", sent_bytes)
                .set("sent_msgs", sent_msgs)
                .set("cd_updates", run.output.cd_updates_local)
                .set("full_passes", 0usize)
                .set("cutoffs", 0usize)
                .set("sync_wait_secs", 0.0);
            write_line(&mut ctrl_w, &done)?;
            drop(transport);
            crate::obs_info!(
                "worker",
                format!(
                    "rank {} done after {} λ points ({} iterations)",
                    spec.rank,
                    run.output.points.len(),
                    total_iters
                ),
            );
        }
    }
    Ok(spec.rank)
}

/// Bind the coordinator's listener and ship the job to every worker in rank
/// order (the mesh-ordering invariant), returning the resolved cluster, the
/// listener, and the still-open control connections. Shared by the train
/// and path coordinators.
fn ship_job(
    spec0: &JobSpec,
) -> anyhow::Result<(Vec<String>, TcpListener, Vec<BufReader<TcpStream>>)> {
    let listener = TcpListener::bind(&spec0.cluster[0])
        .map_err(|e| anyhow::anyhow!("bind {}: {e}", spec0.cluster[0]))?;
    // Resolve :0 so workers can dial us back for the mesh.
    let mut cluster = spec0.cluster.clone();
    cluster[0] = listener.local_addr()?.to_string();
    let opts = TcpOptions::default();

    // Control phase — dial in rank order (the mesh-ordering invariant).
    let mut ctrls = Vec::new();
    for (r, addr) in cluster.iter().enumerate().skip(1) {
        let mut s = dial_with_backoff(addr, &opts)?;
        let spec_r = JobSpec {
            rank: r,
            cluster: cluster.clone(),
            ..spec0.clone()
        };
        write_line(&mut s, &spec_r.to_json())?;
        // Ack must arrive promptly; the later done-report read is unbounded
        // (training takes as long as it takes), so clear the timeout after.
        set_read_timeout_logged(&s, "coordinator control", Some(opts.connect_timeout));
        let mut br = BufReader::new(s);
        let mut ack = String::new();
        br.read_line(&mut ack)
            .map_err(|e| anyhow::anyhow!("worker {addr} sent no ack: {e}"))?;
        let ack = json::parse(ack.trim())
            .map_err(|e| anyhow::anyhow!("worker {addr} sent a bad ack: {e}"))?;
        anyhow::ensure!(
            matches!(ack.get("ok"), Some(Json::Bool(true)))
                && ack.get("rank").and_then(|j| j.as_f64()) == Some(r as f64),
            "worker {addr} rejected the job: {}",
            ack.dump()
        );
        set_read_timeout_logged(br.get_ref(), "coordinator control", None);
        ctrls.push(br);
    }
    Ok((cluster, listener, ctrls))
}

/// One worker's done report, summed into the coordinator's totals.
fn read_done_report(br: &mut BufReader<TcpStream>) -> anyhow::Result<Json> {
    let mut line = String::new();
    br.read_line(&mut line)?;
    json::parse(line.trim()).map_err(|e| anyhow::anyhow!("worker sent a bad done report: {e}"))
}

/// `dglmnet train --cluster A0,A1,...`: run as coordinator (rank 0, address
/// `A0`), ship the job to the workers listening at `A1..`, train as one of
/// the M nodes, and reassemble the global model. `preloaded` lets a caller
/// that already materialized the spec's dataset recipe (the CLI does, for
/// its banner and final test scoring) avoid a second full load.
///
/// Protocol v6: when the spec checkpoints (`checkpoint_dir` set and
/// `checkpoint_every > 0`), a run that dies of peer loss is retried from
/// the latest complete checkpoint: the coordinator probes every worker
/// address with `{"op":"ping"}` for the rejoin window, drops the ranks
/// that never answer, and re-ships a `resume` job to the survivors (the
/// feature blocks re-shard across them; see [`load_resume_points`]). Any
/// other error — and any failure once [`MAX_RECOVERY_ATTEMPTS`] is spent —
/// stays fatal.
pub fn train_cluster(
    spec0: &JobSpec,
    preloaded: Option<&Splits>,
) -> anyhow::Result<ClusterFitResult> {
    anyhow::ensure!(spec0.rank == 0, "coordinator must be rank 0");
    anyhow::ensure!(spec0.mode == JobMode::Train, "train_cluster needs a train-mode spec");
    // Protocol v7: a shards:<dir> recipe never materializes the full
    // splits — rank 0 loads only its own block inside prepare_rank_data.
    let owned_splits;
    let splits: Option<&Splits> = if crate::data::shards::shard_recipe(&spec0.dataset).is_some() {
        None
    } else {
        match preloaded {
            Some(s) => Some(s),
            None => {
                owned_splits =
                    crate::harness::load_splits(&spec0.dataset, spec0.scale, spec0.seed)?;
                Some(&owned_splits)
            }
        }
    };
    let mut spec = spec0.clone();
    let mut attempt = 0usize;
    loop {
        match train_cluster_once(&spec, splits) {
            Ok(res) => return Ok(res),
            Err(e) => {
                let peer_gone = e.downcast_ref::<TransportError>().is_some();
                let resumable = spec.checkpoint_every > 0 && spec.checkpoint_dir.is_some();
                if !peer_gone || !resumable || attempt >= MAX_RECOVERY_ATTEMPTS {
                    return Err(e);
                }
                attempt += 1;
                crate::obs::metrics::global().counter("cluster.recoveries").inc();
                crate::obs_warn!(
                    "cluster",
                    format!(
                        "rank failure ({e}); recovery attempt {attempt}/{MAX_RECOVERY_ATTEMPTS}"
                    )
                );
                spec = recover_spec(&spec)?;
            }
        }
    }
}

/// Probe every worker address of a failed job, keep the survivors, and
/// build the resume spec that re-ships to them. The coordinator itself
/// (rank 0) always survives; a cluster where every worker is gone shrinks
/// to a single-rank resume, which is still a valid mesh.
fn recover_spec(spec: &JobSpec) -> anyhow::Result<JobSpec> {
    // Probe in parallel: a permanently dead rank burns its whole rejoin
    // window, and sequential probes would stack those timeouts.
    let survivors: Vec<bool> = std::thread::scope(|scope| {
        let handles: Vec<_> = spec.cluster[1..]
            .iter()
            .map(|addr| scope.spawn(move || probe_worker(addr)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(false)).collect()
    });
    let mut keep = vec![0usize];
    let mut lost = Vec::new();
    for (i, up) in survivors.iter().enumerate() {
        if *up {
            keep.push(i + 1);
        } else {
            lost.push(i + 1);
        }
    }
    if lost.is_empty() {
        // Every worker answers the probe: the crashed rank came back on its
        // old address (`--rejoin`). The re-shipped job is not a retry of an
        // identical one — `resume` makes the cluster start from the latest
        // checkpoint — and MAX_RECOVERY_ATTEMPTS still bounds a rank that
        // keeps dying deterministically.
        crate::obs_warn!(
            "cluster",
            format!(
                "all {} workers answered the liveness probe; \
                 re-shipping a resume job to the full cluster",
                spec.cluster.len() - 1
            )
        );
        let mut next = spec.clone();
        next.resume = true;
        return Ok(next);
    }
    crate::obs_warn!(
        "cluster",
        format!(
            "excluding unresponsive ranks {lost:?}; resuming with {} of {} ranks",
            keep.len(),
            spec.cluster.len()
        )
    );
    let pick_or = |xs: &Vec<f64>, i: usize, default: f64| -> f64 {
        xs.get(i).copied().unwrap_or(default)
    };
    let mut next = spec.clone();
    next.cluster = keep.iter().map(|&i| spec.cluster[i].clone()).collect();
    if !spec.straggler_delays.is_empty() {
        next.straggler_delays =
            keep.iter().map(|&i| pick_or(&spec.straggler_delays, i, 0.0)).collect();
    }
    if !spec.slow_factors.is_empty() {
        next.slow_factors = keep.iter().map(|&i| pick_or(&spec.slow_factors, i, 1.0)).collect();
    }
    if !spec.threads.is_empty() {
        next.threads = keep.iter().map(|&i| spec.threads.get(i).copied().unwrap_or(1)).collect();
    }
    next.resume = true;
    Ok(next)
}

/// Liveness probe for one worker address: dial, send `{"op":"ping"}`, and
/// require an `ok` reply. Retries until the rejoin window closes so a
/// `--rejoin` worker that is still tearing down its dead job's sockets has
/// time to get back to its accept loop.
fn probe_worker(addr: &str) -> bool {
    let deadline = Instant::now() + rejoin_window();
    loop {
        if ping_once(addr) {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

fn ping_once(addr: &str) -> bool {
    let Some(target) = addr.to_socket_addrs().ok().and_then(|mut a| a.next()) else {
        return false;
    };
    let Ok(mut s) = TcpStream::connect_timeout(&target, Duration::from_millis(500)) else {
        return false;
    };
    set_read_timeout_logged(&s, "recovery probe", Some(Duration::from_secs(2)));
    let mut ping = Json::obj();
    ping.set("op", "ping");
    if write_line(&mut s, &ping).is_err() {
        return false;
    }
    let mut br = BufReader::new(s);
    let mut line = String::new();
    if br.read_line(&mut line).is_err() || line.trim().is_empty() {
        return false;
    }
    matches!(
        json::parse(line.trim()).ok().as_ref().and_then(|j| j.get("ok")),
        Some(Json::Bool(true))
    )
}

/// One attempt at the distributed fit — ship, train as rank 0, gather,
/// reassemble. Peer loss surfaces as a [`TransportError`] inside the
/// `anyhow` chain, which [`train_cluster`]'s recovery loop downcasts.
/// `splits` is `None` for shards datasets (no full materialization).
fn train_cluster_once(
    spec0: &JobSpec,
    splits: Option<&Splits>,
) -> anyhow::Result<ClusterFitResult> {
    let m = spec0.cluster.len();
    let (cluster, listener, mut ctrls) = ship_job(spec0)?;

    // Train as rank 0 of the mesh.
    let spec = JobSpec {
        rank: 0,
        cluster,
        ..spec0.clone()
    };
    let data = prepare_rank_data(&spec, splits)?;
    let run = solve_rank(&spec, &listener, &data, &WorkerOverrides::default())?;
    let mut transport = run.transport;

    // Gather β blocks.
    let mut blocks: Vec<Vec<f64>> = Vec::with_capacity(m);
    blocks.push(run.output.beta_local.clone());
    for r in 1..m {
        let block = transport.recv_from(r, GATHER_TAG)?;
        anyhow::ensure!(
            block.len() == data.partition.blocks[r].len(),
            "rank {r} gathered {} weights, expected {}",
            block.len(),
            data.partition.blocks[r].len()
        );
        blocks.push(block);
    }
    let beta = data.partition.unshard_weights(&blocks);

    // Collect accounting + per-rank load reports, and merge the v5 span
    // journals / per-phase comm breakdowns shipped in each done report.
    let mut comm_bytes = run.output.sent_bytes;
    let mut comm_msgs = run.output.sent_msgs;
    let mut barrier_wait_secs = run.output.sync_wait_secs;
    let mut rank0_load = RankLoad::from_output(&run.output);
    rank0_load.loaded_cols = data.loaded_cols;
    rank0_load.loaded_bytes = data.loaded_bytes;
    rank0_load.cut = data.cut;
    let mut per_rank: Vec<RankLoad> = vec![rank0_load];
    let mut spans: Vec<SpanRecord> = run.output.spans.clone();
    let mut phase_acc: std::collections::BTreeMap<String, (u64, u64)> = run
        .output
        .comm_by_phase
        .iter()
        .map(|(p, b, m)| (p.clone(), (*b, *m)))
        .collect();
    for br in ctrls.iter_mut() {
        let done = read_done_report(br)?;
        let field = |k: &str| done.get(k).and_then(|j| j.as_f64()).unwrap_or(0.0);
        let updates_per_thread: Vec<u64> = match done.get("updates_per_thread") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .filter_map(|x| x.as_f64())
                .map(|v| v as u64)
                .collect(),
            _ => Vec::new(),
        };
        comm_bytes += field("sent_bytes") as u64;
        comm_msgs += field("sent_msgs") as u64;
        barrier_wait_secs += field("sync_wait_secs");
        let worker_rank = field("rank") as usize;
        if let Some(Json::Arr(xs)) = done.get("spans") {
            spans.extend(xs.iter().filter_map(|v| SpanRecord::from_compact(worker_rank, v)));
        }
        if let Some(Json::Arr(xs)) = done.get("comm_by_phase") {
            for row in xs {
                if let Json::Arr(cols) = row {
                    if let (Some(p), Some(b), Some(m)) = (
                        cols.first().and_then(|c| c.as_str()),
                        cols.get(1).and_then(|c| c.as_f64()),
                        cols.get(2).and_then(|c| c.as_f64()),
                    ) {
                        let e = phase_acc.entry(p.to_string()).or_insert((0, 0));
                        e.0 += b as u64;
                        e.1 += m as u64;
                    }
                }
            }
        }
        per_rank.push(RankLoad {
            rank: field("rank") as usize,
            cd_updates: field("cd_updates") as u64,
            full_passes: field("full_passes") as u64,
            cutoffs: field("cutoffs") as u64,
            sent_bytes: field("sent_bytes") as u64,
            sent_msgs: field("sent_msgs") as u64,
            sync_wait_secs: field("sync_wait_secs"),
            threads: (field("threads") as usize).max(1),
            updates_per_thread,
            loaded_cols: field("loaded_cols") as usize,
            loaded_bytes: field("loaded_bytes") as u64,
            cut: done.get("cut").and_then(|j| j.as_f64()).unwrap_or(-1.0),
        });
    }
    per_rank.sort_by_key(|l| l.rank);
    drop(transport);

    let mut trace = run.output.trace.expect("rank 0 produces the trace");
    trace.dataset = data.train_name.clone();
    trace.comm_bytes = comm_bytes;
    let n = data.n;
    let max_block = data
        .partition
        .blocks
        .iter()
        .map(|b| b.len())
        .max()
        .unwrap_or(0);
    Ok(ClusterFitResult {
        objective: trace.final_objective(),
        iters: run.output.iters,
        beta,
        trace,
        comm_bytes,
        comm_msgs,
        sim_wire_secs: 0.0,
        barrier_wait_secs,
        peak_node_f64_slots: 4 * n + 2 * max_block,
        per_rank,
        spans,
        comm_by_phase: phase_acc.into_iter().map(|(p, (b, m))| (p, b, m)).collect(),
    })
}

/// `dglmnet path --cluster A0,A1,...`: the multi-process λ-path sweep. The
/// coordinator ships a v3 `path` job, sweeps the grid as rank 0 of the mesh
/// (warm starts + KKT screening, see [`run_worker_path`]), gathers every
/// rank's per-λ β blocks, and reassembles one full model per grid point;
/// the validation-best index was already derived SPMD on every rank.
pub fn path_cluster(
    spec0: &JobSpec,
    preloaded: Option<&Splits>,
) -> anyhow::Result<ClusterPathResult> {
    anyhow::ensure!(spec0.rank == 0, "coordinator must be rank 0");
    anyhow::ensure!(spec0.mode == JobMode::Path, "path_cluster needs a path-mode spec");
    anyhow::ensure!(!spec0.lambda_grid.is_empty(), "path job with an empty λ grid");
    anyhow::ensure!(
        spec0.lambda_grid.len() <= MAX_PATH_POINTS,
        "λ grid has {} points (max {MAX_PATH_POINTS})",
        spec0.lambda_grid.len()
    );
    anyhow::ensure!(spec0.alb_kappa.is_none(), "path jobs are BSP-only");
    anyhow::ensure!(
        spec0.straggler_delays.is_empty() && spec0.slow_factors.is_empty() && !spec0.virtual_time,
        "path jobs do not support straggler/slow-factor chaos or the virtual clock"
    );
    anyhow::ensure!(
        spec0.checkpoint_dir.is_none() && spec0.checkpoint_every == 0 && !spec0.resume,
        "path jobs do not support checkpoints or resume (protocol v6 is train-mode only)"
    );
    anyhow::ensure!(
        crate::data::shards::shard_recipe(&spec0.dataset).is_none(),
        "path jobs do not support shards:<dir> datasets (train-mode only)"
    );
    let owned_splits;
    let splits = match preloaded {
        Some(s) => s,
        None => {
            owned_splits =
                crate::harness::load_splits(&spec0.dataset, spec0.scale, spec0.seed)?;
            &owned_splits
        }
    };
    let m = spec0.cluster.len();
    let (cluster, listener, mut ctrls) = ship_job(spec0)?;

    // Sweep as rank 0 of the mesh.
    let spec = JobSpec {
        rank: 0,
        cluster,
        ..spec0.clone()
    };
    let run = solve_rank_path(&spec, &listener, splits, &WorkerOverrides::default())?;
    let mut transport = run.transport;

    // Gather per-λ β blocks: each worker sends one frame per grid point on
    // the gather tag, in grid order (FIFO per (peer, tag)).
    let k_pts = run.output.points.len();
    let mut per_lambda: Vec<Vec<Vec<f64>>> = (0..k_pts).map(|_| vec![Vec::new(); m]).collect();
    for (k, pt) in run.output.points.iter().enumerate() {
        per_lambda[k][0] = pt.beta_local.clone();
    }
    for r in 1..m {
        for point_blocks in per_lambda.iter_mut() {
            let block = transport.recv_from(r, GATHER_TAG)?;
            anyhow::ensure!(
                block.len() == run.partition.blocks[r].len(),
                "rank {r} gathered {} weights, expected {}",
                block.len(),
                run.partition.blocks[r].len()
            );
            point_blocks[r] = block;
        }
    }

    // Collect accounting from the done reports.
    let mut comm_bytes = run.output.sent_bytes;
    let mut comm_msgs = run.output.sent_msgs;
    for br in ctrls.iter_mut() {
        let done = read_done_report(br)?;
        let field = |k: &str| done.get(k).and_then(|j| j.as_f64()).unwrap_or(0.0);
        comm_bytes += field("sent_bytes") as u64;
        comm_msgs += field("sent_msgs") as u64;
    }
    drop(transport);

    let points = crate::coordinator::driver::assemble_path_points(
        &run.partition,
        &run.output.points,
        &per_lambda,
        spec.l2,
    );
    Ok(ClusterPathResult {
        path: PathResult {
            points,
            best: run.output.best,
        },
        comm_bytes,
        comm_msgs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec {
            rank: 0,
            cluster: vec!["127.0.0.1:0".into(), "127.0.0.1:7001".into()],
            dataset: "epsilon_like".into(),
            scale: 0.05,
            seed: 3,
            loss: "logistic".into(),
            l1: 0.5,
            l2: 0.1,
            max_iters: 7,
            mu0: 1.0,
            adaptive_mu: true,
            tol: 1e-7,
            patience: 2,
            eval_every: 0,
            allreduce: AllReduceAlgo::Ring,
            alb_kappa: None,
            max_passes: 4,
            chunk: 64,
            virtual_time: false,
            straggler_delays: Vec::new(),
            slow_factors: Vec::new(),
            mode: JobMode::Train,
            lambda_grid: Vec::new(),
            screen: false,
            threads: Vec::new(),
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
            partition: None,
            fast_math: false,
        }
    }

    fn path_spec() -> JobSpec {
        JobSpec {
            mode: JobMode::Path,
            lambda_grid: vec![2.0, 0.5, 0.125],
            screen: true,
            ..spec()
        }
    }

    #[test]
    fn job_spec_json_roundtrip() {
        let mut s = spec();
        s.alb_kappa = Some(0.75);
        s.max_passes = 3;
        s.chunk = 16;
        s.virtual_time = true;
        s.straggler_delays = vec![0.0, 0.04];
        s.slow_factors = vec![1.0, 2.5];
        s.threads = vec![1, 1];
        s.checkpoint_dir = Some("/tmp/ckpts".into());
        s.checkpoint_every = 2;
        s.resume = true;
        s.partition = Some(PartitionStrategy::Clustered);
        let text = s.to_json().dump();
        let back = JobSpec::from_json(&text).unwrap();
        assert_eq!(back.rank, s.rank);
        assert_eq!(back.cluster, s.cluster);
        assert_eq!(back.dataset, s.dataset);
        assert_eq!(back.scale, s.scale);
        assert_eq!(back.seed, s.seed);
        assert_eq!(back.loss, s.loss);
        assert_eq!(back.l1, s.l1);
        assert_eq!(back.l2, s.l2);
        assert_eq!(back.max_iters, s.max_iters);
        assert_eq!(back.adaptive_mu, s.adaptive_mu);
        assert_eq!(back.tol, s.tol);
        assert_eq!(back.patience, s.patience);
        assert_eq!(back.eval_every, s.eval_every);
        assert_eq!(back.allreduce, s.allreduce);
        assert_eq!(back.alb_kappa, s.alb_kappa);
        assert_eq!(back.max_passes, s.max_passes);
        assert_eq!(back.chunk, s.chunk);
        assert_eq!(back.virtual_time, s.virtual_time);
        assert_eq!(back.straggler_delays, s.straggler_delays);
        assert_eq!(back.slow_factors, s.slow_factors);
        assert_eq!(back.mode, s.mode);
        assert_eq!(back.lambda_grid, s.lambda_grid);
        assert_eq!(back.screen, s.screen);
        assert_eq!(back.threads, s.threads);
        assert_eq!(back.checkpoint_dir, s.checkpoint_dir);
        assert_eq!(back.checkpoint_every, s.checkpoint_every);
        assert_eq!(back.resume, s.resume);
        assert_eq!(back.partition, s.partition);
        assert_eq!(back.fast_math, s.fast_math);
    }

    #[test]
    fn job_spec_fast_math_roundtrips() {
        // Protocol v9: the kernel-mode pin survives the wire in both states
        // (false must ship explicitly, not rely on field absence — a v9
        // coordinator always says what mode it wants).
        for on in [false, true] {
            let mut s = spec();
            s.fast_math = on;
            let text = s.to_json().dump();
            assert!(text.contains("fast_math"), "fast_math missing from {text}");
            assert_eq!(JobSpec::from_json(&text).unwrap().fast_math, on);
        }
    }

    #[test]
    fn job_spec_partition_roundtrips_and_validates() {
        // Absent stays absent (pre-v8 behavior: hashed for text datasets).
        let s = spec();
        let text = s.to_json().dump();
        assert!(!text.contains("partition"));
        assert_eq!(JobSpec::from_json(&text).unwrap().partition, None);
        // Every named strategy survives the wire.
        for strat in PartitionStrategy::ALL {
            let mut s = spec();
            s.partition = Some(strat);
            let back = JobSpec::from_json(&s.to_json().dump()).unwrap();
            assert_eq!(back.partition, Some(strat));
        }
        // Unknown names and non-strings are spec errors, never a silent
        // hashed fallback.
        let mut j = spec().to_json();
        j.set("partition", "metis");
        let err = JobSpec::from_json(&j.dump()).unwrap_err();
        assert!(err.contains("partition strategy"), "unhelpful error: {err}");
        let mut j = spec().to_json();
        j.set("partition", 2u64);
        assert!(JobSpec::from_json(&j.dump()).is_err());
    }

    #[test]
    fn job_spec_threads_roundtrip_and_validation() {
        // Per-rank thread list survives the wire.
        let mut s = spec();
        s.threads = vec![4, 2];
        let back = JobSpec::from_json(&s.to_json().dump()).unwrap();
        assert_eq!(back.threads, vec![4, 2]);
        // Zero, fractional, and absurd counts are rejected.
        for bad in [0.0, 2.5, -1.0, 4096.0] {
            let mut j = spec().to_json();
            j.set("threads", Json::Arr(vec![Json::Num(bad)]));
            assert!(
                JobSpec::from_json(&j.dump()).is_err(),
                "threads entry {bad} must be rejected"
            );
        }
        // The virtual clock cannot charge hybrid pool compute yet.
        let mut s = spec();
        s.virtual_time = true;
        s.threads = vec![1, 4];
        assert!(JobSpec::from_json(&s.to_json().dump()).is_err());
        // Path jobs may use hybrid threads.
        let mut s = path_spec();
        s.threads = vec![2, 2];
        let back = JobSpec::from_json(&s.to_json().dump()).unwrap();
        assert_eq!(back.threads, vec![2, 2]);
    }

    #[test]
    fn path_job_spec_roundtrips() {
        let s = path_spec();
        let back = JobSpec::from_json(&s.to_json().dump()).unwrap();
        assert_eq!(back.mode, JobMode::Path);
        assert_eq!(back.lambda_grid, s.lambda_grid);
        assert!(back.screen);
    }

    #[test]
    fn path_job_spec_validation() {
        // Empty grid.
        let mut j = path_spec().to_json();
        j.set("lambda_grid", Json::Arr(Vec::new()));
        assert!(JobSpec::from_json(&j.dump()).is_err());
        // Non-positive λ.
        let mut j = path_spec().to_json();
        j.set("lambda_grid", Json::Arr(vec![Json::Num(0.5), Json::Num(0.0)]));
        assert!(JobSpec::from_json(&j.dump()).is_err());
        // ALB on a path job.
        let mut j = path_spec().to_json();
        j.set("alb_kappa", 0.75);
        assert!(JobSpec::from_json(&j.dump()).is_err());
        // Chaos fields on a path job: rejected, never silently ignored.
        let mut j = path_spec().to_json();
        j.set("straggler_delays", Json::Arr(vec![Json::Num(0.04)]));
        assert!(JobSpec::from_json(&j.dump()).is_err());
        let mut j = path_spec().to_json();
        j.set("slow_factors", Json::Arr(vec![Json::Num(2.0)]));
        assert!(JobSpec::from_json(&j.dump()).is_err());
        let mut j = path_spec().to_json();
        j.set("virtual_time", true);
        assert!(JobSpec::from_json(&j.dump()).is_err());
        // Unknown mode.
        let mut j = spec().to_json();
        j.set("mode", "wander");
        assert!(JobSpec::from_json(&j.dump()).is_err());
        // Oversized grid.
        let mut j = path_spec().to_json();
        j.set(
            "lambda_grid",
            Json::Arr((0..=MAX_PATH_POINTS).map(|k| Json::Num(1.0 + k as f64)).collect()),
        );
        assert!(JobSpec::from_json(&j.dump()).is_err());
        // A train job carries the grid fields inertly.
        let mut j = spec().to_json();
        j.set("lambda_grid", Json::Arr(Vec::new()));
        assert!(JobSpec::from_json(&j.dump()).is_ok());
    }

    #[test]
    fn path_job_spec_rejects_shard_datasets() {
        // Protocol v7: out-of-core ingestion is train-mode only; a worker
        // must reject a path job naming a shard directory at the wire, not
        // fail later inside load_splits.
        let mut j = path_spec().to_json();
        j.set("dataset", "shards:/tmp/never-read");
        let err = JobSpec::from_json(&j.dump()).unwrap_err();
        assert!(err.contains("shards"), "unhelpful error: {err}");
        // The same recipe on a train job parses fine (nothing is read yet).
        let mut j = spec().to_json();
        j.set("dataset", "shards:/tmp/never-read");
        assert!(JobSpec::from_json(&j.dump()).is_ok());
    }

    #[test]
    fn job_spec_bsp_roundtrips_without_alb_kappa() {
        let s = spec();
        let text = s.to_json().dump();
        assert!(!text.contains("alb_kappa"));
        let back = JobSpec::from_json(&text).unwrap();
        assert_eq!(back.alb_kappa, None);
    }

    #[test]
    fn job_spec_rejects_protocol_mismatch() {
        let mut j = spec().to_json();
        j.set("proto", 999u64);
        assert!(JobSpec::from_json(&j.dump()).is_err());
    }

    #[test]
    fn job_spec_rejects_out_of_range_rank() {
        let mut j = spec().to_json();
        j.set("rank", 5usize);
        assert!(JobSpec::from_json(&j.dump()).is_err());
    }

    #[test]
    fn job_spec_rejects_bad_chaos_values() {
        let mut j = spec().to_json();
        j.set("alb_kappa", 1.5);
        assert!(JobSpec::from_json(&j.dump()).is_err());
        let mut j = spec().to_json();
        j.set("straggler_delays", Json::Arr(vec![Json::Num(-0.5)]));
        assert!(JobSpec::from_json(&j.dump()).is_err());
        let mut j = spec().to_json();
        j.set("slow_factors", Json::Arr(vec![Json::Num(0.0)]));
        assert!(JobSpec::from_json(&j.dump()).is_err());
        // Protocol v6: delays past the Duration-overflow guard are rejected
        // at the wire, not clamped deep inside `Duration::from_secs_f64`.
        for bad in [f64::NAN, f64::INFINITY, MAX_STRAGGLER_DELAY_SECS + 1.0, 1e300] {
            let mut j = spec().to_json();
            j.set("straggler_delays", Json::Arr(vec![Json::Num(bad)]));
            assert!(
                JobSpec::from_json(&j.dump()).is_err(),
                "straggler delay {bad} must be rejected"
            );
        }
    }

    #[test]
    fn job_spec_rejects_bad_checkpoint_values() {
        for bad in [-1.0, 2.5, f64::NAN, f64::INFINITY, (MAX_CHECKPOINT_EVERY as f64) * 2.0] {
            let mut j = spec().to_json();
            j.set("checkpoint_every", bad);
            assert!(
                JobSpec::from_json(&j.dump()).is_err(),
                "checkpoint_every {bad} must be rejected"
            );
        }
        let mut j = spec().to_json();
        j.set("checkpoint_dir", 7u64);
        assert!(JobSpec::from_json(&j.dump()).is_err(), "non-string checkpoint_dir");
        // Path jobs never checkpoint or resume.
        let mut j = path_spec().to_json();
        j.set("checkpoint_every", 1u64);
        assert!(JobSpec::from_json(&j.dump()).is_err());
        let mut j = path_spec().to_json();
        j.set("checkpoint_dir", "/tmp/ckpts");
        assert!(JobSpec::from_json(&j.dump()).is_err());
        let mut j = path_spec().to_json();
        j.set("resume", true);
        assert!(JobSpec::from_json(&j.dump()).is_err());
    }

    #[test]
    fn bounded_delay_saturates_the_panic_domain() {
        assert_eq!(bounded_delay(0.5), Duration::from_millis(500));
        assert_eq!(bounded_delay(0.0), Duration::ZERO);
        assert_eq!(bounded_delay(-3.0), Duration::ZERO);
        assert_eq!(bounded_delay(f64::NAN), Duration::ZERO);
        assert_eq!(bounded_delay(f64::INFINITY), Duration::from_secs(3600));
        assert_eq!(bounded_delay(1e300), Duration::from_secs(3600));
    }

    #[test]
    fn worker_config_picks_this_ranks_chaos_entries() {
        let mut s = spec();
        s.rank = 1;
        s.alb_kappa = Some(0.75);
        s.virtual_time = true;
        s.straggler_delays = vec![0.0, 0.03];
        s.slow_factors = vec![1.0, 4.0];
        s.threads = vec![1, 8];
        let cfg = s.worker_config();
        assert_eq!(cfg.straggler_delay, Duration::from_millis(30));
        assert_eq!(cfg.slow_factor, 4.0);
        assert!(cfg.virtual_time, "virtual clock must reach the worker");
        assert_eq!(cfg.threads, 8, "rank 1 picks its own threads entry");
        assert_eq!(cfg.max_passes, 4);
        // BSP forces a single pass regardless of max_passes.
        s.alb_kappa = None;
        assert_eq!(s.worker_config().max_passes, 1);
    }

    #[test]
    fn worker_overrides_replace_spec_chaos() {
        let mut cfg = spec().worker_config();
        let ov = WorkerOverrides {
            slow_factor: Some(2.0),
            straggler_delay: Some(Duration::from_millis(5)),
            threads: Some(4),
            die_after_iters: Some(3),
        };
        ov.apply(&mut cfg);
        assert_eq!(cfg.slow_factor, 2.0);
        assert_eq!(cfg.straggler_delay, Duration::from_millis(5));
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.die_after_iters, Some(3));
        WorkerOverrides::default().apply(&mut cfg);
        assert_eq!(cfg.slow_factor, 2.0, "empty overrides change nothing");
        assert_eq!(cfg.threads, 4, "empty overrides change nothing");
        assert_eq!(cfg.die_after_iters, Some(3), "empty overrides change nothing");
    }

    /// Full in-test cluster: 1 coordinator + 2 workers as threads of this
    /// process, each running the real process entry points over loopback.
    #[test]
    fn coordinator_and_workers_complete_a_job() {
        use std::net::TcpListener;
        // Workers hold their ephemeral ports from the start — no
        // bind-drop-rebind race against concurrently running tests.
        let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let w2 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a1 = w1.local_addr().unwrap().to_string();
        let a2 = w2.local_addr().unwrap().to_string();
        let mut s = spec();
        s.cluster = vec!["127.0.0.1:0".into(), a1, a2];

        let h1 =
            std::thread::spawn(move || run_worker_on(w1, WorkerOverrides::default()).unwrap());
        let h2 =
            std::thread::spawn(move || run_worker_on(w2, WorkerOverrides::default()).unwrap());
        let fit = train_cluster(&s, None).unwrap();
        assert_eq!(h1.join().unwrap(), 1);
        assert_eq!(h2.join().unwrap(), 2);

        assert!(fit.objective.is_finite());
        assert!(fit.comm_bytes > 0, "three ranks must have talked");
        assert_eq!(fit.per_rank.len(), 3);
        for (r, load) in fit.per_rank.iter().enumerate() {
            assert_eq!(load.rank, r);
            assert_eq!(load.full_passes, fit.iters as u64, "BSP: 1 pass/iter");
            assert_eq!(load.cutoffs, 0);
        }

        // Protocol v5: every rank's done report shipped a span journal that
        // covers every (iteration, phase) pair at depth 0.
        for r in 0..3usize {
            for it in 1..=fit.iters as u64 {
                for ph in crate::obs::runlog::PHASES {
                    assert!(
                        fit.spans.iter().any(|sp| sp.rank == r
                            && sp.iter == it
                            && sp.phase == ph
                            && sp.depth == 0),
                        "rank {r} iter {it}: missing '{ph}' span in the merged journal"
                    );
                }
            }
        }
        // The per-phase comm rows cover the training traffic; only the
        // final β gather frames (sent after the worker loop returns) ride
        // outside the attribution.
        let phase_bytes: u64 = fit.comm_by_phase.iter().map(|(_, b, _)| b).sum();
        assert!(phase_bytes > 0, "no bytes attributed to phases");
        assert!(
            phase_bytes <= fit.comm_bytes,
            "phase bytes {phase_bytes} exceed total {}",
            fit.comm_bytes
        );

        // Oracle: identical math to the single-process reference.
        let splits = crate::harness::load_splits("epsilon_like", 0.05, 3).unwrap();
        assert_eq!(fit.beta.len(), splits.train.p());
        let seq = crate::solver::dglmnet::fit(
            &splits.train,
            &NativeCompute::new(LossKind::Logistic),
            &ElasticNet::new(0.5, 0.1),
            &crate::solver::dglmnet::DGlmnetConfig {
                nodes: 3,
                max_iters: 7,
                tol: 1e-7,
                patience: 2,
                seed: 3,
                eval_every: 0,
                ..Default::default()
            },
            None,
        );
        assert!(
            (fit.objective - seq.objective).abs() / seq.objective.abs() < 1e-6,
            "cluster {} vs reference {}",
            fit.objective,
            seq.objective
        );

        // Protocol v7 ingestion accounting on the text path: every rank
        // sharded the full materialized matrix, so it reports its hashed
        // block width and a non-zero byte count.
        let part = FeaturePartition::hashed(splits.train.p(), 3, 3);
        for (r, load) in fit.per_rank.iter().enumerate() {
            assert_eq!(load.loaded_cols, part.blocks[r].len(), "rank {r} loaded_cols");
            assert!(load.loaded_bytes > 0, "rank {r} loaded_bytes");
            // Protocol v8: the text path observes a real cut fraction on
            // every rank (shards ranks would report the −1 sentinel).
            assert!(
                (0.0..=1.0).contains(&load.cut),
                "rank {r} cut {} outside [0, 1]",
                load.cut
            );
        }
    }

    /// An idle worker's control port answers a `{"op":"stats"}` probe
    /// (protocol v5) with a metrics snapshot, rejects unknown ops, and
    /// still serves the real job shipped afterwards.
    #[test]
    fn idle_worker_answers_stats_probe_then_serves_the_job() {
        use std::net::{TcpListener, TcpStream};
        let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a1 = w1.local_addr().unwrap().to_string();
        let mut s = spec();
        s.cluster = vec!["127.0.0.1:0".into(), a1.clone()];
        s.max_iters = 2;

        let h =
            std::thread::spawn(move || run_worker_on(w1, WorkerOverrides::default()).unwrap());

        // Probe stats before any job exists.
        let probe = |body: &str| -> Json {
            let mut conn = TcpStream::connect(&a1).unwrap();
            conn.write_all(body.as_bytes()).unwrap();
            conn.write_all(b"\n").unwrap();
            let mut br = BufReader::new(conn);
            let mut line = String::new();
            br.read_line(&mut line).unwrap();
            json::parse(line.trim()).unwrap()
        };
        let v = probe("{\"op\":\"stats\"}");
        assert!(matches!(v.get("ok"), Some(Json::Bool(true))), "{}", v.dump());
        assert!(
            v.get("metrics").and_then(|m| m.get("counters")).is_some(),
            "stats reply must carry a registry snapshot: {}",
            v.dump()
        );
        let v = probe("{\"op\":\"wander\"}");
        assert!(matches!(v.get("ok"), Some(Json::Bool(false))), "{}", v.dump());

        // The worker is still idle and healthy: ship it a real job.
        let fit = train_cluster(&s, None).unwrap();
        assert_eq!(h.join().unwrap(), 1);
        assert!(fit.objective.is_finite());
    }

    /// The same in-test cluster under ALB with an injected straggler: the
    /// per-rank load report must show the slow rank doing less CD work.
    #[test]
    fn alb_cluster_job_reports_straggler_load() {
        use std::net::TcpListener;
        let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let w2 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a1 = w1.local_addr().unwrap().to_string();
        let a2 = w2.local_addr().unwrap().to_string();
        let mut s = spec();
        s.cluster = vec!["127.0.0.1:0".into(), a1, a2];
        s.alb_kappa = Some(0.5); // M=3 → threshold ⌈1.5⌉ = 2: fast ranks decide
        s.chunk = 4;
        s.max_iters = 6;
        s.tol = 0.0;
        s.straggler_delays = vec![0.0, 0.03, 0.0]; // rank 1 sleeps per pass

        let h1 =
            std::thread::spawn(move || run_worker_on(w1, WorkerOverrides::default()).unwrap());
        let h2 =
            std::thread::spawn(move || run_worker_on(w2, WorkerOverrides::default()).unwrap());
        let fit = train_cluster(&s, None).unwrap();
        h1.join().unwrap();
        h2.join().unwrap();

        assert!(fit.objective.is_finite());
        assert_eq!(fit.per_rank.len(), 3);
        let straggler = &fit.per_rank[1];
        let fast_min = fit.per_rank[0].cd_updates.min(fit.per_rank[2].cd_updates);
        assert!(
            straggler.cd_updates < fast_min,
            "straggler did {} updates vs fastest {} — ALB did not cut it off",
            straggler.cd_updates,
            fast_min
        );
        assert!(straggler.cutoffs > 0, "straggler never reported a cut-off");
    }

    /// The same in-test cluster in hybrid mode: every rank splits its block
    /// across an intra-rank pool, and the per-rank load report must carry
    /// the thread count plus per-thread update accounting.
    #[test]
    fn hybrid_cluster_job_reports_per_thread_load() {
        use std::net::TcpListener;
        let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let w2 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a1 = w1.local_addr().unwrap().to_string();
        let a2 = w2.local_addr().unwrap().to_string();
        let mut s = spec();
        s.cluster = vec!["127.0.0.1:0".into(), a1, a2];
        s.threads = vec![2, 2, 2];
        s.max_iters = 80;
        s.tol = 1e-10;
        s.patience = 3;

        let h1 =
            std::thread::spawn(move || run_worker_on(w1, WorkerOverrides::default()).unwrap());
        let h2 =
            std::thread::spawn(move || run_worker_on(w2, WorkerOverrides::default()).unwrap());
        let fit = train_cluster(&s, None).unwrap();
        h1.join().unwrap();
        h2.join().unwrap();

        assert!(fit.objective.is_finite());
        assert_eq!(fit.per_rank.len(), 3);
        for load in &fit.per_rank {
            assert_eq!(load.threads, 2, "rank {} thread count", load.rank);
            assert_eq!(load.updates_per_thread.len(), 2, "rank {}", load.rank);
            assert_eq!(
                load.updates_per_thread.iter().sum::<u64>(),
                load.cd_updates,
                "rank {}: per-thread accounting must total the rank's updates",
                load.rank
            );
        }
        // Quality: the unique optimum does not depend on the block count —
        // the hybrid run (3 ranks × 2 sub-blocks) must land within 1e-3 of
        // the high-precision single-process reference at convergence.
        let splits = crate::harness::load_splits("epsilon_like", 0.05, 3).unwrap();
        let f_star = crate::solver::dglmnet::fit(
            &splits.train,
            &NativeCompute::new(LossKind::Logistic),
            &ElasticNet::new(0.5, 0.1),
            &crate::solver::dglmnet::DGlmnetConfig {
                nodes: 1,
                max_iters: 400,
                tol: 1e-13,
                patience: 5,
                seed: 3,
                eval_every: 0,
                ..Default::default()
            },
            None,
        )
        .objective;
        let gap = (fit.objective - f_star) / f_star.abs().max(1e-12);
        assert!(
            gap < 1e-3 && gap > -1e-6,
            "hybrid cluster objective {} vs reference optimum {f_star} (gap {gap:.3e})",
            fit.objective
        );
    }

    /// Full in-test path cluster: 1 coordinator + 2 workers as threads of
    /// this process running the real entry points, checked against the
    /// single-process `l1_path` sweep (same recipe, same partition seed).
    #[test]
    fn coordinator_and_workers_complete_a_path_job() {
        use std::net::TcpListener;
        let w1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let w2 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a1 = w1.local_addr().unwrap().to_string();
        let a2 = w2.local_addr().unwrap().to_string();
        let mut s = path_spec();
        s.cluster = vec!["127.0.0.1:0".into(), a1, a2];
        s.max_iters = 40;
        // Naive allreduce accumulates rank blocks in the same order as the
        // sequential reference — keeps the iterates bit-aligned.
        s.allreduce = AllReduceAlgo::Naive;

        let h1 =
            std::thread::spawn(move || run_worker_on(w1, WorkerOverrides::default()).unwrap());
        let h2 =
            std::thread::spawn(move || run_worker_on(w2, WorkerOverrides::default()).unwrap());
        let res = path_cluster(&s, None).unwrap();
        assert_eq!(h1.join().unwrap(), 1);
        assert_eq!(h2.join().unwrap(), 2);

        assert_eq!(res.path.points.len(), 3);
        assert!(res.comm_bytes > 0, "three ranks must have talked");

        let splits = crate::harness::load_splits("epsilon_like", 0.05, 3).unwrap();
        let reference = crate::solver::path::l1_path(
            &splits,
            &NativeCompute::new(LossKind::Logistic),
            &s.lambda_grid,
            s.l2,
            &crate::solver::dglmnet::DGlmnetConfig {
                nodes: 3,
                max_iters: 40,
                tol: s.tol,
                patience: s.patience,
                seed: 3,
                eval_every: 0,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(res.path.best, reference.best, "best λ index drifted");
        for (got, want) in res.path.points.iter().zip(reference.points.iter()) {
            assert_eq!(got.lambda1, want.lambda1);
            let gap = (got.objective - want.objective).abs()
                / want.objective.abs().max(1e-12);
            assert!(
                gap < 1e-6,
                "λ1={}: cluster {} vs reference {} (gap {gap:.3e})",
                got.lambda1,
                got.objective,
                want.objective
            );
            assert_eq!(got.beta.len(), want.beta.len());
            let dn = got.nnz as i64 - want.nnz as i64;
            assert!(dn.abs() <= 2, "λ1={}: nnz {} vs {}", got.lambda1, got.nnz, want.nnz);
        }
    }
}
