//! Simulated cluster substrate: message fabric with byte accounting and a
//! network cost model, AllReduce collectives (naive + ring), a reusable
//! instrumented barrier, and the ALB slow-node controller. This is the
//! stand-in for the paper's 16-node MPI cluster — see DESIGN.md
//! §Substitutions for why the replacement preserves algorithm behaviour.

pub mod alb;
pub mod allreduce;
pub mod barrier;
pub mod fabric;

pub use alb::AlbController;
pub use allreduce::{allreduce_scalar, allreduce_sum, AllReduceAlgo, TAG_STRIDE};
pub use barrier::Barrier;
pub use fabric::{fabric, Endpoint, FabricStats, NetworkModel};
