//! Cluster substrate behind the [`Transport`] seam: the in-process message
//! fabric (byte accounting + network cost model) and the real-socket TCP
//! mesh both implement the same trait, so collectives (naive + ring
//! AllReduce), barriers, the ALB slow-node controller, and the coordinator
//! run unchanged over simulated threads or separate OS processes. See
//! DESIGN.md §Transport for the seam's accounting guarantees.

pub mod alb;
pub mod allreduce;
pub mod barrier;
pub mod checkpoint;
pub mod fabric;
pub mod process;
pub mod tcp;
pub mod transport;

pub use alb::{
    drain_retired_tag, quorum_threshold, AlbController, AlbMode, AlbQuorum, RemoteQuorum,
};
pub use allreduce::{allreduce_scalar, allreduce_sum, AllReduceAlgo, TAG_STRIDE};
pub use barrier::transport_barrier;
pub use checkpoint::{Checkpoint, ResumePoint};
pub use fabric::{fabric, Endpoint, FabricStats, NetworkModel};
pub use tcp::{bind_loopback, TcpOptions, TcpTransport};
pub use transport::{frame_bytes, Transport, TransportError};
