//! AllReduce (sum) collectives over the message fabric — the paper's
//! `MPI_AllReduce` (Algorithm 4 step 6, the only communication d-GLMNET
//! needs: Mn doubles per iteration).
//!
//! Two algorithms, both byte-accounted by the fabric:
//! * `naive`  — gather to rank 0, sum, broadcast. 2(M−1) messages of n
//!              doubles: simple, low-latency for small vectors (the scalar
//!              regularizer sums).
//! * `ring`   — reduce-scatter + allgather, 2(M−1) steps of n/M doubles per
//!              node: bandwidth-optimal for the big XΔβ vectors.
//!
//! Tags: each collective call consumes a caller-provided base tag; callers
//! must use distinct bases per logical collective (the coordinator derives
//! them from the iteration counter).

use crate::cluster::transport::{Transport, TransportError};

/// Which collective algorithm to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllReduceAlgo {
    Naive,
    Ring,
}

impl AllReduceAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            AllReduceAlgo::Naive => "naive",
            AllReduceAlgo::Ring => "ring",
        }
    }

    pub fn parse(s: &str) -> Option<AllReduceAlgo> {
        match s {
            "naive" => Some(AllReduceAlgo::Naive),
            "ring" => Some(AllReduceAlgo::Ring),
            _ => None,
        }
    }
}

/// In-place allreduce-sum of `data` across all endpoints (SPMD: every rank
/// calls this with its local contribution; all ranks return the global sum).
/// Errors with the transport's typed error if a peer dies mid-collective —
/// `data` is then left partially reduced and must not be used.
pub fn allreduce_sum(
    t: &mut dyn Transport,
    tag_base: u64,
    data: &mut [f64],
    algo: AllReduceAlgo,
) -> Result<(), TransportError> {
    match algo {
        AllReduceAlgo::Naive => naive(t, tag_base, data),
        AllReduceAlgo::Ring => ring(t, tag_base, data),
    }
}

/// Convenience: allreduce a single scalar.
///
/// Deliberately takes no `algo`: a 1-element reduction is below ring's
/// chunking threshold on every cluster size, so the result (and the wire
/// traffic) must be identical no matter which algorithm a caller would have
/// picked. Routing through [`allreduce_sum`] rather than a private helper
/// keeps that contract pinned to the public entry point — the
/// `scalar_matches_one_element_vector_under_both_algos` regression test
/// checks it against both algorithms.
pub fn allreduce_scalar(
    t: &mut dyn Transport,
    tag_base: u64,
    x: f64,
) -> Result<f64, TransportError> {
    let mut v = [x];
    allreduce_sum(t, tag_base, &mut v, AllReduceAlgo::Naive)?;
    Ok(v[0])
}

/// AllReduce with max instead of sum (used for the virtual cluster clock:
/// the slowest node's compute time bounds the iteration).
pub fn allreduce_max(t: &mut dyn Transport, tag_base: u64, x: f64) -> Result<f64, TransportError> {
    let m = t.size();
    if m == 1 {
        return Ok(x);
    }
    if t.rank() == 0 {
        let mut best = x;
        for from in 1..m {
            let part = t.recv_from(from, tag_base)?;
            best = best.max(part[0]);
        }
        for to in 1..m {
            t.send(to, tag_base + 1, vec![best])?;
        }
        Ok(best)
    } else {
        t.send(0, tag_base, vec![x])?;
        Ok(t.recv_from(0, tag_base + 1)?[0])
    }
}

fn naive(t: &mut dyn Transport, tag_base: u64, data: &mut [f64]) -> Result<(), TransportError> {
    let m = t.size();
    if m == 1 {
        return Ok(());
    }
    if t.rank() == 0 {
        for from in 1..m {
            let part = t.recv_from(from, tag_base)?;
            debug_assert_eq!(part.len(), data.len());
            for (d, p) in data.iter_mut().zip(part.iter()) {
                *d += p;
            }
        }
        for to in 1..m {
            t.send(to, tag_base + 1, data.to_vec())?;
        }
    } else {
        t.send(0, tag_base, data.to_vec())?;
        let total = t.recv_from(0, tag_base + 1)?;
        data.copy_from_slice(&total);
    }
    Ok(())
}

/// Ring allreduce: reduce-scatter then allgather. Chunk c ends up fully
/// reduced at rank (c + 1) mod M after M−1 reduce steps, then circulates.
fn ring(t: &mut dyn Transport, tag_base: u64, data: &mut [f64]) -> Result<(), TransportError> {
    let m = t.size();
    if m == 1 {
        return Ok(());
    }
    let n = data.len();
    if n < m {
        // Degenerate chunking — fall back to naive.
        return naive(t, tag_base, data);
    }
    let rank = t.rank();
    let next = (rank + 1) % m;
    let prev = (rank + m - 1) % m;
    let bounds = |c: usize| -> (usize, usize) {
        let lo = c * n / m;
        let hi = (c + 1) * n / m;
        (lo, hi)
    };
    // Reduce-scatter: at step s, send chunk (rank - s) mod m, receive and
    // accumulate chunk (rank - s - 1) mod m.
    for s in 0..m - 1 {
        let send_c = (rank + m - s) % m;
        let recv_c = (rank + m - s - 1) % m;
        let (slo, shi) = bounds(send_c);
        t.send(next, tag_base + s as u64, data[slo..shi].to_vec())?;
        let part = t.recv_from(prev, tag_base + s as u64)?;
        let (rlo, rhi) = bounds(recv_c);
        debug_assert_eq!(part.len(), rhi - rlo);
        for (d, p) in data[rlo..rhi].iter_mut().zip(part.iter()) {
            *d += p;
        }
    }
    // Allgather: rank now owns the fully-reduced chunk (rank + 1) mod m.
    for s in 0..m - 1 {
        let send_c = (rank + 1 + m - s) % m;
        let recv_c = (rank + m - s) % m;
        let (slo, shi) = bounds(send_c);
        t.send(next, tag_base + (m + s) as u64, data[slo..shi].to_vec())?;
        let part = t.recv_from(prev, tag_base + (m + s) as u64)?;
        let (rlo, rhi) = bounds(recv_c);
        data[rlo..rhi].copy_from_slice(&part);
    }
    Ok(())
}

/// Number of distinct tags one allreduce call may consume — callers space
/// their tag bases by at least this.
pub const TAG_STRIDE: u64 = 4096;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fabric::{fabric, NetworkModel};
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crossbeam_utils::thread;

    fn run_allreduce(m: usize, n: usize, algo: AllReduceAlgo, seed: u64) {
        let (eps, _stats) = fabric(m, NetworkModel::default());
        // Build per-rank inputs and the expected sum.
        let mut rng = Rng::new(seed);
        let inputs: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let mut want = vec![0.0; n];
        for inp in &inputs {
            for (w, v) in want.iter_mut().zip(inp.iter()) {
                *w += v;
            }
        }
        thread::scope(|s| {
            for (ep, inp) in eps.into_iter().zip(inputs.clone()) {
                let want = want.clone();
                s.spawn(move |_| {
                    let mut ep = ep;
                    let mut data = inp;
                    allreduce_sum(&mut ep, 1000, &mut data, algo).unwrap();
                    prop::all_close(&data, &want, 1e-12)
                        .unwrap_or_else(|e| panic!("rank {}: {e}", ep.rank));
                });
            }
        })
        .unwrap();
    }

    #[test]
    fn naive_matches_serial_sum() {
        for m in [1, 2, 3, 8] {
            run_allreduce(m, 17, AllReduceAlgo::Naive, m as u64);
        }
    }

    #[test]
    fn ring_matches_serial_sum() {
        for m in [1, 2, 3, 5, 8] {
            run_allreduce(m, 40, AllReduceAlgo::Ring, 100 + m as u64);
        }
    }

    #[test]
    fn ring_handles_non_divisible_lengths() {
        for n in [7, 13, 29, 31] {
            run_allreduce(4, n, AllReduceAlgo::Ring, n as u64);
        }
    }

    #[test]
    fn ring_small_vector_fallback() {
        // n < m falls back to naive.
        run_allreduce(8, 3, AllReduceAlgo::Ring, 7);
    }

    #[test]
    fn scalar_allreduce() {
        let (eps, _) = fabric(4, NetworkModel::default());
        thread::scope(|s| {
            for ep in eps {
                s.spawn(move |_| {
                    let mut ep = ep;
                    let rank = ep.rank as f64;
                    let total = allreduce_scalar(&mut ep, 0, rank + 1.0).unwrap();
                    assert_eq!(total, 10.0); // 1+2+3+4
                });
            }
        })
        .unwrap();
    }

    #[test]
    fn ring_moves_fewer_bytes_per_node_than_naive_at_root() {
        let m = 8;
        let n = 8000;
        let bytes_of = |algo: AllReduceAlgo| {
            let (eps, stats) = fabric(m, NetworkModel::default());
            thread::scope(|s| {
                for ep in eps {
                    s.spawn(move |_| {
                        let mut ep = ep;
                        let mut data = vec![1.0; n];
                        allreduce_sum(&mut ep, 0, &mut data, algo).unwrap();
                    });
                }
            })
            .unwrap();
            // Busiest NODE: total bytes in + out. Naive concentrates
            // 2(M−1)n at rank 0; ring spreads ~2n per node.
            let mut max_node = 0u64;
            for a in 0..m {
                let mut node = 0u64;
                for b in 0..m {
                    node += stats.link_bytes(a, b) + stats.link_bytes(b, a);
                }
                max_node = max_node.max(node);
            }
            (stats.total_bytes(), max_node)
        };
        let (naive_total, naive_hot) = bytes_of(AllReduceAlgo::Naive);
        let (ring_total, ring_hot) = bytes_of(AllReduceAlgo::Ring);
        // Naive root handles 2(M−1)n ≈ 14n; a ring node handles ≈ 4n
        // (2n out + 2n in). Expect at least a 2× reduction at the hot spot.
        assert!(
            ring_hot < naive_hot / 2,
            "ring hot {ring_hot} vs naive hot {naive_hot}"
        );
        // Totals are the same order (both Θ(Mn)).
        assert!(ring_total < naive_total * 2);
    }

    #[test]
    fn scalar_matches_one_element_vector_under_both_algos() {
        // Regression for the allreduce_scalar contract: the algo-less scalar
        // reduction must agree exactly with a 1-element allreduce_sum under
        // BOTH algorithms (ring degenerates to naive below the chunking
        // threshold, so all three paths are the same reduction tree).
        for m in [1, 2, 3, 5] {
            let (eps, _) = fabric(m, NetworkModel::default());
            thread::scope(|s| {
                for ep in eps {
                    s.spawn(move |_| {
                        let mut ep = ep;
                        let x = (ep.rank as f64 + 1.0) * 0.25;
                        let scalar = allreduce_scalar(&mut ep, 0, x).unwrap();
                        let mut v_naive = [x];
                        allreduce_sum(&mut ep, TAG_STRIDE, &mut v_naive, AllReduceAlgo::Naive)
                            .unwrap();
                        let mut v_ring = [x];
                        allreduce_sum(&mut ep, 2 * TAG_STRIDE, &mut v_ring, AllReduceAlgo::Ring)
                            .unwrap();
                        assert_eq!(scalar, v_naive[0], "scalar vs naive, m={m}");
                        assert_eq!(scalar, v_ring[0], "scalar vs ring, m={m}");
                        let want: f64 = (1..=m).map(|r| r as f64 * 0.25).sum();
                        assert!((scalar - want).abs() < 1e-12, "sum wrong: {scalar} vs {want}");
                    });
                }
            })
            .unwrap();
        }
    }

    #[test]
    fn consecutive_collectives_with_distinct_tags() {
        // Two back-to-back allreduces must not cross-talk.
        let (eps, _) = fabric(3, NetworkModel::default());
        thread::scope(|s| {
            for ep in eps {
                s.spawn(move |_| {
                    let mut ep = ep;
                    let mut a = vec![ep.rank as f64];
                    let mut b = vec![10.0 * (ep.rank as f64 + 1.0)];
                    allreduce_sum(&mut ep, 0, &mut a, AllReduceAlgo::Naive).unwrap();
                    allreduce_sum(&mut ep, TAG_STRIDE, &mut b, AllReduceAlgo::Naive).unwrap();
                    assert_eq!(a, vec![3.0]); // 0+1+2
                    assert_eq!(b, vec![60.0]); // 10+20+30
                });
            }
        })
        .unwrap();
    }
}
