//! Message-based barrier over the [`Transport`] seam — the only barrier the
//! system needs now that ALB runs on per-iteration quorum tags (the old
//! shared-memory sense-reversing `Barrier`, which existed solely for ALB's
//! generation reset, is gone). Gather to rank 0 then broadcast:
//! 2(M−1) empty frames.

use crate::cluster::transport::{Transport, TransportError};

/// Message-based barrier over a [`Transport`]: every rank blocks until all
/// M ranks have entered. Consumes tags `tag_base` and `tag_base + 1`;
/// callers must space distinct barriers by at least 2 tags (the coordinator
/// uses the shared `TAG_STRIDE` allocator, which leaves plenty of room).
/// A peer dying while the barrier is held propagates as the transport's
/// typed error — the barrier can never complete once a rank is gone.
pub fn transport_barrier(t: &mut dyn Transport, tag_base: u64) -> Result<(), TransportError> {
    let m = t.size();
    if m == 1 {
        return Ok(());
    }
    if t.rank() == 0 {
        for from in 1..m {
            t.recv_from(from, tag_base)?;
        }
        for to in 1..m {
            t.send(to, tag_base + 1, Vec::new())?;
        }
    } else {
        t.send(0, tag_base, Vec::new())?;
        t.recv_from(0, tag_base + 1)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn transport_barrier_synchronizes_fabric_ranks() {
        use crate::cluster::fabric::{fabric, NetworkModel};
        let m = 4;
        let (eps, _) = fabric(m, NetworkModel::default());
        let arrived = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for ep in eps {
            let arrived = arrived.clone();
            handles.push(std::thread::spawn(move || {
                let mut ep = ep;
                // Stagger arrivals so the barrier actually has to hold.
                std::thread::sleep(std::time::Duration::from_millis(5 * ep.rank as u64));
                arrived.fetch_add(1, Ordering::SeqCst);
                transport_barrier(&mut ep, 100).unwrap();
                assert_eq!(arrived.load(Ordering::SeqCst), m);
                // Reusable: a second barrier on fresh tags also completes.
                transport_barrier(&mut ep, 200).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
