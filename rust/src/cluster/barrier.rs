//! Barriers for the BSP phases of the cluster.
//!
//! Two implementations:
//! * [`Barrier`] — shared-memory sense-reversing barrier (Mutex + Condvar)
//!   for the in-process fabric. Owning the implementation (rather than
//!   std's `Barrier`) lets the coordinator instrument wait time — the
//!   "slow node" diagnosis in the ALB experiments.
//! * [`transport_barrier`] — message-based barrier over any [`Transport`],
//!   the only kind available once nodes are separate OS processes. Gather
//!   to rank 0 then broadcast: 2(M−1) empty frames.

use crate::cluster::transport::Transport;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Message-based barrier over a [`Transport`]: every rank blocks until all
/// M ranks have entered. Consumes tags `tag_base` and `tag_base + 1`;
/// callers must space distinct barriers by at least 2 tags (the coordinator
/// uses the shared `TAG_STRIDE` allocator, which leaves plenty of room).
pub fn transport_barrier(t: &mut dyn Transport, tag_base: u64) {
    let m = t.size();
    if m == 1 {
        return;
    }
    if t.rank() == 0 {
        for from in 1..m {
            t.recv_from(from, tag_base);
        }
        for to in 1..m {
            t.send(to, tag_base + 1, Vec::new());
        }
    } else {
        t.send(0, tag_base, Vec::new());
        t.recv_from(0, tag_base + 1);
    }
}

pub struct Barrier {
    lock: Mutex<BarrierState>,
    cv: Condvar,
    parties: usize,
    /// Total nanoseconds threads spent blocked here (all parties summed).
    wait_ns: AtomicU64,
}

struct BarrierState {
    count: usize,
    generation: u64,
}

impl Barrier {
    pub fn new(parties: usize) -> Barrier {
        assert!(parties > 0);
        Barrier {
            lock: Mutex::new(BarrierState {
                count: 0,
                generation: 0,
            }),
            cv: Condvar::new(),
            parties,
            wait_ns: AtomicU64::new(0),
        }
    }

    /// Block until all parties arrive. Returns true for exactly one
    /// "leader" per generation (the last arriver).
    pub fn wait(&self) -> bool {
        let t0 = Instant::now();
        let mut st = self.lock.lock().unwrap();
        st.count += 1;
        if st.count == self.parties {
            st.count = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cv.notify_all();
            self.wait_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            true
        } else {
            let gen = st.generation;
            while st.generation == gen {
                st = self.cv.wait(st).unwrap();
            }
            self.wait_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            false
        }
    }

    /// Cumulative blocked time across all parties (seconds).
    pub fn total_wait_secs(&self) -> f64 {
        self.wait_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn all_threads_cross_together() {
        let parties = 8;
        let barrier = Arc::new(Barrier::new(parties));
        let before = Arc::new(AtomicUsize::new(0));
        let after = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..parties {
            let (b, bf, af) = (barrier.clone(), before.clone(), after.clone());
            handles.push(std::thread::spawn(move || {
                bf.fetch_add(1, Ordering::SeqCst);
                b.wait();
                // When any thread is past the barrier, all must have arrived.
                assert_eq!(bf.load(Ordering::SeqCst), 8);
                af.fetch_add(1, Ordering::SeqCst);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(after.load(Ordering::SeqCst), parties);
    }

    #[test]
    fn exactly_one_leader_per_generation() {
        let parties = 4;
        let generations = 10;
        let barrier = Arc::new(Barrier::new(parties));
        let leaders = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..parties {
            let (b, l) = (barrier.clone(), leaders.clone());
            handles.push(std::thread::spawn(move || {
                for _ in 0..generations {
                    if b.wait() {
                        l.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(leaders.load(Ordering::SeqCst), generations);
    }

    #[test]
    fn transport_barrier_synchronizes_fabric_ranks() {
        use crate::cluster::fabric::{fabric, NetworkModel};
        let m = 4;
        let (eps, _) = fabric(m, NetworkModel::default());
        let arrived = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for ep in eps {
            let arrived = arrived.clone();
            handles.push(std::thread::spawn(move || {
                let mut ep = ep;
                // Stagger arrivals so the barrier actually has to hold.
                std::thread::sleep(std::time::Duration::from_millis(5 * ep.rank as u64));
                arrived.fetch_add(1, Ordering::SeqCst);
                transport_barrier(&mut ep, 100);
                assert_eq!(arrived.load(Ordering::SeqCst), m);
                // Reusable: a second barrier on fresh tags also completes.
                transport_barrier(&mut ep, 200);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn wait_time_recorded_for_stragglers() {
        let barrier = Arc::new(Barrier::new(2));
        let b2 = barrier.clone();
        let h = std::thread::spawn(move || {
            b2.wait();
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        barrier.wait();
        h.join().unwrap();
        // The early thread blocked ~30 ms.
        assert!(barrier.total_wait_secs() > 0.02);
    }
}
