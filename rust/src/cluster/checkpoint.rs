//! Deterministic per-iteration checkpoints — the persistence half of the
//! elastic fault-tolerance story (DESIGN.md §Failure model).
//!
//! A checkpoint captures everything the SPMD train loop needs to resume an
//! interrupted fit *bit-identically* (the hybrid-parallelism PR made every
//! execution shape deterministic, which is what makes exact resume
//! feasible): the SPMD-identical globals (outer iteration, adaptive-μ
//! state, stall counter, current objective, the synced margin vector Xβ)
//! plus every rank's private block (β^m, the cyclic CD cursor, and the
//! hybrid sub-block cursors). Working stats (w, z, loss) are *derived* from
//! the margins by the same deterministic code on resume, and the
//! regularizer value re-allreduces to the same bits, so none of them are
//! stored.
//!
//! Rank 0 writes one file per checkpointed iteration — `ckpt-{iter:08}.bin`
//! under `--checkpoint-dir` — via a temp-file + rename so a crash mid-write
//! can never leave a half-written file under the final name. On recovery
//! the coordinator takes [`latest`](Checkpoint::latest): newest file that
//! parses completely (older complete checkpoints survive as fallbacks).
//!
//! The format is a tiny fixed little-endian binary layout (no serde — the
//! container bakes in no such dependency): magic `DGCK`, format version,
//! the globals, the margin vector, then the per-rank blocks, closed by an
//! end marker. A `lambda_idx` slot is reserved so a future PR can extend
//! checkpointing to λ-path sweeps without a format break (path jobs
//! currently reject `--checkpoint-dir` up front).

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// File magic: "DGCK".
const MAGIC: [u8; 4] = *b"DGCK";
/// Format version (bump on layout changes).
const FORMAT_VERSION: u32 = 1;
/// Trailing end marker proving the write ran to completion.
const END_MARKER: u64 = 0x444B_4345_4E44_4B43;

/// Reserved fixed tag the coordinator uses to ship each surviving rank its
/// [`ResumePoint`] right after mesh formation, before the worker's
/// `TAG_STRIDE` allocator starts. Spaced well clear of the other reserved
/// tags near `u64::MAX` (poison, gather).
pub const RESUME_TAG: u64 = u64::MAX - 24;

/// One rank's private slice of a checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct RankBlock {
    /// Cyclic CD cursor into the rank's block (mid-block under ALB).
    pub cursor: usize,
    /// Hybrid sub-block cursors (empty on the classic single-thread path).
    pub sub_cursors: Vec<usize>,
    /// The rank's local weights β^m.
    pub beta: Vec<f64>,
}

/// A complete cluster checkpoint: the SPMD globals plus all M rank blocks.
/// Holding *every* rank's β is what makes re-shard-on-exclusion possible —
/// the coordinator can reassemble the full β and re-partition it across
/// M−1 survivors without the dead rank's cooperation.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Outer iteration this state is the end of (resume starts at iter+1).
    pub iter: usize,
    /// Convergence stall counter at the end of that iteration.
    pub stall: usize,
    /// Adaptive-μ value entering the next iteration.
    pub mu: f64,
    /// Objective f(β) at the end of the iteration.
    pub f_cur: f64,
    /// Reserved for λ-path position (0 for train jobs).
    pub lambda_idx: u64,
    /// The synced margin vector Xβ (SPMD-identical on every rank).
    pub margins: Vec<f64>,
    /// Per-rank private state, indexed by rank.
    pub ranks: Vec<RankBlock>,
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Sequential little-endian reader over a checkpoint image.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "truncated checkpoint: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64, String> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        let s = self.take(8)?;
        Ok(f64::from_le_bytes(s.try_into().unwrap()))
    }

    fn usize_bounded(&mut self, what: &str, max: u64) -> Result<usize, String> {
        let v = self.u64()?;
        if v > max {
            return Err(format!("{what} {v} exceeds sanity bound {max}"));
        }
        Ok(v as usize)
    }
}

/// Sanity bound on vector lengths read from disk — generous for any real
/// dataset, small enough that a corrupt length can't trigger an OOM
/// allocation before the truncation check fires.
const MAX_LEN: u64 = 1 << 40;

impl Checkpoint {
    /// Serialize to the fixed little-endian layout (bit-exact round-trip:
    /// f64 travels as raw `to_le_bytes`).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(
            64 + 8 * self.margins.len()
                + self
                    .ranks
                    .iter()
                    .map(|r| 24 + 8 * (r.sub_cursors.len() + r.beta.len()))
                    .sum::<usize>(),
        );
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        push_u64(&mut buf, self.iter as u64);
        push_u64(&mut buf, self.stall as u64);
        push_u64(&mut buf, self.lambda_idx);
        push_f64(&mut buf, self.mu);
        push_f64(&mut buf, self.f_cur);
        push_u64(&mut buf, self.margins.len() as u64);
        for &m in &self.margins {
            push_f64(&mut buf, m);
        }
        push_u64(&mut buf, self.ranks.len() as u64);
        for r in &self.ranks {
            push_u64(&mut buf, r.cursor as u64);
            push_u64(&mut buf, r.sub_cursors.len() as u64);
            for &c in &r.sub_cursors {
                push_u64(&mut buf, c as u64);
            }
            push_u64(&mut buf, r.beta.len() as u64);
            for &b in &r.beta {
                push_f64(&mut buf, b);
            }
        }
        push_u64(&mut buf, END_MARKER);
        buf
    }

    /// Parse a checkpoint image; any truncation, bad magic, or missing end
    /// marker is an error (the recovery scan treats it as "incomplete —
    /// fall back to an older file").
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint, String> {
        let mut r = Reader { buf, pos: 0 };
        if r.take(4)? != MAGIC {
            return Err("bad checkpoint magic".to_string());
        }
        let version = u32::from_le_bytes(r.take(4)?.try_into().unwrap());
        if version != FORMAT_VERSION {
            return Err(format!(
                "checkpoint format v{version}, this build reads v{FORMAT_VERSION}"
            ));
        }
        let iter = r.usize_bounded("iter", MAX_LEN)?;
        let stall = r.usize_bounded("stall", MAX_LEN)?;
        let lambda_idx = r.u64()?;
        let mu = r.f64()?;
        let f_cur = r.f64()?;
        let n = r.usize_bounded("margin length", MAX_LEN)?;
        let mut margins = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            margins.push(r.f64()?);
        }
        let m = r.usize_bounded("rank count", 1 << 20)?;
        let mut ranks = Vec::with_capacity(m.min(1 << 10));
        for _ in 0..m {
            let cursor = r.usize_bounded("cursor", MAX_LEN)?;
            let k = r.usize_bounded("sub-cursor count", 1 << 20)?;
            let mut sub_cursors = Vec::with_capacity(k.min(1 << 10));
            for _ in 0..k {
                sub_cursors.push(r.usize_bounded("sub-cursor", MAX_LEN)?);
            }
            let p = r.usize_bounded("beta length", MAX_LEN)?;
            let mut beta = Vec::with_capacity(p.min(1 << 20));
            for _ in 0..p {
                beta.push(r.f64()?);
            }
            ranks.push(RankBlock {
                cursor,
                sub_cursors,
                beta,
            });
        }
        if r.u64()? != END_MARKER {
            return Err("checkpoint end marker missing (incomplete write)".to_string());
        }
        Ok(Checkpoint {
            iter,
            stall,
            mu,
            f_cur,
            lambda_idx,
            margins,
            ranks,
        })
    }

    /// File name a checkpoint of iteration `iter` is stored under —
    /// zero-padded so lexicographic order is iteration order.
    pub fn file_name(iter: usize) -> String {
        format!("ckpt-{iter:08}.bin")
    }

    /// Atomically persist under `dir` (created if missing): the image goes
    /// to a dot-prefixed temp file first, then an atomic rename publishes
    /// it — a crash mid-write can never leave a torn file under the final
    /// name, so `latest` only ever sees complete or absent checkpoints.
    pub fn write_atomic(&self, dir: &Path) -> std::io::Result<PathBuf> {
        fs::create_dir_all(dir)?;
        let final_path = dir.join(Self::file_name(self.iter));
        let tmp_path = dir.join(format!(".ckpt-{:08}.tmp", self.iter));
        {
            let mut f = fs::File::create(&tmp_path)?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        fs::rename(&tmp_path, &final_path)?;
        Ok(final_path)
    }

    /// Newest complete checkpoint under `dir`: scan `ckpt-*.bin` names
    /// descending and return the first that parses end-to-end, skipping
    /// anything truncated or corrupt. `None` if the directory holds no
    /// loadable checkpoint (or doesn't exist).
    pub fn latest(dir: &Path) -> Option<(PathBuf, Checkpoint)> {
        let entries = fs::read_dir(dir).ok()?;
        let mut names: Vec<String> = entries
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.starts_with("ckpt-") && n.ends_with(".bin"))
            .collect();
        names.sort_unstable();
        for name in names.into_iter().rev() {
            let path = dir.join(&name);
            if let Ok(bytes) = fs::read(&path) {
                if let Ok(ck) = Checkpoint::from_bytes(&bytes) {
                    return Some((path, ck));
                }
            }
        }
        None
    }

    /// Extract the resume payload for one rank (globals + that rank's
    /// private block).
    pub fn resume_point(&self, rank: usize) -> ResumePoint {
        let b = &self.ranks[rank];
        ResumePoint {
            iter: self.iter,
            stall: self.stall,
            mu: self.mu,
            f_cur: self.f_cur,
            margins: self.margins.clone(),
            cursor: b.cursor,
            sub_cursors: b.sub_cursors.clone(),
            beta: b.beta.clone(),
        }
    }
}

/// What one rank needs to resume mid-fit — the coordinator derives one per
/// surviving rank from the loaded [`Checkpoint`] (re-sharding first if a
/// rank was excluded) and ships it over [`RESUME_TAG`] on the TCP path.
#[derive(Clone, Debug, PartialEq)]
pub struct ResumePoint {
    pub iter: usize,
    pub stall: usize,
    pub mu: f64,
    pub f_cur: f64,
    pub margins: Vec<f64>,
    pub cursor: usize,
    pub sub_cursors: Vec<usize>,
    pub beta: Vec<f64>,
}

impl ResumePoint {
    /// Encode as one f64 vector for a transport send: the header counters
    /// ride as exact small integers (all < 2^53), the float payload as raw
    /// values — `unflatten` restores every field bit-for-bit.
    pub fn flatten(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(
            7 + self.margins.len() + self.sub_cursors.len() + self.beta.len(),
        );
        v.push(self.iter as f64);
        v.push(self.stall as f64);
        v.push(self.mu);
        v.push(self.f_cur);
        v.push(self.margins.len() as f64);
        v.extend_from_slice(&self.margins);
        v.push(self.cursor as f64);
        v.push(self.sub_cursors.len() as f64);
        v.extend(self.sub_cursors.iter().map(|&c| c as f64));
        v.push(self.beta.len() as f64);
        v.extend_from_slice(&self.beta);
        v
    }

    /// Inverse of [`flatten`](Self::flatten).
    pub fn unflatten(v: &[f64]) -> Result<ResumePoint, String> {
        fn scalar(v: &[f64], pos: &mut usize, what: &str) -> Result<f64, String> {
            let x = *v
                .get(*pos)
                .ok_or_else(|| format!("resume payload truncated at {what}"))?;
            *pos += 1;
            Ok(x)
        }
        fn count(v: &[f64], pos: &mut usize, what: &str) -> Result<usize, String> {
            let x = scalar(v, pos, what)?;
            if !(x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x < MAX_LEN as f64) {
                return Err(format!("resume payload: bad {what} {x}"));
            }
            Ok(x as usize)
        }
        fn slice(v: &[f64], pos: &mut usize, n: usize, what: &str) -> Result<Vec<f64>, String> {
            if *pos + n > v.len() {
                return Err(format!("resume payload truncated in {what}"));
            }
            let out = v[*pos..*pos + n].to_vec();
            *pos += n;
            Ok(out)
        }
        let mut pos = 0usize;
        let iter = count(v, &mut pos, "iter")?;
        let stall = count(v, &mut pos, "stall")?;
        let mu = scalar(v, &mut pos, "mu")?;
        let f_cur = scalar(v, &mut pos, "f_cur")?;
        let n = count(v, &mut pos, "margin length")?;
        let margins = slice(v, &mut pos, n, "margins")?;
        let cursor = count(v, &mut pos, "cursor")?;
        let k = count(v, &mut pos, "sub-cursor count")?;
        let mut sub_cursors = Vec::with_capacity(k.min(1 << 10));
        for _ in 0..k {
            sub_cursors.push(count(v, &mut pos, "sub-cursor")?);
        }
        let p = count(v, &mut pos, "beta length")?;
        let beta = slice(v, &mut pos, p, "beta")?;
        if pos != v.len() {
            return Err(format!(
                "resume payload has {} trailing values",
                v.len() - pos
            ));
        }
        Ok(ResumePoint {
            iter,
            stall,
            mu,
            f_cur,
            margins,
            cursor,
            sub_cursors,
            beta,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            iter: 12,
            stall: 1,
            mu: 0.5,
            f_cur: 0.482_913_771,
            lambda_idx: 0,
            margins: vec![0.25, -1.5, f64::MIN_POSITIVE, 3.75e300],
            ranks: vec![
                RankBlock {
                    cursor: 3,
                    sub_cursors: vec![],
                    beta: vec![0.1, -0.2, 0.0],
                },
                RankBlock {
                    cursor: 0,
                    sub_cursors: vec![1, 0],
                    beta: vec![1.5e-17, 2.0],
                },
            ],
        }
    }

    #[test]
    fn bytes_roundtrip_is_bit_exact() {
        let ck = sample();
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back, ck);
        // Bit-exactness beyond PartialEq: raw bit patterns survive.
        assert_eq!(
            back.margins[2].to_bits(),
            f64::MIN_POSITIVE.to_bits()
        );
    }

    #[test]
    fn truncated_and_corrupt_images_are_rejected() {
        let bytes = sample().to_bytes();
        for cut in [0, 3, 4, 10, bytes.len() - 8, bytes.len() - 1] {
            assert!(
                Checkpoint::from_bytes(&bytes[..cut]).is_err(),
                "cut at {cut} accepted"
            );
        }
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Checkpoint::from_bytes(&bad).is_err(), "bad magic accepted");
        let mut bad = bytes;
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        assert!(
            Checkpoint::from_bytes(&bad).is_err(),
            "bad end marker accepted"
        );
    }

    #[test]
    fn latest_prefers_newest_complete_file() {
        let dir = std::env::temp_dir().join(format!("dgck-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let mut ck = sample();
        ck.iter = 4;
        ck.write_atomic(&dir).unwrap();
        ck.iter = 8;
        ck.f_cur = 0.25;
        ck.write_atomic(&dir).unwrap();
        // A torn newer write under the final name must be skipped.
        fs::write(dir.join(Checkpoint::file_name(12)), b"DGCKgarbage").unwrap();
        let (path, got) = Checkpoint::latest(&dir).unwrap();
        assert_eq!(got.iter, 8);
        assert_eq!(got.f_cur, 0.25);
        assert!(path.ends_with(Checkpoint::file_name(8)));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_on_missing_or_empty_dir_is_none() {
        let dir = std::env::temp_dir().join(format!("dgck-none-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        assert!(Checkpoint::latest(&dir).is_none());
        fs::create_dir_all(&dir).unwrap();
        assert!(Checkpoint::latest(&dir).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_point_flatten_roundtrip() {
        let ck = sample();
        for rank in 0..ck.ranks.len() {
            let rp = ck.resume_point(rank);
            let back = ResumePoint::unflatten(&rp.flatten()).unwrap();
            assert_eq!(back, rp);
        }
    }

    #[test]
    fn unflatten_rejects_malformed_payloads() {
        let rp = sample().resume_point(1);
        let flat = rp.flatten();
        assert!(ResumePoint::unflatten(&flat[..flat.len() - 1]).is_err());
        let mut extra = flat.clone();
        extra.push(0.0);
        assert!(ResumePoint::unflatten(&extra).is_err());
        let mut nan_count = flat;
        nan_count[0] = f64::NAN; // iter
        assert!(ResumePoint::unflatten(&nan_count).is_err());
    }
}
