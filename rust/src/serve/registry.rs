//! Versioned model registry with lock-free-read hot-swap.
//!
//! Serving threads call [`ModelRegistry::current`] on every request, so the
//! read path must never contend with a promotion. The registry keeps the
//! live snapshot behind an `AtomicPtr`; readers do one atomic load plus one
//! refcount increment — no lock, no waiting on a writer. Writers (promotions
//! are rare: one per training run) serialize on a mutex that also owns the
//! version history.
//!
//! Safety of the raw-pointer read: every snapshot ever published is retained
//! in the history vector for the registry's lifetime, so a pointer observed
//! in `current` is always backed by at least one strong reference and
//! `Arc::increment_strong_count` can never race with deallocation. The cost
//! is that old versions are kept alive until the registry drops — each
//! holding the model's **dense** weight vector (8·p bytes), bounded by the
//! number of *distinct* promotions: `load_path`/`reload` compare against the
//! live model and return the current version without publishing when the
//! file content is unchanged, so a periodic swap-model cron does not grow
//! memory. Genuinely new models accumulate by design (rollback/debugging);
//! a server promoting truly distinct models at high frequency should be
//! restarted occasionally or taught pruning first.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicPtr, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::glm::model::{GlmModel, ModelError};

/// One immutable published model version.
#[derive(Debug)]
pub struct Snapshot {
    /// Monotonically increasing, starting at 1.
    pub version: u64,
    pub model: GlmModel,
    /// Where the model was loaded from, if it came from disk.
    pub source: Option<PathBuf>,
    /// When this version was promoted (relative to registry creation).
    pub promoted_at: Instant,
}

struct WriterState {
    /// Every snapshot ever published (see module docs for why nothing is
    /// ever pruned).
    history: Vec<Arc<Snapshot>>,
    /// Default path for `reload()` — the most recent disk source.
    source: Option<PathBuf>,
}

/// Versioned registry of [`GlmModel`] snapshots; see module docs.
pub struct ModelRegistry {
    current: AtomicPtr<Snapshot>,
    writer: Mutex<WriterState>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    /// An empty registry; `current()` returns `None` until a first publish.
    pub fn new() -> ModelRegistry {
        ModelRegistry {
            current: AtomicPtr::new(std::ptr::null_mut()),
            writer: Mutex::new(WriterState {
                history: Vec::new(),
                source: None,
            }),
        }
    }

    /// Registry seeded with an initial model (version 1).
    pub fn with_model(model: GlmModel) -> ModelRegistry {
        let reg = ModelRegistry::new();
        reg.publish(model);
        reg
    }

    /// Promote a model as the new current version. Returns its version.
    pub fn publish(&self, model: GlmModel) -> u64 {
        self.publish_inner(model, None)
    }

    fn publish_inner(&self, model: GlmModel, source: Option<PathBuf>) -> u64 {
        let mut w = self.writer.lock().unwrap();
        let version = w.history.len() as u64 + 1;
        let snap = Arc::new(Snapshot {
            version,
            model,
            source: source.clone(),
            promoted_at: Instant::now(),
        });
        // Retain the strong reference *before* exposing the pointer so a
        // concurrent reader can never observe an unanchored snapshot.
        w.history.push(Arc::clone(&snap));
        if source.is_some() {
            w.source = source;
        }
        self.current
            .store(Arc::as_ptr(&snap) as *mut Snapshot, Ordering::Release);
        version
    }

    /// Load a model JSON written by `train --save-model` and promote it.
    /// The path is remembered for [`ModelRegistry::reload`]. If the loaded
    /// model is identical to the live one, no new version is published
    /// (keeps periodic reloads from growing the history) and the current
    /// version is returned.
    pub fn load_path(&self, path: impl AsRef<Path>) -> Result<u64, ModelError> {
        let path = path.as_ref().to_path_buf();
        let model = GlmModel::load(&path)?;
        if let Some(cur) = self.current() {
            if cur.model == model {
                self.writer.lock().unwrap().source = Some(path);
                return Ok(cur.version);
            }
        }
        Ok(self.publish_inner(model, Some(path)))
    }

    /// Re-read the most recent disk source and promote the result — the
    /// "a new model landed at the same path" promotion.
    pub fn reload(&self) -> Result<u64, ModelError> {
        let path = {
            let w = self.writer.lock().unwrap();
            w.source.clone().ok_or_else(|| {
                ModelError::Malformed("registry has no disk source to reload".into())
            })?
        };
        self.load_path(path)
    }

    /// The live snapshot, or `None` before the first publish. Lock-free:
    /// one `Acquire` load and one refcount increment.
    pub fn current(&self) -> Option<Arc<Snapshot>> {
        let p = self.current.load(Ordering::Acquire);
        if p.is_null() {
            return None;
        }
        // SAFETY: `p` was produced by `Arc::as_ptr` on a snapshot whose Arc
        // is held in `writer.history` for the lifetime of `self`, so the
        // allocation is live and its strong count is ≥ 1 for the whole call.
        unsafe {
            Arc::increment_strong_count(p);
            Some(Arc::from_raw(p))
        }
    }

    /// Version of the live snapshot (0 = nothing published yet).
    pub fn current_version(&self) -> u64 {
        self.current().map(|s| s.version).unwrap_or(0)
    }

    /// Number of versions ever published.
    pub fn versions(&self) -> u64 {
        self.writer.lock().unwrap().history.len() as u64
    }

    /// Fetch a historical snapshot by version (1-based).
    pub fn get(&self, version: u64) -> Option<Arc<Snapshot>> {
        let w = self.writer.lock().unwrap();
        w.history.get(version.checked_sub(1)? as usize).cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::loss::LossKind;
    use std::sync::atomic::AtomicBool;

    fn model(tag: f64) -> GlmModel {
        let mut beta = vec![0.0; 8];
        beta[0] = tag;
        beta[5] = -tag;
        GlmModel::new(LossKind::Logistic, beta)
    }

    #[test]
    fn empty_registry_has_no_current() {
        let reg = ModelRegistry::new();
        assert!(reg.current().is_none());
        assert_eq!(reg.current_version(), 0);
        assert!(reg.reload().is_err());
    }

    #[test]
    fn publish_bumps_version_and_swaps_current() {
        let reg = ModelRegistry::new();
        assert_eq!(reg.publish(model(1.0)), 1);
        assert_eq!(reg.publish(model(2.0)), 2);
        let cur = reg.current().unwrap();
        assert_eq!(cur.version, 2);
        assert_eq!(cur.model.beta[0], 2.0);
        // History keeps the old version addressable.
        assert_eq!(reg.get(1).unwrap().model.beta[0], 1.0);
        assert_eq!(reg.versions(), 2);
    }

    #[test]
    fn load_and_reload_from_disk() {
        let dir = std::env::temp_dir().join(format!("dglmnet_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        model(1.0).save(&path).unwrap();
        let reg = ModelRegistry::new();
        assert_eq!(reg.load_path(&path).unwrap(), 1);
        assert_eq!(reg.current_version(), 1);
        // Reload with the file unchanged: no new version, no history growth.
        assert_eq!(reg.reload().unwrap(), 1);
        assert_eq!(reg.versions(), 1);
        // A retrain lands at the same path; reload() promotes it.
        model(3.0).save(&path).unwrap();
        assert_eq!(reg.reload().unwrap(), 2);
        assert_eq!(reg.current().unwrap().model.beta[0], 3.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_malformed_file() {
        let dir = std::env::temp_dir().join(format!("dglmnet_regbad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"format\":\"wrong\"}").unwrap();
        let reg = ModelRegistry::new();
        assert!(reg.load_path(&path).is_err());
        assert_eq!(reg.current_version(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The satellite requirement: hot-swap under concurrent readers. Readers
    /// hammer `current()` while a writer publishes versions; every observed
    /// snapshot must be internally consistent (version tag matches the
    /// weights planted for that version) and versions must be monotone per
    /// reader.
    #[test]
    fn hot_swap_under_concurrent_readers() {
        let reg = Arc::new(ModelRegistry::with_model(model(1.0)));
        let stop = Arc::new(AtomicBool::new(false));
        let n_writes = 200u64;
        std::thread::scope(|s| {
            let mut readers = Vec::new();
            for _ in 0..4 {
                let reg = Arc::clone(&reg);
                let stop = Arc::clone(&stop);
                readers.push(s.spawn(move || {
                    let mut last = 0u64;
                    let mut seen = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let snap = reg.current().expect("published");
                        assert!(snap.version >= last, "version went backwards");
                        // Consistency: weights carry the version they were
                        // published with.
                        assert_eq!(snap.model.beta[0], snap.version as f64);
                        assert_eq!(snap.model.beta[5], -(snap.version as f64));
                        last = snap.version;
                        seen += 1;
                    }
                    seen
                }));
            }
            for v in 2..=n_writes {
                reg.publish(model(v as f64));
            }
            stop.store(true, Ordering::Relaxed);
            for r in readers {
                assert!(r.join().unwrap() > 0);
            }
        });
        assert_eq!(reg.current_version(), n_writes);
    }
}
