//! Online model serving — the deployment layer the paper's CTR framing
//! implies: a trained model's whole purpose is to be scored against live
//! traffic (Trofimov & Genkin 2016 §1; 2014 §5).
//!
//! The subsystem is four pieces, composed by `dglmnet serve`:
//!
//! - [`registry`] — versioned [`GlmModel`] snapshots with lock-free-read
//!   hot-swap, so a freshly trained model (`train --save-model`) can be
//!   promoted under load without restarting or stalling readers.
//! - [`scorer`] — turns the registry's current snapshot into a dense
//!   scoring plan (sparse β densified once per version) and scores sparse
//!   rows through the same `NativeCompute`/`XlaCompute` seam the trainer
//!   uses ([`GlmCompute`]).
//! - [`batcher`] — a micro-batching queue that coalesces concurrent
//!   requests into blocks before they hit the scorer, so throughput scales
//!   with cores instead of with request count.
//! - [`server`] — a minimal thread-pool TCP front speaking newline-delimited
//!   JSON (`predict` / `health` / `swap-model`), reusing `util::json`.
//!
//! [`loadgen`] drives a running server from N client threads and reports
//! QPS plus p50/p99 latency through [`metrics::latency::LatencyHistogram`]
//! (`dglmnet bench-serve`, `benches/serve_throughput.rs`).
//!
//! [`GlmModel`]: crate::glm::GlmModel
//! [`GlmCompute`]: crate::solver::compute::GlmCompute
//! [`metrics::latency::LatencyHistogram`]: crate::metrics::latency::LatencyHistogram

pub mod batcher;
pub mod loadgen;
pub mod registry;
pub mod scorer;
pub mod server;

pub use batcher::{Batcher, BatcherConfig, BatcherStats};
pub use loadgen::{run_loadgen, synthetic_model, LoadgenConfig, LoadgenReport};
pub use registry::{ModelRegistry, Snapshot};
pub use scorer::{ComputeFactory, NativeFactory, ScoreError, ScoredBatch, Scorer, SparseRow};
pub use server::{serve, ServeClient, ServerConfig, ServerHandle};
