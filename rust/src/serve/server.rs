//! Thread-pool TCP server speaking newline-delimited JSON.
//!
//! Protocol (one JSON object per line, one reply line per request):
//!
//! ```text
//! → {"op":"predict","rows":[[[0,1.5],[3,-0.2]],[[2,1.0]]]}
//! ← {"ok":true,"version":1,"probs":[0.62,0.31],"margins":[0.5,-0.8]}
//! → {"op":"health"}
//! ← {"ok":true,"version":1,"engine":"native","requests":…,"latency":{…},"batcher":{…}}
//! → {"op":"swap-model","path":"new_model.json"}     ("path" optional: reload)
//! ← {"ok":true,"version":2,"nnz":1234}
//! → {"op":"stats"}
//! ← {"ok":true,"server":{…},"metrics":{"counters":{…},"gauges":{…},"histograms":{…}}}
//! ```
//!
//! Rows are arrays of `[feature, value]` pairs. Errors come back as
//! `{"ok":false,"error":"…"}` on the same line; the connection stays up.
//!
//! The accept thread hands connections to a fixed pool of I/O workers (one
//! connection per worker at a time — size the pool to the expected client
//! fan-in; a connection beyond the pool is refused with an error line
//! instead of queueing silently). `predict` latency (parse to scored) is
//! recorded into a [`LatencyHistogram`]; scoring itself is delegated to the
//! [`Batcher`] so concurrent connections coalesce into micro-batches.
//!
//! **Trust model:** the protocol has no authentication, and `swap-model`
//! reads any server-side path and replaces the live model. Bind to
//! loopback or a trusted network segment; an internet-facing deployment
//! needs a fronting proxy that terminates auth and blocks admin ops.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::latency::LatencyHistogram;
use crate::serve::batcher::{Batcher, BatcherConfig};
use crate::serve::scorer::{Scorer, SparseRow};
use crate::util::json::{self, Json};

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Listen address; port 0 picks a free port (see `ServerHandle::addr`).
    pub addr: String,
    /// Connection-handling threads = max concurrent connections; excess
    /// connections get `{"ok":false,"error":"server at capacity…"}` and are
    /// dropped rather than queued silently.
    pub io_threads: usize,
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".to_string(),
            io_threads: 8,
            batcher: BatcherConfig::default(),
        }
    }
}

struct ServerShared {
    batcher: Batcher,
    stop: AtomicBool,
    /// `predict` latency only — admin/health ops would pollute the p99.
    latency: LatencyHistogram,
    requests: AtomicU64,
    errors: AtomicU64,
    swaps: AtomicU64,
    /// Connections currently admitted (admission-controlled in accept).
    conns: AtomicUsize,
    started: Instant,
    engine: &'static str,
}

/// A running server. `stop()` (or drop) shuts it down and joins all threads.
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

/// Bind, spawn the accept loop and I/O pool, and return immediately.
pub fn serve(scorer: Arc<Scorer>, cfg: ServerConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let engine = scorer.engine_name();
    let shared = Arc::new(ServerShared {
        batcher: Batcher::start(scorer, cfg.batcher),
        stop: AtomicBool::new(false),
        latency: LatencyHistogram::new(),
        requests: AtomicU64::new(0),
        errors: AtomicU64::new(0),
        swaps: AtomicU64::new(0),
        conns: AtomicUsize::new(0),
        started: Instant::now(),
        engine,
    });

    let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let workers = (0..cfg.io_threads.max(1))
        .map(|_| {
            let rx = Arc::clone(&conn_rx);
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || loop {
                let stream = match rx.lock().unwrap().recv() {
                    Ok(s) => s,
                    Err(_) => return, // accept loop gone
                };
                handle_connection(stream, &shared);
                shared.conns.fetch_sub(1, Ordering::Relaxed);
            })
        })
        .collect();

    let accept = {
        let shared = Arc::clone(&shared);
        let max_conns = cfg.io_threads.max(1);
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                if shared.stop.load(Ordering::SeqCst) {
                    return; // conn_tx drops; workers drain and exit
                }
                match stream {
                    Ok(mut s) => {
                        // Admission control: refuse loudly instead of
                        // queueing a connection no worker will reach.
                        if shared.conns.load(Ordering::Relaxed) >= max_conns {
                            shared.errors.fetch_add(1, Ordering::Relaxed);
                            let mut out = err_json(format!(
                                "server at capacity ({max_conns} connections)"
                            ))
                            .dump();
                            out.push('\n');
                            let _ = s.write_all(out.as_bytes());
                            continue; // drop the socket
                        }
                        shared.conns.fetch_add(1, Ordering::Relaxed);
                        if conn_tx.send(s).is_err() {
                            return;
                        }
                    }
                    Err(_) => continue,
                }
            }
        })
    };

    Ok(ServerHandle {
        addr,
        shared,
        accept: Some(accept),
        workers,
    })
}

impl ServerHandle {
    /// The actual bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn requests(&self) -> u64 {
        self.shared.requests.load(Ordering::Relaxed)
    }

    pub fn latency(&self) -> &LatencyHistogram {
        &self.shared.latency
    }

    /// Signal shutdown and join every thread. Idempotent.
    pub fn stop(&mut self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the blocking accept() so it observes the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve one connection until the peer closes, errors, or the server stops.
/// Reads are chunked into an accumulator (never through a line reader, so a
/// read timeout mid-line loses nothing) and complete lines are answered in
/// arrival order.
fn handle_connection(mut stream: TcpStream, shared: &ServerShared) {
    /// A single request line may not exceed this; past it the connection is
    /// answered with an error and dropped, so a peer streaming bytes with
    /// no newline cannot grow the accumulator without bound.
    const MAX_LINE_BYTES: usize = 4 << 20;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let _ = stream.set_nodelay(true);
    let mut acc: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 16 * 1024];
    loop {
        while let Some(pos) = acc.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = acc.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&line[..line.len() - 1]).into_owned();
            if line.trim().is_empty() {
                continue;
            }
            let reply = handle_request(line.trim(), shared);
            shared.requests.fetch_add(1, Ordering::Relaxed);
            let mut out = reply.dump();
            out.push('\n');
            if stream.write_all(out.as_bytes()).is_err() {
                return;
            }
        }
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // peer closed
            Ok(n) => {
                acc.extend_from_slice(&chunk[..n]);
                if acc.len() > MAX_LINE_BYTES && !acc.contains(&b'\n') {
                    let mut out = err_json("request line exceeds 4 MiB").dump();
                    out.push('\n');
                    let _ = stream.write_all(out.as_bytes());
                    return;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // timeout tick: re-check the stop flag
            }
            Err(_) => return,
        }
    }
}

fn err_json(msg: impl std::fmt::Display) -> Json {
    let mut o = Json::obj();
    o.set("ok", false).set("error", msg.to_string());
    o
}

fn handle_request(line: &str, shared: &ServerShared) -> Json {
    let req = match json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            shared.errors.fetch_add(1, Ordering::Relaxed);
            return err_json(format!("bad json: {e}"));
        }
    };
    let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("");
    let reply = match op {
        "predict" => {
            // Only the serving path feeds the latency histogram — a
            // swap-model's disk load would otherwise pollute the p99.
            let t0 = Instant::now();
            let r = op_predict(&req, shared);
            shared.latency.record(t0.elapsed());
            r
        }
        "health" => Ok(op_health(shared)),
        "stats" => Ok(op_stats(shared)),
        "swap-model" => op_swap(&req, shared),
        "" => Err("missing op".to_string()),
        other => Err(format!("unknown op '{other}'")),
    };
    reply.unwrap_or_else(|e| {
        shared.errors.fetch_add(1, Ordering::Relaxed);
        err_json(e)
    })
}

/// Decode `"rows":[[[idx,val],…],…]` into sparse rows.
fn parse_rows(req: &Json) -> Result<Vec<SparseRow>, String> {
    let rows = match req.get("rows") {
        Some(Json::Arr(rows)) => rows,
        _ => return Err("predict needs a 'rows' array".to_string()),
    };
    let mut out = Vec::with_capacity(rows.len());
    for (ri, row) in rows.iter().enumerate() {
        let pairs = match row {
            Json::Arr(pairs) => pairs,
            _ => return Err(format!("row {ri} is not an array")),
        };
        let mut feats: SparseRow = Vec::with_capacity(pairs.len());
        for pair in pairs {
            let (j, v) = match pair {
                Json::Arr(p) if p.len() == 2 => {
                    match (p[0].as_f64(), p[1].as_f64()) {
                        (Some(j), Some(v)) => (j, v),
                        _ => return Err(format!("row {ri}: non-numeric pair")),
                    }
                }
                _ => return Err(format!("row {ri}: entries must be [feature,value] pairs")),
            };
            if j < 0.0 || j.fract() != 0.0 || j > u32::MAX as f64 {
                return Err(format!("row {ri}: bad feature index {j}"));
            }
            feats.push((j as u32, v));
        }
        out.push(feats);
    }
    Ok(out)
}

fn op_predict(req: &Json, shared: &ServerShared) -> Result<Json, String> {
    let rows = parse_rows(req)?;
    let scored = shared
        .batcher
        .score(rows)
        .map_err(|e| e.to_string())?;
    let mut o = Json::obj();
    o.set("ok", true)
        .set("version", scored.version)
        .set("probs", scored.probs)
        .set("margins", scored.margins);
    Ok(o)
}

fn op_health(shared: &ServerShared) -> Json {
    let reg = shared.batcher.scorer().registry();
    let (version, nnz, p) = match reg.current() {
        Some(s) => (s.version, s.model.nnz(), s.model.p),
        None => (0, 0, 0),
    };
    let mut o = Json::obj();
    o.set("ok", true)
        .set("version", version)
        .set("model_nnz", nnz)
        .set("model_p", p)
        .set("engine", shared.engine)
        .set("uptime_s", shared.started.elapsed().as_secs_f64())
        .set("requests", shared.requests.load(Ordering::Relaxed))
        .set("errors", shared.errors.load(Ordering::Relaxed))
        .set("swaps", shared.swaps.load(Ordering::Relaxed))
        .set("connections", shared.conns.load(Ordering::Relaxed))
        .set("latency", shared.latency.to_json())
        .set("batcher", shared.batcher.stats().to_json());
    o
}

/// The NDJSON admin stats endpoint: the process-wide metrics-registry
/// snapshot (`obs::metrics::global()`) plus this server's own counters —
/// the same payload shape the worker protocol's `{"op":"stats"}` control
/// frame answers with, so one poller speaks to both.
fn op_stats(shared: &ServerShared) -> Json {
    let mut server = Json::obj();
    server
        .set("engine", shared.engine)
        .set("uptime_s", shared.started.elapsed().as_secs_f64())
        .set("requests", shared.requests.load(Ordering::Relaxed))
        .set("errors", shared.errors.load(Ordering::Relaxed))
        .set("swaps", shared.swaps.load(Ordering::Relaxed))
        .set("connections", shared.conns.load(Ordering::Relaxed))
        .set("latency", shared.latency.to_json());
    let mut o = Json::obj();
    o.set("ok", true)
        .set("server", server)
        .set("metrics", crate::obs::metrics::global().snapshot());
    o
}

fn op_swap(req: &Json, shared: &ServerShared) -> Result<Json, String> {
    let reg = shared.batcher.scorer().registry();
    let version = match req.get("path").and_then(|p| p.as_str()) {
        Some(path) => reg.load_path(path).map_err(|e| e.to_string())?,
        None => reg.reload().map_err(|e| e.to_string())?,
    };
    shared.swaps.fetch_add(1, Ordering::Relaxed);
    let snap = reg.get(version).expect("just published");
    let mut o = Json::obj();
    o.set("ok", true)
        .set("version", version)
        .set("nnz", snap.model.nnz())
        .set("p", snap.model.p);
    Ok(o)
}

/// Blocking line-protocol client — the shape the examples, the load
/// generator and the tests talk to the server with.
pub struct ServeClient {
    stream: TcpStream,
    acc: Vec<u8>,
}

impl ServeClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> std::io::Result<ServeClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(ServeClient {
            stream,
            acc: Vec::new(),
        })
    }

    /// Send one request line and block for its reply line.
    pub fn roundtrip(&mut self, req: &Json) -> Result<Json, String> {
        let mut line = req.dump();
        line.push('\n');
        self.stream
            .write_all(line.as_bytes())
            .map_err(|e| format!("send: {e}"))?;
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if let Some(pos) = self.acc.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = self.acc.drain(..=pos).collect();
                let text = String::from_utf8_lossy(&line[..line.len() - 1]).into_owned();
                return json::parse(&text).map_err(|e| format!("bad reply: {e}"));
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err("server closed connection".to_string()),
                Ok(n) => self.acc.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(format!("recv: {e}")),
            }
        }
    }

    /// Score rows; returns (model version, probabilities).
    pub fn predict(&mut self, rows: &[SparseRow]) -> Result<(u64, Vec<f64>), String> {
        let rows_json: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::Arr(
                    r.iter()
                        .map(|&(j, v)| Json::Arr(vec![Json::Num(j as f64), Json::Num(v)]))
                        .collect(),
                )
            })
            .collect();
        let mut req = Json::obj();
        req.set("op", "predict").set("rows", Json::Arr(rows_json));
        let reply = self.roundtrip(&req)?;
        expect_ok(&reply)?;
        let version = reply
            .get("version")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        let probs = match reply.get("probs") {
            Some(Json::Arr(ps)) => ps.iter().filter_map(|p| p.as_f64()).collect(),
            _ => return Err("reply missing probs".to_string()),
        };
        Ok((version, probs))
    }

    pub fn health(&mut self) -> Result<Json, String> {
        let mut req = Json::obj();
        req.set("op", "health");
        let reply = self.roundtrip(&req)?;
        expect_ok(&reply)?;
        Ok(reply)
    }

    /// Fetch the admin stats payload: server counters + the process-wide
    /// metrics-registry snapshot.
    pub fn stats(&mut self) -> Result<Json, String> {
        let mut req = Json::obj();
        req.set("op", "stats");
        let reply = self.roundtrip(&req)?;
        expect_ok(&reply)?;
        Ok(reply)
    }

    /// Promote a model: from `path`, or re-read the server's current source.
    pub fn swap_model(&mut self, path: Option<&str>) -> Result<u64, String> {
        let mut req = Json::obj();
        req.set("op", "swap-model");
        if let Some(p) = path {
            req.set("path", p);
        }
        let reply = self.roundtrip(&req)?;
        expect_ok(&reply)?;
        Ok(reply.get("version").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64)
    }
}

fn expect_ok(reply: &Json) -> Result<(), String> {
    match reply.get("ok") {
        Some(Json::Bool(true)) => Ok(()),
        _ => Err(reply
            .get("error")
            .and_then(|e| e.as_str())
            .unwrap_or("request failed")
            .to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::loss::LossKind;
    use crate::glm::model::GlmModel;
    use crate::serve::registry::ModelRegistry;
    use crate::serve::scorer::NativeFactory;

    fn start_with(beta: Vec<f64>, io_threads: usize) -> (Arc<ModelRegistry>, ServerHandle) {
        let reg = Arc::new(ModelRegistry::with_model(GlmModel::new(
            LossKind::Logistic,
            beta,
        )));
        let scorer = Arc::new(Scorer::new(Arc::clone(&reg), Box::new(NativeFactory)));
        let handle = serve(
            scorer,
            ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                io_threads,
                batcher: BatcherConfig::default(),
            },
        )
        .unwrap();
        (reg, handle)
    }

    fn start(beta: Vec<f64>) -> (Arc<ModelRegistry>, ServerHandle) {
        start_with(beta, 4)
    }

    #[test]
    fn predict_health_roundtrip() {
        let (_, mut h) = start(vec![0.0, 1.0, -2.0]);
        let mut c = ServeClient::connect(h.addr()).unwrap();
        let (version, probs) = c.predict(&[vec![(1, 1.0)], vec![(2, 1.0)]]).unwrap();
        assert_eq!(version, 1);
        assert_eq!(probs.len(), 2);
        assert!(probs[0] > 0.5 && probs[1] < 0.5);
        let health = c.health().unwrap();
        assert_eq!(health.get("version").unwrap().as_f64(), Some(1.0));
        assert!(health.get("requests").unwrap().as_f64().unwrap() >= 1.0);
        h.stop();
    }

    #[test]
    fn stats_op_returns_registry_snapshot_and_server_counters() {
        let (_, mut h) = start(vec![0.0, 1.0]);
        let mut c = ServeClient::connect(h.addr()).unwrap();
        c.predict(&[vec![(1, 1.0)]]).unwrap();
        let stats = c.stats().unwrap();
        let server = stats.get("server").expect("server section");
        assert!(server.get("requests").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(server.get("engine").unwrap().as_str(), Some("native"));
        assert!(
            server.get("latency").and_then(|l| l.get("count")).is_some(),
            "stats must embed the predict latency histogram"
        );
        let metrics = stats.get("metrics").expect("metrics section");
        for section in ["counters", "gauges", "histograms"] {
            assert!(metrics.get(section).is_some(), "missing {section}");
        }
        h.stop();
    }

    #[test]
    fn malformed_lines_keep_connection_alive() {
        let (_, mut h) = start(vec![1.0]);
        let mut c = ServeClient::connect(h.addr()).unwrap();
        for bad in [
            "not json at all",
            "{\"op\":\"bogus\"}",
            "{\"no\":\"op\"}",
            "{\"op\":\"predict\"}",
            "{\"op\":\"predict\",\"rows\":[[[\"x\",1]]]}",
            "{\"op\":\"predict\",\"rows\":[[[-3,1.0]]]}",
        ] {
            c.stream
                .write_all(format!("{bad}\n").as_bytes())
                .unwrap();
            let mut chunk = [0u8; 4096];
            let reply = loop {
                if let Some(pos) = c.acc.iter().position(|&b| b == b'\n') {
                    let line: Vec<u8> = c.acc.drain(..=pos).collect();
                    break String::from_utf8_lossy(&line[..line.len() - 1]).into_owned();
                }
                let n = c.stream.read(&mut chunk).unwrap();
                assert!(n > 0, "server closed on malformed input");
                c.acc.extend_from_slice(&chunk[..n]);
            };
            let j = json::parse(&reply).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "input: {bad}");
        }
        // Still serving after the garbage.
        let (_, probs) = c.predict(&[vec![(0, 1.0)]]).unwrap();
        assert_eq!(probs.len(), 1);
        h.stop();
    }

    #[test]
    fn swap_model_over_socket() {
        let dir = std::env::temp_dir().join(format!("dglmnet_srv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m2.json");
        GlmModel::new(LossKind::Logistic, vec![5.0]).save(&path).unwrap();
        let (_, mut h) = start(vec![-5.0]);
        let mut c = ServeClient::connect(h.addr()).unwrap();
        let (v1, p1) = c.predict(&[vec![(0, 1.0)]]).unwrap();
        assert_eq!(v1, 1);
        assert!(p1[0] < 0.5);
        let v2 = c.swap_model(Some(path.to_str().unwrap())).unwrap();
        assert_eq!(v2, 2);
        let (v, p2) = c.predict(&[vec![(0, 1.0)]]).unwrap();
        assert_eq!(v, 2);
        assert!(p2[0] > 0.5, "new model must be live");
        // Swap to a bad path fails but the old model keeps serving.
        assert!(c.swap_model(Some("/nonexistent/model.json")).is_err());
        let (v, _) = c.predict(&[vec![(0, 1.0)]]).unwrap();
        assert_eq!(v, 2);
        std::fs::remove_dir_all(&dir).ok();
        h.stop();
    }

    #[test]
    fn excess_connections_refused_loudly() {
        let (_, mut h) = start_with(vec![1.0], 1);
        let mut c1 = ServeClient::connect(h.addr()).unwrap();
        // A successful request proves c1 was admitted (conns = 1).
        c1.predict(&[vec![(0, 1.0)]]).unwrap();
        // The refusal line arrives unsolicited; read without writing so the
        // server-side close can't RST our request away first.
        let mut c2 = ServeClient::connect(h.addr()).unwrap();
        let mut buf = [0u8; 4096];
        while !c2.acc.contains(&b'\n') {
            let n = c2.stream.read(&mut buf).unwrap();
            assert!(n > 0, "connection closed before the refusal line");
            c2.acc.extend_from_slice(&buf[..n]);
        }
        let line = String::from_utf8_lossy(&c2.acc);
        assert!(line.contains("capacity"), "{line}");
        // The admitted connection keeps working.
        c1.predict(&[vec![(0, 1.0)]]).unwrap();
        h.stop();
    }

    #[test]
    fn stop_is_clean_and_idempotent() {
        let (_, mut h) = start(vec![1.0]);
        let addr = h.addr();
        h.stop();
        h.stop();
        assert!(ServeClient::connect(addr)
            .and_then(|mut c| {
                c.stream.write_all(b"{\"op\":\"health\"}\n")?;
                let mut buf = [0u8; 16];
                let n = c.stream.read(&mut buf)?;
                Ok(n)
            })
            .map(|n| n == 0)
            .unwrap_or(true));
    }
}
