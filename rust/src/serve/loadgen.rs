//! Load generator for a running serve endpoint (`dglmnet bench-serve`,
//! `benches/serve_throughput.rs`).
//!
//! N client threads each open their own connection and fire a fixed number
//! of synchronous `predict` requests with synthetic sparse rows (Zipf-free
//! uniform features — the scorer cost is nnz-bound, not skew-bound).
//! Per-request wall latency lands in a per-thread [`LatencyHistogram`];
//! the report merges them and derives QPS from total requests over the
//! longest thread's wall time (the honest aggregate for closed-loop load).

use std::sync::Arc;
use std::time::Instant;

use crate::glm::loss::LossKind;
use crate::glm::model::GlmModel;
use crate::metrics::latency::LatencyHistogram;
use crate::serve::scorer::SparseRow;
use crate::serve::server::ServeClient;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A synthetic L1-style model: `nnz` normal weights planted in a zero β
/// over `p` features — the shape `bench-serve`, the throughput bench and
/// the tests all load-test against, defined once.
pub fn synthetic_model(p: usize, nnz: usize, seed: u64) -> GlmModel {
    let mut rng = Rng::new(seed);
    let mut beta = vec![0.0; p];
    for _ in 0..nnz {
        beta[rng.below(p)] = rng.normal();
    }
    GlmModel::new(LossKind::Logistic, beta)
}

#[derive(Clone, Copy, Debug)]
pub struct LoadgenConfig {
    /// Concurrent closed-loop clients (the acceptance bar is ≥ 4).
    pub threads: usize,
    pub requests_per_thread: usize,
    pub rows_per_request: usize,
    pub nnz_per_row: usize,
    /// Feature-space width to draw indices from (≤ the model's p).
    pub p: usize,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            threads: 4,
            requests_per_thread: 1_000,
            rows_per_request: 4,
            nnz_per_row: 32,
            p: 1 << 16,
            seed: 1,
        }
    }
}

pub struct LoadgenReport {
    pub threads: usize,
    pub total_requests: u64,
    pub total_rows: u64,
    /// Wall-clock of the slowest client thread, seconds.
    pub wall_secs: f64,
    pub hist: LatencyHistogram,
}

impl LoadgenReport {
    pub fn qps(&self) -> f64 {
        self.total_requests as f64 / self.wall_secs.max(1e-12)
    }

    pub fn rows_per_sec(&self) -> f64 {
        self.total_rows as f64 / self.wall_secs.max(1e-12)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("threads", self.threads)
            .set("requests", self.total_requests)
            .set("rows", self.total_rows)
            .set("wall_secs", self.wall_secs)
            .set("qps", self.qps())
            .set("rows_per_sec", self.rows_per_sec())
            .set("latency", self.hist.to_json());
        o
    }

    pub fn print(&self) {
        crate::obs::log::emit(&format!(
            "bench-serve: {} threads × {} req | {:.0} req/s, {:.0} rows/s | \
             latency p50 {:.3}ms p99 {:.3}ms max {:.3}ms",
            self.threads,
            self.total_requests / self.threads.max(1) as u64,
            self.qps(),
            self.rows_per_sec(),
            self.hist.quantile_ns(0.50) as f64 / 1e6,
            self.hist.quantile_ns(0.99) as f64 / 1e6,
            self.hist.max_ns() as f64 / 1e6,
        ));
    }
}

fn synth_rows(rng: &mut Rng, cfg: &LoadgenConfig) -> Vec<SparseRow> {
    (0..cfg.rows_per_request)
        .map(|_| {
            (0..cfg.nnz_per_row)
                .map(|_| (rng.below(cfg.p) as u32, rng.range_f64(-1.0, 1.0)))
                .collect()
        })
        .collect()
}

/// Drive `addr` with `cfg`; blocks until every client thread finishes.
pub fn run_loadgen(
    addr: impl std::net::ToSocketAddrs + Clone + Send + Sync,
    cfg: LoadgenConfig,
) -> Result<LoadgenReport, String> {
    let merged = Arc::new(LatencyHistogram::new());
    let mut wall_secs = 0.0f64;
    let mut total_rows = 0u64;
    let results: Vec<Result<(f64, u64), String>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.threads.max(1))
            .map(|t| {
                let addr = addr.clone();
                let merged = Arc::clone(&merged);
                s.spawn(move || -> Result<(f64, u64), String> {
                    let mut client =
                        ServeClient::connect(addr).map_err(|e| format!("connect: {e}"))?;
                    let mut rng = Rng::new(cfg.seed ^ (t as u64).wrapping_mul(0x9E37_79B9));
                    let hist = LatencyHistogram::new();
                    let mut rows_sent = 0u64;
                    let t0 = Instant::now();
                    for _ in 0..cfg.requests_per_thread {
                        let rows = synth_rows(&mut rng, &cfg);
                        rows_sent += rows.len() as u64;
                        let r0 = Instant::now();
                        let (_, probs) = client.predict(&rows)?;
                        hist.record(r0.elapsed());
                        if probs.len() != cfg.rows_per_request {
                            return Err(format!(
                                "reply arity {} != {}",
                                probs.len(),
                                cfg.rows_per_request
                            ));
                        }
                    }
                    let wall = t0.elapsed().as_secs_f64();
                    merged.merge(&hist);
                    Ok((wall, rows_sent))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| "client thread panicked".to_string())?)
            .collect()
    });
    for r in results {
        let (wall, rows) = r?;
        wall_secs = wall_secs.max(wall);
        total_rows += rows;
    }
    let total_requests = (cfg.threads.max(1) * cfg.requests_per_thread) as u64;
    let hist = LatencyHistogram::new();
    hist.merge(&merged);
    Ok(LoadgenReport {
        threads: cfg.threads.max(1),
        total_requests,
        total_rows,
        wall_secs,
        hist,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::ModelRegistry;
    use crate::serve::scorer::{NativeFactory, Scorer};
    use crate::serve::server::{serve, ServerConfig};

    #[test]
    fn loadgen_against_in_process_server() {
        let p = 1 << 10;
        let reg = Arc::new(ModelRegistry::with_model(synthetic_model(p, 64, 7)));
        let scorer = Arc::new(Scorer::new(reg, Box::new(NativeFactory)));
        let mut h = serve(
            scorer,
            ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                io_threads: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let report = run_loadgen(
            h.addr(),
            LoadgenConfig {
                threads: 4,
                requests_per_thread: 25,
                rows_per_request: 3,
                nnz_per_row: 8,
                p,
                seed: 42,
            },
        )
        .unwrap();
        assert_eq!(report.total_requests, 100);
        assert_eq!(report.total_rows, 300);
        assert_eq!(report.hist.count(), 100);
        assert!(report.qps() > 0.0);
        assert!(report.hist.quantile_ns(0.99) >= report.hist.quantile_ns(0.50));
        h.stop();
    }
}
