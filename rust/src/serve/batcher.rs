//! Micro-batching queue between the request threads and the scorer.
//!
//! Online traffic arrives one small request at a time, but the scorer's
//! throughput comes from scoring blocks (one plan lookup, one dense-weight
//! pass, one batched link application). The batcher coalesces concurrent
//! requests: the first request to arrive opens a batch, the worker lingers
//! up to `max_wait` for more rows (up to `max_batch_rows`), then scores the
//! whole block once and routes each slice of results back to its caller.
//! Under light load a request pays at most the linger; under heavy load
//! batches fill instantly and throughput scales with cores, not with
//! request count.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::serve::scorer::{ScoreError, ScoredBatch, Scorer, SparseRow};
use crate::util::json::Json;

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Row budget per micro-batch; a batch may exceed it by at most one
    /// request (requests are never split).
    pub max_batch_rows: usize,
    /// How long a non-full batch lingers waiting for company.
    pub max_wait: Duration,
    /// Scoring worker threads draining the queue.
    pub workers: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch_rows: 256,
            max_wait: Duration::from_micros(200),
            workers: 2,
        }
    }
}

/// Running counters, all relaxed — approximate under concurrency, exact
/// once quiescent.
#[derive(Default)]
pub struct BatcherStats {
    pub batches: AtomicU64,
    pub requests: AtomicU64,
    pub rows: AtomicU64,
}

impl BatcherStats {
    pub fn to_json(&self) -> Json {
        let batches = self.batches.load(Ordering::Relaxed);
        let requests = self.requests.load(Ordering::Relaxed);
        let rows = self.rows.load(Ordering::Relaxed);
        let mut o = Json::obj();
        o.set("batches", batches)
            .set("requests", requests)
            .set("rows", rows)
            .set(
                "avg_batch_rows",
                if batches == 0 {
                    0.0
                } else {
                    rows as f64 / batches as f64
                },
            );
        o
    }
}

struct Job {
    rows: Vec<SparseRow>,
    reply: mpsc::Sender<Result<ScoredBatch, ScoreError>>,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    nonempty: Condvar,
    stop: AtomicBool,
    scorer: Arc<Scorer>,
    stats: BatcherStats,
}

/// The micro-batching queue; see module docs. Dropping it stops and joins
/// the workers (pending jobs are answered first).
pub struct Batcher {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(scorer: Arc<Scorer>, cfg: BatcherConfig) -> Batcher {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            nonempty: Condvar::new(),
            stop: AtomicBool::new(false),
            scorer,
            stats: BatcherStats::default(),
        });
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, &cfg))
            })
            .collect();
        Batcher { shared, workers }
    }

    /// Enqueue rows for scoring; the receiver yields exactly one result
    /// whose `margins`/`probs` are parallel to `rows`.
    pub fn submit(&self, rows: Vec<SparseRow>) -> mpsc::Receiver<Result<ScoredBatch, ScoreError>> {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Job { rows, reply: tx });
        }
        self.shared.nonempty.notify_one();
        rx
    }

    /// Convenience: submit and block for the result.
    pub fn score(&self, rows: Vec<SparseRow>) -> Result<ScoredBatch, ScoreError> {
        self.submit(rows)
            .recv()
            .expect("batcher worker dropped reply")
    }

    pub fn stats(&self) -> &BatcherStats {
        &self.shared.stats
    }

    pub fn scorer(&self) -> &Arc<Scorer> {
        &self.shared.scorer
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.nonempty.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared, cfg: &BatcherConfig) {
    loop {
        // Wait for the first job of the next batch.
        let first = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                // Bounded wait so a stop() without traffic is noticed even
                // if the notify raced ahead of this wait.
                let (guard, _) = shared
                    .nonempty
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
        };
        let mut batch = vec![first];
        let mut total_rows = batch[0].rows.len();
        let deadline = Instant::now() + cfg.max_wait;

        // Linger: top the batch up until the row budget or the deadline.
        while total_rows < cfg.max_batch_rows {
            let mut q = shared.queue.lock().unwrap();
            if let Some(job) = q.pop_front() {
                total_rows += job.rows.len();
                batch.push(job);
                continue;
            }
            let now = Instant::now();
            if now >= deadline || shared.stop.load(Ordering::SeqCst) {
                break;
            }
            let (_guard, timeout) = shared
                .nonempty
                .wait_timeout(q, deadline - now)
                .unwrap();
            if timeout.timed_out() {
                break;
            }
        }

        // Move the rows out of the jobs into one contiguous block (no row
        // clones on the hot path), remembering each job's span for routing
        // results back. Then score the coalesced block once, outside every
        // lock.
        let mut all: Vec<SparseRow> = Vec::with_capacity(total_rows);
        let mut spans = Vec::with_capacity(batch.len());
        for job in &mut batch {
            let rows = std::mem::take(&mut job.rows);
            spans.push((all.len(), rows.len()));
            all.extend(rows);
        }
        let result = shared.scorer.score(&all);
        shared.stats.batches.fetch_add(1, Ordering::Relaxed);
        shared
            .stats
            .requests
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        shared
            .stats
            .rows
            .fetch_add(total_rows as u64, Ordering::Relaxed);

        // Split results back per request (send fails only if the caller
        // gave up waiting — not an error for the batch).
        match result {
            Ok(scored) => {
                for (job, (off, n)) in batch.into_iter().zip(spans) {
                    let slice = ScoredBatch {
                        version: scored.version,
                        margins: scored.margins[off..off + n].to_vec(),
                        probs: scored.probs[off..off + n].to_vec(),
                    };
                    let _ = job.reply.send(Ok(slice));
                }
            }
            Err(_) => {
                // One bad row must not poison its batch-mates: fall back to
                // scoring each request alone, so only the offender sees the
                // error (and with a request-relative row index).
                for (job, (off, n)) in batch.into_iter().zip(spans) {
                    let _ = job.reply.send(shared.scorer.score(&all[off..off + n]));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::loss::LossKind;
    use crate::glm::model::GlmModel;
    use crate::serve::registry::ModelRegistry;
    use crate::serve::scorer::NativeFactory;

    fn batcher(cfg: BatcherConfig) -> (Arc<ModelRegistry>, Batcher) {
        let mut beta = vec![0.0; 16];
        for (j, b) in beta.iter_mut().enumerate() {
            *b = j as f64;
        }
        let reg = Arc::new(ModelRegistry::with_model(GlmModel::new(
            LossKind::Logistic,
            beta,
        )));
        let scorer = Arc::new(Scorer::new(Arc::clone(&reg), Box::new(NativeFactory)));
        (reg, Batcher::start(scorer, cfg))
    }

    #[test]
    fn single_request_roundtrip() {
        let (_, b) = batcher(BatcherConfig::default());
        let got = b.score(vec![vec![(2, 1.0)], vec![(3, 2.0)]]).unwrap();
        assert_eq!(got.margins, vec![2.0, 6.0]);
        assert_eq!(got.probs.len(), 2);
    }

    #[test]
    fn error_propagates_to_caller() {
        let (_, b) = batcher(BatcherConfig::default());
        let err = b.score(vec![vec![(99, 1.0)]]).unwrap_err();
        assert!(matches!(err, ScoreError::FeatureOutOfRange { .. }));
    }

    #[test]
    fn concurrent_requests_coalesce_and_route_correctly() {
        // One slow-draining worker + a generous linger forces coalescing;
        // every caller must still get exactly its own rows back.
        let (_, b) = batcher(BatcherConfig {
            max_batch_rows: 64,
            max_wait: Duration::from_millis(20),
            workers: 1,
        });
        let b = &b;
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..16u32 {
                handles.push(s.spawn(move || {
                    let got = b.score(vec![vec![(t % 16, 1.0)]]).unwrap();
                    assert_eq!(got.margins, vec![(t % 16) as f64], "thread {t}");
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        let stats = b.stats();
        let batches = stats.batches.load(Ordering::Relaxed);
        let requests = stats.requests.load(Ordering::Relaxed);
        assert_eq!(requests, 16);
        assert!(batches < 16, "expected coalescing, got {batches} batches");
    }

    #[test]
    fn row_budget_bounds_batches() {
        let (_, b) = batcher(BatcherConfig {
            max_batch_rows: 4,
            max_wait: Duration::from_millis(10),
            workers: 1,
        });
        let pending: Vec<_> = (0..12)
            .map(|_| b.submit(vec![vec![(1, 1.0)], vec![(2, 1.0)]]))
            .collect();
        for rx in pending {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(got.margins, vec![1.0, 2.0]);
        }
        // 12 requests × 2 rows with a 4-row budget ⇒ at least 5 batches
        // (each batch holds ≤ 2 requests: budget may overshoot by one job).
        let batches = b.stats().batches.load(Ordering::Relaxed);
        assert!(batches >= 5, "batches {batches}");
    }

    #[test]
    fn bad_request_does_not_poison_batchmates() {
        // Generous linger + single worker so the two requests coalesce;
        // the valid one must still succeed when its batch-mate errors.
        let (_, b) = batcher(BatcherConfig {
            max_batch_rows: 64,
            max_wait: Duration::from_millis(20),
            workers: 1,
        });
        let b = &b;
        std::thread::scope(|s| {
            let good = s.spawn(move || b.score(vec![vec![(1, 1.0)]]));
            let bad = s.spawn(move || b.score(vec![vec![(999, 1.0)]]));
            assert_eq!(good.join().unwrap().unwrap().margins, vec![1.0]);
            let err = bad.join().unwrap().unwrap_err();
            // Row index is request-relative, not batch-global.
            assert_eq!(
                err,
                ScoreError::FeatureOutOfRange {
                    row: 0,
                    feature: 999,
                    p: 16
                }
            );
        });
    }

    #[test]
    fn empty_rows_request_is_fine() {
        let (_, b) = batcher(BatcherConfig::default());
        let got = b.score(Vec::new()).unwrap();
        assert!(got.margins.is_empty() && got.probs.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let (_, b) = batcher(BatcherConfig::default());
        b.score(vec![vec![(1, 1.0)]]).unwrap();
        drop(b); // must not hang
    }
}
