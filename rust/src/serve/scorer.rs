//! Scoring engine: registry snapshot → dense scoring plan → probabilities.
//!
//! Serving scores a handful of sparse rows per request against a *fixed*
//! model, so the profitable layout is the opposite of training: densify the
//! model's sparse β once per version ([`GlmModel::dense_weights`]) and make
//! each row a gather against that dense vector. The inverse link runs
//! through the same [`GlmCompute`] seam the trainer uses — `--engine native`
//! builds [`NativeCompute`], `--engine xla` plugs the PJRT-backed
//! `XlaCompute` in behind the identical trait — so serving honors the
//! crate's compute split instead of inventing a parallel one.
//!
//! [`NativeCompute`]: crate::solver::compute::NativeCompute

use std::sync::{Arc, RwLock};

use crate::glm::loss::LossKind;
use crate::glm::model::GlmModel;
use crate::serve::registry::ModelRegistry;
use crate::solver::compute::{GlmCompute, NativeCompute};
use crate::sparse::Csr;

/// One example to score: sparse (feature, value) pairs, any order.
pub type SparseRow = Vec<(u32, f64)>;

/// Pluggable compute construction — the serve-side face of the
/// `NativeCompute`/`XlaCompute` engine split. Built once per model version
/// (the loss family can change across promotions).
pub trait ComputeFactory: Send + Sync {
    fn name(&self) -> &'static str;
    fn build(&self, kind: LossKind) -> Box<dyn GlmCompute>;
}

/// Pure-Rust engine (the default, and the correctness oracle).
pub struct NativeFactory;

impl ComputeFactory for NativeFactory {
    fn name(&self) -> &'static str {
        "native"
    }
    fn build(&self, kind: LossKind) -> Box<dyn GlmCompute> {
        Box::new(NativeCompute::new(kind))
    }
}

#[derive(Clone, Debug, PartialEq, thiserror::Error)]
pub enum ScoreError {
    #[error("no model published yet")]
    NoModel,
    #[error("row {row}: feature {feature} outside model space ({p} features)")]
    FeatureOutOfRange { row: usize, feature: u32, p: usize },
}

/// Immutable per-version scoring state: dense weights + compute engine.
pub struct ScorePlan {
    pub version: u64,
    pub kind: LossKind,
    /// β densified over the model's full feature space, built once per
    /// version.
    pub weights: Vec<f64>,
    pub nnz: usize,
    compute: Box<dyn GlmCompute>,
}

/// Scores from one batch, tagged with the model version that produced them.
#[derive(Clone, Debug, PartialEq)]
pub struct ScoredBatch {
    pub version: u64,
    pub margins: Vec<f64>,
    pub probs: Vec<f64>,
}

/// The scoring engine; see module docs.
pub struct Scorer {
    registry: Arc<ModelRegistry>,
    factory: Box<dyn ComputeFactory>,
    plan: RwLock<Option<Arc<ScorePlan>>>,
}

impl Scorer {
    pub fn new(registry: Arc<ModelRegistry>, factory: Box<dyn ComputeFactory>) -> Scorer {
        Scorer {
            registry,
            factory,
            plan: RwLock::new(None),
        }
    }

    pub fn engine_name(&self) -> &'static str {
        self.factory.name()
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// The plan for the registry's *current* version, rebuilding (densify +
    /// compute construction) only when the version changed since the last
    /// call. The fast path is a read lock and a version compare.
    pub fn plan(&self) -> Result<Arc<ScorePlan>, ScoreError> {
        let live = self.registry.current().ok_or(ScoreError::NoModel)?;
        if let Some(p) = self.plan.read().unwrap().as_ref() {
            if p.version == live.version {
                return Ok(Arc::clone(p));
            }
        }
        let mut slot = self.plan.write().unwrap();
        // Re-fetch under the write lock: a swap may have landed since the
        // first read, and a thread holding a stale `live` must not clobber
        // a newer cached plan with an older one (versions are monotone, so
        // building against the re-fetched snapshot is always current).
        let live = self.registry.current().ok_or(ScoreError::NoModel)?;
        if let Some(p) = slot.as_ref() {
            if p.version == live.version {
                return Ok(Arc::clone(p));
            }
        }
        let built = Arc::new(self.build_plan(&live.model, live.version));
        *slot = Some(Arc::clone(&built));
        Ok(built)
    }

    fn build_plan(&self, model: &GlmModel, version: u64) -> ScorePlan {
        ScorePlan {
            version,
            kind: model.kind,
            weights: model.dense_weights(model.p),
            nnz: model.nnz(),
            compute: self.factory.build(model.kind),
        }
    }

    /// Score a block of sparse rows. One plan lookup, one margin gather per
    /// row, one batched inverse-link application through the compute seam.
    pub fn score(&self, rows: &[SparseRow]) -> Result<ScoredBatch, ScoreError> {
        let plan = self.plan()?;
        let p = plan.weights.len();
        let mut margins = Vec::with_capacity(rows.len());
        for (ri, row) in rows.iter().enumerate() {
            let mut m = 0.0;
            for &(j, v) in row {
                let j = j as usize;
                if j >= p {
                    return Err(ScoreError::FeatureOutOfRange {
                        row: ri,
                        feature: j as u32,
                        p,
                    });
                }
                m += plan.weights[j] * v;
            }
            margins.push(m);
        }
        let probs = plan.compute.predict_probs(&margins);
        Ok(ScoredBatch {
            version: plan.version,
            margins,
            probs,
        })
    }

    /// Score an already-assembled CSR block (batch `predict` over a file).
    pub fn score_csr(&self, x: &Csr) -> Result<ScoredBatch, ScoreError> {
        let plan = self.plan()?;
        let p = plan.weights.len();
        if x.ncols > p {
            return Err(ScoreError::FeatureOutOfRange {
                row: 0,
                feature: x.ncols as u32 - 1,
                p,
            });
        }
        let margins: Vec<f64> = (0..x.nrows).map(|i| x.dot_row(i, &plan.weights)).collect();
        let probs = plan.compute.predict_probs(&margins);
        Ok(ScoredBatch {
            version: plan.version,
            margins,
            probs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::loss::LossKind;

    fn scorer_with(beta: Vec<f64>) -> (Arc<ModelRegistry>, Scorer) {
        let reg = Arc::new(ModelRegistry::with_model(GlmModel::new(
            LossKind::Logistic,
            beta,
        )));
        let sc = Scorer::new(Arc::clone(&reg), Box::new(NativeFactory));
        (reg, sc)
    }

    #[test]
    fn score_matches_model_predict() {
        let mut beta = vec![0.0; 6];
        beta[1] = 2.0;
        beta[4] = -1.0;
        let (_, sc) = scorer_with(beta.clone());
        let rows: Vec<SparseRow> = vec![vec![(1, 1.0)], vec![(4, 2.0), (1, 0.5)], vec![]];
        let got = sc.score(&rows).unwrap();
        assert_eq!(got.version, 1);
        assert_eq!(got.margins, vec![2.0, -1.0, 0.0]);
        let model = GlmModel::new(LossKind::Logistic, beta);
        let x = Csr::from_rows(6, &[vec![(1, 1.0)], vec![(4, 2.0), (1, 0.5)], vec![]]);
        assert_eq!(got.probs, model.predict_proba(&x));
        // CSR path agrees with the row path.
        assert_eq!(sc.score_csr(&x).unwrap(), got);
    }

    #[test]
    fn out_of_range_feature_rejected() {
        let (_, sc) = scorer_with(vec![0.5; 4]);
        let err = sc.score(&[vec![(9, 1.0)]]).unwrap_err();
        assert_eq!(
            err,
            ScoreError::FeatureOutOfRange {
                row: 0,
                feature: 9,
                p: 4
            }
        );
    }

    #[test]
    fn empty_registry_errors() {
        let reg = Arc::new(ModelRegistry::new());
        let sc = Scorer::new(reg, Box::new(NativeFactory));
        assert_eq!(sc.score(&[vec![]]).unwrap_err(), ScoreError::NoModel);
    }

    #[test]
    fn plan_rebuilds_only_on_version_change() {
        let (reg, sc) = scorer_with(vec![1.0, 0.0, 3.0]);
        let p1 = sc.plan().unwrap();
        assert!(Arc::ptr_eq(&p1, &sc.plan().unwrap()), "plan must be cached");
        assert_eq!(p1.nnz, 2);
        reg.publish(GlmModel::new(LossKind::Probit, vec![0.0, 5.0]));
        let p2 = sc.plan().unwrap();
        assert_eq!(p2.version, 2);
        assert_eq!(p2.kind, LossKind::Probit);
        assert_eq!(p2.weights, vec![0.0, 5.0]);
    }

    #[test]
    fn swap_is_visible_to_scoring() {
        let (reg, sc) = scorer_with(vec![1.0]);
        assert_eq!(sc.score(&[vec![(0, 1.0)]]).unwrap().margins, vec![1.0]);
        reg.publish(GlmModel::new(LossKind::Logistic, vec![-4.0]));
        let after = sc.score(&[vec![(0, 1.0)]]).unwrap();
        assert_eq!(after.version, 2);
        assert_eq!(after.margins, vec![-4.0]);
    }
}
