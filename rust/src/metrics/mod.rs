//! Classification quality metrics, plus serving latency instrumentation.
//!
//! The paper's headline quality measure is area under the precision-recall
//! curve (Appendix C) — chosen over ROC AUC because the click datasets are
//! heavily imbalanced. We implement auPRC exactly as defined there (sweep
//! the threshold over predicted scores), plus ROC AUC, log-loss and accuracy
//! for cross-checks. The [`latency`] submodule holds the lock-free p50/p99
//! histogram the serve subsystem reports through; the same histogram type is
//! what [`crate::obs::metrics`]'s registry hands out, so training-side span
//! telemetry and serving-side latency share one quantile implementation.
//! Cluster-wide observability (structured logs, spans, counters/gauges) is
//! [`crate::obs`] — import `obs::prelude` for the whole kit.

pub mod latency;

pub use latency::LatencyHistogram;

/// Area under the precision-recall curve (Appendix C definition), estimated
/// as average precision: Σ_k (R_k − R_{k−1}) · P_k over the distinct-score
/// PR points. Step-wise (not trapezoid-from-(0,1)) so a constant classifier
/// scores exactly the positive base rate — the robust estimator Davis &
/// Goadrich (2006), the paper's reference [32], recommend.
/// NaN policy (shared by [`auprc`] and [`roc_auc`]): scores are ranked and
/// tie-grouped under the IEEE 754 total order (`f64::total_cmp`), so a
/// degenerate model whose margins contain NaN/±inf yields a *defined,
/// deterministic* metric instead of panicking the sort — the failure mode
/// approximate distributed inner solves are known to produce (Mahajan et
/// al., arXiv:1405.4544). A split with no positives scores 0.0, with no
/// negatives 1.0 (auPRC) / 0.5 (auROC).
pub fn auprc(labels: &[f64], scores: &[f64]) -> f64 {
    assert_eq!(labels.len(), scores.len());
    let total_pos = labels.iter().filter(|&&y| y > 0.0).count();
    if total_pos == 0 || total_pos == labels.len() {
        return if total_pos == 0 { 0.0 } else { 1.0 };
    }
    let mut order: Vec<usize> = (0..labels.len()).collect();
    order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));

    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut area = 0.0;
    let mut prev_recall = 0.0;
    let mut i = 0;
    while i < order.len() {
        // Consume the whole tie group before emitting a PR point. Ties are
        // `==` (so ±0.0 stay one group, as before) OR total-order equality
        // (so a NaN group advances instead of looping forever — NaN != NaN).
        // The sort keeps ==-equal values adjacent (nothing orders between
        // -0.0 and +0.0), so this grouping is sound.
        let s = scores[order[i]];
        while i < order.len()
            && (scores[order[i]] == s || scores[order[i]].total_cmp(&s).is_eq())
        {
            if labels[order[i]] > 0.0 {
                tp += 1;
            } else {
                fp += 1;
            }
            i += 1;
        }
        let recall = tp as f64 / total_pos as f64;
        let precision = tp as f64 / (tp + fp) as f64;
        area += (recall - prev_recall) * precision;
        prev_recall = recall;
    }
    area
}

/// ROC AUC via the rank-sum (Mann–Whitney) formulation with tie correction.
/// NaN scores follow the total-order policy documented on [`auprc`].
pub fn roc_auc(labels: &[f64], scores: &[f64]) -> f64 {
    assert_eq!(labels.len(), scores.len());
    let n_pos = labels.iter().filter(|&&y| y > 0.0).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..labels.len()).collect();
    order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    // Average ranks over tie groups: `==` (±0.0 stay one group) OR
    // total-order equality (NaN groups advance instead of spinning).
    let mut rank_sum_pos = 0.0;
    let mut i = 0;
    while i < order.len() {
        let s = scores[order[i]];
        let start = i;
        while i < order.len()
            && (scores[order[i]] == s || scores[order[i]].total_cmp(&s).is_eq())
        {
            i += 1;
        }
        let avg_rank = (start + 1 + i) as f64 / 2.0; // ranks are 1-based
        for &k in &order[start..i] {
            if labels[k] > 0.0 {
                rank_sum_pos += avg_rank;
            }
        }
    }
    (rank_sum_pos - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0) / (n_pos as f64 * n_neg as f64)
}

/// Mean logistic log-loss for labels in {-1,+1} and probability scores.
pub fn logloss(labels: &[f64], probs: &[f64]) -> f64 {
    assert_eq!(labels.len(), probs.len());
    let eps = 1e-15;
    let mut acc = 0.0;
    for (&y, &p) in labels.iter().zip(probs.iter()) {
        let p = p.clamp(eps, 1.0 - eps);
        acc -= if y > 0.0 { p.ln() } else { (1.0 - p).ln() };
    }
    acc / labels.len().max(1) as f64
}

/// Accuracy at threshold 0.5 on probabilities (or 0.0 on margins).
pub fn accuracy(labels: &[f64], scores: &[f64], threshold: f64) -> f64 {
    assert_eq!(labels.len(), scores.len());
    let correct = labels
        .iter()
        .zip(scores.iter())
        .filter(|(&y, &s)| (s >= threshold) == (y > 0.0))
        .count();
    correct as f64 / labels.len().max(1) as f64
}

/// Number of non-zero weights — the paper's sparsity axis (Fig. 4).
pub fn nnz_weights(beta: &[f64]) -> usize {
    beta.iter().filter(|&&b| b != 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn auprc_perfect_ranking() {
        let y = [1.0, 1.0, -1.0, -1.0];
        let s = [0.9, 0.8, 0.2, 0.1];
        assert!((auprc(&y, &s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auprc_worst_ranking() {
        let y = [-1.0, -1.0, 1.0, 1.0];
        let s = [0.9, 0.8, 0.2, 0.1];
        // PR points: recall 0.5 @ prec 1/3, recall 1.0 @ prec 0.5
        let got = auprc(&y, &s);
        assert!(got < 0.5, "got {got}");
    }

    #[test]
    fn auprc_known_value() {
        // 3 examples: scores .9(+), .5(-), .3(+)
        // AP = 0.5·1 (first pos) + 0.5·(2/3) (second pos) = 5/6.
        let y = [1.0, -1.0, 1.0];
        let s = [0.9, 0.5, 0.3];
        let want = 0.5 * 1.0 + 0.5 * (2.0 / 3.0);
        assert!((auprc(&y, &s) - want).abs() < 1e-12);
    }

    #[test]
    fn auprc_ties_handled_as_group() {
        // All scores equal: single PR point (recall 1, precision = base
        // rate) — a constant classifier must score exactly the base rate.
        let y = [1.0, -1.0, 1.0, -1.0];
        let s = [0.5, 0.5, 0.5, 0.5];
        assert!((auprc(&y, &s) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn roc_auc_known() {
        let y = [1.0, -1.0, 1.0, -1.0];
        let s = [0.9, 0.8, 0.7, 0.1];
        // pairs: (p1,n1): .9>.8 ✓, (p1,n2): .9>.1 ✓, (p2,n1): .7<.8 ✗, (p2,n2): ✓
        assert!((roc_auc(&y, &s) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn roc_auc_ties_half_credit() {
        let y = [1.0, -1.0];
        let s = [0.5, 0.5];
        assert!((roc_auc(&y, &s) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn prop_auc_invariant_to_monotone_transform() {
        prop::check("auc invariant under monotone map", 50, |rng| {
            let n = 5 + rng.below(50);
            let y: Vec<f64> = (0..n)
                .map(|_| if rng.bernoulli(0.4) { 1.0 } else { -1.0 })
                .collect();
            let s: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let s2: Vec<f64> = s.iter().map(|&v| (3.0 * v - 1.0).exp()).collect();
            prop::close(roc_auc(&y, &s), roc_auc(&y, &s2), 1e-12)?;
            prop::close(auprc(&y, &s), auprc(&y, &s2), 1e-12)
        });
    }

    #[test]
    fn prop_auprc_in_unit_interval() {
        prop::check("auprc in [0,1]", 100, |rng| {
            let n = 2 + rng.below(40);
            let y: Vec<f64> = (0..n)
                .map(|_| if rng.bernoulli(0.3) { 1.0 } else { -1.0 })
                .collect();
            let s: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let a = auprc(&y, &s);
            if (0.0..=1.0 + 1e-12).contains(&a) {
                Ok(())
            } else {
                Err(format!("auprc {a}"))
            }
        });
    }

    #[test]
    fn logloss_perfect_and_uninformed() {
        let y = [1.0, -1.0];
        assert!(logloss(&y, &[1.0, 0.0]) < 1e-10);
        let half = logloss(&y, &[0.5, 0.5]);
        assert!((half - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn accuracy_threshold() {
        let y = [1.0, -1.0, 1.0];
        assert!((accuracy(&y, &[0.9, 0.1, 0.2], 0.5) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn nnz_counts() {
        assert_eq!(nnz_weights(&[0.0, 1.0, -0.5, 0.0]), 2);
    }

    #[test]
    fn degenerate_label_sets() {
        // Zero positives → 0.0, zero negatives → 1.0 (auPRC) / 0.5 (auROC):
        // a validation split with a one-sided label distribution must select
        // a model deterministically, never yield NaN or panic.
        assert_eq!(auprc(&[1.0, 1.0], &[0.5, 0.4]), 1.0);
        assert_eq!(auprc(&[-1.0, -1.0], &[0.5, 0.4]), 0.0);
        assert_eq!(roc_auc(&[1.0, 1.0], &[0.5, 0.4]), 0.5);
        assert_eq!(roc_auc(&[-1.0, -1.0], &[0.5, 0.4]), 0.5);
        // Degenerate labels trump degenerate scores.
        assert_eq!(auprc(&[-1.0, -1.0], &[f64::NAN, 0.4]), 0.0);
        assert_eq!(auprc(&[1.0, 1.0], &[f64::NAN, f64::NAN]), 1.0);
    }

    #[test]
    fn signed_zeros_stay_one_tie_group() {
        // -0.0 == +0.0 numerically: they must remain a single tie group
        // even though the total-order sort distinguishes them — a constant
        // classifier emitting mixed-sign zeros scores like any constant.
        let y = [1.0, -1.0];
        let s = [-0.0, 0.0];
        assert!((roc_auc(&y, &s) - 0.5).abs() < 1e-12);
        assert!((auprc(&y, &s) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn nan_scores_yield_defined_metrics() {
        // A diverged model (NaN margins) must produce a finite, in-range
        // metric under the documented total-order policy — previously the
        // sort panicked and the tie-group loop could spin forever.
        let y = [1.0, -1.0, 1.0, -1.0];
        let some_nan = [0.9, f64::NAN, 0.3, 0.1];
        let all_nan = [f64::NAN; 4];
        for s in [&some_nan, &all_nan] {
            let pr = auprc(&y, s);
            let roc = roc_auc(&y, s);
            assert!((0.0..=1.0).contains(&pr), "auprc {pr}");
            assert!((0.0..=1.0).contains(&roc), "roc {roc}");
        }
        // All-NaN scores form one tie group → constant-classifier values.
        assert!((auprc(&y, &all_nan) - 0.5).abs() < 1e-12);
        assert!((roc_auc(&y, &all_nan) - 0.5).abs() < 1e-12);
        // ±inf scores are ordered, not fatal.
        let inf = [f64::INFINITY, f64::NEG_INFINITY, 0.5, 0.2];
        assert!((0.0..=1.0).contains(&auprc(&y, &inf)));
    }
}
