//! Lock-free latency histogram for the serving path (p50/p99/QPS reports).
//!
//! HdrHistogram-style log-linear buckets over nanoseconds: 8 sub-buckets per
//! power of two, so quantiles carry ≤ 12.5% relative bucket error — plenty
//! for latency reporting — while `record` is two relaxed atomic adds and
//! never allocates or locks, which is what the request hot path needs.
//! Concurrent recorders share one histogram; reads are racy-but-consistent
//! snapshots (counters may lag each other by in-flight records).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS; // 8 sub-buckets per octave
/// Values 0..8 ns map 1:1; octaves 3..=63 get 8 buckets each, so the top
/// index is exactly BUCKETS - 1 (keeps `bucket_floor` shift-safe).
const BUCKETS: usize = SUB + (61 << SUB_BITS); // 496, covers all u64

#[inline]
fn bucket_index(ns: u64) -> usize {
    if ns < SUB as u64 {
        return ns as usize;
    }
    let octave = 63 - ns.leading_zeros(); // >= SUB_BITS
    let sub = ((ns >> (octave - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    ((octave - SUB_BITS + 1) as usize) * SUB + sub
}

/// Lower edge of bucket `idx` (inverse of `bucket_index`).
#[inline]
fn bucket_floor(idx: usize) -> u64 {
    if idx < SUB {
        return idx as u64;
    }
    let octave = (idx / SUB) as u32 + SUB_BITS - 1;
    let sub = (idx % SUB) as u64;
    (1u64 << octave) + (sub << (octave - SUB_BITS))
}

/// Thread-safe log-linear histogram of durations; see module docs.
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// The q-quantile (q in [0,1]) in nanoseconds: the midpoint of the
    /// bucket holding the ⌈q·n⌉-th observation, clamped to the recorded max.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                let lo = bucket_floor(idx);
                let hi = if idx + 1 < BUCKETS {
                    bucket_floor(idx + 1)
                } else {
                    u64::MAX
                };
                return (lo + (hi - lo) / 2).min(self.max_ns());
            }
        }
        self.max_ns()
    }

    pub fn p50(&self) -> Duration {
        Duration::from_nanos(self.quantile_ns(0.50))
    }

    pub fn p99(&self) -> Duration {
        Duration::from_nanos(self.quantile_ns(0.99))
    }

    /// Fold another histogram's counts into this one.
    pub fn merge(&self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = b.load(Ordering::Relaxed);
            if v > 0 {
                a.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns
            .fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_ns
            .fetch_max(other.max_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Summary as JSON (milliseconds, the unit health endpoints report).
    pub fn to_json(&self) -> Json {
        let ms = |ns: u64| ns as f64 / 1e6;
        let mut o = Json::obj();
        o.set("count", self.count())
            .set("mean_ms", self.mean_ns() / 1e6)
            .set("p50_ms", ms(self.quantile_ns(0.50)))
            .set("p90_ms", ms(self.quantile_ns(0.90)))
            .set("p99_ms", ms(self.quantile_ns(0.99)))
            .set("max_ms", ms(self.max_ns()));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_monotone_and_invertible() {
        let mut last = 0usize;
        for ns in [0u64, 1, 7, 8, 9, 15, 16, 100, 1_000, 65_535, 1 << 30, u64::MAX] {
            let idx = bucket_index(ns);
            assert!(idx >= last, "index not monotone at {ns}");
            assert!(idx < BUCKETS, "index {idx} out of range for {ns}");
            assert!(bucket_floor(idx) <= ns, "floor above value at {ns}");
            if idx + 1 < BUCKETS {
                assert!(bucket_floor(idx + 1) > ns, "value past bucket at {ns}");
            }
            last = idx;
        }
    }

    #[test]
    fn exact_below_eight_ns() {
        for ns in 0..8u64 {
            let h = LatencyHistogram::new();
            h.record_ns(ns);
            assert_eq!(h.quantile_ns(1.0), ns);
        }
    }

    #[test]
    fn quantiles_of_known_distribution() {
        let h = LatencyHistogram::new();
        // 99 fast observations at ~1µs, one slow at ~1s.
        for _ in 0..99 {
            h.record_ns(1_000);
        }
        h.record_ns(1_000_000_000);
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_ns(0.50);
        assert!((900..=1_200).contains(&p50), "p50 {p50}");
        let p99 = h.quantile_ns(0.99);
        assert!(p99 <= 1_200, "p99 must still be fast, got {p99}");
        let p100 = h.quantile_ns(1.0);
        assert!(p100 >= 900_000_000, "max quantile {p100}");
        assert_eq!(h.max_ns(), 1_000_000_000);
    }

    #[test]
    fn relative_error_bounded() {
        for ns in [100u64, 5_000, 123_456, 10_000_000, 3_000_000_000] {
            let h = LatencyHistogram::new();
            h.record_ns(ns);
            let got = h.quantile_ns(0.5) as f64;
            let err = (got - ns as f64).abs() / ns as f64;
            assert!(err <= 0.125 + 1e-9, "err {err} at {ns}");
        }
    }

    #[test]
    fn merge_accumulates() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        for i in 1..=50u64 {
            a.record_ns(i * 1_000);
            b.record_ns(i * 2_000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert_eq!(a.max_ns(), 100_000);
        assert!(a.mean_ns() > 0.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ns(0.99), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..1_000u64 {
                        h.record_ns((t + 1) * 1_000 + i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4_000);
        assert!(h.quantile_ns(0.5) >= 1_000);
    }

    #[test]
    fn json_summary_shape() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(250));
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_f64(), Some(1.0));
        assert!(j.get("p99_ms").unwrap().as_f64().unwrap() > 0.0);
    }
}
