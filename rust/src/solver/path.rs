//! Regularization path and validation-based λ selection.
//!
//! The paper's experimental protocol (§8.2): "For each dataset we selected
//! L1 and L2 regularization coefficients from the range {2⁻⁶, …, 2⁶}
//! yielding the best classification quality on the validation set." This
//! module implements exactly that sweep, with the warm-starting trick that
//! makes GLMNET-family path computation cheap: solutions are computed from
//! the largest λ down, each fit starting from the previous solution.
//!
//! Also provides `lambda_max` — the smallest λ1 for which β = 0 is optimal
//! (the classical KKT bound max_j |∇L_j(0)|), the natural top of the path.

use crate::data::{Dataset, Splits};
use crate::glm::loss::LossKind;
use crate::glm::regularizer::{ElasticNet, Penalty1D};
use crate::metrics;
use crate::solver::compute::GlmCompute;
use crate::solver::dglmnet::DGlmnetConfig;
use crate::solver::linesearch::line_search;
use crate::solver::subproblem::{cd_cycle, CycleBudget, SubproblemState};
use crate::sparse::{Csc, FeaturePartition};

/// λ1 at which the all-zeros solution is optimal: max_j |Σ_i ℓ'(y_i, 0) x_ij|.
pub fn lambda_max(train: &Dataset, kind: LossKind) -> f64 {
    let n = train.n();
    let g0: Vec<f64> = (0..n).map(|i| kind.d1(train.y[i], 0.0)).collect();
    let grad = train.x.tmul_vec(&g0);
    grad.iter().fold(0.0f64, |m, g| m.max(g.abs()))
}

/// A single point on the path.
#[derive(Clone, Debug)]
pub struct PathPoint {
    pub lambda1: f64,
    pub lambda2: f64,
    pub beta: Vec<f64>,
    pub objective: f64,
    pub nnz: usize,
    /// Validation auPRC (classification) — the paper's selection criterion.
    pub val_auprc: f64,
    pub iters: usize,
}

/// Result of a path sweep.
#[derive(Clone, Debug)]
pub struct PathResult {
    pub points: Vec<PathPoint>,
    /// Index of the validation-best point.
    pub best: usize,
}

impl PathResult {
    pub fn best_point(&self) -> &PathPoint {
        &self.points[self.best]
    }
}

/// Warm-started fit at one (λ1, λ2), reusing the partition/shards and
/// starting from `beta` (the previous path point). A slimmed copy of
/// `dglmnet::fit` that threads an initial β through; kept separate so the
/// cold-start reference implementation stays simple.
#[allow(clippy::too_many_arguments)]
fn warm_fit(
    train: &Dataset,
    shards: &[Csc],
    partition: &FeaturePartition,
    compute: &dyn GlmCompute,
    pen: &ElasticNet,
    cfg: &DGlmnetConfig,
    beta: &mut Vec<f64>,
) -> (f64, usize) {
    let n = train.n();
    let mut margins = train.x.mul_vec(beta);
    let mut w = vec![0.0; n];
    let mut z = vec![0.0; n];
    let mut mu = cfg.mu0;
    let mut states: Vec<SubproblemState> = partition
        .blocks
        .iter()
        .map(|b| SubproblemState::new(b.len(), n))
        .collect();
    let mut loss = compute.stats(&train.y, &margins, &mut w, &mut z);
    let mut reg = pen.value(beta);
    let mut f_cur = loss + reg;
    let mut stall = 0;
    let mut iters = 0;
    for it in 1..=cfg.max_iters {
        iters = it;
        let mut dmargins = vec![0.0; n];
        for (m, block) in partition.blocks.iter().enumerate() {
            if block.is_empty() {
                continue;
            }
            let local_beta: Vec<f64> = block.iter().map(|&j| beta[j]).collect();
            let st = &mut states[m];
            st.reset();
            cd_cycle(
                &shards[m],
                &local_beta,
                &w,
                &z,
                mu,
                cfg.nu,
                pen,
                st,
                CycleBudget::full_cycle(block.len()),
            );
            for i in 0..n {
                dmargins[i] += st.t[i];
            }
        }
        // ∇L(β)ᵀΔβ from the cached working set: g_i = −w_i z_i exactly
        // (z = −g/w with the same floored w), so no extra stats pass.
        let mut grad_dot = 0.0;
        for i in 0..n {
            grad_dot += -w[i] * z[i] * dmargins[i];
        }
        let reg_ray = |alphas: &[f64]| -> Vec<f64> {
            let mut out = vec![0.0; alphas.len()];
            for (m, block) in partition.blocks.iter().enumerate() {
                let st = &states[m];
                for (local, &j) in block.iter().enumerate() {
                    let (b, d) = (beta[j], st.delta_beta[local]);
                    for (k, &a) in alphas.iter().enumerate() {
                        out[k] += pen.value_1d(b + a * d);
                    }
                }
            }
            out
        };
        let ls = line_search(
            compute,
            &cfg.linesearch,
            &train.y,
            &margins,
            &dmargins,
            f_cur,
            reg,
            grad_dot,
            &reg_ray,
        );
        if ls.alpha > 0.0 {
            for (m, block) in partition.blocks.iter().enumerate() {
                let st = &states[m];
                for (local, &j) in block.iter().enumerate() {
                    beta[j] += ls.alpha * st.delta_beta[local];
                }
            }
            for i in 0..n {
                margins[i] += ls.alpha * dmargins[i];
            }
        }
        if cfg.adaptive_mu {
            if ls.alpha < 1.0 {
                mu *= cfg.eta1;
            } else {
                mu = (mu / cfg.eta2).max(1.0);
            }
        }
        loss = compute.stats(&train.y, &margins, &mut w, &mut z);
        reg = pen.value(beta);
        let f_new = loss + reg;
        let rel = (f_cur - f_new) / f_cur.abs().max(1e-12);
        f_cur = f_new;
        if rel.abs() < cfg.tol {
            stall += 1;
            if stall >= cfg.patience {
                break;
            }
        } else {
            stall = 0;
        }
    }
    (f_cur, iters)
}

/// Sweep an L1 path over `lambdas` (fit in the given order — pass them
/// descending for warm starts to pay off), selecting by validation auPRC.
/// `l2` is held fixed.
pub fn l1_path(
    splits: &Splits,
    compute: &dyn GlmCompute,
    lambdas: &[f64],
    l2: f64,
    cfg: &DGlmnetConfig,
) -> PathResult {
    let train = &splits.train;
    let partition = FeaturePartition::hashed(train.p(), cfg.nodes, cfg.seed);
    let x_csc = train.to_csc();
    let shards: Vec<Csc> = (0..cfg.nodes).map(|m| partition.shard(&x_csc, m)).collect();

    let mut beta = vec![0.0; train.p()];
    let mut points = Vec::with_capacity(lambdas.len());
    for &l1 in lambdas {
        let pen = ElasticNet::new(l1, l2);
        let (objective, iters) =
            warm_fit(train, &shards, &partition, compute, &pen, cfg, &mut beta);
        let scores = splits.validation.x.mul_vec(&beta);
        let val_auprc = metrics::auprc(&splits.validation.y, &scores);
        points.push(PathPoint {
            lambda1: l1,
            lambda2: l2,
            beta: beta.clone(),
            objective,
            nnz: metrics::nnz_weights(&beta),
            val_auprc,
            iters,
        });
    }
    let best = points
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.val_auprc.partial_cmp(&b.1.val_auprc).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    PathResult { points, best }
}

/// The paper's §8.2 grid: {2⁻⁶, …, 2⁶}, descending for warm starts.
pub fn paper_lambda_grid() -> Vec<f64> {
    (-6..=6).rev().map(|e| 2f64.powi(e)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;
    use crate::solver::compute::NativeCompute;
    use crate::solver::dglmnet;

    fn cfg() -> DGlmnetConfig {
        DGlmnetConfig {
            nodes: 3,
            max_iters: 60,
            tol: 1e-9,
            eval_every: 0,
            ..Default::default()
        }
    }

    #[test]
    fn lambda_max_kills_all_weights() {
        let splits = Corpus::webspam_like(0.05, 2);
        let compute = NativeCompute::new(LossKind::Logistic);
        let lmax = lambda_max(&splits.train, LossKind::Logistic);
        // At λ1 slightly above λ_max the fit must stay at zero.
        let res = l1_path(&splits, &compute, &[lmax * 1.01], 0.0, &cfg());
        assert_eq!(res.points[0].nnz, 0, "β should be all-zero above λ_max");
        // Slightly below, some weight enters.
        let res2 = l1_path(&splits, &compute, &[lmax * 0.9], 0.0, &cfg());
        assert!(res2.points[0].nnz > 0, "β should activate below λ_max");
    }

    #[test]
    fn path_nnz_monotone_descending_lambda() {
        let splits = Corpus::webspam_like(0.05, 3);
        let compute = NativeCompute::new(LossKind::Logistic);
        let lmax = lambda_max(&splits.train, LossKind::Logistic);
        let lambdas: Vec<f64> = (0..5).map(|k| lmax * 0.7f64.powi(k + 1)).collect();
        let res = l1_path(&splits, &compute, &lambdas, 0.0, &cfg());
        for w in res.points.windows(2) {
            assert!(
                w[1].nnz + 2 >= w[0].nnz, // allow tiny non-monotonicity
                "nnz dropped along decreasing λ: {} -> {}",
                w[0].nnz,
                w[1].nnz
            );
        }
    }

    #[test]
    fn warm_fit_matches_cold_fit_objective() {
        let splits = Corpus::epsilon_like(0.04, 4);
        let compute = NativeCompute::new(LossKind::Logistic);
        let c = DGlmnetConfig {
            max_iters: 300,
            tol: 1e-12,
            patience: 3,
            ..cfg()
        };
        let res = l1_path(&splits, &compute, &[0.5], 0.1, &c);
        let cold = dglmnet::fit(
            &splits.train,
            &compute,
            &ElasticNet::new(0.5, 0.1),
            &c,
            None,
        );
        let gap = (res.points[0].objective - cold.objective).abs() / cold.objective;
        assert!(gap < 1e-6, "warm path point {} vs cold {}", res.points[0].objective, cold.objective);
    }

    #[test]
    fn best_point_maximizes_validation_auprc() {
        let splits = Corpus::clickstream(0.05, 5);
        let compute = NativeCompute::new(LossKind::Logistic);
        let res = l1_path(&splits, &compute, &[4.0, 1.0, 0.25], 0.0, &cfg());
        let best = res.best_point().val_auprc;
        for p in &res.points {
            assert!(p.val_auprc <= best + 1e-12);
        }
    }

    #[test]
    fn paper_grid_shape() {
        let g = paper_lambda_grid();
        assert_eq!(g.len(), 13);
        assert_eq!(g[0], 64.0);
        assert_eq!(g[12], 1.0 / 64.0);
        for w in g.windows(2) {
            assert!(w[0] > w[1]);
        }
    }
}
