//! Regularization path and validation-based λ selection.
//!
//! The paper's experimental protocol (§8.2): "For each dataset we selected
//! L1 and L2 regularization coefficients from the range {2⁻⁶, …, 2⁶}
//! yielding the best classification quality on the validation set." This
//! module implements exactly that sweep, with the warm-starting trick that
//! makes GLMNET-family path computation cheap: solutions are computed from
//! the largest λ down, each fit starting from the previous solution.
//!
//! On top of warm starts the sweep applies **sequential strong-rule
//! screening** (Tibshirani et al. 2012, the glmnet rule): at λ_k, coming
//! from the solution at λ_{k−1}, a coordinate is skipped when
//!
//! ```text
//!   |∇L_j(β̂(λ_{k−1}))| < max(2λ_k − λ_{k−1}, λ_k/2)
//! ```
//!
//! (the λ_k/2 floor keeps screening alive on coarse grids — see
//! [`strong_rule_threshold`]). The rule is a heuristic, so after the
//! screened fit converges every
//! discarded coordinate's exact KKT condition (|∇L_j| ≤ λ1 at β_j = 0) is
//! re-checked; violators are added back and the fit re-cycled until clean.
//! That violation pass makes screening **exact**: the screened sweep solves
//! the same problems as the unscreened one, touching a fraction of the
//! block per pass (see `benches/path_screening.rs` for the update counts).
//!
//! Also provides `lambda_max` — the smallest λ1 for which β = 0 is optimal
//! (the classical KKT bound max_j |∇L_j(0)|), the natural top of the path.
//!
//! The distributed mirror of this sweep — same math, M real ranks, the λ
//! grid swept once over sharded data — lives in
//! `coordinator::driver::fit_path_distributed`.

use crate::data::{Dataset, Splits};
use crate::glm::loss::LossKind;
use crate::glm::regularizer::{ElasticNet, Penalty1D};
use crate::metrics;
use crate::solver::compute::GlmCompute;
use crate::solver::dglmnet::DGlmnetConfig;
use crate::solver::linesearch::line_search;
use crate::solver::subproblem::{cd_cycle, CycleBudget, SubproblemState};
use crate::sparse::{Csc, FeaturePartition};

/// Slack on the exact KKT re-check |∇L_j| ≤ λ1: the active fit itself only
/// converges to `cfg.tol`, so excluded gradients sit within solver noise of
/// the bound. Adding a borderline coordinate is always safe (just extra
/// work), so the slack only has to filter float fuzz.
pub const KKT_SLACK: f64 = 1e-9;

/// Errors a path sweep can report instead of panicking or silently
/// returning point 0.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PathError {
    /// The λ grid was empty — there is no point to select.
    EmptyGrid,
}

impl std::fmt::Display for PathError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PathError::EmptyGrid => write!(f, "λ-path sweep given an empty λ1 grid"),
        }
    }
}

impl std::error::Error for PathError {}

/// λ1 at which the all-zeros solution is optimal: max_j |Σ_i ℓ'(y_i, 0) x_ij|.
pub fn lambda_max(train: &Dataset, kind: LossKind) -> f64 {
    let n = train.n();
    let g0: Vec<f64> = (0..n).map(|i| kind.d1(train.y[i], 0.0)).collect();
    let grad = train.x.tmul_vec(&g0);
    grad.iter().fold(0.0f64, |m, g| m.max(g.abs()))
}

/// A single point on the path.
#[derive(Clone, Debug)]
pub struct PathPoint {
    pub lambda1: f64,
    pub lambda2: f64,
    pub beta: Vec<f64>,
    pub objective: f64,
    pub nnz: usize,
    /// Validation auPRC (classification) — the paper's selection criterion.
    pub val_auprc: f64,
    pub iters: usize,
    /// Coordinate updates spent on this point (summed over all blocks and
    /// KKT re-cycles) — the axis screening shrinks.
    pub cd_updates: u64,
}

/// Result of a path sweep.
#[derive(Clone, Debug)]
pub struct PathResult {
    pub points: Vec<PathPoint>,
    /// Index of the validation-best point.
    pub best: usize,
}

impl PathResult {
    pub fn best_point(&self) -> &PathPoint {
        &self.points[self.best]
    }

    /// Total coordinate updates across the sweep (the screening win axis).
    pub fn total_cd_updates(&self) -> u64 {
        self.points.iter().map(|p| p.cd_updates).sum()
    }
}

/// Index of the maximum under an explicit NaN policy: NaN ranks below every
/// real value, so a degenerate score (empty validation split, diverged fit)
/// can never win the selection — and never panics it. Ties keep the first
/// (largest-λ, sparsest) point. `None` only for an empty slice.
pub fn nan_safe_argmax(vals: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &v) in vals.iter().enumerate() {
        let key = if v.is_nan() { f64::NEG_INFINITY } else { v };
        match best {
            None => best = Some((i, key)),
            Some((_, b)) if key > b => best = Some((i, key)),
            _ => {}
        }
    }
    best.map(|(i, _)| i)
}

/// The discard bound at λ_k coming from λ_prev: the sequential strong rule
/// `2λ_k − λ_prev` (Tibshirani et al. 2012), **floored at λ_k/2**. The
/// floor matters on coarse grids: the paper's §8.2 grid halves λ each step,
/// which drives the strong-rule bound to exactly 0 — it would screen
/// nothing. Below-floor coordinates (|∇L_j| < λ_k/2 at the warm start)
/// would need their gradient to more than double to activate, so dropping
/// them is an aggressive working-set rule in the spirit of newGLMNET's
/// shrinking — and the KKT violation re-cycle restores exactness for ANY
/// bound. `None` (screen nothing) on the first point or a non-descending
/// step.
pub fn strong_rule_threshold(lambda_k: f64, lambda_prev: Option<f64>) -> Option<f64> {
    match lambda_prev {
        Some(lp) if lp > lambda_k => Some((2.0 * lambda_k - lp).max(0.5 * lambda_k)),
        _ => None,
    }
}

/// Local column indices surviving the strong rule: everything when `thresh`
/// is `None`, otherwise the currently-nonzero weights plus every coordinate
/// whose loss gradient clears the bound.
pub fn screen_columns(local_beta: &[f64], grads: &[f64], thresh: Option<f64>) -> Vec<usize> {
    debug_assert_eq!(local_beta.len(), grads.len());
    match thresh {
        None => (0..local_beta.len()).collect(),
        Some(t) => (0..local_beta.len())
            .filter(|&j| local_beta[j] != 0.0 || grads[j].abs() >= t)
            .collect(),
    }
}

/// Screened-out coordinates violating the exact KKT condition at β_j = 0
/// (|∇L_j| > λ1 + slack). These must be added back and the fit re-cycled —
/// the pass that keeps strong-rule screening exact.
pub fn kkt_violations(active: &[usize], grads: &[f64], l1: f64, slack: f64) -> Vec<usize> {
    let mut is_active = vec![false; grads.len()];
    for &j in active {
        is_active[j] = true;
    }
    (0..grads.len())
        .filter(|&j| !is_active[j] && grads[j].abs() > l1 + slack)
        .collect()
}

/// What one warm fit spent and reached.
struct WarmFitOutcome {
    objective: f64,
    iters: usize,
    cd_updates: u64,
}

/// Warm-started fit at one (λ1, λ2), reusing the partition/shards and
/// starting from `beta` (the previous path point), restricted to the given
/// per-block active sets. A slimmed copy of `dglmnet::fit` that threads an
/// initial β through; kept separate so the cold-start reference
/// implementation stays simple.
#[allow(clippy::too_many_arguments)]
fn warm_fit(
    train: &Dataset,
    shards: &[Csc],
    partition: &FeaturePartition,
    compute: &dyn GlmCompute,
    pen: &ElasticNet,
    cfg: &DGlmnetConfig,
    active: &[Vec<usize>],
    beta: &mut Vec<f64>,
) -> WarmFitOutcome {
    let n = train.n();
    let mut margins = train.x.mul_vec(beta);
    let mut w = vec![0.0; n];
    let mut z = vec![0.0; n];
    let mut mu = cfg.mu0;
    let mut states: Vec<SubproblemState> = partition
        .blocks
        .iter()
        .map(|b| SubproblemState::new(b.len(), n))
        .collect();
    let mut loss = compute.stats(&train.y, &margins, &mut w, &mut z);
    let mut reg = pen.value(beta);
    let mut f_cur = loss + reg;
    let mut stall = 0;
    let mut iters = 0;
    let mut cd_updates = 0u64;
    for it in 1..=cfg.max_iters {
        iters = it;
        let mut dmargins = vec![0.0; n];
        for (m, block) in partition.blocks.iter().enumerate() {
            if block.is_empty() || active[m].is_empty() {
                continue;
            }
            let local_beta: Vec<f64> = block.iter().map(|&j| beta[j]).collect();
            let st = &mut states[m];
            st.reset();
            let out = cd_cycle(
                &shards[m],
                &local_beta,
                &w,
                &z,
                mu,
                cfg.nu,
                pen,
                st,
                CycleBudget::screened(&active[m]),
            );
            cd_updates += out.updates as u64;
            for i in 0..n {
                dmargins[i] += st.t[i];
            }
        }
        // ∇L(β)ᵀΔβ from the cached working set: g_i = −w_i z_i exactly
        // (z = −g/w with the same floored w), so no extra stats pass.
        let mut grad_dot = 0.0;
        for i in 0..n {
            grad_dot += -w[i] * z[i] * dmargins[i];
        }
        let reg_ray = |alphas: &[f64]| -> Vec<f64> {
            let mut out = vec![0.0; alphas.len()];
            for (m, block) in partition.blocks.iter().enumerate() {
                let st = &states[m];
                for (local, &j) in block.iter().enumerate() {
                    let (b, d) = (beta[j], st.delta_beta[local]);
                    for (k, &a) in alphas.iter().enumerate() {
                        out[k] += pen.value_1d(b + a * d);
                    }
                }
            }
            out
        };
        let ls = line_search(
            compute,
            &cfg.linesearch,
            &train.y,
            &margins,
            &dmargins,
            f_cur,
            reg,
            grad_dot,
            &reg_ray,
        );
        if ls.alpha > 0.0 {
            for (m, block) in partition.blocks.iter().enumerate() {
                let st = &states[m];
                for (local, &j) in block.iter().enumerate() {
                    beta[j] += ls.alpha * st.delta_beta[local];
                }
            }
            for i in 0..n {
                margins[i] += ls.alpha * dmargins[i];
            }
        }
        if cfg.adaptive_mu {
            if ls.alpha < 1.0 {
                mu *= cfg.eta1;
            } else {
                mu = (mu / cfg.eta2).max(1.0);
            }
        }
        loss = compute.stats(&train.y, &margins, &mut w, &mut z);
        reg = pen.value(beta);
        let f_new = loss + reg;
        let rel = (f_cur - f_new) / f_cur.abs().max(1e-12);
        f_cur = f_new;
        if rel.abs() < cfg.tol {
            stall += 1;
            if stall >= cfg.patience {
                break;
            }
        } else {
            stall = 0;
        }
    }
    WarmFitOutcome {
        objective: f_cur,
        iters,
        cd_updates,
    }
}

/// Sweep an L1 path over `lambdas` (fit in the given order — pass them
/// descending for warm starts and screening to pay off), selecting by
/// validation auPRC. `l2` is held fixed. Strong-rule screening is ON; use
/// [`l1_path_with_screening`] to ablate it. Errors on an empty λ grid.
pub fn l1_path(
    splits: &Splits,
    compute: &dyn GlmCompute,
    lambdas: &[f64],
    l2: f64,
    cfg: &DGlmnetConfig,
) -> Result<PathResult, PathError> {
    l1_path_with_screening(splits, compute, lambdas, l2, cfg, true)
}

/// [`l1_path`] with the KKT screening switch exposed (`screen = false`
/// cycles every coordinate at every point — the ablation baseline the
/// screening bench compares against).
pub fn l1_path_with_screening(
    splits: &Splits,
    compute: &dyn GlmCompute,
    lambdas: &[f64],
    l2: f64,
    cfg: &DGlmnetConfig,
    screen: bool,
) -> Result<PathResult, PathError> {
    if lambdas.is_empty() {
        return Err(PathError::EmptyGrid);
    }
    let train = &splits.train;
    let n = train.n();
    let x_csc = train.to_csc();
    let partition = cfg.partition.resolve(&x_csc, cfg.nodes, cfg.seed);
    let shards: Vec<Csc> = (0..cfg.nodes).map(|m| partition.shard(&x_csc, m)).collect();

    let mut beta = vec![0.0; train.p()];
    let mut w = vec![0.0; n];
    let mut z = vec![0.0; n];
    let mut points = Vec::with_capacity(lambdas.len());
    let mut lambda_prev: Option<f64> = None;

    // Per-block loss gradients ∇L_j at the current β (g_i = −w_i z_i from
    // the floored working set — the same quantity `cd_cycle` sees).
    let block_grads = |beta: &[f64], w: &mut [f64], z: &mut [f64]| -> Vec<Vec<f64>> {
        let margins = train.x.mul_vec(beta);
        compute.stats(&train.y, &margins, w, z);
        let g: Vec<f64> = (0..n).map(|i| -w[i] * z[i]).collect();
        shards.iter().map(|s| s.tmul_vec(&g)).collect()
    };

    for &l1 in lambdas {
        let pen = ElasticNet::new(l1, l2);
        let thresh = if screen {
            strong_rule_threshold(l1, lambda_prev)
        } else {
            None
        };
        // The gradient pass is only paid when a discard bound exists —
        // the unscreened sweep (and the first grid point) must not do
        // extra O(nnz) work the plain algorithm wouldn't.
        let mut active: Vec<Vec<usize>> = if thresh.is_some() {
            let grads = block_grads(&beta, &mut w, &mut z);
            partition
                .blocks
                .iter()
                .enumerate()
                .map(|(m, block)| {
                    let local_beta: Vec<f64> = block.iter().map(|&j| beta[j]).collect();
                    screen_columns(&local_beta, &grads[m], thresh)
                })
                .collect()
        } else {
            partition.blocks.iter().map(|b| (0..b.len()).collect()).collect()
        };

        // Fit, then re-check the exact KKT conditions on everything the
        // strong rule discarded; re-cycle until clean. The active sets only
        // grow, so this terminates (worst case: everything active).
        let mut objective;
        let mut iters = 0usize;
        let mut cd_updates = 0u64;
        loop {
            let out = warm_fit(
                train, &shards, &partition, compute, &pen, cfg, &active, &mut beta,
            );
            objective = out.objective;
            iters += out.iters;
            cd_updates += out.cd_updates;
            if !screen {
                break;
            }
            let grads = block_grads(&beta, &mut w, &mut z);
            let mut any = false;
            for (m, bg) in grads.iter().enumerate() {
                let viol = kkt_violations(&active[m], bg, l1, KKT_SLACK);
                if !viol.is_empty() {
                    any = true;
                    active[m].extend(viol);
                    active[m].sort_unstable();
                }
            }
            if !any {
                break;
            }
        }

        let scores = splits.validation.x.mul_vec(&beta);
        let val_auprc = metrics::auprc(&splits.validation.y, &scores);
        points.push(PathPoint {
            lambda1: l1,
            lambda2: l2,
            beta: beta.clone(),
            objective,
            nnz: metrics::nnz_weights(&beta),
            val_auprc,
            iters,
            cd_updates,
        });
        lambda_prev = Some(l1);
    }
    let auprcs: Vec<f64> = points.iter().map(|p| p.val_auprc).collect();
    let best = nan_safe_argmax(&auprcs).expect("grid checked non-empty above");
    Ok(PathResult { points, best })
}

/// The paper's §8.2 grid: {2⁻⁶, …, 2⁶}, descending for warm starts.
pub fn paper_lambda_grid() -> Vec<f64> {
    (-6..=6).rev().map(|e| 2f64.powi(e)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;
    use crate::solver::compute::NativeCompute;
    use crate::solver::dglmnet;
    use crate::util::prop;

    fn cfg() -> DGlmnetConfig {
        DGlmnetConfig {
            nodes: 3,
            max_iters: 60,
            tol: 1e-9,
            eval_every: 0,
            ..Default::default()
        }
    }

    #[test]
    fn lambda_max_kills_all_weights() {
        let splits = Corpus::webspam_like(0.05, 2);
        let compute = NativeCompute::new(LossKind::Logistic);
        let lmax = lambda_max(&splits.train, LossKind::Logistic);
        // At λ1 slightly above λ_max the fit must stay at zero.
        let res = l1_path(&splits, &compute, &[lmax * 1.01], 0.0, &cfg()).unwrap();
        assert_eq!(res.points[0].nnz, 0, "β should be all-zero above λ_max");
        // Slightly below, some weight enters.
        let res2 = l1_path(&splits, &compute, &[lmax * 0.9], 0.0, &cfg()).unwrap();
        assert!(res2.points[0].nnz > 0, "β should activate below λ_max");
    }

    #[test]
    fn empty_grid_is_an_error_not_point_zero() {
        let splits = Corpus::webspam_like(0.05, 2);
        let compute = NativeCompute::new(LossKind::Logistic);
        assert_eq!(
            l1_path(&splits, &compute, &[], 0.0, &cfg()).unwrap_err(),
            PathError::EmptyGrid
        );
    }

    #[test]
    fn path_nnz_monotone_descending_lambda() {
        let splits = Corpus::webspam_like(0.05, 3);
        let compute = NativeCompute::new(LossKind::Logistic);
        let lmax = lambda_max(&splits.train, LossKind::Logistic);
        let lambdas: Vec<f64> = (0..5).map(|k| lmax * 0.7f64.powi(k + 1)).collect();
        let res = l1_path(&splits, &compute, &lambdas, 0.0, &cfg()).unwrap();
        for w in res.points.windows(2) {
            assert!(
                w[1].nnz + 2 >= w[0].nnz, // allow tiny non-monotonicity
                "nnz dropped along decreasing λ: {} -> {}",
                w[0].nnz,
                w[1].nnz
            );
        }
    }

    #[test]
    fn warm_fit_matches_cold_fit_objective() {
        let splits = Corpus::epsilon_like(0.04, 4);
        let compute = NativeCompute::new(LossKind::Logistic);
        let c = DGlmnetConfig {
            max_iters: 300,
            tol: 1e-12,
            patience: 3,
            ..cfg()
        };
        let res = l1_path(&splits, &compute, &[0.5], 0.1, &c).unwrap();
        let cold = dglmnet::fit(
            &splits.train,
            &compute,
            &ElasticNet::new(0.5, 0.1),
            &c,
            None,
        );
        let gap = (res.points[0].objective - cold.objective).abs() / cold.objective;
        assert!(gap < 1e-6, "warm path point {} vs cold {}", res.points[0].objective, cold.objective);
    }

    #[test]
    fn best_point_maximizes_validation_auprc() {
        let splits = Corpus::clickstream(0.05, 5);
        let compute = NativeCompute::new(LossKind::Logistic);
        let res = l1_path(&splits, &compute, &[4.0, 1.0, 0.25], 0.0, &cfg()).unwrap();
        let best = res.best_point().val_auprc;
        for p in &res.points {
            assert!(p.val_auprc <= best + 1e-12);
        }
    }

    #[test]
    fn paper_grid_shape() {
        let g = paper_lambda_grid();
        assert_eq!(g.len(), 13);
        assert_eq!(g[0], 64.0);
        assert_eq!(g[12], 1.0 / 64.0);
        for w in g.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn nan_safe_argmax_policy() {
        assert_eq!(nan_safe_argmax(&[]), None);
        assert_eq!(nan_safe_argmax(&[0.3, 0.9, 0.1]), Some(1));
        // NaN never wins; ties keep the first (largest-λ) point.
        assert_eq!(nan_safe_argmax(&[f64::NAN, 0.2, 0.2]), Some(1));
        assert_eq!(nan_safe_argmax(&[f64::NAN, f64::NAN]), Some(0));
        assert_eq!(nan_safe_argmax(&[f64::NEG_INFINITY, f64::NAN]), Some(0));
    }

    #[test]
    fn strong_rule_threshold_cases() {
        assert_eq!(strong_rule_threshold(1.0, None), None);
        assert_eq!(strong_rule_threshold(1.0, Some(0.5)), None); // ascending step
        // Fine step: the strong-rule bound binds (0.9 > the 0.5 floor).
        assert_eq!(strong_rule_threshold(1.0, Some(1.1)), Some(2.0 - 1.1));
        assert_eq!(strong_rule_threshold(2.0, Some(3.0)), Some(1.0));
        // Dyadic step (the §8.2 grid): the strong rule degenerates to 0 —
        // the λ_k/2 floor keeps screening alive.
        assert_eq!(strong_rule_threshold(1.0, Some(2.0)), Some(0.5));
        // Steep drop: bound would be negative without the floor.
        assert_eq!(strong_rule_threshold(0.1, Some(3.0)), Some(0.05));
    }

    #[test]
    fn screen_columns_keeps_nonzero_weights() {
        let beta = [0.0, 0.7, 0.0];
        let grads = [0.1, 0.0, 0.9];
        assert_eq!(screen_columns(&beta, &grads, Some(0.5)), vec![1, 2]);
        assert_eq!(screen_columns(&beta, &grads, None), vec![0, 1, 2]);
    }

    #[test]
    fn kkt_violations_only_on_excluded() {
        let grads = [2.0, 0.1, 1.5, 0.2];
        // Coordinate 0 is active (never a "violation"); 2 exceeds λ1 = 1.
        assert_eq!(kkt_violations(&[0], &grads, 1.0, KKT_SLACK), vec![2]);
        assert_eq!(kkt_violations(&[0, 2], &grads, 1.0, KKT_SLACK), Vec::<usize>::new());
    }

    /// Screening must be exact: the screened sweep reaches the unscreened
    /// objective within 1e-6 at EVERY path point, over random corpora,
    /// grids and λ2.
    #[test]
    fn prop_screened_path_matches_unscreened() {
        prop::check("screened path = unscreened path", 4, |rng| {
            let seed = 1 + rng.below(1000) as u64;
            let splits = Corpus::webspam_like(0.04, seed);
            let compute = NativeCompute::new(LossKind::Logistic);
            let lmax = lambda_max(&splits.train, LossKind::Logistic);
            let npts = 3 + rng.below(3);
            let decay = 0.4 + 0.3 * rng.f64();
            let lambdas: Vec<f64> = (0..npts)
                .map(|k| lmax * decay.powi(k as i32 + 1))
                .collect();
            let l2 = if rng.bernoulli(0.5) { 0.05 } else { 0.0 };
            let c = DGlmnetConfig {
                max_iters: 120,
                tol: 1e-11,
                patience: 3,
                ..cfg()
            };
            let on = l1_path_with_screening(&splits, &compute, &lambdas, l2, &c, true)
                .map_err(|e| e.to_string())?;
            let off = l1_path_with_screening(&splits, &compute, &lambdas, l2, &c, false)
                .map_err(|e| e.to_string())?;
            for (a, b) in on.points.iter().zip(off.points.iter()) {
                let gap = (a.objective - b.objective).abs() / b.objective.abs().max(1e-12);
                if gap > 1e-6 {
                    return Err(format!(
                        "λ1={}: screened {} vs unscreened {} (gap {gap:.3e})",
                        a.lambda1, a.objective, b.objective
                    ));
                }
            }
            Ok(())
        });
    }

    /// The acceptance bar: on the paper's §8.2 grid the screened sweep
    /// performs strictly fewer CD updates than the unscreened one while
    /// selecting the same best point.
    #[test]
    fn screening_strictly_cheaper_on_paper_grid() {
        let splits = Corpus::webspam_like(0.05, 7);
        let compute = NativeCompute::new(LossKind::Logistic);
        let grid = paper_lambda_grid();
        let c = cfg();
        let on = l1_path_with_screening(&splits, &compute, &grid, 0.0, &c, true).unwrap();
        let off = l1_path_with_screening(&splits, &compute, &grid, 0.0, &c, false).unwrap();
        assert!(
            on.total_cd_updates() < off.total_cd_updates(),
            "screened {} updates vs unscreened {}",
            on.total_cd_updates(),
            off.total_cd_updates()
        );
        assert_eq!(on.best, off.best, "screening changed the selected point");
        let gap = (on.best_point().objective - off.best_point().objective).abs()
            / off.best_point().objective.abs().max(1e-12);
        assert!(gap < 1e-6, "best objectives diverged (gap {gap:.3e})");
    }

    /// A validation split with no positives must select a model (auPRC 0.0
    /// everywhere → first point wins) without panicking — the degenerate
    /// split that used to NaN-panic the `max_by`.
    #[test]
    fn degenerate_validation_split_selects_without_panicking() {
        let mut splits = Corpus::webspam_like(0.05, 9);
        for y in splits.validation.y.iter_mut() {
            *y = -1.0;
        }
        let compute = NativeCompute::new(LossKind::Logistic);
        let lmax = lambda_max(&splits.train, LossKind::Logistic);
        let res = l1_path(&splits, &compute, &[lmax * 0.5, lmax * 0.25], 0.0, &cfg()).unwrap();
        assert_eq!(res.best, 0, "all-0.0 auPRC keeps the first (sparsest) point");
        assert!(res.points.iter().all(|p| p.val_auprc == 0.0));
    }
}
