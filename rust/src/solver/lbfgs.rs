//! L-BFGS with distributed gradients + online warmstart — the paper's third
//! baseline for L2 runs (Agarwal et al. 2014, Algorithm 2: average online
//! models from the example shards, then switch to quasi-Newton).
//!
//! Two-loop recursion with history r (paper/VW default r = 15); the
//! log-likelihood and gradient are separable over examples, so each shard
//! computes its partial on its own thread and the parts are summed — exactly
//! the "easily implemented for example-wise splitting" property the paper
//! cites. Backtracking Armijo line search on the smooth objective
//! L(β) + (λ₂/2)‖β‖².

use crate::data::Dataset;
use crate::glm::loss::LossKind;
use crate::metrics;
use crate::solver::online::{fit_online, OnlineConfig};
use crate::solver::trace::{Trace, TracePoint};
use crate::sparse::{Csr, ExamplePartition};
use std::collections::VecDeque;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct LbfgsConfig {
    pub kind: LossKind,
    pub l2: f64,
    pub nodes: usize,
    pub max_iters: usize,
    /// History size r (paper: default 15).
    pub history: usize,
    pub tol: f64,
    /// Online warmstart epochs (0 = cold start from zero).
    pub warmstart_epochs: usize,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for LbfgsConfig {
    fn default() -> Self {
        LbfgsConfig {
            kind: LossKind::Logistic,
            l2: 1.0,
            nodes: 8,
            max_iters: 100,
            history: 15,
            tol: 1e-9,
            warmstart_epochs: 1,
            eval_every: 1,
            seed: 0x5EED,
        }
    }
}

#[derive(Clone, Debug)]
pub struct LbfgsResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    pub iters: usize,
    pub trace: Trace,
}

/// Distributed objective + gradient: partial sums per example shard on
/// separate threads, then reduced (the by-example analogue of AllReduce).
struct ShardedProblem<'a> {
    shards: Vec<Csr>,
    labels: Vec<Vec<f64>>,
    kind: LossKind,
    l2: f64,
    p: usize,
    _marker: std::marker::PhantomData<&'a ()>,
}

impl<'a> ShardedProblem<'a> {
    fn new(train: &'a Dataset, cfg: &LbfgsConfig) -> Self {
        let parts = ExamplePartition::hashed(train.n(), cfg.nodes, cfg.seed);
        let shards: Vec<Csr> = (0..cfg.nodes).map(|m| parts.shard(&train.x, m)).collect();
        let labels: Vec<Vec<f64>> = (0..cfg.nodes)
            .map(|m| parts.shard_labels(&train.y, m))
            .collect();
        ShardedProblem {
            shards,
            labels,
            kind: cfg.kind,
            l2: cfg.l2,
            p: train.p(),
            _marker: std::marker::PhantomData,
        }
    }

    /// (f, ∇f) with the ridge term included.
    fn eval(&self, beta: &[f64]) -> (f64, Vec<f64>) {
        let m = self.shards.len();
        let mut partials: Vec<Option<(f64, Vec<f64>)>> = vec![None; m];
        crossbeam_utils::thread::scope(|scope| {
            let mut handles = Vec::new();
            for k in 0..m {
                let (shard, ys) = (&self.shards[k], &self.labels[k]);
                let (kind, p) = (self.kind, self.p);
                handles.push((
                    k,
                    scope.spawn(move |_| {
                        let mut loss = 0.0;
                        let mut grad = vec![0.0; p];
                        for i in 0..shard.nrows {
                            let margin = shard.dot_row(i, beta);
                            loss += kind.value(ys[i], margin);
                            let g = kind.d1(ys[i], margin);
                            shard.axpy_row(i, g, &mut grad);
                        }
                        (loss, grad)
                    }),
                ));
            }
            for (k, h) in handles {
                partials[k] = Some(h.join().expect("gradient worker panicked"));
            }
        })
        .expect("lbfgs scope");
        let mut f = 0.0;
        let mut grad = vec![0.0; self.p];
        for (lk, gk) in partials.into_iter().flatten() {
            f += lk;
            for (g, gi) in grad.iter_mut().zip(gk.iter()) {
                *g += gi;
            }
        }
        for j in 0..self.p {
            f += 0.5 * self.l2 * beta[j] * beta[j];
            grad[j] += self.l2 * beta[j];
        }
        (f, grad)
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}

/// Fit L2-regularized GLM with (optionally warmstarted) L-BFGS.
pub fn fit_lbfgs(train: &Dataset, test: Option<&Dataset>, cfg: &LbfgsConfig) -> LbfgsResult {
    let problem = ShardedProblem::new(train, cfg);
    let started = Instant::now();
    let mut trace = Trace::new(
        if cfg.warmstart_epochs > 0 {
            "online+lbfgs"
        } else {
            "lbfgs"
        },
        &train.name,
    );

    // ---- Agarwal et al. Algorithm 2, part 1: online warmstart ----
    let mut beta = if cfg.warmstart_epochs > 0 {
        let ocfg = OnlineConfig {
            kind: cfg.kind,
            l1: 0.0,
            l2: cfg.l2,
            nodes: cfg.nodes,
            epochs: cfg.warmstart_epochs,
            trunc_period: 0,
            eval_every: 0,
            seed: cfg.seed,
            ..Default::default()
        };
        fit_online(train, None, &ocfg).beta
    } else {
        vec![0.0; train.p()]
    };

    let record = |trace: &mut Trace, iter: usize, f: f64, beta: &[f64]| {
        let auprc = test.and_then(|t| {
            (cfg.eval_every > 0 && iter % cfg.eval_every == 0).then(|| {
                let scores = t.x.mul_vec(beta);
                metrics::auprc(&t.y, &scores)
            })
        });
        trace.push(TracePoint {
            t_sec: started.elapsed().as_secs_f64(),
            iter,
            objective: f,
            nnz: metrics::nnz_weights(beta),
            alpha: 1.0,
            mu: 1.0,
            auprc,
        });
    };

    let (mut f_cur, mut grad) = problem.eval(&beta);
    record(&mut trace, 0, f_cur, &beta);

    // ---- part 2: L-BFGS two-loop recursion ----
    let mut s_hist: VecDeque<Vec<f64>> = VecDeque::new();
    let mut y_hist: VecDeque<Vec<f64>> = VecDeque::new();
    let mut rho_hist: VecDeque<f64> = VecDeque::new();
    let mut iters = 0;
    for it in 1..=cfg.max_iters {
        iters = it;
        // Two-loop recursion for d = -H·grad.
        let mut q = grad.clone();
        let mut alphas = Vec::with_capacity(s_hist.len());
        for k in (0..s_hist.len()).rev() {
            let a = rho_hist[k] * dot(&s_hist[k], &q);
            for (qi, yi) in q.iter_mut().zip(y_hist[k].iter()) {
                *qi -= a * yi;
            }
            alphas.push(a);
        }
        alphas.reverse();
        // Initial Hessian scaling γ = sᵀy / yᵀy.
        if let (Some(s), Some(yv)) = (s_hist.back(), y_hist.back()) {
            let gamma = dot(s, yv) / dot(yv, yv).max(1e-300);
            for qi in q.iter_mut() {
                *qi *= gamma;
            }
        }
        for k in 0..s_hist.len() {
            let b = rho_hist[k] * dot(&y_hist[k], &q);
            let corr = alphas[k] - b;
            for (qi, si) in q.iter_mut().zip(s_hist[k].iter()) {
                *qi += corr * si;
            }
        }
        let dir: Vec<f64> = q.iter().map(|v| -v).collect();

        // Backtracking Armijo line search.
        let gd = dot(&grad, &dir);
        if gd >= 0.0 {
            // Not a descent direction (history went stale): reset.
            s_hist.clear();
            y_hist.clear();
            rho_hist.clear();
            continue;
        }
        let mut step = 1.0;
        let mut accepted = false;
        let mut beta_new = beta.clone();
        let mut f_new = f_cur;
        for _ in 0..40 {
            for j in 0..beta.len() {
                beta_new[j] = beta[j] + step * dir[j];
            }
            let (f_try, _) = problem.eval(&beta_new);
            if f_try <= f_cur + 1e-4 * step * gd {
                f_new = f_try;
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        if !accepted {
            break; // numerically converged
        }
        let (_, grad_new) = problem.eval(&beta_new);
        // Curvature update.
        let s: Vec<f64> = beta_new
            .iter()
            .zip(beta.iter())
            .map(|(a, b)| a - b)
            .collect();
        let yv: Vec<f64> = grad_new
            .iter()
            .zip(grad.iter())
            .map(|(a, b)| a - b)
            .collect();
        let sy = dot(&s, &yv);
        if sy > 1e-12 {
            s_hist.push_back(s);
            y_hist.push_back(yv);
            rho_hist.push_back(1.0 / sy);
            if s_hist.len() > cfg.history {
                s_hist.pop_front();
                y_hist.pop_front();
                rho_hist.pop_front();
            }
        }
        let rel = (f_cur - f_new) / f_cur.abs().max(1e-12);
        beta = beta_new;
        grad = grad_new;
        f_cur = f_new;
        record(&mut trace, it, f_cur, &beta);
        if rel.abs() < cfg.tol {
            break;
        }
    }

    LbfgsResult {
        beta,
        objective: f_cur,
        iters,
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::glm::regularizer::ElasticNet;
    use crate::solver::compute::NativeCompute;
    use crate::solver::dglmnet::{self, DGlmnetConfig};

    #[test]
    fn quadratic_exact_in_few_iterations() {
        // Squared loss + ridge = strictly convex quadratic: L-BFGS must hit
        // machine precision quickly.
        let ds = synth::regression_toy(100, 6, 0.05, 41);
        let cfg = LbfgsConfig {
            kind: LossKind::Squared,
            l2: 0.5,
            nodes: 2,
            max_iters: 60,
            warmstart_epochs: 0,
            eval_every: 0,
            ..Default::default()
        };
        let res = fit_lbfgs(&ds, None, &cfg);
        let problem = ShardedProblem::new(&ds, &cfg);
        let (_, grad) = problem.eval(&res.beta);
        let gnorm: f64 = grad.iter().map(|g| g * g).sum::<f64>().sqrt();
        assert!(gnorm < 1e-5, "gradient norm {gnorm}");
    }

    #[test]
    fn matches_dglmnet_on_l2_logistic() {
        let ds = synth::epsilon_like(&synth::SynthConfig {
            n: 200,
            p: 12,
            seed: 42,
        });
        let l2 = 0.5;
        let lb = fit_lbfgs(
            &ds,
            None,
            &LbfgsConfig {
                l2,
                nodes: 3,
                max_iters: 150,
                warmstart_epochs: 0,
                eval_every: 0,
                tol: 1e-12,
                ..Default::default()
            },
        );
        let compute = NativeCompute::new(LossKind::Logistic);
        let dg = dglmnet::fit(
            &ds,
            &compute,
            &ElasticNet::l2_only(l2),
            &DGlmnetConfig {
                nodes: 3,
                max_iters: 300,
                tol: 1e-12,
                patience: 3,
                eval_every: 0,
                ..Default::default()
            },
            None,
        );
        let gap = (lb.objective - dg.objective).abs() / dg.objective;
        assert!(gap < 1e-4, "lbfgs {} vs dglmnet {}", lb.objective, dg.objective);
    }

    #[test]
    fn warmstart_starts_lower() {
        let ds = synth::epsilon_like(&synth::SynthConfig {
            n: 1500,
            p: 15,
            seed: 43,
        });
        let base = LbfgsConfig {
            l2: 0.5,
            nodes: 4,
            max_iters: 1,
            eval_every: 1,
            ..Default::default()
        };
        let cold = fit_lbfgs(
            &ds,
            None,
            &LbfgsConfig {
                warmstart_epochs: 0,
                ..base.clone()
            },
        );
        let warm = fit_lbfgs(
            &ds,
            None,
            &LbfgsConfig {
                warmstart_epochs: 2,
                ..base
            },
        );
        // The warmstarted run's *initial* objective (first trace point)
        // must beat the cold start's initial objective.
        let cold0 = cold.trace.points[0].objective;
        let warm0 = warm.trace.points[0].objective;
        assert!(warm0 < cold0, "warmstart {warm0} vs cold {cold0}");
    }

    #[test]
    fn sharding_invariant() {
        // The distributed gradient must not depend on the number of shards.
        let ds = synth::epsilon_like(&synth::SynthConfig {
            n: 120,
            p: 8,
            seed: 44,
        });
        let mut objs = Vec::new();
        for nodes in [1, 2, 5] {
            let cfg = LbfgsConfig {
                l2: 0.3,
                nodes,
                max_iters: 80,
                warmstart_epochs: 0,
                eval_every: 0,
                tol: 1e-13,
                ..Default::default()
            };
            objs.push(fit_lbfgs(&ds, None, &cfg).objective);
        }
        for o in &objs[1..] {
            assert!((o - objs[0]).abs() / objs[0] < 1e-6, "objectives {objs:?}");
        }
    }
}
