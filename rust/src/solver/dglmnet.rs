//! Algorithm 1 — the d-GLMNET outer loop (single-process reference).
//!
//! This runs the exact distributed algorithm with the M blocks processed
//! sequentially in one process: the math (block-diagonal Hessian model,
//! summed Δβ, one global line search, adaptive μ) is identical to the
//! threaded coordinator in `coordinator/`, which makes it the correctness
//! oracle for the distributed path and the reference-optimum (`f*`) solver
//! for the suboptimality plots. With `nodes = 1` it degenerates to a
//! newGLMNET-style single-machine solver (one CD pass per Newton step).

use crate::data::Dataset;
use crate::glm::regularizer::Penalty1D;
use crate::metrics;
use crate::solver::compute::GlmCompute;
use crate::solver::linesearch::{line_search, LineSearchConfig};
use crate::solver::subproblem::{cd_cycle, CycleBudget, SubproblemState};
use crate::solver::trace::{Trace, TracePoint};
use crate::sparse::{Csc, PartitionStrategy};
use std::time::Instant;

/// Configuration of Algorithm 1. Paper defaults: η₁ = η₂ = 2, adaptive μ for
/// L1 runs, constant μ = 1 for pure-L2 runs.
#[derive(Clone, Debug)]
pub struct DGlmnetConfig {
    /// Number of feature blocks M (the simulated node count).
    pub nodes: usize,
    /// Adaptive trust-region μ (Section 4). When false, μ stays at `mu0`.
    pub adaptive_mu: bool,
    pub mu0: f64,
    pub eta1: f64,
    pub eta2: f64,
    /// Positive-definiteness shift ν (Section 5).
    pub nu: f64,
    pub max_iters: usize,
    /// Stop when the relative objective decrease stays below this for
    /// `patience` consecutive iterations.
    pub tol: f64,
    pub patience: usize,
    pub seed: u64,
    pub linesearch: LineSearchConfig,
    /// Evaluate test metrics every k iterations (0 = never).
    pub eval_every: usize,
    /// How features map to the M simulated blocks — resolved through
    /// [`PartitionStrategy::resolve`], the same seam the distributed
    /// drivers use, so an oracle comparison sees identical blocks.
    pub partition: PartitionStrategy,
}

impl Default for DGlmnetConfig {
    fn default() -> Self {
        DGlmnetConfig {
            nodes: 8,
            adaptive_mu: true,
            mu0: 1.0,
            eta1: 2.0,
            eta2: 2.0,
            nu: 1e-6,
            max_iters: 100,
            tol: 1e-7,
            patience: 2,
            seed: 0x5EED,
            linesearch: LineSearchConfig::default(),
            eval_every: 1,
            partition: PartitionStrategy::default(),
        }
    }
}

/// Result of a fit.
#[derive(Clone, Debug)]
pub struct FitResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    pub iters: usize,
    pub trace: Trace,
}

/// Optional test-set hook for auPRC-vs-time traces.
pub struct TestEval<'a> {
    pub dataset: &'a Dataset,
}

/// Fit a regularized GLM with the d-GLMNET algorithm (single process).
pub fn fit(
    train: &Dataset,
    compute: &dyn GlmCompute,
    penalty: &dyn Penalty1D,
    cfg: &DGlmnetConfig,
    test: Option<&TestEval<'_>>,
) -> FitResult {
    let n = train.n();
    let p = train.p();
    let x_csc = train.to_csc();
    let partition = cfg.partition.resolve(&x_csc, cfg.nodes, cfg.seed);
    let shards: Vec<Csc> = (0..cfg.nodes).map(|m| partition.shard(&x_csc, m)).collect();

    let mut beta = vec![0.0; p];
    let mut margins = vec![0.0; n];
    let mut w = vec![0.0; n];
    let mut z = vec![0.0; n];
    let mut mu = cfg.mu0;
    let mut states: Vec<SubproblemState> = partition
        .blocks
        .iter()
        .map(|b| SubproblemState::new(b.len(), n))
        .collect();

    let mut trace = Trace::new("d-glmnet", &train.name);
    let started = Instant::now();
    // One kernel-mode lookup for the whole fit — the mode is pinned before
    // any solver runs (kernels::set_fast_math), never flipped mid-fit.
    let ker = crate::kernels::active();

    let mut loss = compute.stats(&train.y, &margins, &mut w, &mut z);
    let mut reg = penalty.value(&beta);
    let mut f_cur = loss + reg;
    record(
        &mut trace, &started, 0, f_cur, &beta, 1.0, mu, test, compute, cfg,
    );

    let mut stall = 0usize;
    let mut iters = 0usize;
    for it in 1..=cfg.max_iters {
        iters = it;
        // ---- parallel-block subproblems (sequential here, same math) ----
        let mut dmargins = vec![0.0; n];
        for m in 0..cfg.nodes {
            let block = &partition.blocks[m];
            if block.is_empty() {
                continue;
            }
            let local_beta: Vec<f64> = block.iter().map(|&j| beta[j]).collect();
            let st = &mut states[m];
            st.reset();
            cd_cycle(
                &shards[m],
                &local_beta,
                &w,
                &z,
                mu,
                cfg.nu,
                penalty,
                st,
                CycleBudget::full_cycle(block.len()),
            );
            // Merge the block's XᵐΔβᵐ into the global direction (α = 1 is
            // exact, so this is the same fused axpy as the step apply).
            ker.margin_update_with_xdelta(&mut dmargins, &st.t, 1.0);
        }

        // ---- global line search over the merged direction ----
        // ∇L(β)ᵀΔβ from the cached working set: g_i = −w_i z_i exactly
        // (z = −g/w with the same floored w), so no extra stats pass.
        let grad_dot = ker.neg_wz_dot(&w, &z, &dmargins);
        let reg_ray = |alphas: &[f64]| -> Vec<f64> {
            let mut out = vec![0.0; alphas.len()];
            for (m, block) in partition.blocks.iter().enumerate() {
                let st = &states[m];
                for (local, &j) in block.iter().enumerate() {
                    let (b, d) = (beta[j], st.delta_beta[local]);
                    for (k, &a) in alphas.iter().enumerate() {
                        out[k] += penalty.value_1d(b + a * d);
                    }
                }
            }
            out
        };
        let ls = line_search(
            compute,
            &cfg.linesearch,
            &train.y,
            &margins,
            &dmargins,
            f_cur,
            reg,
            grad_dot,
            &reg_ray,
        );

        // ---- apply the step ----
        if ls.alpha > 0.0 {
            for (m, block) in partition.blocks.iter().enumerate() {
                let st = &states[m];
                for (local, &j) in block.iter().enumerate() {
                    beta[j] += ls.alpha * st.delta_beta[local];
                }
            }
            ker.margin_update_with_xdelta(&mut margins, &dmargins, ls.alpha);
        }

        // ---- adaptive μ (Algorithm 1 steps 9-12) ----
        if cfg.adaptive_mu {
            if ls.alpha < 1.0 {
                mu *= cfg.eta1;
            } else {
                mu = (mu / cfg.eta2).max(1.0);
            }
        }

        // ---- bookkeeping + convergence ----
        loss = compute.stats(&train.y, &margins, &mut w, &mut z);
        reg = penalty.value(&beta);
        let f_new = loss + reg;
        let rel_drop = (f_cur - f_new) / f_cur.abs().max(1e-12);
        f_cur = f_new;
        record(
            &mut trace, &started, it, f_cur, &beta, ls.alpha, mu, test, compute, cfg,
        );
        if rel_drop.abs() < cfg.tol {
            stall += 1;
            if stall >= cfg.patience {
                break;
            }
        } else {
            stall = 0;
        }
    }

    FitResult {
        beta,
        objective: f_cur,
        iters,
        trace,
    }
}

#[allow(clippy::too_many_arguments)]
fn record(
    trace: &mut Trace,
    started: &Instant,
    iter: usize,
    objective: f64,
    beta: &[f64],
    alpha: f64,
    mu: f64,
    test: Option<&TestEval<'_>>,
    _compute: &dyn GlmCompute,
    cfg: &DGlmnetConfig,
) {
    let auprc = match test {
        Some(te) if cfg.eval_every > 0 && iter % cfg.eval_every == 0 => {
            let scores = te.dataset.x.mul_vec(beta);
            Some(metrics::auprc(&te.dataset.y, &scores))
        }
        _ => None,
    };
    trace.push(TracePoint {
        t_sec: started.elapsed().as_secs_f64(),
        iter,
        objective,
        nnz: metrics::nnz_weights(beta),
        alpha,
        mu,
        auprc,
    });
}

/// Compute f(β) = L + R for an explicit weight vector (used by tests and by
/// the f* reference harness).
pub fn objective(
    train: &Dataset,
    compute: &dyn GlmCompute,
    penalty: &dyn Penalty1D,
    beta: &[f64],
) -> f64 {
    let margins = train.x.mul_vec(beta);
    compute.total_loss(&train.y, &margins) + penalty.value(beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::glm::loss::LossKind;
    use crate::glm::regularizer::ElasticNet;
    use crate::solver::compute::NativeCompute;
    use crate::sparse::csr::Csr;

    fn small_classification(n: usize, p: usize, seed: u64) -> Dataset {
        let cfg = synth::SynthConfig { n, p, seed };
        synth::epsilon_like(&cfg)
    }

    #[test]
    fn objective_monotone_nonincreasing() {
        let ds = small_classification(200, 10, 1);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.5, 0.1);
        let cfg = DGlmnetConfig {
            nodes: 4,
            max_iters: 30,
            eval_every: 0,
            ..Default::default()
        };
        let fit = fit(&ds, &compute, &pen, &cfg, None);
        let objs: Vec<f64> = fit.trace.points.iter().map(|p| p.objective).collect();
        for w in objs.windows(2) {
            assert!(
                w[1] <= w[0] + 1e-9,
                "objective increased: {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn converges_to_same_objective_regardless_of_block_count() {
        // The optimum of the convex problem is unique; M must not change it.
        let ds = small_classification(150, 8, 2);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.2, 0.1);
        let mut finals = Vec::new();
        for nodes in [1, 2, 5] {
            let cfg = DGlmnetConfig {
                nodes,
                max_iters: 200,
                tol: 1e-10,
                patience: 3,
                eval_every: 0,
                ..Default::default()
            };
            finals.push(fit(&ds, &compute, &pen, &cfg, None).objective);
        }
        for f in &finals[1..] {
            assert!(
                (f - finals[0]).abs() / finals[0] < 1e-4,
                "objectives diverge across M: {finals:?}"
            );
        }
    }

    #[test]
    fn lasso_univariate_matches_closed_form() {
        // Squared loss, single feature: argmin ½Σ(y - βx)² + λ|β| has the
        // closed form β* = T(Σxy, λ)/Σx².
        let x = Csr::from_rows(1, &[vec![(0, 1.0)], vec![(0, 2.0)], vec![(0, -1.0)]]);
        let y = vec![2.0, 3.9, -2.1];
        let ds = Dataset::new("uni", x, y.clone());
        let compute = NativeCompute::new(LossKind::Squared);
        let lambda = 1.5;
        let pen = ElasticNet::l1_only(lambda);
        let cfg = DGlmnetConfig {
            nodes: 1,
            max_iters: 100,
            tol: 1e-12,
            eval_every: 0,
            ..Default::default()
        };
        let fitres = fit(&ds, &compute, &pen, &cfg, None);
        let sxy: f64 = 1.0 * 2.0 + 2.0 * 3.9 + (-1.0) * (-2.1);
        let sxx: f64 = 1.0 + 4.0 + 1.0;
        let want = crate::glm::soft_threshold(sxy, lambda) / sxx;
        assert!(
            (fitres.beta[0] - want).abs() < 1e-6,
            "beta {} want {want}",
            fitres.beta[0]
        );
    }

    #[test]
    fn ridge_matches_normal_equations() {
        // Squared loss + pure L2 on a small dense system: compare against
        // the (XᵀX + λI)β = Xᵀy solution computed by Gaussian elimination.
        let ds = synth::regression_toy(80, 4, 0.1, 3);
        let compute = NativeCompute::new(LossKind::Squared);
        let l2 = 2.0;
        let pen = ElasticNet::l2_only(l2);
        let cfg = DGlmnetConfig {
            nodes: 2,
            max_iters: 400,
            tol: 1e-13,
            patience: 3,
            eval_every: 0,
            ..Default::default()
        };
        let fitres = fit(&ds, &compute, &pen, &cfg, None);
        // Build XᵀX + λI and Xᵀy densely.
        let p = 4;
        let mut a = vec![vec![0.0; p]; p];
        let mut b = vec![0.0; p];
        for i in 0..ds.n() {
            let row: Vec<(usize, f64)> = ds.x.row(i).collect();
            for &(j1, v1) in &row {
                b[j1] += v1 * ds.y[i];
                for &(j2, v2) in &row {
                    a[j1][j2] += v1 * v2;
                }
            }
        }
        for j in 0..p {
            a[j][j] += l2;
        }
        // Gaussian elimination.
        let mut m = a.clone();
        let mut rhs = b.clone();
        for col in 0..p {
            let piv = (col..p)
                .max_by(|&r1, &r2| m[r1][col].abs().total_cmp(&m[r2][col].abs()))
                .unwrap();
            m.swap(col, piv);
            rhs.swap(col, piv);
            for r in col + 1..p {
                let f = m[r][col] / m[col][col];
                for c in col..p {
                    m[r][c] -= f * m[col][c];
                }
                rhs[r] -= f * rhs[col];
            }
        }
        let mut want = vec![0.0; p];
        for r in (0..p).rev() {
            let mut acc = rhs[r];
            for c in r + 1..p {
                acc -= m[r][c] * want[c];
            }
            want[r] = acc / m[r][r];
        }
        for j in 0..p {
            assert!(
                (fitres.beta[j] - want[j]).abs() < 1e-4,
                "beta[{j}] = {} want {}",
                fitres.beta[j],
                want[j]
            );
        }
    }

    #[test]
    fn l1_produces_sparser_solution_than_l2() {
        let ds = small_classification(300, 40, 4);
        let compute = NativeCompute::new(LossKind::Logistic);
        let cfg = DGlmnetConfig {
            nodes: 4,
            max_iters: 60,
            eval_every: 0,
            ..Default::default()
        };
        let l1_fit = fit(&ds, &compute, &ElasticNet::l1_only(6.0), &cfg, None);
        let l2_fit = fit(&ds, &compute, &ElasticNet::l2_only(6.0), &cfg, None);
        let nnz_l1 = metrics::nnz_weights(&l1_fit.beta);
        let nnz_l2 = metrics::nnz_weights(&l2_fit.beta);
        assert!(
            nnz_l1 < nnz_l2,
            "L1 nnz {nnz_l1} should be < L2 nnz {nnz_l2}"
        );
        assert!(nnz_l1 < 40);
        assert_eq!(nnz_l2, 40); // ridge keeps everything
    }

    #[test]
    fn probit_and_logistic_both_learn() {
        let ds = small_classification(400, 10, 5);
        for kind in [LossKind::Logistic, LossKind::Probit] {
            let compute = NativeCompute::new(kind);
            let pen = ElasticNet::l2_only(0.1);
            let cfg = DGlmnetConfig {
                nodes: 3,
                max_iters: 80,
                eval_every: 0,
                ..Default::default()
            };
            let fitres = fit(&ds, &compute, &pen, &cfg, None);
            let scores = ds.x.mul_vec(&fitres.beta);
            let auc = metrics::roc_auc(&ds.y, &scores);
            // Labels are drawn through a noisy logistic link (margin sd
            // ≈ 1.5), so the Bayes-optimal AUC itself is ~0.75-0.8.
            assert!(auc > 0.65, "{:?} train AUC {auc}", kind);
        }
    }

    #[test]
    fn test_eval_hook_fills_auprc() {
        let splits = synth::Corpus::epsilon_like(0.05, 6);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(0.1, 0.1);
        let cfg = DGlmnetConfig {
            nodes: 2,
            max_iters: 5,
            eval_every: 2,
            ..Default::default()
        };
        let fitres = fit(
            &splits.train,
            &compute,
            &pen,
            &cfg,
            Some(&TestEval {
                dataset: &splits.test,
            }),
        );
        assert!(fitres.trace.points.iter().any(|p| p.auprc.is_some()));
        assert!(fitres
            .trace
            .points
            .iter()
            .filter_map(|p| p.auprc)
            .all(|a| (0.0..=1.0).contains(&a)));
    }

    #[test]
    fn adaptive_mu_grows_on_backtracks() {
        // Contiguous correlated blocks + large M forces conflicts; μ should
        // leave 1.0 at least once on datasets with correlated features.
        let ds = small_classification(100, 30, 7);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::l1_only(0.05);
        let cfg = DGlmnetConfig {
            nodes: 15,
            max_iters: 25,
            eval_every: 0,
            ..Default::default()
        };
        let fitres = fit(&ds, &compute, &pen, &cfg, None);
        // μ is recorded per iteration; just assert the mechanism runs and
        // stays >= 1.
        assert!(fitres.trace.points.iter().all(|p| p.mu >= 1.0));
    }
}
