//! The per-example GLM compute interface — the seam between the Rust
//! coordinator (L3) and the AOT-compiled XLA artifacts (L2/L1).
//!
//! Everything the d-GLMNET outer loop needs from the loss is:
//!   1. `stats`      — working weights/responses (w, z) + total loss at the
//!                     current margins (one call per outer iteration),
//!   2. `loss_at_alphas` — L(Xβ + α·XΔβ) for a batch of step sizes (one call
//!                     per line search),
//!   3. `grad_dot`   — ∇L(β)ᵀΔβ = Σ g_i (XΔβ)_i for the Armijo decrease D.
//!
//! `NativeCompute` is the pure-Rust implementation (also the correctness
//! oracle); `runtime::XlaCompute` implements the same trait by executing the
//! Pallas-kernel artifacts through PJRT.

use crate::glm::loss::{LossKind, W_FLOOR};

/// Per-example statistics + batched line-search losses for one loss family.
pub trait GlmCompute: Send + Sync {
    fn kind(&self) -> LossKind;

    /// Fill `w` and `z` from margins; return total loss Σ ℓ(y_i, m_i).
    fn stats(&self, y: &[f64], margins: &[f64], w: &mut [f64], z: &mut [f64]) -> f64;

    /// Return Σ_i ℓ(y_i, m_i + α d_i) for each α in `alphas`.
    fn loss_at_alphas(
        &self,
        y: &[f64],
        margins: &[f64],
        dmargins: &[f64],
        alphas: &[f64],
    ) -> Vec<f64>;

    /// ∇L(β)ᵀΔβ computed through the margin space: Σ_i ℓ'(y_i, m_i) d_i.
    fn grad_dot(&self, y: &[f64], margins: &[f64], dmargins: &[f64]) -> f64;

    /// Total loss at the current margins (default: via `loss_at_alphas`).
    fn total_loss(&self, y: &[f64], margins: &[f64]) -> f64 {
        let zeros = vec![0.0; margins.len()];
        self.loss_at_alphas(y, margins, &zeros, &[0.0])[0]
    }

    /// Inverse-link probabilities for a margin block — the serving path
    /// (`serve::Scorer`). Logistic goes through the batched
    /// `kernels::sigmoid_margins` sweep (element-wise, bit-identical in
    /// every mode); other families use the loss family's scalar link.
    fn predict_probs(&self, margins: &[f64]) -> Vec<f64> {
        let kind = self.kind();
        if kind == LossKind::Logistic {
            let mut out = vec![0.0; margins.len()];
            crate::kernels::active().sigmoid_margins(margins, &mut out);
            return out;
        }
        margins.iter().map(|&m| kind.prob(m)).collect()
    }
}

/// Pure-Rust reference implementation of [`GlmCompute`].
#[derive(Clone, Copy, Debug)]
pub struct NativeCompute {
    pub kind: LossKind,
}

impl NativeCompute {
    pub fn new(kind: LossKind) -> Self {
        NativeCompute { kind }
    }
}

impl GlmCompute for NativeCompute {
    fn kind(&self) -> LossKind {
        self.kind
    }

    fn stats(&self, y: &[f64], margins: &[f64], w: &mut [f64], z: &mut [f64]) -> f64 {
        debug_assert_eq!(y.len(), margins.len());
        debug_assert_eq!(y.len(), w.len());
        debug_assert_eq!(y.len(), z.len());
        let mut loss = 0.0;
        for i in 0..y.len() {
            let (yi, mi) = (y[i], margins[i]);
            loss += self.kind.value(yi, mi);
            let g = self.kind.d1(yi, mi);
            let wi = self.kind.d2(yi, mi).max(W_FLOOR);
            w[i] = wi;
            z[i] = -g / wi;
        }
        loss
    }

    fn loss_at_alphas(
        &self,
        y: &[f64],
        margins: &[f64],
        dmargins: &[f64],
        alphas: &[f64],
    ) -> Vec<f64> {
        debug_assert_eq!(y.len(), margins.len());
        debug_assert_eq!(y.len(), dmargins.len());
        let mut out = vec![0.0; alphas.len()];
        if self.kind == LossKind::Logistic {
            // The line-search grid for the hot-path family goes through the
            // kernel seam; same i-outer/k-inner accumulation order, so the
            // result is bit-identical to the generic loop below.
            crate::kernels::active().logloss_grid(y, margins, dmargins, alphas, &mut out);
            return out;
        }
        for i in 0..y.len() {
            let (yi, mi, di) = (y[i], margins[i], dmargins[i]);
            for (k, &a) in alphas.iter().enumerate() {
                out[k] += self.kind.value(yi, mi + a * di);
            }
        }
        out
    }

    fn grad_dot(&self, y: &[f64], margins: &[f64], dmargins: &[f64]) -> f64 {
        let mut acc = 0.0;
        for i in 0..y.len() {
            acc += self.kind.d1(y[i], margins[i]) * dmargins[i];
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, close};

    #[test]
    fn stats_matches_loss_pieces() {
        let c = NativeCompute::new(LossKind::Logistic);
        let y = [1.0, -1.0, 1.0];
        let m = [0.5, -0.25, 2.0];
        let mut w = [0.0; 3];
        let mut z = [0.0; 3];
        let loss = c.stats(&y, &m, &mut w, &mut z);
        let want: f64 = (0..3).map(|i| LossKind::Logistic.value(y[i], m[i])).sum();
        assert!((loss - want).abs() < 1e-12);
        for i in 0..3 {
            let (wi, zi) = LossKind::Logistic.working_response(y[i], m[i]);
            assert_eq!(w[i], wi);
            assert_eq!(z[i], zi);
        }
    }

    #[test]
    fn loss_at_alphas_zero_alpha_is_total_loss() {
        let c = NativeCompute::new(LossKind::Probit);
        let y = [1.0, -1.0];
        let m = [0.3, 0.4];
        let d = [1.0, -2.0];
        let at0 = c.loss_at_alphas(&y, &m, &d, &[0.0])[0];
        assert!((at0 - c.total_loss(&y, &m)).abs() < 1e-12);
    }

    #[test]
    fn prop_grad_dot_is_directional_derivative() {
        prop::check("grad_dot = d/dα loss(α)|₀", 100, |rng| {
            for kind in [LossKind::Logistic, LossKind::Squared, LossKind::Probit] {
                let c = NativeCompute::new(kind);
                let n = 1 + rng.below(20);
                let y: Vec<f64> = (0..n)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect();
                let m = prop::dense_vec(rng, n, 2.0);
                let d = prop::dense_vec(rng, n, 1.0);
                let h = 1e-6;
                let ls = c.loss_at_alphas(&y, &m, &d, &[h, -h]);
                let fd = (ls[0] - ls[1]) / (2.0 * h);
                close(c.grad_dot(&y, &m, &d), fd, 1e-4)?;
            }
            Ok(())
        });
    }
}
