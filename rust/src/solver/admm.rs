//! ADMM with sharing — the paper's first baseline (Section 8.1; Boyd et al.
//! 2011 §7.3, §8.3.1/8.3.3).
//!
//! The design matrix is split by features into M blocks (same vertical
//! sharding as d-GLMNET). Sharing ADMM alternates:
//!
//!   β^m ← argmin  λ₁‖β^m‖₁ + (λ₂/2)‖β^m‖² + (ρ/2)‖X^m β^m − v^m‖²
//!           (a LASSO solved by Shooting, warm-started; in parallel over m)
//!   z̄  ← argmin  Σᵢ ℓ(yᵢ, M z̄ᵢ) + (Mρ/2)‖z̄ − u − x̄‖²
//!           (n independent 1-D problems, damped Newton — the paper's
//!            footnote 3 fix: the coefficient is ρM/2, not ρ/2)
//!   u  ← u + x̄ − z̄
//!
//! where x̄ = (1/M) Σ X^m β^m. Like the paper's implementation, weights live
//! distributed per block and x-updates run concurrently (one thread per
//! block, mirroring the node parallelism).

use crate::data::Dataset;
use crate::glm::loss::LossKind;
use crate::metrics;
use crate::solver::shooting::{shooting, ShootingConfig};
use crate::solver::trace::{Trace, TracePoint};
use crate::sparse::{Csc, FeaturePartition};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct AdmmConfig {
    pub kind: LossKind,
    pub l1: f64,
    pub l2: f64,
    pub rho: f64,
    pub nodes: usize,
    pub max_iters: usize,
    /// Shooting passes per x-update (warm-started, few passes suffice).
    pub shooting_passes: usize,
    pub newton_iters: usize,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for AdmmConfig {
    fn default() -> Self {
        AdmmConfig {
            kind: LossKind::Logistic,
            l1: 1.0,
            l2: 0.0,
            rho: 1.0,
            nodes: 8,
            max_iters: 100,
            shooting_passes: 5,
            newton_iters: 25,
            eval_every: 1,
            seed: 0x5EED,
        }
    }
}

#[derive(Clone, Debug)]
pub struct AdmmResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    pub iters: usize,
    pub trace: Trace,
}

/// One-dimensional z-update: argmin_z ℓ(y, M z) + (Mρ/2)(z − c)², damped
/// Newton from z = c (the objective is strongly convex, ℓ convex smooth).
fn z_update_1d(kind: LossKind, y: f64, m: f64, rho: f64, c: f64, iters: usize) -> f64 {
    let mut z = c;
    for _ in 0..iters {
        let g = m * kind.d1(y, m * z) + m * rho * (z - c);
        let h = m * m * kind.d2(y, m * z) + m * rho;
        let step = g / h;
        z -= step;
        if step.abs() < 1e-13 * (1.0 + z.abs()) {
            break;
        }
    }
    z
}

/// Fit a regularized GLM with sharing ADMM over `cfg.nodes` feature blocks.
pub fn fit_admm(train: &Dataset, test: Option<&Dataset>, cfg: &AdmmConfig) -> AdmmResult {
    let n = train.n();
    let p = train.p();
    let m_nodes = cfg.nodes;
    let partition = FeaturePartition::hashed(p, m_nodes, cfg.seed);
    let x_csc = train.to_csc();
    let shards: Vec<Csc> = (0..m_nodes).map(|m| partition.shard(&x_csc, m)).collect();

    // Per-block weights and predictions X^m β^m.
    let mut betas: Vec<Vec<f64>> = partition.blocks.iter().map(|b| vec![0.0; b.len()]).collect();
    let mut preds: Vec<Vec<f64>> = (0..m_nodes).map(|_| vec![0.0; n]).collect();
    let mut zbar = vec![0.0; n];
    let mut u = vec![0.0; n];

    let mut trace = Trace::new("admm", &train.name);
    let started = Instant::now();
    let mf = m_nodes as f64;

    let objective = |betas: &[Vec<f64>], preds: &[Vec<f64>]| -> f64 {
        let mut margins = vec![0.0; n];
        for pr in preds {
            for (mi, pi) in margins.iter_mut().zip(pr.iter()) {
                *mi += pi;
            }
        }
        let mut loss = 0.0;
        for i in 0..n {
            loss += cfg.kind.value(train.y[i], margins[i]);
        }
        let mut l1 = 0.0;
        let mut l2 = 0.0;
        for b in betas {
            for w in b {
                l1 += w.abs();
                l2 += w * w;
            }
        }
        loss + cfg.l1 * l1 + 0.5 * cfg.l2 * l2
    };

    let record = |trace: &mut Trace,
                  started: &Instant,
                  iter: usize,
                  f: f64,
                  betas: &[Vec<f64>]| {
        let nnz: usize = betas.iter().map(|b| metrics::nnz_weights(b)).sum();
        let auprc = test.and_then(|t| {
            (cfg.eval_every > 0 && iter % cfg.eval_every == 0).then(|| {
                let beta = partition.unshard_weights(&betas.to_vec());
                let scores = t.x.mul_vec(&beta);
                metrics::auprc(&t.y, &scores)
            })
        });
        trace.push(TracePoint {
            t_sec: started.elapsed().as_secs_f64(),
            iter,
            objective: f,
            nnz,
            alpha: 1.0,
            mu: 1.0,
            auprc,
        });
    };

    let mut f_cur = objective(&betas, &preds);
    record(&mut trace, &started, 0, f_cur, &betas);

    let mut iters = 0;
    for it in 1..=cfg.max_iters {
        iters = it;
        // x̄ = average of block predictions.
        let mut xbar = vec![0.0; n];
        for pr in &preds {
            for (xi, pi) in xbar.iter_mut().zip(pr.iter()) {
                *xi += pi;
            }
        }
        for xi in xbar.iter_mut() {
            *xi /= mf;
        }

        // ---- x-update: parallel shooting per block ----
        let sh_cfg = ShootingConfig {
            rho: cfg.rho,
            l1: cfg.l1,
            l2: cfg.l2,
            max_passes: cfg.shooting_passes,
            tol: 1e-10,
        };
        crossbeam_utils::thread::scope(|scope| {
            for ((beta_m, pred_m), shard) in
                betas.iter_mut().zip(preds.iter_mut()).zip(shards.iter())
            {
                let (xbar, zbar, u) = (&xbar, &zbar, &u);
                let sh_cfg = sh_cfg;
                scope.spawn(move |_| {
                    // v^m = X^m β^m + z̄ − x̄ − u
                    let mut v = vec![0.0; pred_m.len()];
                    for i in 0..v.len() {
                        v[i] = pred_m[i] + zbar[i] - xbar[i] - u[i];
                    }
                    shooting(shard, &v, beta_m, &sh_cfg);
                    *pred_m = shard.mul_vec(beta_m);
                });
            }
        })
        .expect("admm x-update scope");

        // Recompute x̄ with the new predictions.
        let mut xbar = vec![0.0; n];
        for pr in &preds {
            for (xi, pi) in xbar.iter_mut().zip(pr.iter()) {
                *xi += pi;
            }
        }
        for xi in xbar.iter_mut() {
            *xi /= mf;
        }

        // ---- z-update: n independent 1-D Newton solves ----
        for i in 0..n {
            let c = u[i] + xbar[i];
            zbar[i] = z_update_1d(cfg.kind, train.y[i], mf, cfg.rho, c, cfg.newton_iters);
        }

        // ---- dual update ----
        for i in 0..n {
            u[i] += xbar[i] - zbar[i];
        }

        f_cur = objective(&betas, &preds);
        record(&mut trace, &started, it, f_cur, &betas);
    }

    let beta = partition.unshard_weights(&betas);
    AdmmResult {
        beta,
        objective: f_cur,
        iters,
        trace,
    }
}

/// The paper's ρ selection: try ρ ∈ {4⁻³ … 4³}, run `probe_iters`
/// iterations, keep the ρ with the best objective.
pub fn select_rho(train: &Dataset, cfg: &AdmmConfig, probe_iters: usize) -> f64 {
    let mut best = (f64::INFINITY, cfg.rho);
    for e in -3..=3 {
        let rho = 4f64.powi(e);
        let probe_cfg = AdmmConfig {
            rho,
            max_iters: probe_iters,
            eval_every: 0,
            ..cfg.clone()
        };
        let res = fit_admm(train, None, &probe_cfg);
        if res.objective < best.0 {
            best = (res.objective, rho);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::glm::regularizer::ElasticNet;
    use crate::solver::compute::NativeCompute;
    use crate::solver::dglmnet::{self, DGlmnetConfig};

    #[test]
    fn z_update_solves_first_order_condition() {
        for kind in [LossKind::Logistic, LossKind::Squared] {
            for &(y, c) in &[(1.0, 0.3), (-1.0, -0.2), (1.0, -1.0)] {
                let (m, rho) = (4.0, 0.7);
                let z = z_update_1d(kind, y, m, rho, c, 50);
                let g = m * kind.d1(y, m * z) + m * rho * (z - c);
                assert!(g.abs() < 1e-9, "{kind:?} FOC residual {g}");
            }
        }
    }

    #[test]
    fn admm_reaches_dglmnet_objective() {
        let ds = synth::epsilon_like(&synth::SynthConfig {
            n: 150,
            p: 10,
            seed: 21,
        });
        let (l1, l2) = (0.5, 0.1);
        let admm_cfg = AdmmConfig {
            kind: LossKind::Logistic,
            l1,
            l2,
            rho: 1.0,
            nodes: 3,
            max_iters: 300,
            shooting_passes: 10,
            eval_every: 0,
            ..Default::default()
        };
        let admm = fit_admm(&ds, None, &admm_cfg);
        let compute = NativeCompute::new(LossKind::Logistic);
        let pen = ElasticNet::new(l1, l2);
        let dg = dglmnet::fit(
            &ds,
            &compute,
            &pen,
            &DGlmnetConfig {
                nodes: 3,
                max_iters: 300,
                tol: 1e-12,
                patience: 3,
                eval_every: 0,
                ..Default::default()
            },
            None,
        );
        let gap = (admm.objective - dg.objective).abs() / dg.objective;
        assert!(
            gap < 0.01,
            "admm {} vs dglmnet {} (gap {gap})",
            admm.objective,
            dg.objective
        );
    }

    #[test]
    fn admm_objective_trends_down() {
        let ds = synth::epsilon_like(&synth::SynthConfig {
            n: 100,
            p: 8,
            seed: 22,
        });
        let cfg = AdmmConfig {
            max_iters: 40,
            nodes: 2,
            l1: 0.3,
            eval_every: 0,
            ..Default::default()
        };
        let res = fit_admm(&ds, None, &cfg);
        let first = res.trace.points.first().unwrap().objective;
        let last = res.trace.points.last().unwrap().objective;
        assert!(last < first * 0.9, "no real progress: {first} -> {last}");
    }

    #[test]
    fn l1_yields_sparsity() {
        let ds = synth::epsilon_like(&synth::SynthConfig {
            n: 200,
            p: 30,
            seed: 23,
        });
        let cfg = AdmmConfig {
            l1: 4.0,
            l2: 0.0,
            max_iters: 80,
            nodes: 4,
            eval_every: 0,
            ..Default::default()
        };
        let res = fit_admm(&ds, None, &cfg);
        let nnz = metrics::nnz_weights(&res.beta);
        assert!(nnz < 30, "no sparsity: nnz = {nnz}");
    }

    #[test]
    fn select_rho_returns_candidate() {
        let ds = synth::epsilon_like(&synth::SynthConfig {
            n: 60,
            p: 6,
            seed: 24,
        });
        let cfg = AdmmConfig {
            nodes: 2,
            l1: 0.2,
            ..Default::default()
        };
        let rho = select_rho(&ds, &cfg, 5);
        assert!((0.015..=64.01).contains(&rho));
    }
}
