//! Distributed online learning — the paper's second baseline.
//!
//! "Online learning via truncated gradient" (Langford, Li & Zhang 2009) for
//! L1, plain online gradient descent for L2, with the distributed recipe of
//! Agarwal et al. 2014 / Zinkevich et al. 2010: the training set is split
//! *by examples* over M nodes, each node runs one sequential online epoch
//! over its shard, the M weight vectors are averaged, and the average
//! warm-starts the next epoch. Epochs run on real threads (one per shard).
//!
//! Truncated gradient (the sparsity-inducing part): every `trunc_period`
//! steps, weights are pulled toward zero by `period · η · λ₁` and clipped at
//! zero — the online analogue of the L1 prox.

use crate::data::Dataset;
use crate::glm::loss::LossKind;
use crate::metrics;
use crate::solver::trace::{Trace, TracePoint};
use crate::sparse::{Csr, ExamplePartition};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct OnlineConfig {
    pub kind: LossKind,
    pub l1: f64,
    pub l2: f64,
    pub nodes: usize,
    pub epochs: usize,
    /// Base learning rate η₀ (paper sweeps 0.1–0.5).
    pub rate: f64,
    /// Learning-rate decay power p: η_t = η₀ / t^p (paper sweeps 0.5–0.9).
    pub power: f64,
    /// Truncation period K of Langford et al. (gravity applied every K
    /// steps). 0 disables truncation (the L2 configuration).
    pub trunc_period: usize,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            kind: LossKind::Logistic,
            l1: 0.0,
            l2: 0.0,
            nodes: 8,
            epochs: 20,
            rate: 0.3,
            power: 0.6,
            trunc_period: 10,
            eval_every: 1,
            seed: 0x5EED,
        }
    }
}

#[derive(Clone, Debug)]
pub struct OnlineResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    pub trace: Trace,
}

/// One sequential online pass over a shard, starting from `beta` (owned).
/// `t0` is the global step count so the learning-rate schedule continues
/// across epochs; `n_total` is the full training-set size — the objective is
/// Σℓ + λ‖β‖, so the per-example stochastic regularizer weight is λ/n.
/// λ₁ via truncation (Langford et al.), λ₂ via weight decay on touched
/// coordinates (lazy, sparse-update-friendly).
fn online_epoch(
    x: &Csr,
    y: &[f64],
    mut beta: Vec<f64>,
    cfg: &OnlineConfig,
    t0: usize,
    n_total: usize,
) -> Vec<f64> {
    let n = x.nrows;
    let l1_per_example = cfg.l1 / n_total.max(1) as f64;
    let l2_per_example = cfg.l2 / n_total.max(1) as f64;
    let gravity = cfg.trunc_period.max(1) as f64 * l1_per_example;
    let mut steps_since_trunc = 0usize;
    for i in 0..n {
        let t = t0 + i + 1;
        let eta = cfg.rate / (t as f64).powf(cfg.power);
        let margin = x.dot_row(i, &beta);
        let g = cfg.kind.d1(y[i], margin);
        // Gradient step on the touched coordinates.
        let (cols, vals) = x.row_raw(i);
        for (c, v) in cols.iter().zip(vals.iter()) {
            let j = *c as usize;
            // L2 term: weight decay folded into the sparse step.
            let grad_j = g * v + l2_per_example * beta[j];
            beta[j] -= eta * grad_j;
        }
        steps_since_trunc += 1;
        if cfg.trunc_period > 0 && steps_since_trunc >= cfg.trunc_period && cfg.l1 > 0.0 {
            // Truncation: pull every weight toward 0 by η·gravity, clip at 0.
            let pull = eta * gravity;
            for b in beta.iter_mut() {
                if *b > 0.0 {
                    *b = (*b - pull).max(0.0);
                } else if *b < 0.0 {
                    *b = (*b + pull).min(0.0);
                }
            }
            steps_since_trunc = 0;
        }
    }
    // Final (possibly partial-period) truncation so the epoch ends on the
    // prox step — otherwise the trailing gradient updates leave every
    // touched coordinate infinitesimally non-zero and averaging destroys
    // sparsity entirely.
    if cfg.trunc_period > 0 && cfg.l1 > 0.0 && steps_since_trunc > 0 {
        let t = t0 + n;
        let eta = cfg.rate / (t.max(1) as f64).powf(cfg.power);
        let pull = eta * steps_since_trunc as f64 * l1_per_example;
        for b in beta.iter_mut() {
            if *b > 0.0 {
                *b = (*b - pull).max(0.0);
            } else if *b < 0.0 {
                *b = (*b + pull).min(0.0);
            }
        }
    }
    beta
}

/// Train with distributed online learning: per-epoch shard passes in
/// parallel, average, repeat.
pub fn fit_online(train: &Dataset, test: Option<&Dataset>, cfg: &OnlineConfig) -> OnlineResult {
    let p = train.p();
    let parts = ExamplePartition::hashed(train.n(), cfg.nodes, cfg.seed);
    let shards: Vec<Csr> = (0..cfg.nodes).map(|m| parts.shard(&train.x, m)).collect();
    let labels: Vec<Vec<f64>> = (0..cfg.nodes)
        .map(|m| parts.shard_labels(&train.y, m))
        .collect();

    let mut beta = vec![0.0; p];
    let mut trace = Trace::new("online-tg", &train.name);
    let started = Instant::now();

    let objective = |beta: &[f64]| -> f64 {
        let margins = train.x.mul_vec(beta);
        let mut loss = 0.0;
        for i in 0..train.n() {
            loss += cfg.kind.value(train.y[i], margins[i]);
        }
        let l1: f64 = beta.iter().map(|b| b.abs()).sum();
        let l2: f64 = beta.iter().map(|b| b * b).sum();
        loss + cfg.l1 * l1 + 0.5 * cfg.l2 * l2
    };

    let record = |trace: &mut Trace, started: &Instant, iter: usize, f: f64, beta: &[f64]| {
        let auprc = test.and_then(|t| {
            (cfg.eval_every > 0 && iter % cfg.eval_every == 0).then(|| {
                let scores = t.x.mul_vec(beta);
                metrics::auprc(&t.y, &scores)
            })
        });
        trace.push(TracePoint {
            t_sec: started.elapsed().as_secs_f64(),
            iter,
            objective: f,
            nnz: metrics::nnz_weights(beta),
            alpha: 1.0,
            mu: 1.0,
            auprc,
        });
    };

    record(&mut trace, &started, 0, objective(&beta), &beta);

    let mut t_global = 0usize;
    for epoch in 1..=cfg.epochs {
        let mut results: Vec<Option<Vec<f64>>> = vec![None; cfg.nodes];
        crossbeam_utils::thread::scope(|scope| {
            let mut handles = Vec::new();
            for m in 0..cfg.nodes {
                let beta0 = beta.clone();
                let (shard, ys) = (&shards[m], &labels[m]);
                let cfg_ref = &*cfg;
                let n_total = train.n();
                handles.push((
                    m,
                    scope.spawn(move |_| {
                        online_epoch(shard, ys, beta0, cfg_ref, t_global, n_total)
                    }),
                ));
            }
            for (m, h) in handles {
                results[m] = Some(h.join().expect("online worker panicked"));
            }
        })
        .expect("online scope");
        // Average the shard models (uniform — shards are balanced).
        let mut avg = vec![0.0; p];
        for r in results.iter().flatten() {
            for (a, b) in avg.iter_mut().zip(r.iter()) {
                *a += b;
            }
        }
        let inv = 1.0 / cfg.nodes as f64;
        for a in avg.iter_mut() {
            *a *= inv;
        }
        beta = avg;
        t_global += shards.iter().map(|s| s.nrows).max().unwrap_or(0);
        record(&mut trace, &started, epoch, objective(&beta), &beta);
    }

    OnlineResult {
        objective: objective(&beta),
        beta,
        trace,
    }
}

/// The paper's hyperparameter sweep: jointly tune rate ∈ {0.1..0.5} and
/// power ∈ {0.5..0.9}, pick the best objective after `probe_epochs`.
pub fn select_hyperparams(train: &Dataset, cfg: &OnlineConfig, probe_epochs: usize) -> (f64, f64) {
    let mut best = (f64::INFINITY, cfg.rate, cfg.power);
    for rate in [0.1, 0.2, 0.3, 0.4, 0.5] {
        for power in [0.5, 0.6, 0.7, 0.8, 0.9] {
            let probe = OnlineConfig {
                rate,
                power,
                epochs: probe_epochs,
                eval_every: 0,
                ..cfg.clone()
            };
            let res = fit_online(train, None, &probe);
            if res.objective < best.0 {
                best = (res.objective, rate, power);
            }
        }
    }
    (best.1, best.2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn ds(n: usize, p: usize, seed: u64) -> Dataset {
        synth::epsilon_like(&synth::SynthConfig { n, p, seed })
    }

    #[test]
    fn online_learns_signal() {
        let train = ds(2000, 10, 31);
        let cfg = OnlineConfig {
            nodes: 4,
            epochs: 10,
            l1: 0.0,
            l2: 0.01,
            trunc_period: 0,
            eval_every: 0,
            ..Default::default()
        };
        let res = fit_online(&train, None, &cfg);
        let scores = train.x.mul_vec(&res.beta);
        let auc = metrics::roc_auc(&train.y, &scores);
        assert!(auc > 0.65, "train AUC {auc}");
    }

    #[test]
    fn objective_improves_over_epochs() {
        let train = ds(1500, 8, 32);
        let cfg = OnlineConfig {
            nodes: 4,
            epochs: 8,
            l2: 0.01,
            trunc_period: 0,
            eval_every: 0,
            ..Default::default()
        };
        let res = fit_online(&train, None, &cfg);
        let first = res.trace.points.first().unwrap().objective;
        let last = res.trace.points.last().unwrap().objective;
        assert!(last < first, "no progress {first} -> {last}");
    }

    #[test]
    fn truncation_produces_sparsity() {
        // Sparse text-like data: truncation zeroes the rarely-touched tail.
        let train = synth::webspam_like(
            &synth::SynthConfig {
                n: 1200,
                p: 400,
                seed: 33,
            },
            20,
        );
        let dense_cfg = OnlineConfig {
            nodes: 2,
            epochs: 6,
            l1: 0.0,
            trunc_period: 0,
            eval_every: 0,
            ..Default::default()
        };
        let sparse_cfg = OnlineConfig {
            l1: 150.0, // total-objective λ1; per-example gravity is λ1/n
            trunc_period: 5,
            ..dense_cfg.clone()
        };
        let dense = fit_online(&train, None, &dense_cfg);
        let sparse = fit_online(&train, None, &sparse_cfg);
        let nnz_d = metrics::nnz_weights(&dense.beta);
        let nnz_s = metrics::nnz_weights(&sparse.beta);
        assert!(
            nnz_s < nnz_d,
            "truncated nnz {nnz_s} should be < plain {nnz_d}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let train = ds(300, 6, 34);
        let cfg = OnlineConfig {
            nodes: 3,
            epochs: 3,
            eval_every: 0,
            ..Default::default()
        };
        let a = fit_online(&train, None, &cfg);
        let b = fit_online(&train, None, &cfg);
        assert_eq!(a.beta, b.beta);
    }

    #[test]
    fn hyperparam_sweep_returns_grid_point() {
        let train = ds(200, 5, 35);
        let cfg = OnlineConfig {
            nodes: 2,
            eval_every: 0,
            ..Default::default()
        };
        let (rate, power) = select_hyperparams(&train, &cfg, 2);
        assert!([0.1, 0.2, 0.3, 0.4, 0.5].contains(&rate));
        assert!([0.5, 0.6, 0.7, 0.8, 0.9].contains(&power));
    }
}
