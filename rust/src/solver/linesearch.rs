//! Algorithm 3 — the global line search.
//!
//! Works entirely in margin space: all it needs are the shared n-vectors
//! `Xβ`, `XΔβ` and a way to evaluate the (separable) regularizer along the
//! ray — exactly the O(n) "sufficient data" claim of the paper.
//!
//! The search is *batched*: each phase evaluates the loss at a whole vector
//! of candidate α in one `GlmCompute::loss_at_alphas` call, so when the
//! compute is backed by the XLA runtime a full line search costs at most two
//! PJRT executions (grid + Armijo sequence) instead of one per probe.

use crate::solver::compute::GlmCompute;

/// Parameters of Algorithm 3. Paper's experiments: b = 0.5, σ = 0.01, γ = 0.
#[derive(Clone, Copy, Debug)]
pub struct LineSearchConfig {
    /// Lower bound δ of the α_init search interval (0, 1].
    pub delta: f64,
    /// Backtracking factor b ∈ (0,1).
    pub b: f64,
    /// Armijo sufficient-decrease constant σ ∈ (0,1).
    pub sigma: f64,
    /// Size of the α_init candidate grid.
    pub grid: usize,
    /// Max backtracking steps.
    pub max_backtracks: usize,
}

impl Default for LineSearchConfig {
    fn default() -> Self {
        LineSearchConfig {
            delta: 1e-3,
            b: 0.5,
            sigma: 0.01,
            grid: 16,
            max_backtracks: 40,
        }
    }
}

/// Outcome of one line search.
#[derive(Clone, Copy, Debug)]
pub struct LineSearchResult {
    pub alpha: f64,
    /// f(β + αΔβ) at the accepted α.
    pub f_new: f64,
    /// Whether α = 1 satisfied the Armijo condition directly (drives the
    /// adaptive-μ update: μ shrinks on success, grows on failure).
    pub full_step: bool,
    /// Number of loss evaluations (for the comm/compute accounting).
    pub evals: usize,
}

/// Regularizer values along the ray: returns R(β + α·Δβ) for each α.
/// In the distributed setting each node computes its block's contribution
/// and the fabric sums them; single-process callers close over (β, Δβ).
pub type RegAlongRay<'a> = dyn Fn(&[f64]) -> Vec<f64> + 'a;

/// Run Algorithm 3.
///
/// * `f_cur`   — current objective f(β) = L + R.
/// * `reg_cur` — current R(β).
/// * `grad_dot` — ∇L(β)ᵀΔβ (from `GlmCompute::grad_dot`).
/// * `reg_ray` — R(β + αΔβ) for batches of α.
///
/// Returns the accepted α (0 if even the smallest step fails Armijo — the
/// caller treats that as "grow μ and retry next iteration").
#[allow(clippy::too_many_arguments)]
pub fn line_search(
    compute: &dyn GlmCompute,
    cfg: &LineSearchConfig,
    y: &[f64],
    margins: &[f64],
    dmargins: &[f64],
    f_cur: f64,
    reg_cur: f64,
    grad_dot: f64,
    reg_ray: &RegAlongRay<'_>,
) -> LineSearchResult {
    // D from (12) with γ = 0: ∇LᵀΔβ + R(β+Δβ) − R(β).
    let reg_at_1 = reg_ray(&[1.0])[0];
    let d_armijo = grad_dot + reg_at_1 - reg_cur;

    // Phase 1 (fast path): Algorithm 3 step 1 — test α = 1 alone. After μ
    // has adapted, the full step passes most iterations, so this keeps the
    // common case at ONE loss evaluation instead of a whole grid.
    let f1 = compute.loss_at_alphas(y, margins, dmargins, &[1.0])[0] + reg_at_1;
    let mut evals = 1usize;
    if f1 <= f_cur + cfg.sigma * d_armijo {
        return LineSearchResult {
            alpha: 1.0,
            f_new: f1,
            full_step: true,
            evals,
        };
    }

    // Phase 2: α_init = argmin over a log-spaced grid in (δ, 1) — one
    // batched call (the paper's step 4).
    let mut alphas = Vec::with_capacity(cfg.grid);
    let log_lo = cfg.delta.ln();
    for k in 0..cfg.grid {
        let frac = (k as f64 + 0.5) / cfg.grid as f64;
        alphas.push((log_lo * (1.0 - frac)).exp()); // δ^(1-frac) spans (δ,1)
    }
    let losses = compute.loss_at_alphas(y, margins, dmargins, &alphas);
    let regs = reg_ray(&alphas);
    evals += alphas.len();

    let f_at = |k: usize| losses[k] + regs[k];
    let mut best_k = 0;
    for k in 1..alphas.len() {
        if f_at(k) < f_at(best_k) {
            best_k = k;
        }
    }
    let alpha_init = alphas[best_k];

    // Phase 3: Armijo backtracking from α_init — batch the geometric
    // sequence {α_init·bʲ} in ONE call and accept the largest passing step.
    let mut seq = Vec::with_capacity(cfg.max_backtracks);
    let mut a = alpha_init;
    for _ in 0..cfg.max_backtracks {
        seq.push(a);
        a *= cfg.b;
    }
    let seq_losses = compute.loss_at_alphas(y, margins, dmargins, &seq);
    let seq_regs = reg_ray(&seq);
    evals += seq.len();
    for (k, &alpha) in seq.iter().enumerate() {
        let f = seq_losses[k] + seq_regs[k];
        if f <= f_cur + alpha * cfg.sigma * d_armijo {
            return LineSearchResult {
                alpha,
                f_new: f,
                full_step: false,
                evals,
            };
        }
    }
    // No step passed: signal failure with α = 0 (caller grows μ).
    LineSearchResult {
        alpha: 0.0,
        f_new: f_cur,
        full_step: false,
        evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::loss::LossKind;
    use crate::glm::regularizer::ElasticNet;
    use crate::solver::compute::NativeCompute;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Assemble a line search over an explicit (β, Δβ, X) problem.
    struct Harness {
        compute: NativeCompute,
        cfg: LineSearchConfig,
        y: Vec<f64>,
        margins: Vec<f64>,
        dmargins: Vec<f64>,
        beta: Vec<f64>,
        delta: Vec<f64>,
        pen: ElasticNet,
    }

    impl Harness {
        fn run(&self) -> LineSearchResult {
            let f_cur =
                self.compute.total_loss(&self.y, &self.margins) + self.pen.value(&self.beta);
            let reg_cur = self.pen.value(&self.beta);
            let gd = self
                .compute
                .grad_dot(&self.y, &self.margins, &self.dmargins);
            let reg_ray = |alphas: &[f64]| -> Vec<f64> {
                alphas
                    .iter()
                    .map(|&a| self.pen.value_shifted(&self.beta, &self.delta, a))
                    .collect()
            };
            line_search(
                &self.compute,
                &self.cfg,
                &self.y,
                &self.margins,
                &self.dmargins,
                f_cur,
                reg_cur,
                gd,
                &reg_ray,
            )
        }

        fn objective_at(&self, alpha: f64) -> f64 {
            let l = self
                .compute
                .loss_at_alphas(&self.y, &self.margins, &self.dmargins, &[alpha])[0];
            l + self.pen.value_shifted(&self.beta, &self.delta, alpha)
        }
    }

    fn random_harness(rng: &mut Rng, descent: bool) -> Harness {
        let n = 5 + rng.below(30);
        let p = 3 + rng.below(8);
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        let beta = prop::dense_vec(rng, p, 0.5);
        // margins arbitrary; dmargins from a descent-ish direction if asked.
        let margins = prop::dense_vec(rng, n, 1.0);
        let compute = NativeCompute::new(LossKind::Logistic);
        let dmargins: Vec<f64> = if descent {
            // steepest-descent in margin space: d_i = -g_i
            margins
                .iter()
                .zip(&y)
                .map(|(&m, &yi)| -LossKind::Logistic.d1(yi, m))
                .collect()
        } else {
            prop::dense_vec(rng, n, 1.0)
        };
        let delta = prop::dense_vec(rng, p, 0.2);
        Harness {
            compute,
            cfg: LineSearchConfig::default(),
            y,
            margins,
            dmargins,
            beta,
            delta,
            pen: ElasticNet::new(rng.range_f64(0.0, 0.2), rng.range_f64(0.0, 0.2)),
        }
    }

    #[test]
    fn prop_accepted_alpha_satisfies_armijo() {
        prop::check("line search result satisfies (12)", 60, |rng| {
            let h = random_harness(rng, true);
            let res = h.run();
            if res.alpha == 0.0 {
                return Ok(()); // declared failure is allowed
            }
            let f_cur = h.objective_at(0.0);
            let reg_cur = h.pen.value(&h.beta);
            let gd = h.compute.grad_dot(&h.y, &h.margins, &h.dmargins);
            let reg1 = h.pen.value_shifted(&h.beta, &h.delta, 1.0);
            let d = gd + reg1 - reg_cur;
            let bound = f_cur + res.alpha * h.cfg.sigma * d;
            // α=1 uses the un-scaled bound per Algorithm 3 step 1.
            let bound = if res.alpha == 1.0 {
                f_cur + h.cfg.sigma * d
            } else {
                bound
            };
            if res.f_new <= bound + 1e-9 {
                Ok(())
            } else {
                Err(format!("f_new {} > bound {bound}", res.f_new))
            }
        });
    }

    #[test]
    fn prop_objective_never_increases_on_success() {
        prop::check("line search decreases f", 60, |rng| {
            let h = random_harness(rng, true);
            let res = h.run();
            let f_cur = h.objective_at(0.0);
            if res.alpha == 0.0 || res.f_new <= f_cur + 1e-9 {
                Ok(())
            } else {
                Err(format!("f increased {f_cur} -> {}", res.f_new))
            }
        });
    }

    #[test]
    fn full_step_taken_when_direction_is_good() {
        // Tiny step in a pure descent direction with no regularizer: α=1
        // must pass.
        let compute = NativeCompute::new(LossKind::Squared);
        let y = vec![1.0, -1.0, 0.5];
        let margins = vec![0.0, 0.0, 0.0];
        // Newton direction for squared loss from 0 margins: d = y (full
        // correction); Armijo at α=1 holds exactly for quadratics with σ<0.5.
        let dmargins = y.clone();
        let pen = ElasticNet::new(0.0, 0.0);
        let beta = vec![0.0];
        let delta = vec![0.0];
        let f_cur = compute.total_loss(&y, &margins);
        let gd = compute.grad_dot(&y, &margins, &dmargins);
        let reg_ray = |alphas: &[f64]| -> Vec<f64> {
            alphas
                .iter()
                .map(|&a| pen.value_shifted(&beta, &delta, a))
                .collect()
        };
        let res = line_search(
            &compute,
            &LineSearchConfig::default(),
            &y,
            &margins,
            &dmargins,
            f_cur,
            0.0,
            gd,
            &reg_ray,
        );
        assert!(res.full_step);
        assert_eq!(res.alpha, 1.0);
        assert!(res.f_new < 1e-12); // exact fit
    }

    #[test]
    fn overshooting_direction_backtracks() {
        // Direction 100× the Newton step: α=1 must fail, search must settle
        // on a small step that still decreases the quadratic.
        let compute = NativeCompute::new(LossKind::Squared);
        let y = vec![1.0, -2.0];
        let margins = vec![0.0, 0.0];
        let dmargins = vec![100.0, -200.0];
        let pen = ElasticNet::new(0.0, 0.0);
        let (beta, delta) = (vec![0.0], vec![0.0]);
        let f_cur = compute.total_loss(&y, &margins);
        let gd = compute.grad_dot(&y, &margins, &dmargins);
        let reg_ray = |alphas: &[f64]| -> Vec<f64> {
            alphas
                .iter()
                .map(|&a| pen.value_shifted(&beta, &delta, a))
                .collect()
        };
        let res = line_search(
            &compute,
            &LineSearchConfig::default(),
            &y,
            &margins,
            &dmargins,
            f_cur,
            0.0,
            gd,
            &reg_ray,
        );
        assert!(!res.full_step);
        assert!(res.alpha > 0.0 && res.alpha < 0.05, "alpha = {}", res.alpha);
        assert!(res.f_new < f_cur);
    }

    #[test]
    fn evals_stay_batched() {
        // Exactly 2 batched calls worth of evals: grid+1 and the Armijo seq.
        let mut rng = Rng::new(3);
        let h = random_harness(&mut rng, false);
        let res = h.run();
        assert!(res.evals <= h.cfg.grid + 1 + h.cfg.max_backtracks);
    }
}
