//! Shooting (Fu 1998) — coordinate-descent LASSO / elastic-net solver for
//! quadratic objectives. Used as the x-update inside the ADMM-sharing
//! baseline (the paper: "We used a Shooting [8] to do it since it is well
//! suited for large and sparse datasets").
//!
//! Solves   argmin_β  (ρ/2)‖Xβ − v‖² + λ₁‖β‖₁ + (λ₂/2)‖β‖²
//! by cyclic coordinate descent with an incrementally maintained residual
//! r = v − Xβ (O(nnz(col)) per update).

use crate::glm::regularizer::soft_threshold;
use crate::sparse::Csc;

#[derive(Clone, Copy, Debug)]
pub struct ShootingConfig {
    pub rho: f64,
    pub l1: f64,
    pub l2: f64,
    /// Maximum CD passes over all coordinates.
    pub max_passes: usize,
    /// Stop when the largest coordinate change in a pass is below this.
    pub tol: f64,
}

impl Default for ShootingConfig {
    fn default() -> Self {
        ShootingConfig {
            rho: 1.0,
            l1: 0.0,
            l2: 0.0,
            max_passes: 10,
            tol: 1e-8,
        }
    }
}

/// Minimize (ρ/2)‖Xβ − v‖² + λ₁‖β‖₁ + (λ₂/2)‖β‖², warm-starting from and
/// overwriting `beta`. Returns the number of passes used.
pub fn shooting(x: &Csc, v: &[f64], beta: &mut [f64], cfg: &ShootingConfig) -> usize {
    assert_eq!(x.nrows, v.len());
    assert_eq!(x.ncols, beta.len());
    // Residual r = v − Xβ for the warm start.
    let mut r = v.to_vec();
    for j in 0..x.ncols {
        if beta[j] != 0.0 {
            x.axpy_col(j, -beta[j], &mut r);
        }
    }
    // Cache column squared norms (constant across passes).
    let sq: Vec<f64> = (0..x.ncols).map(|j| x.col_sq_norm(j)).collect();

    let mut passes = 0;
    for _ in 0..cfg.max_passes {
        passes += 1;
        let mut max_change = 0.0f64;
        for j in 0..x.ncols {
            if sq[j] == 0.0 {
                continue;
            }
            let (rows, vals) = x.col_raw(j);
            let mut dot = 0.0;
            for (ri, vi) in rows.iter().zip(vals.iter()) {
                dot += r[*ri as usize] * vi;
            }
            // Partial residual: v − Xβ + β_j x_j projected on x_j.
            let num = cfg.rho * (dot + beta[j] * sq[j]);
            let den = cfg.rho * sq[j] + cfg.l2;
            let new = soft_threshold(num, cfg.l1) / den;
            let change = new - beta[j];
            if change != 0.0 {
                beta[j] = new;
                for (ri, vi) in rows.iter().zip(vals.iter()) {
                    r[*ri as usize] -= change * vi;
                }
                max_change = max_change.max(change.abs());
            }
        }
        if max_change < cfg.tol {
            break;
        }
    }
    passes
}

/// Objective value (for tests).
pub fn shooting_objective(x: &Csc, v: &[f64], beta: &[f64], cfg: &ShootingConfig) -> f64 {
    let pred = x.mul_vec(beta);
    let mut q = 0.0;
    for i in 0..v.len() {
        let d = pred[i] - v[i];
        q += d * d;
    }
    let l1: f64 = beta.iter().map(|b| b.abs()).sum();
    let l2: f64 = beta.iter().map(|b| b * b).sum();
    0.5 * cfg.rho * q + cfg.l1 * l1 + 0.5 * cfg.l2 * l2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_xv(rng: &mut Rng, n: usize, p: usize) -> (Csc, Vec<f64>) {
        let mut trips = Vec::new();
        for j in 0..p {
            for i in 0..n {
                if rng.bernoulli(0.5) {
                    trips.push((i, j, rng.range_f64(-2.0, 2.0)));
                }
            }
        }
        (
            Csc::from_triplets(n, p, trips),
            prop::dense_vec(rng, n, 2.0),
        )
    }

    #[test]
    fn univariate_closed_form() {
        let x = Csc::from_triplets(3, 1, vec![(0, 0, 1.0), (1, 0, 2.0), (2, 0, -1.0)]);
        let v = vec![2.0, 3.9, -2.1];
        let cfg = ShootingConfig {
            rho: 1.0,
            l1: 1.5,
            l2: 0.0,
            max_passes: 50,
            tol: 1e-14,
        };
        let mut beta = vec![0.0];
        shooting(&x, &v, &mut beta, &cfg);
        let sxy: f64 = 2.0 + 7.8 + 2.1;
        let sxx: f64 = 6.0;
        let want = soft_threshold(sxy, 1.5) / sxx;
        assert!((beta[0] - want).abs() < 1e-12);
    }

    #[test]
    fn prop_objective_decreases_each_call() {
        prop::check("shooting decreases objective", 40, |rng| {
            let (n, p) = (3 + rng.below(12), 1 + rng.below(8));
            let (x, v) = random_xv(rng, n, p);
            let cfg = ShootingConfig {
                rho: rng.range_f64(0.2, 3.0),
                l1: rng.range_f64(0.0, 1.0),
                l2: rng.range_f64(0.0, 1.0),
                max_passes: 3,
                tol: 0.0,
            };
            let mut beta = prop::dense_vec(rng, p, 1.0);
            let before = shooting_objective(&x, &v, &beta, &cfg);
            shooting(&x, &v, &mut beta, &cfg);
            let after = shooting_objective(&x, &v, &beta, &cfg);
            if after <= before + 1e-9 {
                Ok(())
            } else {
                Err(format!("objective rose {before} -> {after}"))
            }
        });
    }

    #[test]
    fn prop_kkt_at_convergence() {
        // After convergence: |ρ·xⱼᵀ(v − Xβ) − λ₂βⱼ| ≤ λ₁ for βⱼ = 0 and
        // stationarity for βⱼ ≠ 0.
        prop::check("shooting satisfies KKT", 30, |rng| {
            let (n, p) = (5 + rng.below(10), 1 + rng.below(6));
            let (x, v) = random_xv(rng, n, p);
            let cfg = ShootingConfig {
                rho: 1.0,
                l1: rng.range_f64(0.1, 1.0),
                l2: rng.range_f64(0.0, 0.5),
                max_passes: 500,
                tol: 1e-13,
            };
            let mut beta = vec![0.0; p];
            shooting(&x, &v, &mut beta, &cfg);
            let pred = x.mul_vec(&beta);
            for j in 0..p {
                let (rows, vals) = x.col_raw(j);
                let mut grad = 0.0; // ρ xⱼᵀ(Xβ − v) + λ₂βⱼ
                for (ri, vi) in rows.iter().zip(vals.iter()) {
                    grad += (pred[*ri as usize] - v[*ri as usize]) * vi;
                }
                grad = cfg.rho * grad + cfg.l2 * beta[j];
                if beta[j] == 0.0 {
                    if grad.abs() > cfg.l1 + 1e-6 {
                        return Err(format!("KKT violated at zero coord {j}: |{grad}| > λ1"));
                    }
                } else {
                    let want = -cfg.l1 * beta[j].signum();
                    if (grad - want).abs() > 1e-6 {
                        return Err(format!(
                            "stationarity violated at {j}: grad {grad} want {want}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn warm_start_converges_faster() {
        let mut rng = Rng::new(42);
        let (x, v) = random_xv(&mut rng, 30, 10);
        let cfg = ShootingConfig {
            rho: 1.0,
            l1: 0.3,
            l2: 0.1,
            max_passes: 200,
            tol: 1e-12,
        };
        let mut cold = vec![0.0; 10];
        let cold_passes = shooting(&x, &v, &mut cold, &cfg);
        // Warm start from the solution: must converge in one pass.
        let mut warm = cold.clone();
        let warm_passes = shooting(&x, &v, &mut warm, &cfg);
        assert!(warm_passes <= 2, "warm {warm_passes} vs cold {cold_passes}");
    }
}
