//! Optimization algorithms: the paper's d-GLMNET (Algorithms 1–3) plus the
//! three baselines it is evaluated against (ADMM with sharing, online
//! learning via truncated gradient, L-BFGS with online warmstart).

pub mod admm;
pub mod compute;
pub mod dglmnet;
pub mod lbfgs;
pub mod linesearch;
pub mod online;
pub mod path;
pub mod shooting;
pub mod subproblem;
pub mod trace;

pub use admm::{fit_admm, select_rho, AdmmConfig, AdmmResult};
pub use compute::{GlmCompute, NativeCompute};
pub use lbfgs::{fit_lbfgs, LbfgsConfig, LbfgsResult};
pub use online::{fit_online, OnlineConfig, OnlineResult};
pub use dglmnet::{fit, DGlmnetConfig, FitResult, TestEval};
pub use linesearch::{line_search, LineSearchConfig, LineSearchResult};
pub use trace::{Trace, TracePoint};
