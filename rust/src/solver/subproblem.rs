//! Algorithm 2 — the per-node quadratic subproblem.
//!
//! Node m minimizes  L_q^gen(β, Δβ^m) + Σ_{j∈S^m} R(β_j + Δβ_j^m)  with one
//! cycle of coordinate descent using update rule (11). We re-derived (11)
//! (see DESIGN.md §Key derivations): with t = X^m Δβ^m maintained
//! incrementally, the coordinate update for local column j is
//!
//!   s1    = Σ_i w_i x_ij (z_i − μ t_i)
//!   s2    = Σ_i w_i x_ij²
//!   lin   = s1 + μ (β_j + Δβ_j) s2 + ν β_j
//!   quad  = μ s2 + ν
//!   u*    = argmin_u (quad/2)u² − lin·u + r(u)      (soft threshold for
//!                                                    elastic net)
//!   Δβ_j ← u* − β_j ;  t_i += (Δβ_j_new − Δβ_j_old) x_ij
//!
//! The cycle supports cyclic resume and an external stop signal — the hooks
//! ALB (Section 7) needs: fast nodes keep cycling past one full pass, and
//! everyone stops where they are when the κ-fraction signal fires.

use crate::glm::regularizer::Penalty1D;
use crate::sparse::Csc;
use crate::util::pool::ScopedPool;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};

/// Mutable per-node state for one outer iteration's subproblem.
#[derive(Clone, Debug)]
pub struct SubproblemState {
    /// Δβ^m over the node's local columns.
    pub delta_beta: Vec<f64>,
    /// t = X^m Δβ^m over all n examples.
    pub t: Vec<f64>,
    /// Cyclic cursor: next local column to update (persists across outer
    /// iterations under ALB).
    pub cursor: usize,
}

impl SubproblemState {
    pub fn new(ncols: usize, nrows: usize) -> Self {
        SubproblemState {
            delta_beta: vec![0.0; ncols],
            t: vec![0.0; nrows],
            cursor: 0,
        }
    }

    /// Reset Δβ and t for a new outer iteration (cursor is preserved — the
    /// ALB schedule resumes from the next weight, paper §7).
    pub fn reset(&mut self) {
        self.delta_beta.iter_mut().for_each(|d| *d = 0.0);
        self.t.iter_mut().for_each(|t| *t = 0.0);
    }
}

/// How much of the block one call may update.
pub struct CycleBudget<'a> {
    /// Maximum coordinate updates (usually = block size for one full cycle;
    /// ALB fast nodes pass a multiple).
    pub max_updates: usize,
    /// Optional cooperative stop flag, checked between coordinates.
    pub stop: Option<&'a AtomicBool>,
    /// Restrict the cycle to these local column indices — the KKT
    /// strong-rule screening hook (`solver::path`): a warm path fit touches
    /// only the coordinates that survive the λ_k/λ_{k−1} gradient bound.
    /// `None` cycles the whole block. Indices must be < the block width;
    /// the cursor then counts positions *within this list*.
    pub active: Option<&'a [usize]>,
}

impl<'a> CycleBudget<'a> {
    pub fn full_cycle(ncols: usize) -> Self {
        CycleBudget {
            max_updates: ncols,
            stop: None,
            active: None,
        }
    }

    /// One full pass over a screened subset of the block.
    pub fn screened(active: &'a [usize]) -> Self {
        CycleBudget {
            max_updates: active.len(),
            stop: None,
            active: Some(active),
        }
    }
}

/// Outcome of one subproblem call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CycleOutcome {
    /// Coordinate updates performed.
    pub updates: usize,
    /// Whether at least one full pass over the block completed.
    pub full_pass: bool,
    /// Max |Δ change| over updated coordinates (inner convergence signal).
    pub max_delta: f64,
}

/// Run coordinate descent on the node's block.
///
/// * `x`     — the node's column block X^m (n × |S^m|).
/// * `beta`  — current local weights β^m (indexed like x's columns).
/// * `w, z`  — working weights/responses at the current β (length n).
/// * `mu`    — trust-region multiplier (Section 4).
/// * `nu`    — positive-definiteness shift (Section 5).
#[allow(clippy::too_many_arguments)]
pub fn cd_cycle(
    x: &Csc,
    beta: &[f64],
    w: &[f64],
    z: &[f64],
    mu: f64,
    nu: f64,
    penalty: &dyn Penalty1D,
    state: &mut SubproblemState,
    budget: CycleBudget<'_>,
) -> CycleOutcome {
    let p_local = x.ncols;
    debug_assert_eq!(beta.len(), p_local);
    debug_assert_eq!(state.delta_beta.len(), p_local);
    // Hard checks (not debug_assert): the unsafe hot loops below rely on
    // these lengths.
    assert_eq!(w.len(), x.nrows);
    assert_eq!(z.len(), x.nrows);
    assert_eq!(state.t.len(), x.nrows);
    debug_assert!(mu >= 1.0 && nu > 0.0);
    if let Some(a) = budget.active {
        debug_assert!(a.iter().all(|&j| j < p_local), "active index out of block");
    }

    let mut updates = 0usize;
    let mut max_delta = 0.0f64;
    // Cycle length: the screened subset when one is given, else the block.
    let cycle_len = budget.active.map_or(p_local, |a| a.len());
    if cycle_len == 0 {
        return CycleOutcome {
            updates: 0,
            full_pass: true,
            max_delta: 0.0,
        };
    }
    // A stale cursor (the active set shrank since the last call) restarts
    // the cycle rather than indexing out of the list.
    if state.cursor >= cycle_len {
        state.cursor = 0;
    }
    let t = &mut state.t;
    // One mode lookup per cycle, not per column: the kernel seam is a
    // vtable behind a relaxed atomic (kernels::active()).
    let ker = crate::kernels::active();
    while updates < budget.max_updates {
        if let Some(stop) = budget.stop {
            if stop.load(Ordering::Relaxed) && updates >= 1 {
                break;
            }
        }
        let slot = state.cursor;
        state.cursor = (state.cursor + 1) % cycle_len;
        let j = budget.active.map_or(slot, |a| a[slot]);

        let (rows, vals) = x.col_raw(j);
        // One fused pass over the column: s1 = Σ w x (z − μ t), s2 = Σ w x².
        // SAFETY: row indices are < nrows by Csc construction; w/z/t have
        // length nrows (checked at entry) — the kernel elides the per-entry
        // bounds checks in the hottest loop of the solver (§Perf).
        let (s1, s2) = unsafe { ker.col_weighted_quad(rows, vals, w, z, t, mu) };
        let old_d = state.delta_beta[j];
        let lin = s1 + mu * (beta[j] + old_d) * s2 + nu * beta[j];
        let quad = mu * s2 + nu;
        let u = penalty.solve_penalized_quad(quad, lin);
        let new_d = u - beta[j];
        let change = new_d - old_d;
        if change != 0.0 {
            state.delta_beta[j] = new_d;
            // SAFETY: same bound argument as the gather above.
            unsafe { ker.axpy_col(rows, vals, change, t) };
            max_delta = max_delta.max(change.abs());
        }
        updates += 1;
    }
    CycleOutcome {
        updates,
        full_pass: updates >= cycle_len,
        max_delta,
    }
}

/// Split `0..ncols` into at most `t` contiguous ranges whose lengths differ
/// by at most one (the first `ncols % s` ranges take the extra column).
/// Returns fewer than `t` ranges when the block is narrower than `t`, and a
/// single empty range for an empty block — so the result always has at
/// least one entry and the ranges always cover `0..ncols` exactly.
pub fn split_even(ncols: usize, t: usize) -> Vec<Range<usize>> {
    let s = t.max(1).min(ncols.max(1));
    let base = ncols / s;
    let extra = ncols % s;
    let mut out = Vec::with_capacity(s);
    let mut start = 0;
    for k in 0..s {
        let len = base + usize::from(k < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, ncols);
    out
}

/// The hybrid (intra-rank multi-threaded) decomposition of one rank's
/// feature block: up to T contiguous sub-blocks, each with its own column
/// shard and [`SubproblemState`], run as one pool wave per CD pass against
/// a frozen (β, w, z) snapshot. The sub-blocks partition the rank's
/// columns, so the global block structure becomes M·T blocks and the
/// paper's Theorem 1 line-search merge applies unchanged (DESIGN.md §Hybrid
/// parallelism). Per-sub-block (Δβ, t = X_k Δβ_k) partials are combined by
/// [`HybridCd::reduce_into`] in sub-block index order — a deterministic
/// ordered reduction, so a fit's iterates never depend on pool scheduling.
///
/// Memory: each sub-block holds its own t over all n examples, so the
/// rank's O(n) state grows to O(T·n); the sub-block shards together hold
/// one extra copy of the rank's column data (built once per fit).
pub struct HybridCd {
    /// Contiguous local-column ranges, one per sub-block.
    pub ranges: Vec<Range<usize>>,
    /// Materialized column shards, indexed like `ranges`.
    shards: Vec<Csc>,
    /// Per-sub-block Δβ/t/cursor state (cursors persist across outer
    /// iterations exactly like the rank-level cursor does under ALB).
    pub states: Vec<SubproblemState>,
    pool: ScopedPool,
    /// Coordinate updates each sub-block's thread performed across the run
    /// — the per-thread load accounting the harness table reports.
    pub updates_per_thread: Vec<u64>,
}

impl HybridCd {
    /// Decompose `x` (one rank's column block) into at most `threads`
    /// sub-blocks; the pool gets one worker per sub-block.
    pub fn new(x: &Csc, threads: usize) -> HybridCd {
        let ranges = split_even(x.ncols, threads);
        let shards: Vec<Csc> = ranges.iter().map(|r| x.slice_cols(r.clone())).collect();
        let states: Vec<SubproblemState> = ranges
            .iter()
            .map(|r| SubproblemState::new(r.len(), x.nrows))
            .collect();
        let pool = ScopedPool::new(ranges.len());
        let updates_per_thread = vec![0u64; ranges.len()];
        HybridCd {
            ranges,
            shards,
            states,
            pool,
            updates_per_thread,
        }
    }

    /// Effective sub-block (= pool worker) count.
    pub fn threads(&self) -> usize {
        self.ranges.len()
    }

    /// Reset every sub-block's Δβ/t for a new outer iteration (cursors are
    /// preserved, mirroring [`SubproblemState::reset`]).
    pub fn reset(&mut self) {
        for st in &mut self.states {
            st.reset();
        }
    }

    /// Restart every sub-block's cyclic cursor (the path sweep does this
    /// whenever the screened active set changes shape).
    pub fn reset_cursors(&mut self) {
        for st in &mut self.states {
            st.cursor = 0;
        }
    }

    /// One pool wave: sub-block k runs `cd_cycle` with `budgets[k]` updates
    /// (0 = skip) against the frozen (β, w, z) snapshot, optionally
    /// restricted to `active[k]` (sub-shard-local indices) and watching the
    /// shared `stop` flag. Returns per-sub-block outcomes in index order.
    #[allow(clippy::too_many_arguments)]
    pub fn wave(
        &mut self,
        beta: &[f64],
        w: &[f64],
        z: &[f64],
        mu: f64,
        nu: f64,
        penalty: &dyn Penalty1D,
        budgets: &[usize],
        active: Option<&[Vec<usize>]>,
        stop: Option<&AtomicBool>,
    ) -> Vec<CycleOutcome> {
        let s = self.ranges.len();
        debug_assert_eq!(budgets.len(), s);
        if let Some(a) = active {
            debug_assert_eq!(a.len(), s);
        }
        let mut outcomes = vec![
            CycleOutcome {
                updates: 0,
                full_pass: true,
                max_delta: 0.0,
            };
            s
        ];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(s);
            let iter = self
                .states
                .iter_mut()
                .zip(outcomes.iter_mut())
                .zip(self.shards.iter().zip(self.ranges.iter()))
                .enumerate();
            for (k, ((st, out), (shard, range))) in iter {
                if budgets[k] == 0 {
                    continue;
                }
                let beta_k = &beta[range.clone()];
                let act = active.map(|a| a[k].as_slice());
                let max_updates = budgets[k];
                jobs.push(Box::new(move || {
                    *out = cd_cycle(
                        shard,
                        beta_k,
                        w,
                        z,
                        mu,
                        nu,
                        penalty,
                        st,
                        CycleBudget {
                            max_updates,
                            stop,
                            active: act,
                        },
                    );
                }));
            }
            self.pool.run(jobs);
        }
        for (acc, o) in self.updates_per_thread.iter_mut().zip(outcomes.iter()) {
            *acc += o.updates as u64;
        }
        outcomes
    }

    /// Deterministic ordered reduction: scatter each sub-block's Δβ into
    /// the rank-level state and accumulate the per-sub-block t = X_k Δβ_k
    /// partials in sub-block index order. `state` must be freshly reset.
    pub fn reduce_into(&self, state: &mut SubproblemState) {
        for (st, range) in self.states.iter().zip(self.ranges.iter()) {
            state.delta_beta[range.clone()].copy_from_slice(&st.delta_beta);
            for (acc, t) in state.t.iter_mut().zip(st.t.iter()) {
                *acc += *t;
            }
        }
    }

    /// One full BSP pass: every sub-block runs one full cycle against the
    /// frozen snapshot, then the partials are merged into `state` (which
    /// the caller reset). Returns the coordinate updates performed.
    #[allow(clippy::too_many_arguments)]
    pub fn bsp_pass(
        &mut self,
        beta: &[f64],
        w: &[f64],
        z: &[f64],
        mu: f64,
        nu: f64,
        penalty: &dyn Penalty1D,
        state: &mut SubproblemState,
    ) -> usize {
        self.reset();
        let budgets: Vec<usize> = self.ranges.iter().map(|r| r.len()).collect();
        let outs = self.wave(beta, w, z, mu, nu, penalty, &budgets, None, None);
        self.reduce_into(state);
        outs.iter().map(|o| o.updates).sum()
    }

    /// One screened pass for the path sweep: sub-block k cycles exactly its
    /// entries of the active set (see [`HybridCd::split_active`]).
    #[allow(clippy::too_many_arguments)]
    pub fn screened_pass(
        &mut self,
        beta: &[f64],
        w: &[f64],
        z: &[f64],
        mu: f64,
        nu: f64,
        penalty: &dyn Penalty1D,
        per_active: &[Vec<usize>],
        state: &mut SubproblemState,
    ) -> usize {
        self.reset();
        let budgets: Vec<usize> = per_active.iter().map(|a| a.len()).collect();
        let outs = self.wave(beta, w, z, mu, nu, penalty, &budgets, Some(per_active), None);
        self.reduce_into(state);
        outs.iter().map(|o| o.updates).sum()
    }

    /// Split a rank-local screened active list into per-sub-block lists
    /// rebased to sub-shard-local column indices.
    pub fn split_active(&self, active: &[usize]) -> Vec<Vec<usize>> {
        let mut per: Vec<Vec<usize>> = vec![Vec::new(); self.ranges.len()];
        for &j in active {
            let k = self.ranges.partition_point(|r| r.end <= j);
            debug_assert!(k < self.ranges.len() && self.ranges[k].contains(&j));
            per[k].push(j - self.ranges[k].start);
        }
        per
    }
}

/// The quadratic model value  ∇LᵀΔβ + ½ Δβᵀ(μH̃+νI)Δβ + R(β+Δβ) − R(β)
/// restricted to this node's block — used by tests to certify that a cycle
/// never increases the model (the invariant CD guarantees).
pub fn block_model_value(
    x: &Csc,
    beta: &[f64],
    w: &[f64],
    z: &[f64],
    mu: f64,
    nu: f64,
    penalty: &dyn Penalty1D,
    delta_beta: &[f64],
    t: &[f64],
) -> f64 {
    // ∇L_j = Σ_i g_i x_ij with g_i = -w_i z_i ⇒ ∇LᵀΔβ = Σ_i (-w_i z_i) t_i.
    let mut grad_term = 0.0;
    let mut quad_term = 0.0;
    for i in 0..x.nrows {
        grad_term += -w[i] * z[i] * t[i];
        quad_term += w[i] * t[i] * t[i];
    }
    let mut reg_new = 0.0;
    let mut reg_old = 0.0;
    let mut ridge = 0.0;
    for j in 0..x.ncols {
        reg_new += penalty.value_1d(beta[j] + delta_beta[j]);
        reg_old += penalty.value_1d(beta[j]);
        ridge += delta_beta[j] * delta_beta[j];
    }
    grad_term + 0.5 * mu * quad_term + 0.5 * nu * ridge + reg_new - reg_old
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::regularizer::ElasticNet;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Random CSC block + working stats.
    fn random_problem(
        rng: &mut Rng,
        nrows: usize,
        ncols: usize,
    ) -> (Csc, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut trips = Vec::new();
        for j in 0..ncols {
            for i in 0..nrows {
                if rng.bernoulli(0.4) {
                    trips.push((i, j, rng.range_f64(-2.0, 2.0)));
                }
            }
        }
        let x = Csc::from_triplets(nrows, ncols, trips);
        let beta = prop::dense_vec(rng, ncols, 1.0);
        let w: Vec<f64> = (0..nrows).map(|_| rng.range_f64(0.01, 1.0)).collect();
        let z = prop::dense_vec(rng, nrows, 2.0);
        (x, beta, w, z)
    }

    #[test]
    fn t_vector_consistent_with_delta() {
        let mut rng = Rng::new(5);
        let (x, beta, w, z) = random_problem(&mut rng, 12, 6);
        let pen = ElasticNet::new(0.1, 0.05);
        let mut st = SubproblemState::new(6, 12);
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::full_cycle(6),
        );
        let want = x.mul_vec(&st.delta_beta);
        prop::all_close(&st.t, &want, 1e-10).unwrap();
    }

    #[test]
    fn prop_cycle_never_increases_model() {
        prop::check("cd cycle decreases quadratic model", 60, |rng| {
            let (nr, nc) = (2 + rng.below(15), 1 + rng.below(10));
            let (x, beta, w, z) = random_problem(rng, nr, nc);
            let pen = ElasticNet::new(rng.range_f64(0.0, 0.5), rng.range_f64(0.0, 0.5));
            let mu = 1.0 + rng.range_f64(0.0, 3.0);
            let nu = 1e-6;
            let mut st = SubproblemState::new(nc, nr);
            let before = block_model_value(&x, &beta, &w, &z, mu, nu, &pen, &st.delta_beta, &st.t);
            cd_cycle(
                &x,
                &beta,
                &w,
                &z,
                mu,
                nu,
                &pen,
                &mut st,
                CycleBudget::full_cycle(nc),
            );
            let after = block_model_value(&x, &beta, &w, &z, mu, nu, &pen, &st.delta_beta, &st.t);
            if after <= before + 1e-9 {
                Ok(())
            } else {
                Err(format!("model increased: {before} -> {after}"))
            }
        });
    }

    #[test]
    fn prop_more_cycles_keep_decreasing_model() {
        prop::check("multi-cycle monotone", 30, |rng| {
            let (nr, nc) = (3 + rng.below(12), 2 + rng.below(8));
            let (x, beta, w, z) = random_problem(rng, nr, nc);
            let pen = ElasticNet::new(0.1, 0.1);
            let mut st = SubproblemState::new(nc, nr);
            let mut prev = f64::INFINITY;
            for _ in 0..4 {
                cd_cycle(
                    &x,
                    &beta,
                    &w,
                    &z,
                    1.0,
                    1e-6,
                    &pen,
                    &mut st,
                    CycleBudget::full_cycle(nc),
                );
                let m =
                    block_model_value(&x, &beta, &w, &z, 1.0, 1e-6, &pen, &st.delta_beta, &st.t);
                if m > prev + 1e-9 {
                    return Err(format!("cycle increased model {prev} -> {m}"));
                }
                prev = m;
            }
            Ok(())
        });
    }

    #[test]
    fn single_column_reaches_exact_minimizer() {
        // One column, squared-loss-style stats: the CD update must hit the
        // analytic penalized minimizer in one step.
        let x = Csc::from_triplets(3, 1, vec![(0, 0, 1.0), (1, 0, 2.0), (2, 0, -1.0)]);
        let beta = [0.5];
        let w = [1.0, 1.0, 1.0];
        let z = [1.0, -0.5, 2.0];
        let (l1, l2) = (0.3, 0.2);
        let pen = ElasticNet::new(l1, l2);
        let (mu, nu) = (1.0, 1e-9);
        let mut st = SubproblemState::new(1, 3);
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            mu,
            nu,
            &pen,
            &mut st,
            CycleBudget::full_cycle(1),
        );
        // Analytic: minimize over u: ½Σw(z − (u−β)x)² ... in model form:
        // lin = Σ w x z + β Σ w x², quad = Σ w x²; u* = T(lin+νβ, λ1)/(quad+λ2+ν)
        let s2: f64 = 1.0 + 4.0 + 1.0;
        let s1: f64 = 1.0 * 1.0 + 2.0 * (-0.5) + (-1.0) * 2.0; // Σ w x z
        let lin = s1 + beta[0] * s2 + nu * beta[0];
        let u = crate::glm::soft_threshold(lin, l1) / (s2 + l2 + nu);
        assert!((st.delta_beta[0] - (u - beta[0])).abs() < 1e-12);
    }

    #[test]
    fn cursor_resumes_cyclically() {
        let mut rng = Rng::new(8);
        let (x, beta, w, z) = random_problem(&mut rng, 10, 5);
        let pen = ElasticNet::new(0.1, 0.0);
        let mut st = SubproblemState::new(5, 10);
        // Budget of 3 updates: cursor should land on column 3.
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget {
                max_updates: 3,
                stop: None,
                active: None,
            },
        );
        assert_eq!(st.cursor, 3);
        // Next call with budget 4 wraps around to column 2.
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget {
                max_updates: 4,
                stop: None,
                active: None,
            },
        );
        assert_eq!(st.cursor, 2);
    }

    #[test]
    fn stop_flag_halts_after_current_update() {
        let mut rng = Rng::new(9);
        let (x, beta, w, z) = random_problem(&mut rng, 10, 8);
        let pen = ElasticNet::new(0.0, 0.1);
        let mut st = SubproblemState::new(8, 10);
        let stop = AtomicBool::new(true); // already signalled
        let out = cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget {
                max_updates: 8,
                stop: Some(&stop),
                active: None,
            },
        );
        // At least one update always happens; then the flag is honored.
        assert_eq!(out.updates, 1);
        assert!(!out.full_pass);
    }

    #[test]
    fn empty_block_is_noop() {
        let x = Csc::from_triplets(4, 0, Vec::<(usize, usize, f64)>::new());
        let pen = ElasticNet::new(0.1, 0.1);
        let mut st = SubproblemState::new(0, 4);
        let out = cd_cycle(
            &x,
            &[],
            &[1.0; 4],
            &[0.0; 4],
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::full_cycle(0),
        );
        assert_eq!(out.updates, 0);
        assert!(out.full_pass);
    }

    #[test]
    fn active_set_only_touches_listed_columns() {
        let mut rng = Rng::new(11);
        let (x, beta, w, z) = random_problem(&mut rng, 12, 6);
        let pen = ElasticNet::new(0.05, 0.0);
        let active = [1usize, 4];
        let mut st = SubproblemState::new(6, 12);
        let out = cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::screened(&active),
        );
        assert_eq!(out.updates, 2);
        assert!(out.full_pass, "one pass over the screened subset");
        for j in 0..6 {
            if !active.contains(&j) {
                assert_eq!(st.delta_beta[j], 0.0, "screened-out column {j} moved");
            }
        }
        // The t vector stays consistent with the (screened) Δβ.
        let want = x.mul_vec(&st.delta_beta);
        prop::all_close(&st.t, &want, 1e-10).unwrap();
    }

    #[test]
    fn active_set_matches_full_cycle_on_full_list() {
        // active = [0..p] must be byte-identical to the unscreened cycle.
        let mut rng = Rng::new(12);
        let (x, beta, w, z) = random_problem(&mut rng, 10, 5);
        let pen = ElasticNet::new(0.1, 0.1);
        let all: Vec<usize> = (0..5).collect();
        let mut st_full = SubproblemState::new(5, 10);
        let mut st_act = SubproblemState::new(5, 10);
        cd_cycle(&x, &beta, &w, &z, 1.0, 1e-6, &pen, &mut st_full, CycleBudget::full_cycle(5));
        cd_cycle(&x, &beta, &w, &z, 1.0, 1e-6, &pen, &mut st_act, CycleBudget::screened(&all));
        assert_eq!(st_full.delta_beta, st_act.delta_beta);
        assert_eq!(st_full.cursor, st_act.cursor);
    }

    #[test]
    fn stale_cursor_restarts_screened_cycle() {
        let mut rng = Rng::new(13);
        let (x, beta, w, z) = random_problem(&mut rng, 8, 6);
        let pen = ElasticNet::new(0.1, 0.0);
        let mut st = SubproblemState::new(6, 8);
        st.cursor = 5; // left over from a wider active set
        let active = [0usize, 2];
        let out = cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::screened(&active),
        );
        assert_eq!(out.updates, 2);
        assert!(st.cursor < active.len());
    }

    #[test]
    fn empty_active_set_is_noop_full_pass() {
        let x = Csc::from_triplets(4, 3, vec![(0, 0, 1.0), (1, 1, 2.0)]);
        let pen = ElasticNet::new(0.1, 0.1);
        let mut st = SubproblemState::new(3, 4);
        let active: [usize; 0] = [];
        let out = cd_cycle(
            &x,
            &[0.0; 3],
            &[1.0; 4],
            &[0.0; 4],
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::screened(&active),
        );
        assert_eq!(out.updates, 0);
        assert!(out.full_pass, "an empty screened block is a complete pass");
    }

    #[test]
    fn split_even_covers_and_balances() {
        for (ncols, t) in [(10, 3), (7, 7), (5, 8), (1, 4), (0, 3), (16, 1), (100, 8)] {
            let ranges = split_even(ncols, t);
            assert!(!ranges.is_empty(), "ncols={ncols} t={t}");
            assert!(ranges.len() <= t.max(1));
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, ncols);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "ranges must be contiguous");
            }
            let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1, "ncols={ncols} t={t}: lens {lens:?}");
        }
    }

    #[test]
    fn hybrid_single_subblock_matches_classic_cycle_exactly() {
        // T=1 hybrid is one sub-block covering the whole block: the coupled
        // cycle, bit-for-bit.
        let mut rng = Rng::new(21);
        let (x, beta, w, z) = random_problem(&mut rng, 14, 7);
        let pen = ElasticNet::new(0.1, 0.05);
        let mut st_classic = SubproblemState::new(7, 14);
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.5,
            1e-6,
            &pen,
            &mut st_classic,
            CycleBudget::full_cycle(7),
        );
        let mut h = HybridCd::new(&x, 1);
        let mut st_hybrid = SubproblemState::new(7, 14);
        let updates = h.bsp_pass(&beta, &w, &z, 1.5, 1e-6, &pen, &mut st_hybrid);
        assert_eq!(updates, 7);
        assert_eq!(st_classic.delta_beta, st_hybrid.delta_beta);
        assert_eq!(st_classic.t, st_hybrid.t);
    }

    #[test]
    fn hybrid_pass_matches_manual_subblock_cycles() {
        // T=3: the pool wave + ordered reduction must equal running the
        // three sub-blocks sequentially by hand, bit-for-bit.
        let mut rng = Rng::new(22);
        let (x, beta, w, z) = random_problem(&mut rng, 16, 11);
        let pen = ElasticNet::new(0.2, 0.1);
        let mut h = HybridCd::new(&x, 3);
        assert_eq!(h.threads(), 3);
        let mut st_hybrid = SubproblemState::new(11, 16);
        let updates = h.bsp_pass(&beta, &w, &z, 1.0, 1e-6, &pen, &mut st_hybrid);
        assert_eq!(updates, 11);

        let mut want = SubproblemState::new(11, 16);
        for r in split_even(11, 3) {
            let cols: Vec<usize> = r.clone().collect();
            let shard = x.select_cols(&cols);
            let mut st = SubproblemState::new(r.len(), 16);
            cd_cycle(
                &shard,
                &beta[r.clone()],
                &w,
                &z,
                1.0,
                1e-6,
                &pen,
                &mut st,
                CycleBudget::full_cycle(r.len()),
            );
            want.delta_beta[r.clone()].copy_from_slice(&st.delta_beta);
            for (acc, t) in want.t.iter_mut().zip(st.t.iter()) {
                *acc += *t;
            }
        }
        assert_eq!(st_hybrid.delta_beta, want.delta_beta);
        assert_eq!(st_hybrid.t, want.t);
    }

    #[test]
    fn hybrid_pass_is_deterministic_across_runs() {
        let mut rng = Rng::new(23);
        let (x, beta, w, z) = random_problem(&mut rng, 20, 13);
        let pen = ElasticNet::new(0.1, 0.0);
        let run = || {
            let mut h = HybridCd::new(&x, 4);
            let mut st = SubproblemState::new(13, 20);
            for _ in 0..3 {
                st.reset();
                h.bsp_pass(&beta, &w, &z, 1.0, 1e-6, &pen, &mut st);
            }
            (st.delta_beta.clone(), st.t.clone(), h.updates_per_thread.clone())
        };
        let (d1, t1, u1) = run();
        let (d2, t2, u2) = run();
        assert_eq!(d1, d2, "Δβ must not depend on pool scheduling");
        assert_eq!(t1, t2, "t must not depend on pool scheduling");
        assert_eq!(u1, u2, "per-thread accounting must be deterministic");
        assert_eq!(u1.iter().sum::<u64>(), 3 * 13);
    }

    #[test]
    fn hybrid_split_active_rebases_to_subblocks() {
        let x = Csc::from_triplets(4, 10, vec![(0, 0, 1.0), (1, 5, 2.0), (2, 9, 3.0)]);
        let h = HybridCd::new(&x, 3); // ranges 0..4, 4..7, 7..10
        let per = h.split_active(&[0, 3, 4, 6, 7, 9]);
        assert_eq!(per, vec![vec![0, 3], vec![0, 2], vec![0, 2]]);
        // Every index must land inside its sub-block.
        let per_all = h.split_active(&(0..10).collect::<Vec<_>>());
        let total: usize = per_all.iter().map(|a| a.len()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn hybrid_screened_pass_touches_only_active_columns() {
        let mut rng = Rng::new(24);
        let (x, beta, w, z) = random_problem(&mut rng, 12, 9);
        let pen = ElasticNet::new(0.05, 0.0);
        let mut h = HybridCd::new(&x, 2);
        let active = [1usize, 4, 7];
        let per = h.split_active(&active);
        let mut st = SubproblemState::new(9, 12);
        let updates = h.screened_pass(&beta, &w, &z, 1.0, 1e-6, &pen, &per, &mut st);
        assert_eq!(updates, 3);
        for j in 0..9 {
            if !active.contains(&j) {
                assert_eq!(st.delta_beta[j], 0.0, "screened-out column {j} moved");
            }
        }
        // t stays consistent with the merged Δβ.
        let want = x.mul_vec(&st.delta_beta);
        prop::all_close(&st.t, &want, 1e-10).unwrap();
    }

    #[test]
    fn hybrid_empty_block_is_noop() {
        let x = Csc::from_triplets(4, 0, Vec::<(usize, usize, f64)>::new());
        let pen = ElasticNet::new(0.1, 0.1);
        let mut h = HybridCd::new(&x, 4);
        assert_eq!(h.threads(), 1);
        let mut st = SubproblemState::new(0, 4);
        let updates = h.bsp_pass(&[], &[1.0; 4], &[0.0; 4], 1.0, 1e-6, &pen, &mut st);
        assert_eq!(updates, 0);
    }

    #[test]
    fn zero_weight_examples_excluded() {
        // All w = 0: quad = ν only; with β=0 and z finite the update solves
        // argmin (ν/2)u² − ν·0·u + r(u) = 0 ⇒ no movement.
        let x = Csc::from_triplets(2, 1, vec![(0, 0, 1.0), (1, 0, 1.0)]);
        let pen = ElasticNet::new(0.1, 0.0);
        let mut st = SubproblemState::new(1, 2);
        cd_cycle(
            &x,
            &[0.0],
            &[0.0, 0.0],
            &[5.0, -5.0],
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::full_cycle(1),
        );
        assert_eq!(st.delta_beta[0], 0.0);
    }
}
