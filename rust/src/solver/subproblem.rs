//! Algorithm 2 — the per-node quadratic subproblem.
//!
//! Node m minimizes  L_q^gen(β, Δβ^m) + Σ_{j∈S^m} R(β_j + Δβ_j^m)  with one
//! cycle of coordinate descent using update rule (11). We re-derived (11)
//! (see DESIGN.md §Key derivations): with t = X^m Δβ^m maintained
//! incrementally, the coordinate update for local column j is
//!
//!   s1    = Σ_i w_i x_ij (z_i − μ t_i)
//!   s2    = Σ_i w_i x_ij²
//!   lin   = s1 + μ (β_j + Δβ_j) s2 + ν β_j
//!   quad  = μ s2 + ν
//!   u*    = argmin_u (quad/2)u² − lin·u + r(u)      (soft threshold for
//!                                                    elastic net)
//!   Δβ_j ← u* − β_j ;  t_i += (Δβ_j_new − Δβ_j_old) x_ij
//!
//! The cycle supports cyclic resume and an external stop signal — the hooks
//! ALB (Section 7) needs: fast nodes keep cycling past one full pass, and
//! everyone stops where they are when the κ-fraction signal fires.

use crate::glm::regularizer::Penalty1D;
use crate::sparse::Csc;
use std::sync::atomic::{AtomicBool, Ordering};

/// Mutable per-node state for one outer iteration's subproblem.
#[derive(Clone, Debug)]
pub struct SubproblemState {
    /// Δβ^m over the node's local columns.
    pub delta_beta: Vec<f64>,
    /// t = X^m Δβ^m over all n examples.
    pub t: Vec<f64>,
    /// Cyclic cursor: next local column to update (persists across outer
    /// iterations under ALB).
    pub cursor: usize,
}

impl SubproblemState {
    pub fn new(ncols: usize, nrows: usize) -> Self {
        SubproblemState {
            delta_beta: vec![0.0; ncols],
            t: vec![0.0; nrows],
            cursor: 0,
        }
    }

    /// Reset Δβ and t for a new outer iteration (cursor is preserved — the
    /// ALB schedule resumes from the next weight, paper §7).
    pub fn reset(&mut self) {
        self.delta_beta.iter_mut().for_each(|d| *d = 0.0);
        self.t.iter_mut().for_each(|t| *t = 0.0);
    }
}

/// How much of the block one call may update.
pub struct CycleBudget<'a> {
    /// Maximum coordinate updates (usually = block size for one full cycle;
    /// ALB fast nodes pass a multiple).
    pub max_updates: usize,
    /// Optional cooperative stop flag, checked between coordinates.
    pub stop: Option<&'a AtomicBool>,
    /// Restrict the cycle to these local column indices — the KKT
    /// strong-rule screening hook (`solver::path`): a warm path fit touches
    /// only the coordinates that survive the λ_k/λ_{k−1} gradient bound.
    /// `None` cycles the whole block. Indices must be < the block width;
    /// the cursor then counts positions *within this list*.
    pub active: Option<&'a [usize]>,
}

impl<'a> CycleBudget<'a> {
    pub fn full_cycle(ncols: usize) -> Self {
        CycleBudget {
            max_updates: ncols,
            stop: None,
            active: None,
        }
    }

    /// One full pass over a screened subset of the block.
    pub fn screened(active: &'a [usize]) -> Self {
        CycleBudget {
            max_updates: active.len(),
            stop: None,
            active: Some(active),
        }
    }
}

/// Outcome of one subproblem call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CycleOutcome {
    /// Coordinate updates performed.
    pub updates: usize,
    /// Whether at least one full pass over the block completed.
    pub full_pass: bool,
    /// Max |Δ change| over updated coordinates (inner convergence signal).
    pub max_delta: f64,
}

/// Run coordinate descent on the node's block.
///
/// * `x`     — the node's column block X^m (n × |S^m|).
/// * `beta`  — current local weights β^m (indexed like x's columns).
/// * `w, z`  — working weights/responses at the current β (length n).
/// * `mu`    — trust-region multiplier (Section 4).
/// * `nu`    — positive-definiteness shift (Section 5).
#[allow(clippy::too_many_arguments)]
pub fn cd_cycle(
    x: &Csc,
    beta: &[f64],
    w: &[f64],
    z: &[f64],
    mu: f64,
    nu: f64,
    penalty: &dyn Penalty1D,
    state: &mut SubproblemState,
    budget: CycleBudget<'_>,
) -> CycleOutcome {
    let p_local = x.ncols;
    debug_assert_eq!(beta.len(), p_local);
    debug_assert_eq!(state.delta_beta.len(), p_local);
    // Hard checks (not debug_assert): the unsafe hot loops below rely on
    // these lengths.
    assert_eq!(w.len(), x.nrows);
    assert_eq!(z.len(), x.nrows);
    assert_eq!(state.t.len(), x.nrows);
    debug_assert!(mu >= 1.0 && nu > 0.0);
    if let Some(a) = budget.active {
        debug_assert!(a.iter().all(|&j| j < p_local), "active index out of block");
    }

    let mut updates = 0usize;
    let mut max_delta = 0.0f64;
    // Cycle length: the screened subset when one is given, else the block.
    let cycle_len = budget.active.map_or(p_local, |a| a.len());
    if cycle_len == 0 {
        return CycleOutcome {
            updates: 0,
            full_pass: true,
            max_delta: 0.0,
        };
    }
    // A stale cursor (the active set shrank since the last call) restarts
    // the cycle rather than indexing out of the list.
    if state.cursor >= cycle_len {
        state.cursor = 0;
    }
    let t = &mut state.t;
    while updates < budget.max_updates {
        if let Some(stop) = budget.stop {
            if stop.load(Ordering::Relaxed) && updates >= 1 {
                break;
            }
        }
        let slot = state.cursor;
        state.cursor = (state.cursor + 1) % cycle_len;
        let j = budget.active.map_or(slot, |a| a[slot]);

        let (rows, vals) = x.col_raw(j);
        // One fused pass over the column: s1 = Σ w x (z − μ t), s2 = Σ w x².
        // SAFETY: row indices are < nrows by Csc construction; w/z/t have
        // length nrows (checked at entry) — elide the per-entry bounds
        // checks in the hottest loop of the solver (§Perf).
        let mut s1 = 0.0;
        let mut s2 = 0.0;
        for (r, v) in rows.iter().zip(vals.iter()) {
            let i = *r as usize;
            unsafe {
                let wx = w.get_unchecked(i) * v;
                s1 += wx * (z.get_unchecked(i) - mu * t.get_unchecked(i));
                s2 += wx * v;
            }
        }
        let old_d = state.delta_beta[j];
        let lin = s1 + mu * (beta[j] + old_d) * s2 + nu * beta[j];
        let quad = mu * s2 + nu;
        let u = penalty.solve_penalized_quad(quad, lin);
        let new_d = u - beta[j];
        let change = new_d - old_d;
        if change != 0.0 {
            state.delta_beta[j] = new_d;
            // SAFETY: same bound argument as the gather loop above.
            for (r, v) in rows.iter().zip(vals.iter()) {
                unsafe {
                    *t.get_unchecked_mut(*r as usize) += change * v;
                }
            }
            max_delta = max_delta.max(change.abs());
        }
        updates += 1;
    }
    CycleOutcome {
        updates,
        full_pass: updates >= cycle_len,
        max_delta,
    }
}

/// The quadratic model value  ∇LᵀΔβ + ½ Δβᵀ(μH̃+νI)Δβ + R(β+Δβ) − R(β)
/// restricted to this node's block — used by tests to certify that a cycle
/// never increases the model (the invariant CD guarantees).
pub fn block_model_value(
    x: &Csc,
    beta: &[f64],
    w: &[f64],
    z: &[f64],
    mu: f64,
    nu: f64,
    penalty: &dyn Penalty1D,
    delta_beta: &[f64],
    t: &[f64],
) -> f64 {
    // ∇L_j = Σ_i g_i x_ij with g_i = -w_i z_i ⇒ ∇LᵀΔβ = Σ_i (-w_i z_i) t_i.
    let mut grad_term = 0.0;
    let mut quad_term = 0.0;
    for i in 0..x.nrows {
        grad_term += -w[i] * z[i] * t[i];
        quad_term += w[i] * t[i] * t[i];
    }
    let mut reg_new = 0.0;
    let mut reg_old = 0.0;
    let mut ridge = 0.0;
    for j in 0..x.ncols {
        reg_new += penalty.value_1d(beta[j] + delta_beta[j]);
        reg_old += penalty.value_1d(beta[j]);
        ridge += delta_beta[j] * delta_beta[j];
    }
    grad_term + 0.5 * mu * quad_term + 0.5 * nu * ridge + reg_new - reg_old
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::regularizer::ElasticNet;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Random CSC block + working stats.
    fn random_problem(
        rng: &mut Rng,
        nrows: usize,
        ncols: usize,
    ) -> (Csc, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut trips = Vec::new();
        for j in 0..ncols {
            for i in 0..nrows {
                if rng.bernoulli(0.4) {
                    trips.push((i, j, rng.range_f64(-2.0, 2.0)));
                }
            }
        }
        let x = Csc::from_triplets(nrows, ncols, trips);
        let beta = prop::dense_vec(rng, ncols, 1.0);
        let w: Vec<f64> = (0..nrows).map(|_| rng.range_f64(0.01, 1.0)).collect();
        let z = prop::dense_vec(rng, nrows, 2.0);
        (x, beta, w, z)
    }

    #[test]
    fn t_vector_consistent_with_delta() {
        let mut rng = Rng::new(5);
        let (x, beta, w, z) = random_problem(&mut rng, 12, 6);
        let pen = ElasticNet::new(0.1, 0.05);
        let mut st = SubproblemState::new(6, 12);
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::full_cycle(6),
        );
        let want = x.mul_vec(&st.delta_beta);
        prop::all_close(&st.t, &want, 1e-10).unwrap();
    }

    #[test]
    fn prop_cycle_never_increases_model() {
        prop::check("cd cycle decreases quadratic model", 60, |rng| {
            let (nr, nc) = (2 + rng.below(15), 1 + rng.below(10));
            let (x, beta, w, z) = random_problem(rng, nr, nc);
            let pen = ElasticNet::new(rng.range_f64(0.0, 0.5), rng.range_f64(0.0, 0.5));
            let mu = 1.0 + rng.range_f64(0.0, 3.0);
            let nu = 1e-6;
            let mut st = SubproblemState::new(nc, nr);
            let before = block_model_value(&x, &beta, &w, &z, mu, nu, &pen, &st.delta_beta, &st.t);
            cd_cycle(
                &x,
                &beta,
                &w,
                &z,
                mu,
                nu,
                &pen,
                &mut st,
                CycleBudget::full_cycle(nc),
            );
            let after = block_model_value(&x, &beta, &w, &z, mu, nu, &pen, &st.delta_beta, &st.t);
            if after <= before + 1e-9 {
                Ok(())
            } else {
                Err(format!("model increased: {before} -> {after}"))
            }
        });
    }

    #[test]
    fn prop_more_cycles_keep_decreasing_model() {
        prop::check("multi-cycle monotone", 30, |rng| {
            let (nr, nc) = (3 + rng.below(12), 2 + rng.below(8));
            let (x, beta, w, z) = random_problem(rng, nr, nc);
            let pen = ElasticNet::new(0.1, 0.1);
            let mut st = SubproblemState::new(nc, nr);
            let mut prev = f64::INFINITY;
            for _ in 0..4 {
                cd_cycle(
                    &x,
                    &beta,
                    &w,
                    &z,
                    1.0,
                    1e-6,
                    &pen,
                    &mut st,
                    CycleBudget::full_cycle(nc),
                );
                let m =
                    block_model_value(&x, &beta, &w, &z, 1.0, 1e-6, &pen, &st.delta_beta, &st.t);
                if m > prev + 1e-9 {
                    return Err(format!("cycle increased model {prev} -> {m}"));
                }
                prev = m;
            }
            Ok(())
        });
    }

    #[test]
    fn single_column_reaches_exact_minimizer() {
        // One column, squared-loss-style stats: the CD update must hit the
        // analytic penalized minimizer in one step.
        let x = Csc::from_triplets(3, 1, vec![(0, 0, 1.0), (1, 0, 2.0), (2, 0, -1.0)]);
        let beta = [0.5];
        let w = [1.0, 1.0, 1.0];
        let z = [1.0, -0.5, 2.0];
        let (l1, l2) = (0.3, 0.2);
        let pen = ElasticNet::new(l1, l2);
        let (mu, nu) = (1.0, 1e-9);
        let mut st = SubproblemState::new(1, 3);
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            mu,
            nu,
            &pen,
            &mut st,
            CycleBudget::full_cycle(1),
        );
        // Analytic: minimize over u: ½Σw(z − (u−β)x)² ... in model form:
        // lin = Σ w x z + β Σ w x², quad = Σ w x²; u* = T(lin+νβ, λ1)/(quad+λ2+ν)
        let s2: f64 = 1.0 + 4.0 + 1.0;
        let s1: f64 = 1.0 * 1.0 + 2.0 * (-0.5) + (-1.0) * 2.0; // Σ w x z
        let lin = s1 + beta[0] * s2 + nu * beta[0];
        let u = crate::glm::soft_threshold(lin, l1) / (s2 + l2 + nu);
        assert!((st.delta_beta[0] - (u - beta[0])).abs() < 1e-12);
    }

    #[test]
    fn cursor_resumes_cyclically() {
        let mut rng = Rng::new(8);
        let (x, beta, w, z) = random_problem(&mut rng, 10, 5);
        let pen = ElasticNet::new(0.1, 0.0);
        let mut st = SubproblemState::new(5, 10);
        // Budget of 3 updates: cursor should land on column 3.
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget {
                max_updates: 3,
                stop: None,
                active: None,
            },
        );
        assert_eq!(st.cursor, 3);
        // Next call with budget 4 wraps around to column 2.
        cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget {
                max_updates: 4,
                stop: None,
                active: None,
            },
        );
        assert_eq!(st.cursor, 2);
    }

    #[test]
    fn stop_flag_halts_after_current_update() {
        let mut rng = Rng::new(9);
        let (x, beta, w, z) = random_problem(&mut rng, 10, 8);
        let pen = ElasticNet::new(0.0, 0.1);
        let mut st = SubproblemState::new(8, 10);
        let stop = AtomicBool::new(true); // already signalled
        let out = cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget {
                max_updates: 8,
                stop: Some(&stop),
                active: None,
            },
        );
        // At least one update always happens; then the flag is honored.
        assert_eq!(out.updates, 1);
        assert!(!out.full_pass);
    }

    #[test]
    fn empty_block_is_noop() {
        let x = Csc::from_triplets(4, 0, Vec::<(usize, usize, f64)>::new());
        let pen = ElasticNet::new(0.1, 0.1);
        let mut st = SubproblemState::new(0, 4);
        let out = cd_cycle(
            &x,
            &[],
            &[1.0; 4],
            &[0.0; 4],
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::full_cycle(0),
        );
        assert_eq!(out.updates, 0);
        assert!(out.full_pass);
    }

    #[test]
    fn active_set_only_touches_listed_columns() {
        let mut rng = Rng::new(11);
        let (x, beta, w, z) = random_problem(&mut rng, 12, 6);
        let pen = ElasticNet::new(0.05, 0.0);
        let active = [1usize, 4];
        let mut st = SubproblemState::new(6, 12);
        let out = cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::screened(&active),
        );
        assert_eq!(out.updates, 2);
        assert!(out.full_pass, "one pass over the screened subset");
        for j in 0..6 {
            if !active.contains(&j) {
                assert_eq!(st.delta_beta[j], 0.0, "screened-out column {j} moved");
            }
        }
        // The t vector stays consistent with the (screened) Δβ.
        let want = x.mul_vec(&st.delta_beta);
        prop::all_close(&st.t, &want, 1e-10).unwrap();
    }

    #[test]
    fn active_set_matches_full_cycle_on_full_list() {
        // active = [0..p] must be byte-identical to the unscreened cycle.
        let mut rng = Rng::new(12);
        let (x, beta, w, z) = random_problem(&mut rng, 10, 5);
        let pen = ElasticNet::new(0.1, 0.1);
        let all: Vec<usize> = (0..5).collect();
        let mut st_full = SubproblemState::new(5, 10);
        let mut st_act = SubproblemState::new(5, 10);
        cd_cycle(&x, &beta, &w, &z, 1.0, 1e-6, &pen, &mut st_full, CycleBudget::full_cycle(5));
        cd_cycle(&x, &beta, &w, &z, 1.0, 1e-6, &pen, &mut st_act, CycleBudget::screened(&all));
        assert_eq!(st_full.delta_beta, st_act.delta_beta);
        assert_eq!(st_full.cursor, st_act.cursor);
    }

    #[test]
    fn stale_cursor_restarts_screened_cycle() {
        let mut rng = Rng::new(13);
        let (x, beta, w, z) = random_problem(&mut rng, 8, 6);
        let pen = ElasticNet::new(0.1, 0.0);
        let mut st = SubproblemState::new(6, 8);
        st.cursor = 5; // left over from a wider active set
        let active = [0usize, 2];
        let out = cd_cycle(
            &x,
            &beta,
            &w,
            &z,
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::screened(&active),
        );
        assert_eq!(out.updates, 2);
        assert!(st.cursor < active.len());
    }

    #[test]
    fn empty_active_set_is_noop_full_pass() {
        let x = Csc::from_triplets(4, 3, vec![(0, 0, 1.0), (1, 1, 2.0)]);
        let pen = ElasticNet::new(0.1, 0.1);
        let mut st = SubproblemState::new(3, 4);
        let active: [usize; 0] = [];
        let out = cd_cycle(
            &x,
            &[0.0; 3],
            &[1.0; 4],
            &[0.0; 4],
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::screened(&active),
        );
        assert_eq!(out.updates, 0);
        assert!(out.full_pass, "an empty screened block is a complete pass");
    }

    #[test]
    fn zero_weight_examples_excluded() {
        // All w = 0: quad = ν only; with β=0 and z finite the update solves
        // argmin (ν/2)u² − ν·0·u + r(u) = 0 ⇒ no movement.
        let x = Csc::from_triplets(2, 1, vec![(0, 0, 1.0), (1, 0, 1.0)]);
        let pen = ElasticNet::new(0.1, 0.0);
        let mut st = SubproblemState::new(1, 2);
        cd_cycle(
            &x,
            &[0.0],
            &[0.0, 0.0],
            &[5.0, -5.0],
            1.0,
            1e-6,
            &pen,
            &mut st,
            CycleBudget::full_cycle(1),
        );
        assert_eq!(st.delta_beta[0], 0.0);
    }
}
