//! Per-iteration training telemetry: the *convergence* series.
//!
//! The paper's evaluation plots everything against wall-clock time: relative
//! objective suboptimality (Fig 2, 5), test auPRC (Fig 3, 6), number of
//! non-zero weights (Fig 4). A `Trace` collects exactly those series, plus
//! the line-search/μ internals used in the Fig 1 ablation, and serializes to
//! JSON for the bench harnesses.
//!
//! This is the per-run *curve*; the cluster-side observability layer —
//! structured logs, phase spans, counters, and the `--trace-out` run-log
//! pipeline that `dglmnet trace-report` renders — lives in [`crate::obs`]
//! (re-exported as `obs::prelude`). `Trace.comm_bytes` here is fed from the
//! transport's byte accounting, not estimated.

use crate::util::json::Json;

/// One point of the convergence profile.
#[derive(Clone, Debug)]
pub struct TracePoint {
    /// Seconds since training start.
    pub t_sec: f64,
    /// Outer iteration number (0 = before the first update).
    pub iter: usize,
    /// Objective f(β) = L(β) + R(β).
    pub objective: f64,
    /// Number of non-zero weights.
    pub nnz: usize,
    /// Accepted line-search step (1.0 when the full step passed).
    pub alpha: f64,
    /// Trust-region multiplier μ after adaptation.
    pub mu: f64,
    /// Test auPRC if a test set was attached.
    pub auprc: Option<f64>,
}

/// Convergence profile of one training run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub algorithm: String,
    pub dataset: String,
    pub points: Vec<TracePoint>,
    /// Total bytes moved through the cluster fabric (0 for single-process).
    pub comm_bytes: u64,
}

impl Trace {
    pub fn new(algorithm: &str, dataset: &str) -> Trace {
        Trace {
            algorithm: algorithm.to_string(),
            dataset: dataset.to_string(),
            points: Vec::new(),
            comm_bytes: 0,
        }
    }

    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    pub fn final_objective(&self) -> f64 {
        self.points.last().map(|p| p.objective).unwrap_or(f64::NAN)
    }

    pub fn best_objective(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.objective)
            .fold(f64::INFINITY, f64::min)
    }

    /// Relative suboptimality series (f − f*)/f* against a reference optimum.
    pub fn suboptimality(&self, f_star: f64) -> Vec<(f64, f64)> {
        self.points
            .iter()
            .map(|p| (p.t_sec, (p.objective - f_star) / f_star))
            .collect()
    }

    /// First time the trace came within `frac` (e.g. 0.025) of f* — the
    /// paper's Fig 7/8 "time to 2.5%" measurement. None if never reached.
    pub fn time_to_suboptimality(&self, f_star: f64, frac: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| (p.objective - f_star) / f_star <= frac)
            .map(|p| p.t_sec)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("algorithm", self.algorithm.as_str())
            .set("dataset", self.dataset.as_str())
            .set("comm_bytes", self.comm_bytes)
            .set(
                "t_sec",
                self.points.iter().map(|p| p.t_sec).collect::<Vec<_>>(),
            )
            .set(
                "objective",
                self.points.iter().map(|p| p.objective).collect::<Vec<_>>(),
            )
            .set(
                "nnz",
                self.points.iter().map(|p| p.nnz as f64).collect::<Vec<_>>(),
            )
            .set(
                "alpha",
                self.points.iter().map(|p| p.alpha).collect::<Vec<_>>(),
            )
            .set("mu", self.points.iter().map(|p| p.mu).collect::<Vec<_>>())
            .set(
                "auprc",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| p.auprc.map(Json::Num).unwrap_or(Json::Null))
                        .collect(),
                ),
            );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let mut t = Trace::new("d-glmnet", "toy");
        for (i, f) in [10.0, 5.0, 2.0, 1.05, 1.01].iter().enumerate() {
            t.push(TracePoint {
                t_sec: i as f64,
                iter: i,
                objective: *f,
                nnz: 10 - i,
                alpha: 1.0,
                mu: 1.0,
                auprc: if i % 2 == 0 { Some(0.5 + i as f64 / 10.0) } else { None },
            });
        }
        t
    }

    #[test]
    fn suboptimality_series() {
        let t = sample_trace();
        let s = t.suboptimality(1.0);
        assert_eq!(s.len(), 5);
        assert!((s[0].1 - 9.0).abs() < 1e-12);
        assert!((s[4].1 - 0.01).abs() < 1e-12);
    }

    #[test]
    fn time_to_threshold() {
        let t = sample_trace();
        // 6% of f*=1.0 first reached at t=3 (1.05).
        assert_eq!(t.time_to_suboptimality(1.0, 0.06), Some(3.0));
        assert_eq!(t.time_to_suboptimality(1.0, 1e-6), None);
    }

    #[test]
    fn json_has_all_series() {
        let j = sample_trace().to_json();
        let s = j.dump();
        for key in ["algorithm", "objective", "nnz", "alpha", "mu", "auprc"] {
            assert!(s.contains(key), "missing {key} in {s}");
        }
    }

    #[test]
    fn final_and_best() {
        let t = sample_trace();
        assert_eq!(t.final_objective(), 1.01);
        assert_eq!(t.best_objective(), 1.01);
    }
}
