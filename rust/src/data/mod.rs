//! Datasets: in-memory container + splits (Table 1 summaries), synthetic
//! generators standing in for the paper's corpora (see DESIGN.md
//! §Substitutions), and the binary columnar shard format for out-of-core
//! cluster ingestion (DESIGN.md §Shard format).

pub mod dataset;
pub mod preprocess;
pub mod shards;
pub mod synth;

pub use dataset::{Dataset, Splits, Summary};
pub use preprocess::{with_intercept, NoPenalty, Standardizer};
pub use synth::{clickstream, epsilon_like, webspam_like, Corpus, SynthConfig};
