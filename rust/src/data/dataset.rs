//! In-memory dataset with train/test/validation splits and the Table-1 style
//! summary used throughout the evaluation harness.

use crate::sparse::{Csc, Csr};

/// A labeled dataset in both layouts. CSR is the generation/storage layout;
/// CSC is materialized on demand for feature-sharded training.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Csr,
    /// Labels: {-1,+1} for classification, reals for regression.
    pub y: Vec<f64>,
}

/// Train/test/validation split of a dataset (paper §8.2 splits the public
/// test sets into new test + validation halves).
#[derive(Clone, Debug)]
pub struct Splits {
    pub train: Dataset,
    pub test: Dataset,
    pub validation: Dataset,
}

/// The row of Table 1 for one dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub name: String,
    pub n_train: usize,
    pub n_test: usize,
    pub n_validation: usize,
    pub p: usize,
    pub nnz: usize,
    pub avg_nonzeros: f64,
    /// Approximate in-memory size in bytes (CSR payload), the analogue of
    /// the paper's on-disk size column.
    pub bytes: usize,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: Csr, y: Vec<f64>) -> Dataset {
        assert_eq!(x.nrows, y.len(), "label/example count mismatch");
        Dataset {
            name: name.into(),
            x,
            y,
        }
    }

    pub fn n(&self) -> usize {
        self.x.nrows
    }

    pub fn p(&self) -> usize {
        self.x.ncols
    }

    pub fn nnz(&self) -> usize {
        self.x.nnz()
    }

    /// Feature-major copy for vertical sharding.
    pub fn to_csc(&self) -> Csc {
        self.x.to_csc()
    }

    /// Fraction of positive labels (classification).
    pub fn positive_rate(&self) -> f64 {
        if self.y.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.y.len() as f64
    }

    /// Split by example counts, in order (generators already randomize row
    /// order, so sequential splitting is an unbiased split).
    pub fn split(self, n_test: usize, n_validation: usize) -> Splits {
        let n = self.n();
        assert!(n_test + n_validation < n, "splits exhaust the dataset");
        let n_train = n - n_test - n_validation;
        let idx: Vec<usize> = (0..n).collect();
        let (train_idx, rest) = idx.split_at(n_train);
        let (test_idx, val_idx) = rest.split_at(n_test);
        let take = |ids: &[usize], tag: &str| {
            Dataset::new(
                format!("{}-{tag}", self.name),
                self.x.select_rows(ids),
                ids.iter().map(|&i| self.y[i]).collect(),
            )
        };
        Splits {
            train: take(train_idx, "train"),
            test: take(test_idx, "test"),
            validation: take(val_idx, "validation"),
        }
    }
}

impl Splits {
    pub fn summary(&self) -> Summary {
        let t = &self.train;
        Summary {
            name: t
                .name
                .strip_suffix("-train")
                .unwrap_or(&t.name)
                .to_string(),
            n_train: t.n(),
            n_test: self.test.n(),
            n_validation: self.validation.n(),
            p: t.p(),
            nnz: t.nnz(),
            avg_nonzeros: t.nnz() as f64 / t.n().max(1) as f64,
            bytes: t.x.storage_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::Csr;

    fn toy(n: usize) -> Dataset {
        let rows: Vec<Vec<(usize, f64)>> = (0..n).map(|i| vec![(i % 3, 1.0 + i as f64)]).collect();
        let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        Dataset::new("toy", Csr::from_rows(3, &rows), y)
    }

    #[test]
    fn split_sizes_and_disjointness() {
        let s = toy(10).split(2, 3);
        assert_eq!(s.train.n(), 5);
        assert_eq!(s.test.n(), 2);
        assert_eq!(s.validation.n(), 3);
        // Train rows are the first five originals.
        assert_eq!(s.train.x.row(0).collect::<Vec<_>>(), vec![(0, 1.0)]);
        assert_eq!(s.test.x.row(0).collect::<Vec<_>>(), vec![(2, 6.0)]);
    }

    #[test]
    fn summary_counts() {
        let s = toy(10).split(2, 2);
        let sum = s.summary();
        assert_eq!(sum.name, "toy");
        assert_eq!(sum.n_train, 6);
        assert_eq!(sum.p, 3);
        assert_eq!(sum.nnz, 6);
        assert!((sum.avg_nonzeros - 1.0).abs() < 1e-12);
    }

    #[test]
    fn positive_rate() {
        assert!((toy(10).positive_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "exhaust")]
    fn split_guards_overflow() {
        toy(5).split(3, 2);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn label_count_checked() {
        Dataset::new("bad", Csr::from_rows(1, &[vec![(0, 1.0)]]), vec![1.0, -1.0]);
    }
}
