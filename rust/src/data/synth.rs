//! Synthetic dataset generators standing in for the paper's three corpora
//! (Table 1). Each generator draws a ground-truth ("teacher") linear model
//! and emits labels through the logistic link, so training has a recoverable
//! signal and test auPRC is a meaningful axis. The substitutions and the
//! characteristics they preserve are documented in DESIGN.md §Substitutions.
//!
//! - `epsilon_like`    — dense Gaussian features, every feature non-zero
//!                        (paper: epsilon, 2000 dense features).
//! - `webspam_like`    — sparse binary features with power-law popularity
//!                        (paper: webspam, 16.6M features, ~3.7k nnz/row).
//! - `clickstream`     — very sparse categorical one-hot features, heavy
//!                        class imbalance (paper: yandex_ad, CTR prediction).

use crate::data::dataset::Dataset;
use crate::sparse::csr::Csr;
use crate::util::rng::{Rng, Zipf};
use crate::util::stats::sigmoid;

/// Parameters shared by the generators.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub n: usize,
    pub p: usize,
    pub seed: u64,
}

/// Dense Gaussian features; teacher with all-dense coefficients; labels via
/// the logistic link with moderate noise (label flip on the link).
pub fn epsilon_like(cfg: &SynthConfig) -> Dataset {
    let mut rng = Rng::new(cfg.seed ^ 0xE95);
    // Teacher: N(0,1) coefficients scaled so margins land in a useful range.
    let scale = 1.5 / (cfg.p as f64).sqrt();
    let teacher: Vec<f64> = (0..cfg.p).map(|_| rng.normal() * scale).collect();
    let mut rows = Vec::with_capacity(cfg.n);
    let mut y = Vec::with_capacity(cfg.n);
    for _ in 0..cfg.n {
        let feats: Vec<(usize, f64)> = (0..cfg.p).map(|j| (j, rng.normal())).collect();
        let margin: f64 = feats.iter().map(|&(j, v)| teacher[j] * v).sum();
        y.push(draw_label(&mut rng, margin));
        rows.push(feats);
    }
    Dataset::new("epsilon_like", Csr::from_rows(cfg.p, &rows), y)
}

/// Sparse rows: each example activates `avg_nnz` features on average, chosen
/// by a Zipf popularity law (text-like). Teacher is sparse: only a fraction
/// of features carry signal, mimicking spam-token structure.
pub fn webspam_like(cfg: &SynthConfig, avg_nnz: usize) -> Dataset {
    let mut rng = Rng::new(cfg.seed ^ 0x3EB);
    let zipf = Zipf::new(cfg.p, 1.05);
    // ~5% of features are informative, ±1 weights.
    let mut teacher = vec![0.0; cfg.p];
    let informative = (cfg.p / 20).max(4);
    for j in rng.sample_indices(cfg.p, informative) {
        teacher[j] = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
    }
    let tf_scale = 1.0 / (avg_nnz as f64).sqrt();
    let mut rows = Vec::with_capacity(cfg.n);
    let mut y = Vec::with_capacity(cfg.n);
    for _ in 0..cfg.n {
        // Row length ~ Poisson-ish around avg_nnz via exponential jitter.
        let len = ((avg_nnz as f64) * (0.5 + rng.exponential(1.0) * 0.5)).round() as usize;
        let len = len.clamp(1, cfg.p);
        let mut cols = std::collections::BTreeSet::new();
        while cols.len() < len {
            cols.insert(zipf.sample(&mut rng));
        }
        let feats: Vec<(usize, f64)> = cols
            .into_iter()
            .map(|j| (j, 1.0 + rng.f64())) // tf-like positive weights
            .collect();
        let margin: f64 = feats
            .iter()
            .map(|&(j, v)| teacher[j] * v * tf_scale * 4.0)
            .sum();
        y.push(draw_label(&mut rng, margin));
        rows.push(feats);
    }
    Dataset::new("webspam_like", Csr::from_rows(cfg.p, &rows), y)
}

/// CTR-like data: `fields` categorical fields one-hot encoded into a shared
/// feature space with Zipf-distributed category popularity; labels heavily
/// imbalanced (base CTR set by `base_rate`).
pub fn clickstream(cfg: &SynthConfig, fields: usize, base_rate: f64) -> Dataset {
    let mut rng = Rng::new(cfg.seed ^ 0xC71C);
    assert!(fields >= 1 && cfg.p >= fields);
    let per_field = cfg.p / fields;
    let zipf = Zipf::new(per_field, 1.1);
    // Sparse teacher over categories; intercept shifts base rate.
    let mut teacher = vec![0.0; cfg.p];
    for j in rng.sample_indices(cfg.p, (cfg.p / 10).max(4)) {
        teacher[j] = rng.normal() * 1.2;
    }
    let intercept = (base_rate / (1.0 - base_rate)).ln();
    let mut rows = Vec::with_capacity(cfg.n);
    let mut y = Vec::with_capacity(cfg.n);
    for _ in 0..cfg.n {
        let mut feats = Vec::with_capacity(fields);
        for f in 0..fields {
            let cat = zipf.sample(&mut rng);
            let j = f * per_field + cat;
            if j < cfg.p {
                feats.push((j, 1.0));
            }
        }
        let margin: f64 =
            intercept + feats.iter().map(|&(j, _)| teacher[j]).sum::<f64>();
        let label = if rng.bernoulli(sigmoid(margin)) { 1.0 } else { -1.0 };
        y.push(label);
        rows.push(feats);
    }
    Dataset::new("clickstream", Csr::from_rows(cfg.p, &rows), y)
}

/// Dense features with a common-factor correlation structure:
/// x_ij = √ρ·c_i + √(1−ρ)·n_ij with a shared per-example factor c_i, so any
/// two features have correlation ρ. This is the regime where the
/// block-diagonal Hessian approximation (7) is badly wrong, parallel block
/// steps conflict, and the line search keeps choosing α < 1 — the setting
/// that makes the trust-region μ (Section 4) matter (Fig 1).
pub fn correlated_dense(cfg: &SynthConfig, rho: f64) -> Dataset {
    assert!((0.0..1.0).contains(&rho));
    let mut rng = Rng::new(cfg.seed ^ 0xC0CC);
    let scale = 1.5 / (cfg.p as f64).sqrt();
    let teacher: Vec<f64> = (0..cfg.p).map(|_| rng.normal() * scale).collect();
    let (a, b) = (rho.sqrt(), (1.0 - rho).sqrt());
    let mut rows = Vec::with_capacity(cfg.n);
    let mut y = Vec::with_capacity(cfg.n);
    for _ in 0..cfg.n {
        let c = rng.normal();
        let feats: Vec<(usize, f64)> = (0..cfg.p)
            .map(|j| (j, a * c + b * rng.normal()))
            .collect();
        let margin: f64 = feats.iter().map(|&(j, v)| teacher[j] * v).sum();
        y.push(draw_label(&mut rng, margin));
        rows.push(feats);
    }
    Dataset::new("correlated_dense", Csr::from_rows(cfg.p, &rows), y)
}

/// Block-correlated sparse features: the feature space splits into
/// `groups` consecutive index ranges, every example activates exactly ONE
/// group's columns (dense within the group, zero elsewhere), and the
/// active values share a per-row common factor with correlation ρ. Two
/// columns therefore co-occur iff they belong to the same group — the
/// planted structure `FeaturePartition::cooccurrence_clustered` should
/// recover exactly, and the regime where a hashed layout scatters each
/// correlated group across every rank (cross-block coupling, α < 1 line
/// searches) while a clustered layout keeps the block-diagonal Hessian
/// model (7) nearly exact.
pub fn block_correlated(cfg: &SynthConfig, groups: usize, rho: f64) -> Dataset {
    assert!((0.0..1.0).contains(&rho));
    assert!(groups >= 1 && cfg.p >= groups);
    let mut rng = Rng::new(cfg.seed ^ 0xB10C);
    let per = cfg.p / groups;
    let scale = 1.5 / (per as f64).sqrt();
    let teacher: Vec<f64> = (0..cfg.p).map(|_| rng.normal() * scale).collect();
    let (a, b) = (rho.sqrt(), (1.0 - rho).sqrt());
    let mut rows = Vec::with_capacity(cfg.n);
    let mut y = Vec::with_capacity(cfg.n);
    for i in 0..cfg.n {
        // Round-robin group choice keeps per-group row counts (and thus
        // per-group nnz) balanced deterministically.
        let g = i % groups;
        let lo = g * per;
        let hi = if g + 1 == groups { cfg.p } else { lo + per };
        let c = rng.normal();
        let feats: Vec<(usize, f64)> =
            (lo..hi).map(|j| (j, a * c + b * rng.normal())).collect();
        let margin: f64 = feats.iter().map(|&(j, v)| teacher[j] * v).sum();
        y.push(draw_label(&mut rng, margin));
        rows.push(feats);
    }
    Dataset::new("block_correlated", Csr::from_rows(cfg.p, &rows), y)
}

/// Draw a {-1,+1} label through the logistic link at the given margin.
fn draw_label(rng: &mut Rng, margin: f64) -> f64 {
    if rng.bernoulli(sigmoid(margin)) {
        1.0
    } else {
        -1.0
    }
}

/// Small dense regression problem with known optimum — used by solver unit
/// tests (squared loss: the regularized optimum is computable directly).
pub fn regression_toy(n: usize, p: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let teacher: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let feats: Vec<(usize, f64)> = (0..p).map(|j| (j, rng.normal())).collect();
        let m: f64 = feats.iter().map(|&(j, v)| teacher[j] * v).sum();
        y.push(m + noise * rng.normal());
        rows.push(feats);
    }
    Dataset::new("regression_toy", Csr::from_rows(p, &rows), y)
}

/// The paper's three evaluation datasets at laptop scale, split like §8.2.
pub struct Corpus;

impl Corpus {
    pub fn epsilon_like(scale: f64, seed: u64) -> crate::data::dataset::Splits {
        let n = (5000.0 * scale) as usize;
        let cfg = SynthConfig {
            n,
            p: (500.0 * scale.sqrt()) as usize,
            seed,
        };
        let ds = epsilon_like(&cfg);
        let tenth = n / 10;
        ds.split(tenth, tenth)
    }

    pub fn webspam_like(scale: f64, seed: u64) -> crate::data::dataset::Splits {
        let n = (8000.0 * scale) as usize;
        let cfg = SynthConfig {
            n,
            p: (20_000.0 * scale) as usize,
            seed,
        };
        let ds = webspam_like(&cfg, 60);
        let tenth = n / 10;
        ds.split(tenth, tenth)
    }

    pub fn clickstream(scale: f64, seed: u64) -> crate::data::dataset::Splits {
        let n = (20_000.0 * scale) as usize;
        let cfg = SynthConfig {
            n,
            p: (30_000.0 * scale) as usize,
            seed,
        };
        let ds = clickstream(&cfg, 12, 0.05);
        let tenth = n / 10;
        ds.split(tenth, tenth)
    }

    /// The partition-quality corpus: 8 planted feature groups at ρ = 0.85
    /// (see [`block_correlated`]). Not part of the paper's Table 1 trio —
    /// it exists so `--dataset block_correlated` exercises the clustered
    /// partition on data where the layout actually matters.
    pub fn block_correlated(scale: f64, seed: u64) -> crate::data::dataset::Splits {
        let groups = 8;
        let n = (4000.0 * scale) as usize;
        let cfg = SynthConfig {
            n,
            p: ((256.0 * scale.sqrt()) as usize).max(groups),
            seed,
        };
        let ds = block_correlated(&cfg, groups, 0.85);
        let tenth = n / 10;
        ds.split(tenth, tenth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_like_is_dense() {
        let ds = epsilon_like(&SynthConfig {
            n: 100,
            p: 20,
            seed: 1,
        });
        assert_eq!(ds.n(), 100);
        assert_eq!(ds.nnz(), 100 * 20); // fully dense
        let rate = ds.positive_rate();
        assert!(rate > 0.2 && rate < 0.8, "degenerate labels: {rate}");
    }

    #[test]
    fn webspam_like_sparsity_and_popularity() {
        let ds = webspam_like(
            &SynthConfig {
                n: 2000,
                p: 5000,
                seed: 2,
            },
            40,
        );
        let avg = ds.nnz() as f64 / ds.n() as f64;
        assert!(avg > 15.0 && avg < 90.0, "avg nnz {avg}");
        // Power law: most popular feature should appear in >2% of rows while
        // the median feature is rare.
        let csc = ds.to_csc();
        let max_col = (0..csc.ncols).map(|j| csc.col_nnz(j)).max().unwrap();
        assert!(max_col as f64 > 0.02 * ds.n() as f64, "max col {max_col}");
    }

    #[test]
    fn clickstream_imbalanced() {
        let ds = clickstream(
            &SynthConfig {
                n: 5000,
                p: 2400,
                seed: 3,
            },
            8,
            0.05,
        );
        let rate = ds.positive_rate();
        assert!(rate > 0.01 && rate < 0.25, "positive rate {rate}");
        // one feature per field
        let avg = ds.nnz() as f64 / ds.n() as f64;
        assert!((avg - 8.0).abs() < 0.5, "avg nnz {avg}");
    }

    #[test]
    fn generators_deterministic() {
        let cfg = SynthConfig {
            n: 50,
            p: 30,
            seed: 9,
        };
        let a = webspam_like(&cfg, 10);
        let b = webspam_like(&cfg, 10);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn labels_correlate_with_teacher_signal() {
        // A trained-on-truth sanity: dataset must carry learnable signal —
        // check the margin/label agreement of the generating teacher by
        // regenerating and verifying the positive rate responds to margin.
        let ds = epsilon_like(&SynthConfig {
            n: 4000,
            p: 30,
            seed: 4,
        });
        // With a teacher present, labels should NOT be independent of x:
        // compare positive rate among high-|x_0| rows vs global (weak test
        // that there is structure; exact effect depends on teacher[0]).
        let rate = ds.positive_rate();
        assert!(rate > 0.3 && rate < 0.7);
    }

    #[test]
    fn corpus_splits_shaped_like_table1() {
        let s = Corpus::clickstream(0.1, 1);
        assert_eq!(s.train.n() + s.test.n() + s.validation.n(), 2000);
        assert!(s.test.n() == s.validation.n());
        let sum = s.summary();
        assert!(sum.avg_nonzeros < 20.0);
    }

    #[test]
    fn block_correlated_rows_stay_inside_one_group() {
        let cfg = SynthConfig {
            n: 120,
            p: 40,
            seed: 6,
        };
        let ds = block_correlated(&cfg, 4, 0.8);
        assert_eq!(ds.n(), 120);
        // Every row's nonzeros live in exactly one 10-column group, so any
        // two columns co-occur iff they share a group.
        for i in 0..ds.n() {
            let (idx, _) = ds.x.row_raw(i);
            assert!(!idx.is_empty());
            let g = idx[0] as usize / 10;
            assert!(
                idx.iter().all(|&j| (j as usize) / 10 == g),
                "row {i} crosses groups: {idx:?}"
            );
        }
        // Balanced groups: each owns exactly n/groups rows' worth of nnz.
        let csc = ds.to_csc();
        for j in 0..csc.ncols {
            assert_eq!(csc.col_nnz(j), 30, "col {j}");
        }
        // Deterministic in the seed.
        let again = block_correlated(&cfg, 4, 0.8);
        assert_eq!(ds.x, again.x);
        assert_eq!(ds.y, again.y);
        // Labels keep learnable signal.
        let rate = ds.positive_rate();
        assert!(rate > 0.2 && rate < 0.8, "degenerate labels: {rate}");
    }

    #[test]
    fn regression_toy_has_noise() {
        let ds = regression_toy(100, 5, 0.1, 7);
        assert_eq!(ds.n(), 100);
        assert!(ds.y.iter().any(|&v| v != v.trunc()));
    }
}
