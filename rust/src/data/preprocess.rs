//! Feature preprocessing: standardization and intercept handling.
//!
//! GLMNET-family solvers conventionally standardize columns (unit variance)
//! so a single λ penalizes every feature comparably, and fit an unpenalized
//! intercept. The paper's datasets arrive pre-scaled (epsilon) or binary
//! (webspam/yandex one-hot), so its text does not dwell on this — but a
//! downstream user's CSV-shaped data needs it, and the λ-path module
//! (`solver::path`) assumes comparable column scales for `lambda_max` to be
//! meaningful.
//!
//! Standardization is performed sparsity-preserving: columns are only
//! *scaled* (no centering — centering would densify sparse data; this is
//! glmnet's `standardize` on sparse inputs). The intercept is appended as
//! an explicit all-ones column (see `with_intercept` and the NOTE below on
//! why it shares the penalty).

use crate::data::Dataset;
use crate::glm::regularizer::Penalty1D;
use crate::sparse::Csr;

/// Column scales learned from training data.
#[derive(Clone, Debug)]
pub struct Standardizer {
    /// Multiplicative scale per feature (1/std, with 1.0 for empty columns).
    pub scales: Vec<f64>,
}

impl Standardizer {
    /// Learn per-column scales 1/std (population std over *all* n rows,
    /// zeros included — the convention that keeps sparse data sparse).
    pub fn fit(ds: &Dataset) -> Standardizer {
        let n = ds.n().max(1) as f64;
        let p = ds.p();
        let mut sum = vec![0.0; p];
        let mut sumsq = vec![0.0; p];
        for i in 0..ds.x.nrows {
            for (j, v) in ds.x.row(i) {
                sum[j] += v;
                sumsq[j] += v * v;
            }
        }
        let scales = (0..p)
            .map(|j| {
                let mean = sum[j] / n;
                let var = (sumsq[j] / n - mean * mean).max(0.0);
                if var > 1e-24 {
                    1.0 / var.sqrt()
                } else {
                    1.0
                }
            })
            .collect();
        Standardizer { scales }
    }

    /// Apply to a dataset (returns a new dataset with scaled values).
    pub fn transform(&self, ds: &Dataset) -> Dataset {
        let rows: Vec<Vec<(usize, f64)>> = (0..ds.x.nrows)
            .map(|i| {
                ds.x.row(i)
                    .map(|(j, v)| (j, v * self.scales[j]))
                    .collect()
            })
            .collect();
        Dataset::new(
            format!("{}-std", ds.name),
            Csr::from_rows(ds.p(), &rows),
            ds.y.clone(),
        )
    }

    /// Map weights learned in scaled space back to the original space:
    /// β_orig[j] = β_scaled[j] · scale[j].
    pub fn unscale_weights(&self, beta_scaled: &[f64]) -> Vec<f64> {
        beta_scaled
            .iter()
            .zip(self.scales.iter())
            .map(|(b, s)| b * s)
            .collect()
    }
}

/// Append an all-ones intercept column; returns the new dataset and the
/// intercept's column index.
pub fn with_intercept(ds: &Dataset) -> (Dataset, usize) {
    let p = ds.p();
    let rows: Vec<Vec<(usize, f64)>> = (0..ds.x.nrows)
        .map(|i| {
            let mut row: Vec<(usize, f64)> = ds.x.row(i).collect();
            row.push((p, 1.0));
            row
        })
        .collect();
    (
        Dataset::new(
            format!("{}-b0", ds.name),
            Csr::from_rows(p + 1, &rows),
            ds.y.clone(),
        ),
        p,
    )
}

// NOTE: a positional intercept exemption would need coordinate identity,
// which the 1-D Penalty1D interface deliberately omits (that is what keeps
// the CD update rule (11) uniform). The practical pattern — used by the
// tests below — is to accept the (tiny) bias from penalizing the intercept
// like any column, which the experiments show is negligible at these λ.

/// The zero penalty (unregularized fits / intercept-only blocks).
#[derive(Clone, Copy, Debug)]
pub struct NoPenalty;

impl Penalty1D for NoPenalty {
    fn value_1d(&self, _u: f64) -> f64 {
        0.0
    }
    fn solve_penalized_quad(&self, quad: f64, lin: f64) -> f64 {
        lin / quad
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{self, SynthConfig};
    use crate::glm::loss::LossKind;
    use crate::glm::regularizer::ElasticNet;
    use crate::solver::compute::NativeCompute;
    use crate::solver::dglmnet::{fit, DGlmnetConfig};

    #[test]
    fn standardizer_unit_variance() {
        let ds = synth::regression_toy(500, 6, 0.1, 1);
        let st = Standardizer::fit(&ds);
        let scaled = st.transform(&ds);
        // Column variance of the scaled data must be ~1.
        let st2 = Standardizer::fit(&scaled);
        for s in &st2.scales {
            assert!((s - 1.0).abs() < 0.02, "rescale factor {s} != 1");
        }
    }

    #[test]
    fn empty_column_scale_is_one() {
        let x = Csr::from_rows(3, &[vec![(0, 1.0)], vec![(0, 2.0)], vec![(0, 3.0)]]);
        let ds = Dataset::new("t", x, vec![1.0, -1.0, 1.0]);
        let st = Standardizer::fit(&ds);
        assert_eq!(st.scales[1], 1.0);
        assert_eq!(st.scales[2], 1.0);
    }

    #[test]
    fn unscale_recovers_original_space_predictions() {
        let ds = synth::regression_toy(200, 5, 0.05, 2);
        let st = Standardizer::fit(&ds);
        let scaled = st.transform(&ds);
        // Train on scaled data (ridge), map weights back, and check the
        // predictions in original space match the scaled-space predictions.
        let compute = NativeCompute::new(LossKind::Squared);
        let fitres = fit(
            &scaled,
            &compute,
            &ElasticNet::l2_only(0.1),
            &DGlmnetConfig {
                nodes: 2,
                max_iters: 100,
                eval_every: 0,
                ..Default::default()
            },
            None,
        );
        let pred_scaled = scaled.x.mul_vec(&fitres.beta);
        let beta_orig = st.unscale_weights(&fitres.beta);
        let pred_orig = ds.x.mul_vec(&beta_orig);
        crate::util::prop::all_close(&pred_scaled, &pred_orig, 1e-10).unwrap();
    }

    #[test]
    fn intercept_column_appended() {
        let ds = synth::epsilon_like(&SynthConfig {
            n: 50,
            p: 4,
            seed: 3,
        });
        let (with_b0, b0_col) = with_intercept(&ds);
        assert_eq!(b0_col, 4);
        assert_eq!(with_b0.p(), 5);
        for i in 0..with_b0.x.nrows {
            let last = with_b0.x.row(i).last().unwrap();
            assert_eq!(last, (4, 1.0));
        }
    }

    #[test]
    fn intercept_improves_imbalanced_fit() {
        // Imbalanced labels: an unpenalized-ish intercept captures the base
        // rate that pure features cannot (clickstream has one).
        let ds = synth::clickstream(
            &SynthConfig {
                n: 2000,
                p: 500,
                seed: 4,
            },
            5,
            0.08,
        );
        let compute = NativeCompute::new(LossKind::Logistic);
        let cfg = DGlmnetConfig {
            nodes: 2,
            max_iters: 40,
            eval_every: 0,
            ..Default::default()
        };
        let plain = fit(&ds, &compute, &ElasticNet::l1_only(0.5), &cfg, None);
        let (ds_b0, _) = with_intercept(&ds);
        let with_b0 = fit(&ds_b0, &compute, &ElasticNet::l1_only(0.5), &cfg, None);
        assert!(
            with_b0.objective < plain.objective,
            "intercept did not help: {} vs {}",
            with_b0.objective,
            plain.objective
        );
    }

    #[test]
    fn no_penalty_solves_unregularized_quadratic() {
        let p = NoPenalty;
        assert_eq!(p.solve_penalized_quad(2.0, 3.0), 1.5);
        assert_eq!(p.value_1d(7.0), 0.0);
    }
}
